"""Launchers: production mesh builders, the multi-pod dry-run, train/serve drivers."""
