"""Training driver: real steps on whatever devices exist (CPU dev box → TPU pod).

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m --steps 50 \
        --global-batch 8 --seq 256 --ckpt-dir /tmp/run1 [--resume] [--reduced]

Features exercised here (the 1000-node story in miniature):
  auto-resume from the latest complete checkpoint; async checkpointing every
  --ckpt-every steps; straggler monitor + heartbeat file; deterministic stateless
  data (restart-safe); optional int8 gradient compression; mesh-aware sharding when
  more than one device is visible."""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, reduced_for_smoke
from ..distributed.ctx import MeshAxes, axes_context
from ..distributed.specs import batch_pspecs, opt_state_pspecs, param_pspecs, to_shardings
from ..models.model import init_params
from ..train.checkpoint import CheckpointManager
from ..train.data import synth_batch
from ..train.fault import Heartbeat, StragglerMonitor
from ..train.optimizer import AdamWConfig
from ..train.step import TrainConfig, init_train_state, make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--reduced", action="store_true", help="tiny same-family config")
    ap.add_argument("--width", type=int, default=0, help="override d_model (with --reduced)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced_for_smoke(cfg)
    if args.width:
        cfg = replace(cfg, d_model=args.width, head_dim=max(16, args.width // max(1, cfg.n_heads)))
    if args.layers:
        pat = len(cfg.pattern)
        n = max(pat, (args.layers // pat) * pat) + len(cfg.prefix)
        cfg = replace(cfg, n_layers=n)

    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                          total_steps=args.steps),
        microbatches=args.microbatches,
        compress_grads=args.compress_grads,
    )

    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={jax.device_count()} batch={args.global_batch} seq={args.seq}")

    state = init_train_state(cfg, tcfg, params)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        if args.resume:
            latest = mgr.latest_step()
            if latest is not None:
                restored, meta = mgr.restore(latest, {"params": params, "opt": state})
                params, state = restored["params"], restored["opt"]
                start = latest + 1
                print(f"[train] resumed from step {latest}")

    mon = StragglerMonitor(on_straggler=lambda s, d, e: print(
        f"[straggler] step {s}: {d:.3f}s vs ema {e:.3f}s", flush=True))
    hb = Heartbeat(Path(args.ckpt_dir) / "heartbeat" if args.ckpt_dir else "/tmp/repro_hb")

    history = []
    for step in range(start, args.steps):
        t0 = time.time()
        batch = {
            k: jnp.asarray(v)
            for k, v in synth_batch(cfg, step=step, global_batch=args.global_batch,
                                    seq=args.seq).items()
        }
        params, state, metrics = step_fn(params, state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        mon.record(step, dt)
        hb.beat(step)
        history.append(loss)
        if step % args.log_every == 0:
            tok_s = args.global_batch * args.seq / dt
            print(f"[step {step:5d}] loss={loss:.4f} lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt:.2f}s {tok_s:,.0f} tok/s",
                  flush=True)
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step, {"params": params, "opt": state},
                           {"arch": cfg.name, "loss": loss})
    if mgr and history:
        mgr.wait()
        mgr.save(args.steps - 1, {"params": params, "opt": state}, {"arch": cfg.name})
    if history:
        print(f"[train] done: loss {history[0]:.4f} → {history[-1]:.4f}")
    else:
        print(f"[train] nothing to do (resumed at step {start} ≥ {args.steps})")
    return {"history": history, "n_params": n_params}


if __name__ == "__main__":
    main()
