import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (architecture × input shape) on the
single-pod (16, 16) mesh and the 2-pod (2, 16, 16) mesh, print memory/cost analysis,
and write per-cell JSON artifacts for the roofline table.

MUST be the process entry point (the XLA flag above is read at first jax init):

    PYTHONPATH=src python -m repro.launch.dryrun                      # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --arch mamba2-780m ...

Idempotent/fault-tolerant: each cell's artifact is written atomically to
artifacts/dryrun/; existing artifacts are skipped unless --force. A crashed run (OOM,
timeout) resumes where it left off — the same discipline a 1000-node launcher needs.
"""

import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax

from ..analysis.roofline import collective_bytes, model_flops, roofline_terms
from ..configs import ARCHS, SHAPES, shape_applicable
from ..distributed.ctx import axes_context
from ..distributed.specs import (
    batch_pspecs,
    cache_pspecs,
    opt_state_pspecs,
    param_pspecs,
    to_shardings,
)
from ..train.step import TrainConfig, make_prefill_step, make_serve_step, make_train_step
from .inputs import input_specs
from .mesh import axes_for, make_production_mesh

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# §Perf hillclimb variants: comma-separated config transforms, e.g.
#   --variant ssd64,spon   → artifacts tagged "ssd64,spon"
from dataclasses import replace as _replace

VARIANTS = {
    "ssd64": lambda c: _replace(c, ssd_chunk=64),
    "ssd128": lambda c: _replace(c, ssd_chunk=128),
    "spon": lambda c: _replace(c, sequence_parallel=True),
    "spoff": lambda c: _replace(c, sequence_parallel=False),
    "cap100": lambda c: _replace(c, capacity_factor=1.0),
    "densemoe": lambda c: _replace(c, moe_dispatch="dense"),
    "rematdots": lambda c: _replace(c, remat="dots"),
    "rematnone": lambda c: _replace(c, remat="none"),
    "splayer": lambda c: _replace(c, sp_boundary="layer"),
    # pure-code variants (the transform is the current source tree): identity
    "code": lambda c: c,
    # per-arch best (§Perf): layer-boundary SP resharding where SP is on (hurts
    # non-SP archs by removing anchor constraints), capacity 1.0 for MoE dispatch.
    "opt": lambda c: _replace(
        c,
        sp_boundary="layer" if c.sequence_parallel else c.sp_boundary,
        capacity_factor=1.0 if c.n_experts else c.capacity_factor,
    ),
}


def apply_variant(cfg, variant: str):
    if variant == "baseline":
        return cfg
    for name in variant.split(","):
        cfg = VARIANTS[name](cfg)
    return cfg


def _cost_get(cost, key, default=0.0):
    try:
        v = cost.get(key, default) if hasattr(cost, "get") else default
        return float(v)
    except Exception:
        return default


def run_cell(arch: str, shape_name: str, multi_pod: bool, tcfg: TrainConfig | None = None,
             variant: str = "baseline", cfg_override=None) -> dict:
    cfg = cfg_override if cfg_override is not None else apply_variant(ARCHS[arch], variant)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "variant": variant, "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = axes_for(mesh, sequence_parallel=cfg.sequence_parallel)
    tcfg = tcfg or TrainConfig()
    specs = input_specs(cfg, shape, tcfg)

    t0 = time.time()
    with jax.sharding.set_mesh(mesh), axes_context(axes):
        p_specs = param_pspecs(specs["params"], mesh, axes)
        p_sh = to_shardings(p_specs, mesh)

        if shape.kind == "train":
            o_specs = opt_state_pspecs(p_specs, specs["opt_state"], mesh, axes)
            o_sh = to_shardings(o_specs, mesh)
            b_sh = to_shardings(batch_pspecs(specs["batch"], mesh, axes), mesh)
            step = make_train_step(cfg, tcfg)
            jitted = jax.jit(
                step, in_shardings=(p_sh, o_sh, b_sh), donate_argnums=(0, 1)
            )
            lowered = jitted.lower(specs["params"], specs["opt_state"], specs["batch"])
        elif shape.kind == "prefill":
            b_sh = to_shardings(batch_pspecs(specs["batch"], mesh, axes), mesh)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(specs["params"], specs["batch"])
        else:
            c_sh = to_shardings(cache_pspecs(specs["cache"], mesh, axes, cfg), mesh)
            t_sh = to_shardings(batch_pspecs(specs["tokens"], mesh, axes), mesh)
            step = make_serve_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh), donate_argnums=(1,))
            lowered = jitted.lower(specs["params"], specs["cache"], specs["tokens"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)

        # scan-body trip-count correction (see analysis/probes.py):
        # XLA cost analysis counts while bodies once; add (R-1)×body per probe.
        from ..analysis.probes import probe_costs

        probes = probe_costs(
            cfg, shape, shape.kind, mesh, axes,
            specs["params"], p_specs,
            cache_sds=specs.get("cache"),
            cache_specs=(
                cache_pspecs(specs["cache"], mesh, axes, cfg)
                if shape.kind == "decode" else None
            ),
        )

    n_chips = mesh.devices.size
    flops_raw = _cost_get(cost, "flops")
    bytes_raw = _cost_get(cost, "bytes accessed")
    coll_raw = float(coll["total_bytes"])
    flops_dev, bytes_dev, coll_dev = flops_raw, bytes_raw, coll_raw
    probe_list = []
    for extra, c in probes:
        flops_dev += extra * c["flops"]
        bytes_dev += extra * c["bytes"]
        coll_dev += extra * c["coll_bytes"]
        probe_list.append({"extra_repeats": extra, **c})

    terms = roofline_terms(flops_dev, bytes_dev, coll_dev)
    mflops = model_flops(cfg, shape, shape.kind)
    useful = mflops / max(1.0, flops_dev * n_chips)

    result = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "multi_pod": multi_pod,
        "variant": variant,
        "status": "ok",
        "n_chips": int(n_chips),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "coll_bytes_per_device": coll_dev,
        "raw_module": {"flops": flops_raw, "bytes": bytes_raw, "coll_bytes": coll_raw},
        "probes": probe_list,
        "collectives": coll,
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline": terms,
        "model_flops_global": mflops,
        "useful_flops_fraction": useful,
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape cell (default: all)")
    ap.add_argument("--multi-pod", action="store_true", help="2-pod 512-chip mesh")
    ap.add_argument("--both-meshes", action="store_true", help="run single- AND multi-pod")
    ap.add_argument("--force", action="store_true", help="recompute existing artifacts")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    ART_DIR.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}__{args.variant}"
                path = ART_DIR / f"{tag}.json"
                if path.exists() and not args.force:
                    prev = json.loads(path.read_text())
                    if prev.get("status") != "error":  # errors always retried
                        print(f"[skip-cached] {tag}")
                        continue
                print(f"[cell] {tag} ...", flush=True)
                try:
                    res = run_cell(arch, shape, mp, variant=args.variant)
                except Exception as e:  # record the failure; keep going
                    res = {
                        "arch": arch, "shape": shape, "multi_pod": mp,
                        "variant": args.variant, "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    failures.append(tag)
                tmp = path.with_suffix(".tmp")
                tmp.write_text(json.dumps(res, indent=2, default=str))
                tmp.rename(path)
                status = res["status"]
                extra = ""
                if status == "ok":
                    r = res["roofline"]
                    extra = (
                        f" bottleneck={r['bottleneck']}"
                        f" t_c={r['t_compute_s']:.4f}s t_m={r['t_memory_s']:.4f}s"
                        f" t_x={r['t_collective_s']:.4f}s compile={res['compile_s']:.0f}s"
                    )
                elif status == "skipped":
                    extra = f" ({res['reason']})"
                else:
                    extra = f" ({res['error'][:120]})"
                print(f"[{status}] {tag}{extra}", flush=True)

    if failures:
        print(f"FAILED cells: {failures}")
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
