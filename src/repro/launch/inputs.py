"""input_specs(): ShapeDtypeStruct stand-ins for every model input of a cell —
weak-type-correct, shardable, zero allocation. The dry-run lowers against these."""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from ..models.model import init_cache, init_params
from ..train.step import TrainConfig, init_train_state


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Training/prefill batch stand-ins. For [vlm] the 256-patch stub is part of the
    sequence budget (text tokens = seq - n_frontend); for [audio] the frames feed the
    encoder and the decoder consumes the full seq."""
    b, s = shape.batch, shape.seq
    out: Dict[str, Any] = {}
    if cfg.frontend == "prefix_embeds":
        s_text = s - cfg.n_frontend
        out["tokens"] = _sds((b, s_text), jnp.int32)
        out["labels"] = _sds((b, s_text), jnp.int32)
        out["vision_embeds"] = _sds((b, cfg.n_frontend, cfg.d_model), jnp.float32)
    elif cfg.frontend == "encoder_frames":
        out["tokens"] = _sds((b, s), jnp.int32)
        out["labels"] = _sds((b, s), jnp.int32)
        out["frames"] = _sds((b, cfg.n_frontend, cfg.d_model), jnp.float32)
    else:
        out["tokens"] = _sds((b, s), jnp.int32)
        out["labels"] = _sds((b, s), jnp.int32)
    if shape.kind == "prefill":
        out.pop("labels")
    return out


def params_specs(cfg: ArchConfig):
    return jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))


def opt_specs(cfg: ArchConfig, tcfg: TrainConfig, params_sds):
    return jax.eval_shape(partial(init_train_state, cfg, tcfg), params_sds)


def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
    """Decode-cell cache stand-ins: a full context of shape.seq tokens."""
    return jax.eval_shape(lambda: init_cache(cfg, shape.batch, shape.seq))


def decode_token_specs(shape: ShapeSpec):
    return _sds((shape.batch,), jnp.int32)


def input_specs(cfg: ArchConfig, shape: ShapeSpec, tcfg: TrainConfig | None = None):
    """Everything the jitted step needs, as ShapeDtypeStructs, keyed by step kind."""
    tcfg = tcfg or TrainConfig()
    if shape.kind == "train":
        p = params_specs(cfg)
        return {
            "params": p,
            "opt_state": opt_specs(cfg, tcfg, p),
            "batch": batch_specs(cfg, shape),
        }
    if shape.kind == "prefill":
        return {"params": params_specs(cfg), "batch": batch_specs(cfg, shape)}
    return {
        "params": params_specs(cfg),
        "cache": cache_specs(cfg, shape),
        "tokens": decode_token_specs(shape),
    }
