"""Serving driver: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --reduced \
        --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, reduced_for_smoke
from ..models.model import init_params, prefill
from ..train.data import synth_batch
from ..train.step import make_serve_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced_for_smoke(cfg)

    params = init_params(cfg, jax.random.PRNGKey(0))
    raw = synth_batch(cfg, step=0, global_batch=args.batch, seq=args.prompt_len)
    batch = {k: jnp.asarray(v) for k, v in raw.items() if k != "labels"}

    cache_len = args.prompt_len + args.gen
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, b: prefill(cfg, p, b, cache_len=cache_len)
    )(params, batch)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0
    print(f"[serve] prefill {args.batch}×{args.prompt_len} in {t_prefill:.2f}s")

    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    outputs = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok, logits, cache = serve_step(params, cache, tok)
        outputs.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    toks = args.batch * (args.gen - 1)
    print(f"[serve] decoded {toks} tokens in {t_dec:.2f}s → {toks / max(t_dec,1e-9):,.0f} tok/s")
    gen = np.stack(outputs, axis=1)
    print(f"[serve] sample generation (first row): {gen[0][:16].tolist()}")
    return {"gen": gen, "t_prefill": t_prefill, "t_decode": t_dec}


if __name__ == "__main__":
    main()
