"""Production mesh builders (functions, not module constants — importing this module
never touches jax device state)."""

from __future__ import annotations

import jax

from ..distributed.ctx import MeshAxes


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model). Multi-pod: 2×16×16 = 512 chips
    (pod, data, model). The dry-run launcher sets XLA_FLAGS to fake 512 host devices
    before any jax import; real deployments get the same mesh from the TPU topology."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    kinds = (jax.sharding.AxisType.Auto,) * len(axes)  # GSPMD propagation
    return jax.make_mesh(shape, axes, axis_types=kinds)


def axes_for(mesh, sequence_parallel: bool = False) -> MeshAxes:
    names = mesh.axis_names
    data = tuple(n for n in names if n != "model")
    return MeshAxes(data=data, model="model", sequence_parallel=sequence_parallel)


def make_mesh(shape, axis_names):
    """Elastic-scaling entry: build a mesh of any geometry (restore reshards to it)."""
    kinds = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(shape), tuple(axis_names), axis_types=kinds)
