"""Sharding context: model code annotates activations with logical axes ("dp", "tp",
"sp", None); the context resolves them to mesh axis names — or no-ops when no mesh is
active (single-device smoke tests).

Logical axes:
  dp — data-parallel: ("pod", "data") on the multi-pod mesh, ("data",) on one pod
  tp — tensor-parallel: "model"
  sp — sequence-parallel: "model" when cfg.sequence_parallel else None
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class MeshAxes:
    data: Tuple[str, ...] = ("data",)     # dp axes (includes "pod" when multi-pod)
    model: str = "model"
    sequence_parallel: bool = False

    def resolve(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == "dp":
            return self.data if len(self.data) > 1 else self.data[0]
        if logical == "tp":
            return self.model
        if logical == "sp":
            return self.model if self.sequence_parallel else None
        raise ValueError(f"unknown logical axis {logical!r}")


_AXES: Optional[MeshAxes] = None


def set_axes(axes: Optional[MeshAxes]) -> None:
    global _AXES
    _AXES = axes


def current_axes() -> Optional[MeshAxes]:
    return _AXES


@contextlib.contextmanager
def axes_context(axes: Optional[MeshAxes]):
    global _AXES
    prev = _AXES
    _AXES = axes
    try:
        yield
    finally:
        _AXES = prev


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the active MeshAxes; identity when none."""
    axes = _AXES
    if axes is None:
        return x
    spec = P(*(axes.resolve(a) for a in logical))
    return jax.lax.with_sharding_constraint(x, spec)
