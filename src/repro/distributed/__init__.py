"""Distribution substrate: sharding context, partition-spec rules, collectives."""

from .ctx import MeshAxes, set_axes, shard, current_axes, axes_context
