"""PartitionSpec rules for parameters, optimizer state, batches, and caches.

Rules are keyed on the leaf name (the last path segment), applied to the *trailing*
dims — scanned stacks have a leading repeats dim that is never sharded.

  "tp"   → the model axis        (Megatron column/row sharding, EP on expert dim)
  "fsdp" → the DP axes           (parameter + optimizer-state sharding; ZeRO)
  None   → replicated

FSDP notes: big archs cannot hold bf16 params replicated over DP (mistral-large:
123B × 2B / 16 TP-shards ≈ 15.4 GB/device), so weight matrices are 2-D sharded
(fsdp × tp). The fp32 master/m/v in the optimizer state inherit the same specs,
giving ZeRO semantics for free. Divisibility is checked per-leaf: a rule falls back
to None on any non-divisible dim (e.g. whisper's 12 heads vs 16-way model axis —
its attention weights stay tp-shardable on flat dims, activations replicate)."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .ctx import MeshAxes

# leaf name → logical spec for the trailing dims
_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embedding: vocab over tp (vocab-parallel logits/CE)
    "embedding": ("tp", None),
    # attention
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    # MLA
    "w_dkv": ("fsdp", None),
    "w_uk": (None, "tp"),
    "w_uv": (None, "tp"),
    # dense MLP
    "w_gate": ("fsdp", "tp"),
    "w_up": ("fsdp", "tp"),
    "w_out": ("tp", "fsdp"),
    # MoE (3-D expert stacks: E over tp = expert parallelism)
    "router": (None, None),
    # mamba
    "w_z": ("fsdp", "tp"),
    "w_x": ("fsdp", "tp"),
    "w_B": ("fsdp", None),
    "w_C": ("fsdp", None),
    "w_dt": ("fsdp", None),
    "conv_x": (None, "tp"),
    "conv_B": (None, None),
    "conv_C": (None, None),
    "norm_scale": (None,),
    "A_log": (None,),
    "D": (None,),
    "dt_bias": (None,),
    "scale": (None,),
    "bias": (None,),
}

# MoE expert stacks are 3-D; keyed by (name, ndim-without-stack)
_MOE_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "w_gate": ("tp", "fsdp", None),
    "w_up": ("tp", "fsdp", None),
    "w_out": ("tp", None, "fsdp"),
}


def _resolve(axes: MeshAxes, logical: Optional[str], fsdp: bool):
    if logical == "tp":
        return axes.model
    if logical == "fsdp":
        if not fsdp:
            return None
        return axes.data if len(axes.data) > 1 else axes.data[0]
    return None


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        return int(np.prod([mesh.shape[a] for a in entry]))
    return int(mesh.shape[entry])


def _fit(mesh, shape: Tuple[int, ...], spec: Tuple, stack_dims: int) -> P:
    """Prefix Nones for stacked dims; drop any axis that doesn't divide."""
    full = (None,) * stack_dims + tuple(spec)
    out = []
    for dim, entry in zip(shape, full):
        if entry is not None and dim % _axis_size(mesh, entry) == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def param_pspecs(params, mesh, axes: MeshAxes, fsdp: bool = True):
    """Tree of PartitionSpec matching `params` (which may hold arrays or
    ShapeDtypeStructs)."""

    def one(path, leaf):
        name = None
        in_moe = False
        for seg in path:
            key = getattr(seg, "key", getattr(seg, "name", None))
            if key == "moe":
                in_moe = True
            if key is not None:
                name = key
        shape = leaf.shape
        rules = None
        if in_moe and name in _MOE_RULES and len(shape) >= 3:
            rules = _MOE_RULES[name]
        elif name in _PARAM_RULES:
            rules = _PARAM_RULES[name]
        if rules is None:
            return P(*([None] * len(shape)))
        stack = len(shape) - len(rules)
        assert stack >= 0, (path, shape, rules)
        resolved = tuple(_resolve(axes, r, fsdp) for r in rules)
        return _fit(mesh, shape, resolved, stack)

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_pspecs(param_specs, opt_state, mesh, axes: MeshAxes):
    """master/m/v inherit param specs (ZeRO via fsdp); step is replicated; the error-
    feedback buffer (if present) also inherits."""
    out: Dict[str, Any] = {}
    if "adamw" in opt_state:
        inner = {
            "master": param_specs,
            "m": param_specs,
            "v": param_specs,
            "step": P(),
        }
        out["adamw"] = inner
        if "ef" in opt_state:
            out["ef"] = param_specs
        return out
    raise ValueError("unexpected opt state layout")


def batch_pspecs(batch, mesh, axes: MeshAxes):
    """Shard the batch dim over DP when divisible (long_500k batch=1 stays
    replicated — the DP axes idle, inherent to the shape)."""
    dp = axes.data if len(axes.data) > 1 else axes.data[0]
    dp_size = _axis_size(mesh, dp)

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] % dp_size == 0:
            return P(dp, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(one, batch)


def cache_pspecs(cache, mesh, axes: MeshAxes, cfg):
    """Decode caches: batch over DP (when divisible), long sequence dims over the
    model axis (split-KV flash decoding), SSM heads over the model axis.

    Layout conventions (see models/model.py):
      attn  k/v       (R?, B, S, KV, hd)   → S over tp
      mla   c/kr      (R?, B, S, r)        → S over tp
      mamba state     (R?, B, H, P, N)     → H over tp
      mamba conv_*    (R?, B, k-1, CH)     → CH over tp (x stream only, via fit)
      enc_out         (B, F, d)            → batch over dp
    """
    dp = axes.data if len(axes.data) > 1 else axes.data[0]
    dp_size = _axis_size(mesh, dp)
    tp = axes.model
    tp_size = _axis_size(mesh, tp)

    def one(path, leaf):
        name = None
        for seg in path:
            key = getattr(seg, "key", getattr(seg, "name", None))
            if key is not None and not str(key).isdigit():
                name = key
        shape = leaf.shape
        if leaf.ndim == 0:
            return P()
        # identify stack prefix: blocks caches have leading R
        stacked = any(getattr(s, "key", None) == "blocks" for s in path)
        b_dim = 1 if stacked else 0
        spec = [None] * leaf.ndim
        if shape[b_dim] % dp_size == 0:
            spec[b_dim] = dp
        if name in ("k", "v", "c", "kr"):
            s_dim = b_dim + 1
            if shape[s_dim] % tp_size == 0 and shape[s_dim] >= tp_size * 128:
                spec[s_dim] = tp
        elif name == "state":
            h_dim = b_dim + 1
            if shape[h_dim] % tp_size == 0:
                spec[h_dim] = tp
        elif name in ("conv_x",):
            if shape[-1] % tp_size == 0:
                spec[-1] = tp
        elif name in ("cross_k", "cross_v", "enc_out"):
            pass
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache)


def to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
