"""Mamba-2 / SSD intra-chunk kernel (pl.pallas_call + BlockSpec VMEM tiling).

One grid step processes one (batch·head, chunk) cell entirely in VMEM:
  x (Q,P), dt (Q,), B̃/C (Q,N), prev_state (P,N)  — Q=chunk, P=headdim, N=d_state
  y    = ((C B̃ᵀ) ⊙ L ⊙ dtᵀ) x  +  exp(cum) C prev_stateᵀ       (two MXU matmuls)
  newS = exp(seg) prev_state + xᵀ (B̃ ⊙ (exp(seg-cum)·dt))       (one MXU matmul)

This is the paper-published SSD chunk decomposition with the CUDA selective-scan
replaced by MXU-shaped matmuls (DESIGN.md §2.4). The inter-chunk recurrence stays in
XLA (associative_scan over ~16 chunk states — negligible). Chunk states are carried
*sequentially inside the kernel grid*: the chunk axis is the minor grid dimension and
the state block is revisited, so prev_state for chunk c is the block left by c-1 —
the classic Pallas accumulator pattern.

VMEM budget per cell at (Q,P,N)=(256,64,128): QN+QP+QQ+PN ≈ 0.6 MB fp32 — fits easily.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...][0]          # (Q,P)
    dt = dt_ref[...][0]        # (Q,)
    a = a_ref[...][0]          # scalar (per head)
    b = b_ref[...][0]          # (Q,N)
    c = c_ref[...][0]          # (Q,N)
    prev = state_ref[...][0]   # (P,N)

    q = x.shape[0]
    da = dt * a
    cum = jnp.cumsum(da)
    li = cum[:, None] - cum[None, :]
    iot_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    iot_j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.exp(jnp.where(iot_i >= iot_j, li, -jnp.inf))  # mask pre-exp (no inf)

    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)       # (Q,Q) MXU
    w = cb * decay * dt[None, :]
    y = jnp.dot(w, x, preferred_element_type=jnp.float32)          # (Q,P) MXU
    y += jnp.dot(
        jnp.exp(cum)[:, None] * c, prev.T, preferred_element_type=jnp.float32
    )                                                              # (Q,P) MXU

    decay_tail = jnp.exp(cum[-1] - cum)
    s_new = jnp.dot(
        x.T, b * (decay_tail * dt)[:, None], preferred_element_type=jnp.float32
    )                                                              # (P,N) MXU
    y_ref[...] = y[None]
    state_ref[...] = (jnp.exp(cum[-1]) * prev + s_new)[None]


def ssd_chunk_pallas(
    x: jax.Array,      # (BH, S, P) fp32
    dt: jax.Array,     # (BH, S)
    a: jax.Array,      # (BH,)
    b_ssm: jax.Array,  # (BH, S, N)
    c_ssm: jax.Array,  # (BH, S, N)
    chunk: int,
    interpret: bool = True,
):
    """→ (y (BH,S,P), final_state (BH,P,N)). Grid (BH, S/chunk); the state output
    block is revisited across the chunk axis (sequential recurrence in-kernel)."""
    bh, s, p = x.shape
    n = b_ssm.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    grid = (bh, nc)
    y, state = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, p, n), lambda i, j: (i, 0, 0)),   # revisited: carries state
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a, b_ssm, c_ssm)
    return y, state
