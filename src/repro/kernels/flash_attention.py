"""Flash attention (online-softmax) as a Pallas TPU kernel.

The §Roofline tables show attention-score materialization is the dominant memory term
for every dense train/prefill cell — the jnp path writes (B,H,Cq,Sk) fp32 scores to
HBM several times per softmax. This kernel keeps the (BQ, BK) score tile in VMEM and
carries running (m, l, acc) statistics across the KV sweep, so HBM sees only Q/K/V/O.

Grid (BH, Sq/BQ, Sk/BK) with the KV axis minor: the output block and the (m, l)
statistic blocks are *revisited* across the KV sweep (the Pallas accumulator pattern,
same as kernels/ssd.py). The final KV step normalizes acc by l.

Causal masking is by absolute position (program ids × block shapes); fully-masked
blocks are computed-and-masked (a production TPU kernel would use a triangular grid —
noted as future work; interpret-mode correctness is what this container can validate).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, scale: float, causal: bool,
            bq: int, bk: int, n_k: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...][0]            # (BQ, D)
    k = k_ref[...][0]            # (BK, D)
    v = v_ref[...][0]            # (BK, D)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (BQ, BK)
    if causal:
        i = pl.program_id(1)
        q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...][0]       # (BQ,)
    l_prev = l_ref[...][0]
    acc = o_ref[...][0].astype(jnp.float32)

    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc = acc * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )

    m_ref[...] = m_new[None]
    l_ref[...] = l_new[None]

    @pl.when(j == n_k - 1)
    def _final():
        o_ref[...] = (acc / jnp.maximum(l_new, 1e-30)[:, None])[None].astype(o_ref.dtype)

    @pl.when(j < n_k - 1)
    def _carry():
        o_ref[...] = acc[None].astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,      # (BH, Sq, D)
    k: jax.Array,      # (BH, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
):
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    n_q, n_k = sq // bq, sk // bk
    scale = d ** -0.5
    kern = lambda *refs: _kernel(
        *refs, scale=scale, causal=causal, bq=bq, bk=bk, n_k=n_k
    )
    out, m, l = pl.pallas_call(
        kern,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),   # revisited over j
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out.astype(q.dtype)
