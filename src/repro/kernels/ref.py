"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

MIX_A = 2654435761  # Knuth multiplicative constant (plain int: kernels re-wrap it)
MIX_B = 0x9E3779B9


def merge_join_counts_ref(a_keys: jax.Array, b_keys: jax.Array):
    """a_keys (N,), b_keys (M,) sorted ascending → (lower (N,), upper (N,)) int32:
    matches of a_keys[i] in b_keys live at [lower[i], upper[i])."""
    lower = jnp.searchsorted(b_keys, a_keys, side="left").astype(jnp.int32)
    upper = jnp.searchsorted(b_keys, a_keys, side="right").astype(jnp.int32)
    return lower, upper


def merge_join_pairs_ref(lower: jax.Array, starts: jax.Array, cap_out: int):
    """Expand match ranges into the flat pair list: starts (N,) is the exclusive
    prefix sum of per-key match counts (starts[0] == 0), lower (N,) the per-key
    lower bound in B. → (a_idx, b_idx) int32 (cap_out,); slots past the true
    total alias the last key (callers mask by the total)."""
    n = starts.shape[0]
    t = jnp.arange(cap_out, dtype=jnp.int32)
    a_idx = jnp.clip(
        jnp.searchsorted(starts, t, side="right").astype(jnp.int32) - 1, 0, n - 1
    )
    b_idx = lower[a_idx].astype(jnp.int32) + (t - starts[a_idx].astype(jnp.int32))
    return a_idx, b_idx


def hash_u32_ref(keys: jax.Array) -> jax.Array:
    """Multiplicative mix on uint32 lanes (int64 keys are pre-folded in ops.py)."""
    k = keys.astype(jnp.uint32)
    h = (k ^ (k >> 16)) * jnp.uint32(MIX_A)
    h = (h ^ (h >> 13)) * jnp.uint32(MIX_B)
    return h ^ (h >> 16)


def hash_partition_ref(keys: jax.Array, n_parts: int, tile: int):
    """→ (part (N,) int32, hist (n_tiles, n_parts) int32): partition id per key and
    the per-tile histogram (the exchange's send-count matrix)."""
    part = (hash_u32_ref(keys) % jnp.uint32(n_parts)).astype(jnp.int32)
    n = keys.shape[0]
    n_tiles = n // tile
    onehot = jax.nn.one_hot(part.reshape(n_tiles, tile), n_parts, dtype=jnp.int32)
    hist = onehot.sum(axis=1)
    return part, hist


def hash_partition_pack_ref(keys: jax.Array, count: jax.Array, n_parts: int, tile: int):
    """Fused send-side oracle: → (part (N,) with n_parts marking rows past `count`,
    slot (N,) stable in-partition rank, hist (n_tiles, n_parts))."""
    n = keys.shape[0]
    part = (hash_u32_ref(keys) % jnp.uint32(n_parts)).astype(jnp.int32)
    part = jnp.where(jnp.arange(n) < count, part, jnp.int32(n_parts))
    onehot = jax.nn.one_hot(part, n_parts + 1, dtype=jnp.int32)
    slot = (jnp.cumsum(onehot, axis=0) * onehot).sum(axis=1) - 1
    n_tiles = n // tile
    hist = onehot[:, :n_parts].reshape(n_tiles, tile, n_parts).sum(axis=1)
    return part, slot, hist


def flash_attention_ref(q, k, v, causal: bool = True):
    """Plain softmax attention oracle: q (BH,Sq,D), k/v (BH,Sk,D)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k, preferred_element_type=jnp.float32)
    s = s * (d ** -0.5)
    if causal:
        sq, sk = s.shape[1], s.shape[2]
        iq = jnp.arange(sq)[:, None]
        ik = jnp.arange(sk)[None, :]
        s = jnp.where(ik <= iq, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w.astype(v.dtype), v)


def ssd_chunk_ref(x, dt, a, b_ssm, c_ssm, prev_state):
    """One SSD chunk for one (batch, head): x (Q,P), dt (Q,), a scalar, b/c (Q,N),
    prev_state (P,N) → (y (Q,P), new_state (P,N)). fp32 math."""
    q = x.shape[0]
    da = dt * a                                       # (Q,)
    cum = jnp.cumsum(da)
    li = cum[:, None] - cum[None, :]
    iot = jnp.arange(q)
    mask = iot[:, None] >= iot[None, :]
    decay = jnp.exp(jnp.where(mask, li, -jnp.inf))    # (Q,Q), mask pre-exp
    cb = c_ssm @ b_ssm.T                              # (Q,Q)
    w = cb * decay * dt[None, :]
    y_diag = w @ x                                    # (Q,P)
    y_off = (jnp.exp(cum)[:, None] * c_ssm) @ prev_state.T   # (Q,N)@(N,P)
    decay_tail = jnp.exp(cum[-1] - cum)               # (Q,)
    s_new = x.T @ (b_ssm * (decay_tail * dt)[:, None])       # (P,N)
    new_state = jnp.exp(cum[-1]) * prev_state + s_new
    return y_diag + y_off, new_state
