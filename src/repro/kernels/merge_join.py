"""Sorted-key join probe as a Pallas TPU kernel.

TPU adaptation of the per-machine hash-join probe (DESIGN.md §2.4): GPU hash probes
rely on shared-memory scatter; on TPU we sort both sides (XLA sort is an efficient
bitonic network on TPU) and compute, for every key of A, its match range [lower, upper)
in B with a **tiled compare-reduce**: an A-tile (BLOCK_A keys) sits in VMEM while the
kernel marches over B in BLOCK_B-sized VMEM blocks, accumulating
    lower[i] += Σ_j [b_j <  a_i]      upper[i] += Σ_j [b_j <= a_i]
— branch-free VPU work with perfectly sequential HBM reads (no data-dependent control
flow, which the TPU vector unit cannot do). The compare-reduce does O(N·M / BLOCK)
lane-ops but runs at full vector width; for the |B| ranges the engine feeds it
(capacity-bounded partitions), it beats a gather-based binary search on TPU.

Grid: (n_a_tiles, n_b_blocks); B blocks iterate in the minor grid dimension so the
accumulators live in the output block across the B sweep (revisited output block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_A = 256
BLOCK_B = 1024
BLOCK_T = 256


def _kernel(a_ref, b_ref, lower_ref, upper_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        lower_ref[...] = jnp.zeros_like(lower_ref)
        upper_ref[...] = jnp.zeros_like(upper_ref)

    a = a_ref[...]          # (BLOCK_A,)
    b = b_ref[...]          # (BLOCK_B,)
    lt = (b[None, :] < a[:, None]).astype(jnp.int32)
    le = (b[None, :] <= a[:, None]).astype(jnp.int32)
    lower_ref[...] += lt.sum(axis=1)
    upper_ref[...] += le.sum(axis=1)


def _pairs_kernel(starts_ref, dl_ref, ds_ref, a_ref, b_ref, st_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        a_ref[...] = jnp.zeros_like(a_ref)
        b_ref[...] = jnp.zeros_like(b_ref)
        st_ref[...] = jnp.zeros_like(st_ref)

    i = pl.program_id(0)
    t = i * BLOCK_T + jax.lax.broadcasted_iota(jnp.int32, (BLOCK_T, 1), 0)[:, 0]
    s = starts_ref[...]     # (BLOCK_A,) sorted ascending, sentinel-padded
    hit = s[None, :] <= t[:, None]
    # telescoping compare-reduce: with K(t) = max{j : starts[j] <= t},
    #   Σ_j hit          = K + 1          (starts is nondecreasing)
    #   Σ_j Δlower · hit = lower[K]       (Δ telescopes regardless of sign)
    #   Σ_j Δstarts· hit = starts[K]
    a_ref[...] += hit.astype(jnp.int32).sum(axis=1)
    b_ref[...] += jnp.where(hit, dl_ref[...][None, :], 0).sum(axis=1)
    st_ref[...] += jnp.where(hit, ds_ref[...][None, :], 0).sum(axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        a_ref[...] = a_ref[...] - 1                     # a_idx = K
        b_ref[...] = b_ref[...] + (t - st_ref[...])     # b_idx = lower[K] + (t - starts[K])


def merge_join_pairs_pallas(
    starts: jax.Array, dlower: jax.Array, dstarts: jax.Array,
    cap_out: int, interpret: bool = True,
):
    """Expand per-key match ranges into the flat (a_idx, b_idx) pair list.

    starts (N,) int32: exclusive prefix sum of per-key match counts (starts[0] must
    be 0; pad with +2^31-1 sentinels). dlower/dstarts (N,): first differences of the
    per-key `lower` bound and of `starts` (pad with 0). For output slot t in
    [0, cap_out): a_idx[t] = max{i : starts[i] <= t}, b_idx[t] = lower[a_idx] +
    (t - starts[a_idx]). Returns (a_idx, b_idx, starts_at) int32 (cap_out,);
    starts_at is a scratch output (starts[a_idx] accumulator) callers discard.
    """
    n, t_cap = starts.shape[0], cap_out
    assert n % BLOCK_A == 0 and t_cap % BLOCK_T == 0, (n, t_cap)
    grid = (t_cap // BLOCK_T, n // BLOCK_A)
    return pl.pallas_call(
        _pairs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_A,), lambda i, j: (j,)),
            pl.BlockSpec((BLOCK_A,), lambda i, j: (j,)),
            pl.BlockSpec((BLOCK_A,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_T,), lambda i, j: (i,)),
            pl.BlockSpec((BLOCK_T,), lambda i, j: (i,)),
            pl.BlockSpec((BLOCK_T,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_cap,), jnp.int32),
            jax.ShapeDtypeStruct((t_cap,), jnp.int32),
            jax.ShapeDtypeStruct((t_cap,), jnp.int32),
        ],
        interpret=interpret,
    )(starts, dlower, dstarts)


def merge_join_counts_pallas(
    a_keys: jax.Array, b_keys: jax.Array, interpret: bool = True
):
    """a_keys (N,), b_keys (M,) int32 sorted ascending (padding: +2^31-1 sentinels
    work because they never compare below real keys). Returns (lower, upper) int32."""
    n, m = a_keys.shape[0], b_keys.shape[0]
    assert n % BLOCK_A == 0 and m % BLOCK_B == 0, (n, m)
    grid = (n // BLOCK_A, m // BLOCK_B)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_A,), lambda i, j: (i,)),
            pl.BlockSpec((BLOCK_B,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_A,), lambda i, j: (i,)),
            pl.BlockSpec((BLOCK_A,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(a_keys, b_keys)
