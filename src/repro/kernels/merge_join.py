"""Sorted-key join probe as a Pallas TPU kernel.

TPU adaptation of the per-machine hash-join probe (DESIGN.md §2.4): GPU hash probes
rely on shared-memory scatter; on TPU we sort both sides (XLA sort is an efficient
bitonic network on TPU) and compute, for every key of A, its match range [lower, upper)
in B with a **tiled compare-reduce**: an A-tile (BLOCK_A keys) sits in VMEM while the
kernel marches over B in BLOCK_B-sized VMEM blocks, accumulating
    lower[i] += Σ_j [b_j <  a_i]      upper[i] += Σ_j [b_j <= a_i]
— branch-free VPU work with perfectly sequential HBM reads (no data-dependent control
flow, which the TPU vector unit cannot do). The compare-reduce does O(N·M / BLOCK)
lane-ops but runs at full vector width; for the |B| ranges the engine feeds it
(capacity-bounded partitions), it beats a gather-based binary search on TPU.

Grid: (n_a_tiles, n_b_blocks); B blocks iterate in the minor grid dimension so the
accumulators live in the output block across the B sweep (revisited output block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_A = 256
BLOCK_B = 1024


def _kernel(a_ref, b_ref, lower_ref, upper_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        lower_ref[...] = jnp.zeros_like(lower_ref)
        upper_ref[...] = jnp.zeros_like(upper_ref)

    a = a_ref[...]          # (BLOCK_A,)
    b = b_ref[...]          # (BLOCK_B,)
    lt = (b[None, :] < a[:, None]).astype(jnp.int32)
    le = (b[None, :] <= a[:, None]).astype(jnp.int32)
    lower_ref[...] += lt.sum(axis=1)
    upper_ref[...] += le.sum(axis=1)


def merge_join_counts_pallas(
    a_keys: jax.Array, b_keys: jax.Array, interpret: bool = True
):
    """a_keys (N,), b_keys (M,) int32 sorted ascending (padding: +2^31-1 sentinels
    work because they never compare below real keys). Returns (lower, upper) int32."""
    n, m = a_keys.shape[0], b_keys.shape[0]
    assert n % BLOCK_A == 0 and m % BLOCK_B == 0, (n, m)
    grid = (n // BLOCK_A, m // BLOCK_B)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_A,), lambda i, j: (i,)),
            pl.BlockSpec((BLOCK_B,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_A,), lambda i, j: (i,)),
            pl.BlockSpec((BLOCK_A,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(a_keys, b_keys)
