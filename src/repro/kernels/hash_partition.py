"""Hash partitioning (the exchange's send side) as a Pallas TPU kernel.

Computes, per input tile, (i) the partition id of every key under a multiplicative
uint32 mix and (ii) the tile's partition histogram — the send-count matrix the padded
all_to_all exchange is sized from (repro/dataplane). The histogram is a one-hot
matmul: (BLOCK × P) one-hot against an all-ones vector — MXU-friendly, no scatter
(TPU has no shared-memory atomics; this is the standard TPU radix-count shape).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MIX_A, MIX_B

BLOCK = 1024


def _kernel(keys_ref, part_ref, hist_ref, *, n_parts: int):
    k = keys_ref[...].astype(jnp.uint32)
    h = (k ^ (k >> 16)) * jnp.uint32(MIX_A)
    h = (h ^ (h >> 13)) * jnp.uint32(MIX_B)
    h = h ^ (h >> 16)
    part = (h % jnp.uint32(n_parts)).astype(jnp.int32)
    part_ref[...] = part
    iota = jax.lax.broadcasted_iota(jnp.int32, (BLOCK, n_parts), 1)
    onehot = (part[:, None] == iota).astype(jnp.int32)
    hist_ref[...] = onehot.sum(axis=0)[None, :]


def hash_partition_pallas(
    keys: jax.Array, n_parts: int, interpret: bool = True
):
    """keys (N,) int32/uint32, N % BLOCK == 0 → (part (N,), hist (N/BLOCK, P))."""
    n = keys.shape[0]
    assert n % BLOCK == 0, n
    n_tiles = n // BLOCK
    kernel = lambda kr, pr, hr: _kernel(kr, pr, hr, n_parts=n_parts)
    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1, n_parts), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, n_parts), jnp.int32),
        ],
        interpret=interpret,
    )(keys)
