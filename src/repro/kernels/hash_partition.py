"""Hash partitioning (the exchange's send side) as a Pallas TPU kernel.

Computes, per input tile, (i) the partition id of every key under a multiplicative
uint32 mix and (ii) the tile's partition histogram — the send-count matrix the padded
all_to_all exchange is sized from (repro/dataplane). The histogram is a one-hot
matmul: (BLOCK × P) one-hot against an all-ones vector — MXU-friendly, no scatter
(TPU has no shared-memory atomics; this is the standard TPU radix-count shape).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MIX_A, MIX_B

BLOCK = 1024


def _kernel(keys_ref, part_ref, hist_ref, *, n_parts: int):
    k = keys_ref[...].astype(jnp.uint32)
    h = (k ^ (k >> 16)) * jnp.uint32(MIX_A)
    h = (h ^ (h >> 13)) * jnp.uint32(MIX_B)
    h = h ^ (h >> 16)
    part = (h % jnp.uint32(n_parts)).astype(jnp.int32)
    part_ref[...] = part
    iota = jax.lax.broadcasted_iota(jnp.int32, (BLOCK, n_parts), 1)
    onehot = (part[:, None] == iota).astype(jnp.int32)
    hist_ref[...] = onehot.sum(axis=0)[None, :]


def _pack_kernel(count_ref, keys_ref, part_ref, slot_ref, hist_ref, base_ref, *, n_parts: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        base_ref[...] = jnp.zeros_like(base_ref)

    k = keys_ref[...].astype(jnp.uint32)
    h = (k ^ (k >> 16)) * jnp.uint32(MIX_A)
    h = (h ^ (h >> 13)) * jnp.uint32(MIX_B)
    h = h ^ (h >> 16)
    part = (h % jnp.uint32(n_parts)).astype(jnp.int32)
    # rows past the valid count go to a ghost partition (id == n_parts) so they
    # neither claim slots nor show up in the send histogram
    idx = i * BLOCK + jax.lax.broadcasted_iota(jnp.int32, (BLOCK, 1), 0)[:, 0]
    part = jnp.where(idx < count_ref[0], part, jnp.int32(n_parts))
    part_ref[...] = part
    iota = jax.lax.broadcasted_iota(jnp.int32, (BLOCK, n_parts + 1), 1)
    onehot = (part[:, None] == iota).astype(jnp.int32)
    # slot = running base from earlier tiles + exclusive rank within this tile
    within = ((jnp.cumsum(onehot, axis=0) - onehot) * onehot).sum(axis=1)
    base = base_ref[...]                                  # (1, n_parts + 1)
    slot_ref[...] = within + (onehot * base).sum(axis=1)
    tile_hist = onehot.sum(axis=0)
    hist_ref[...] = tile_hist[None, :n_parts]
    base_ref[...] = base + tile_hist[None, :]


def hash_partition_pack_pallas(
    keys: jax.Array, count: jax.Array, n_parts: int, interpret: bool = True
):
    """Fused exchange send side: hash + partition id + in-partition slot + histogram
    in one pass. keys (N,) int32, N % BLOCK == 0; count (1,) int32 valid prefix
    length. → (part (N,) with n_parts marking invalid rows, slot (N,) stable rank
    within the row's partition, hist (N/BLOCK, P) per-tile send counts). The grid
    is sequential, carrying the running per-partition base in a revisited (1, P+1)
    output block so `slot` is globally correct without a second pass."""
    n = keys.shape[0]
    assert n % BLOCK == 0, n
    n_tiles = n // BLOCK
    kernel = lambda cr, kr, pr, sr, hr, br: _pack_kernel(
        cr, kr, pr, sr, hr, br, n_parts=n_parts
    )
    part, slot, hist, _base = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1, n_parts), lambda i: (i, 0)),
            pl.BlockSpec((1, n_parts + 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, n_parts), jnp.int32),
            jax.ShapeDtypeStruct((1, n_parts + 1), jnp.int32),
        ],
        interpret=interpret,
    )(count, keys)
    return part, slot, hist


def hash_partition_pallas(
    keys: jax.Array, n_parts: int, interpret: bool = True
):
    """keys (N,) int32/uint32, N % BLOCK == 0 → (part (N,), hist (N/BLOCK, P))."""
    n = keys.shape[0]
    assert n % BLOCK == 0, n
    n_tiles = n // BLOCK
    kernel = lambda kr, pr, hr: _kernel(kr, pr, hr, n_parts=n_parts)
    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1, n_parts), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, n_parts), jnp.int32),
        ],
        interpret=interpret,
    )(keys)
