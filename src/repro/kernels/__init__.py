# Pallas TPU kernels for the compute hot-spots of the join data plane + the models:
#   merge_join.py      — sorted-key join probe (tiled compare-reduce over VMEM blocks)
#   hash_partition.py  — multiplicative hash + per-tile radix histogram
#   ssd.py             — Mamba-2/SSD intra-chunk masked matmul + state update
#   flash_attention.py — online-softmax attention (the dominant memory term's fix)
# ops.py holds the jit'd public wrappers (interpret=True on CPU, compiled on TPU);
# ref.py holds the pure-jnp oracles every kernel is allclose-tested against.
from .ops import flash_attention, hash_partition, merge_join_counts, ssd_chunk
