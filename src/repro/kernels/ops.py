"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels execute with interpret=True (the Pallas
interpreter runs the kernel body faithfully, including the grid/BlockSpec schedule);
on TPU backends `_INTERPRET` flips to False and the same code compiles to Mosaic.
"""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import hash_partition as _hp
from . import merge_join as _mj
from . import ssd as _ssd
from . import ref as _ref

_INTERPRET = jax.default_backend() != "tpu"


def probe_use_pallas() -> bool:
    """Whether dataplane shard_map bodies should trace the Pallas kernels.

    On TPU the kernels compile to Mosaic — always use them.  Elsewhere they
    would run under the Pallas *interpreter*, which is bit-identical to the
    jnp reference (asserted in tests/test_kernels.py) but traces to a much
    larger graph: the reference path compiles ~2× faster and runs ~3× faster
    on CPU, which matters when an executor fuses hundreds of stages into a
    handful of executables.

    `REPRO_USE_PALLAS=1` (or `0`) overrides the probe either way — the switch
    the kernel benchmarks and parity tests use to force the Pallas path under
    the interpreter."""
    force = os.environ.get("REPRO_USE_PALLAS")
    if force is not None and force != "":
        return force not in ("0", "false", "no")
    return not _INTERPRET


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "use_pallas"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128, bk: int = 128,
                    use_pallas: bool = True):
    """Online-softmax attention: q (BH,Sq,D), k/v (BH,Sk,D) → (BH,Sq,D)."""
    if not use_pallas:
        return _ref.flash_attention_ref(q, k, v, causal=causal)
    bq = min(bq, q.shape[1])
    bk = min(bk, k.shape[1])
    return _fa.flash_attention_pallas(
        q, k, v, causal=causal, bq=bq, bk=bk, interpret=_INTERPRET
    )


def fold64(keys: jax.Array) -> jax.Array:
    """Fold int64 join keys to int32 lanes for the TPU kernels (xor-fold)."""
    k = keys.astype(jnp.uint64)
    return (jnp.uint32(0xFFFFFFFF) & (k ^ (k >> 32)).astype(jnp.uint32)).astype(jnp.int32)


def _pad_to(x: jax.Array, mult: int, fill) -> jax.Array:
    pad = (-x.shape[0]) % mult
    if pad:
        x = jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])
    return x


@partial(jax.jit, static_argnames=("use_pallas",))
def merge_join_counts(a_keys: jax.Array, b_keys: jax.Array, use_pallas: bool = True):
    """Sorted int32 keys → (lower, upper) match ranges of each a in b.
    Handles arbitrary lengths by sentinel padding (INT32_MAX sorts last)."""
    n, m = a_keys.shape[0], b_keys.shape[0]
    if not use_pallas:
        return _ref.merge_join_counts_ref(a_keys, b_keys)
    big = jnp.iinfo(jnp.int32).max
    a_p = _pad_to(a_keys, _mj.BLOCK_A, big)
    b_p = _pad_to(b_keys, _mj.BLOCK_B, big)
    lower, upper = _mj.merge_join_counts_pallas(a_p, b_p, interpret=_INTERPRET)
    # padded B sentinels never compare < or <= real keys except vs the padded A
    # sentinels; trim A and clamp to the true M.
    return jnp.minimum(lower[:n], m), jnp.minimum(upper[:n], m)


@partial(jax.jit, static_argnames=("cap_out", "use_pallas"))
def merge_join_pairs(lower: jax.Array, starts: jax.Array, cap_out: int,
                     use_pallas: bool = True):
    """Expand sorted-merge match ranges to the flat (a_idx, b_idx) pair list.

    lower (N,) int32: per-A-key lower bound in sorted B; starts (N,) int32:
    exclusive prefix sum of per-key match counts (starts[0] == 0 — guaranteed
    when starts = cumsum(counts) - counts). Output slot t in [0, cap_out) maps
    to the key a_idx[t] = max{i : starts[i] <= t} and b_idx[t] = lower[a_idx] +
    (t - starts[a_idx]); slots at or past the true total alias the last key, so
    callers must mask by the total count. a_idx is clipped to [0, N-1]; b_idx
    is returned unclipped."""
    n = starts.shape[0]
    if n == 0:
        z = jnp.zeros((cap_out,), jnp.int32)
        return z, z
    if not use_pallas:
        return _ref.merge_join_pairs_ref(lower, starts, cap_out)
    big = jnp.iinfo(jnp.int32).max
    dl = jnp.diff(lower.astype(jnp.int32), prepend=jnp.int32(0))
    ds = jnp.diff(starts.astype(jnp.int32), prepend=jnp.int32(0))
    starts_p = _pad_to(starts.astype(jnp.int32), _mj.BLOCK_A, big)
    dl_p = _pad_to(dl, _mj.BLOCK_A, 0)
    ds_p = _pad_to(ds, _mj.BLOCK_A, 0)
    cap_p = -(-cap_out // _mj.BLOCK_T) * _mj.BLOCK_T
    a_idx, b_idx, _ = _mj.merge_join_pairs_pallas(
        starts_p, dl_p, ds_p, cap_p, interpret=_INTERPRET
    )
    return jnp.clip(a_idx[:cap_out], 0, n - 1), b_idx[:cap_out]


@partial(jax.jit, static_argnames=("n_parts", "use_pallas"))
def hash_partition_pack(keys: jax.Array, count: jax.Array, n_parts: int,
                        use_pallas: bool = True):
    """Fused exchange send side: → (part (N,) int32 with n_parts marking rows at or
    past `count`, slot (N,) stable in-partition rank, send_counts (n_parts,))."""
    n = keys.shape[0]
    if keys.dtype in (jnp.int64, jnp.uint64):
        keys = fold64(keys)
    count = jnp.asarray(count, jnp.int32).reshape((1,))
    if not use_pallas:
        part, slot, hist = _ref.hash_partition_pack_ref(keys, count[0], n_parts, tile=n)
        return part, slot, hist.sum(axis=0)
    keys_p = _pad_to(keys, _hp.BLOCK, 0)
    # padding rows sit past `count` (count <= n), so the kernel ghosts them
    part, slot, hist = _hp.hash_partition_pack_pallas(
        keys_p, count, n_parts, interpret=_INTERPRET
    )
    return part[:n], slot[:n], hist.sum(axis=0)


@partial(jax.jit, static_argnames=("n_parts", "use_pallas"))
def hash_partition(keys: jax.Array, n_parts: int, use_pallas: bool = True):
    """→ (part (N,), hist (P,)) partition ids + global histogram."""
    n = keys.shape[0]
    if keys.dtype in (jnp.int64, jnp.uint64):
        keys = fold64(keys)
    if not use_pallas:
        part, hist = _ref.hash_partition_ref(keys, n_parts, tile=min(n, _hp.BLOCK))
        return part, hist.sum(axis=0)
    keys_p = _pad_to(keys, _hp.BLOCK, 0)
    part, hist = _hp.hash_partition_pallas(keys_p, n_parts, interpret=_INTERPRET)
    part = part[:n]
    hist = hist.sum(axis=0)
    if keys_p.shape[0] != n:  # remove the padding keys' contribution (they hash as 0)
        pad_part, _ = _ref.hash_partition_ref(
            jnp.zeros((keys_p.shape[0] - n,), jnp.int32), n_parts, tile=1
        )
        hist = hist - jnp.bincount(pad_part, length=n_parts).astype(hist.dtype)
    return part, hist


@partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd_chunk(x, dt, a, b_ssm, c_ssm, chunk: int = 64, use_pallas: bool = True):
    """(BH,S,P) SSD over chunks → (y, final_state). fp32."""
    if not use_pallas:
        # jnp oracle: sequential over chunks via the per-chunk reference
        bh, s, p = x.shape
        n = b_ssm.shape[-1]
        nc = s // chunk

        def per_bh(xb, dtb, ab, bb, cb):
            def step(state, idx):
                sl = lambda arr: jax.lax.dynamic_slice_in_dim(arr, idx * chunk, chunk)
                y, state = _ref.ssd_chunk_ref(sl(xb), sl(dtb), ab, sl(bb), sl(cb), state)
                return state, y

            state0 = jnp.zeros((p, n), jnp.float32)
            state, ys = jax.lax.scan(step, state0, jnp.arange(nc))
            return ys.reshape(s, p), state

        return jax.vmap(per_bh)(x, dt, a, b_ssm, c_ssm)
    return _ssd.ssd_chunk_pallas(x, dt, a, b_ssm, c_ssm, chunk, interpret=_INTERPRET)
