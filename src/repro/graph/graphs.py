"""Data graphs for subgraph enumeration: loaders and seeded generators.

A :class:`Graph` is a simple undirected graph held as a normalized edge
array: shape (m, 2) int64, u < v per row, rows unique, self-loops dropped —
exactly the physical table the pattern compiler copies per pattern edge.
Generators (Erdős–Rényi, Zipf/power-law) are `np.random.Generator`-seeded so
tests, benchmarks, and examples share reproducible inputs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

import numpy as np


@dataclass(frozen=True)
class Graph:
    """Simple undirected graph: ``edges`` (m, 2) int64, u < v, unique rows."""

    n_vertices: int
    edges: np.ndarray

    @staticmethod
    def from_edges(
        edges: np.ndarray, n_vertices: Optional[int] = None
    ) -> "Graph":
        """Normalize an arbitrary edge-list array: canonical u < v endpoint
        order, duplicate edges and self-loops dropped."""
        arr = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if arr.size and arr.min() < 0:
            raise ValueError("vertex ids must be non-negative")
        arr = arr[arr[:, 0] != arr[:, 1]]                       # self-loops
        lo = np.minimum(arr[:, 0], arr[:, 1])
        hi = np.maximum(arr[:, 0], arr[:, 1])
        arr = np.unique(np.stack([lo, hi], axis=1), axis=0)
        if n_vertices is None:
            n_vertices = int(arr.max()) + 1 if arr.size else 0
        elif arr.size and int(arr.max()) >= n_vertices:
            raise ValueError("edge endpoint exceeds n_vertices")
        return Graph(n_vertices=int(n_vertices), edges=arr)

    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    def degrees(self) -> np.ndarray:
        """(n_vertices,) undirected degree per vertex."""
        deg = np.zeros(self.n_vertices, dtype=np.int64)
        if self.edges.size:
            np.add.at(deg, self.edges[:, 0], 1)
            np.add.at(deg, self.edges[:, 1], 1)
        return deg

    def symmetrized(self) -> np.ndarray:
        """(2m, 2) both orientations of every edge (the unoriented table)."""
        if not self.edges.size:
            return self.edges.reshape(0, 2)
        return np.concatenate([self.edges, self.edges[:, ::-1]], axis=0)


def load_edge_list(path: Union[str, "os.PathLike"]) -> Graph:  # noqa: F821
    """Whitespace-separated ``u v`` text file (``#`` comments) → Graph."""
    arr = np.loadtxt(path, dtype=np.int64, comments="#", ndmin=2)
    return Graph.from_edges(arr)


def erdos_renyi(
    rng: np.random.Generator, n_vertices: int, n_edges: int
) -> Graph:
    """G(n, m)-style: ``n_edges`` distinct uniform edges (best effort — dense
    requests near the complete graph may return slightly fewer)."""
    if n_vertices < 2:
        return Graph(n_vertices=n_vertices, edges=np.zeros((0, 2), np.int64))
    collected = np.zeros((0, 2), np.int64)
    for _ in range(64):
        need = n_edges - collected.shape[0]
        if need <= 0:
            break
        u = rng.integers(0, n_vertices, size=2 * need)
        v = rng.integers(0, n_vertices, size=2 * need)
        batch = np.stack([u, v], axis=1)
        collected = Graph.from_edges(
            np.concatenate([collected, batch]), n_vertices
        ).edges
    return _trim(rng, collected, n_edges, n_vertices)


def _trim(
    rng: np.random.Generator, edges: np.ndarray, n_edges: int, n_vertices: int
) -> Graph:
    """Keep a uniform subset of ``n_edges`` rows (np.unique sorted them, so a
    prefix slice would bias toward low vertex ids)."""
    if edges.shape[0] > n_edges:
        keep = rng.permutation(edges.shape[0])[:n_edges]
        edges = edges[np.sort(keep)]
    return Graph(n_vertices=n_vertices, edges=edges)


def zipf_graph(
    rng: np.random.Generator,
    n_vertices: int,
    n_edges: int,
    skew: float = 1.0,
) -> Graph:
    """Power-law graph: both endpoints drawn ∝ rank^{-skew} (skew = 0 →
    uniform).  Heavy hubs are what make the join taxonomy fan out into
    cross-edge / isolated stages, exactly like ``zipf_relation`` does for
    synthetic relations."""
    if n_vertices < 2:
        return Graph(n_vertices=n_vertices, edges=np.zeros((0, 2), np.int64))
    ranks = np.arange(1, n_vertices + 1, dtype=np.float64)
    probs = ranks ** (-max(0.0, skew))
    probs /= probs.sum()
    collected = np.zeros((0, 2), np.int64)
    for _ in range(64):
        need = n_edges - collected.shape[0]
        if need <= 0:
            break
        u = rng.choice(n_vertices, size=2 * need, p=probs)
        v = rng.choice(n_vertices, size=2 * need, p=probs)
        batch = np.stack([u, v], axis=1)
        collected = Graph.from_edges(
            np.concatenate([collected, batch]), n_vertices
        ).edges
    return _trim(rng, collected, n_edges, n_vertices)


def vertex_order_rank(graph: Graph, mode: str = "degree") -> np.ndarray:
    """Strict total order on G's vertices as a rank array (rank[v] = position).

    ``"id"``: by vertex id.  ``"degree"``: by (degree, id) — the classic
    triangle-counting orientation; every oriented out-neighborhood is
    O(√m)-ish on real graphs, which shrinks the oriented join's intermediate
    sizes.  Any strict total order is sound for symmetry breaking; the mode
    only affects performance."""
    n = graph.n_vertices
    if mode == "id":
        return np.arange(n, dtype=np.int64)
    if mode == "degree":
        order = np.lexsort((np.arange(n), graph.degrees()))
        rank = np.empty(n, dtype=np.int64)
        rank[order] = np.arange(n)
        return rank
    raise ValueError(f"unknown vertex order {mode!r} (want 'id' or 'degree')")
