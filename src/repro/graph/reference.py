"""Brute-force subgraph enumeration oracle (no join machinery shared).

Plain backtracking over adjacency sets: assign G-vertices to pattern vertices
in a connectivity-first order, prune by adjacency and injectivity, then
canonicalize through Aut(P) — the independent ground truth the engine
pipeline is tested against.  Test/bench-sized graphs only.
"""

from __future__ import annotations

from typing import List, Set, Tuple

import numpy as np

from .graphs import Graph
from .patterns import Pattern, automorphisms, canonical_rows


def brute_force_occurrences(graph: Graph, pattern: Pattern) -> np.ndarray:
    """(count, k) canonical, sorted occurrence rows — same format as
    :func:`repro.graph.enumerate.postprocess_rows`."""
    n, k = graph.n_vertices, pattern.n_vertices
    adj: List[Set[int]] = [set() for _ in range(n)]
    for u, v in graph.edges.tolist():
        adj[u].add(v)
        adj[v].add(u)

    nbrs: List[Set[int]] = [set() for _ in range(k)]
    for u, v in pattern.edges:
        nbrs[u].add(v)
        nbrs[v].add(u)
    # connectivity-first vertex order: maximize anchored neighbors so the
    # candidate set is an adjacency intersection, not the whole vertex set
    order: List[int] = []
    remaining = set(range(k))
    while remaining:
        placed = set(order)
        best = max(
            remaining, key=lambda v: (len(nbrs[v] & placed), len(nbrs[v]), -v)
        )
        order.append(best)
        remaining.discard(best)
    depth_of = {v: d for d, v in enumerate(order)}

    found: Set[Tuple[int, ...]] = set()
    assign = [0] * k
    used: Set[int] = set()

    def rec(d: int) -> None:
        if d == k:
            found.add(tuple(assign))
            return
        v = order[d]
        anchored = [u for u in nbrs[v] if depth_of[u] < d]
        if anchored:
            cands = set(adj[assign[anchored[0]]])
            for u in anchored[1:]:
                cands &= adj[assign[u]]
        else:
            cands = set(range(n))
        for g in cands:
            if g in used:
                continue
            assign[v] = g
            used.add(g)
            rec(d + 1)
            used.discard(g)

    rec(0)
    if not found:
        return np.zeros((0, k), np.int64)
    rows = np.array(sorted(found), dtype=np.int64)
    canon = canonical_rows(rows, automorphisms(pattern))
    return np.unique(canon, axis=0)
