"""Subgraph enumeration subsystem: pattern DSL, graph generators, the
pattern → JoinQuery compiler, and the end-to-end enumeration pipeline
(paper Sec. 1.4 — the headline corollary workload)."""

from .compile import CompiledPattern, compile_pattern
from .enumerate import EnumerationResult, enumerate_subgraphs, postprocess_rows
from .graphs import (
    Graph,
    erdos_renyi,
    load_edge_list,
    vertex_order_rank,
    zipf_graph,
)
from .patterns import (
    OrientationPlan,
    Pattern,
    automorphisms,
    canonical_rows,
    clique,
    cycle,
    from_edge_list,
    path,
    plan_orientation,
    star,
    triangle,
)
from .reference import brute_force_occurrences

__all__ = [
    "CompiledPattern",
    "EnumerationResult",
    "Graph",
    "OrientationPlan",
    "Pattern",
    "automorphisms",
    "brute_force_occurrences",
    "canonical_rows",
    "clique",
    "compile_pattern",
    "cycle",
    "enumerate_subgraphs",
    "erdos_renyi",
    "from_edge_list",
    "load_edge_list",
    "path",
    "plan_orientation",
    "postprocess_rows",
    "star",
    "triangle",
    "vertex_order_rank",
    "zipf_graph",
]
