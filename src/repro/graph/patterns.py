"""Constant-size graph patterns: DSL, automorphisms, symmetry-breaking orientation.

Subgraph enumeration — reporting every occurrence of a constant-size pattern
P in a data graph G — is the paper's headline corollary workload (Sec. 1.4):
give every pattern vertex an attribute and let every pattern edge bind a
logical copy of G's edge relation; the rows of Join(Q) are exactly the
homomorphisms P → G, at load Õ(|E| / p^{1/ρ(P)}).

Raw homomorphisms over-report, in two independent ways:

  * **automorphisms** — an occurrence (a subgraph of G isomorphic to P) is hit
    once per σ ∈ Aut(P): 6× for a triangle, 8× for a 4-cycle;
  * **non-injectivity** — a homomorphism may collapse non-adjacent pattern
    vertices (a 4-cycle row with X0 = X2 is a path walked back and forth).

Both are handled here.  The automorphism blow-up is attacked at the *input*
with the classic orientation trick: fix a strict total order on G's vertices
(by id, or by degree with id tie-break — the O(m^{3/2}) triangle-counting
order) and replace the symmetric edge table (2|E| rows) by the oriented one
(|E| rows) for pattern edges carrying a constraint u → v ("the G-vertex bound
to u precedes the one bound to v").  A constraint set C is **sound** iff every
occurrence keeps ≥ 1 satisfying embedding — equivalently, for every linear
order on V(P) some σ ∈ Aut(P) maps it onto one satisfying C — and **complete**
iff exactly one survives.  Patterns are constant-size, so both properties are
decided by brute force over all |V(P)|! orders × Aut(P) (host-side planner
work, like the LP).  ``plan_orientation`` greedily orients edges while
soundness holds; cliques short-circuit to the total orientation, which is
complete, kills the 2|E| symmetrization, *and* implies injectivity.  Whatever
symmetry (or collapsibility) survives an incomplete orientation is removed
post-hoc: ``canonical_rows`` maps every row to the lexicographically smallest
automorphic image, so each occurrence is reported exactly once.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

#: Automorphisms/orientation are brute-forced over vertex permutations —
#: fine for the constant-size patterns of the corollary, meaningless beyond.
MAX_PATTERN_VERTICES = 8

#: plan_orientation's greedy soundness search costs ~ |V|! · |Aut| · 2|E|
#: host-side ops; above this budget (huge-automorphism near-cliques) it
#: falls back to the always-sound empty orientation + post-hoc dedup.
_ORIENTATION_BUDGET = 30_000_000


@dataclass(frozen=True)
class Pattern:
    """A constant-size undirected pattern: vertices 0..n-1, normalized edges."""

    name: str
    n_vertices: int
    edges: Tuple[Tuple[int, int], ...]   # (u, v) with u < v, sorted, unique

    @staticmethod
    def make(
        name: str, n_vertices: int, edges: Sequence[Tuple[int, int]]
    ) -> "Pattern":
        if not 1 <= n_vertices <= MAX_PATTERN_VERTICES:
            raise ValueError(
                f"patterns must have 1..{MAX_PATTERN_VERTICES} vertices, "
                f"got {n_vertices}"
            )
        norm: List[Tuple[int, int]] = []
        seen = set()
        for u, v in edges:
            u, v = int(u), int(v)
            if u == v:
                raise ValueError(f"pattern self-loop on vertex {u}")
            if not (0 <= u < n_vertices and 0 <= v < n_vertices):
                raise ValueError(f"edge ({u},{v}) outside 0..{n_vertices - 1}")
            e = (min(u, v), max(u, v))
            if e in seen:
                raise ValueError(f"duplicate pattern edge {e}")
            seen.add(e)
            norm.append(e)
        touched = {x for e in norm for x in e}
        if touched != set(range(n_vertices)):
            raise ValueError(
                "every pattern vertex must lie on an edge "
                f"(untouched: {sorted(set(range(n_vertices)) - touched)})"
            )
        return Pattern(name=name, n_vertices=n_vertices, edges=tuple(sorted(norm)))

    @property
    def k(self) -> int:
        return self.n_vertices

    def is_clique(self) -> bool:
        return len(self.edges) == self.n_vertices * (self.n_vertices - 1) // 2


# -- built-ins (the corollary's usual suspects) ------------------------------


def triangle() -> Pattern:
    """K_3 — the paper's canonical Sec. 1.4 example pattern."""
    return clique(3)


def clique(k: int) -> Pattern:
    """K_k: k vertices, all pairs adjacent."""
    if k < 2:
        raise ValueError("clique needs k >= 2")
    return Pattern.make(
        f"clique{k}", k, [(i, j) for i in range(k) for j in range(i + 1, k)]
    )


def cycle(k: int) -> Pattern:
    """C_k: k vertices in a cycle."""
    if k < 3:
        raise ValueError("cycle needs k >= 3")
    return Pattern.make(f"cycle{k}", k, [(i, (i + 1) % k) for i in range(k)])


def star(k: int) -> Pattern:
    """S_k: a hub (vertex 0) with k leaves."""
    if k < 1:
        raise ValueError("star needs k >= 1 leaves")
    return Pattern.make(f"star{k}", k + 1, [(0, i) for i in range(1, k + 1)])


def path(k: int) -> Pattern:
    """P_k: k vertices in a path (k - 1 edges)."""
    if k < 2:
        raise ValueError("path needs k >= 2 vertices")
    return Pattern.make(f"path{k}", k, [(i, i + 1) for i in range(k - 1)])


def from_edge_list(
    edges: Sequence[Tuple[int, int]], name: str = "custom"
) -> Pattern:
    """Arbitrary constant-size pattern given as an edge list; vertex ids are
    compacted to 0..n-1 preserving order."""
    verts = sorted({int(x) for e in edges for x in e})
    remap = {v: i for i, v in enumerate(verts)}
    return Pattern.make(name, len(verts), [(remap[u], remap[v]) for u, v in edges])


# -- automorphisms -----------------------------------------------------------


def automorphisms(pattern: Pattern) -> Tuple[Tuple[int, ...], ...]:
    """Aut(P) as vertex permutations, identity first (brute force — patterns
    are constant-size by construction)."""
    eset = set(pattern.edges)
    out = []
    for perm in itertools.permutations(range(pattern.n_vertices)):
        if all(
            (min(perm[u], perm[v]), max(perm[u], perm[v])) in eset
            for u, v in pattern.edges
        ):
            out.append(perm)
    return tuple(out)   # itertools yields the identity first


# -- symmetry-breaking orientation ------------------------------------------


@dataclass(frozen=True)
class OrientationPlan:
    """Directed constraints over pattern edges + what they do NOT guarantee.

    ``constraints``: (u, v) means the G-vertex bound to u must precede the one
    bound to v in the chosen total vertex order — compiled as the oriented
    edge table.  ``complete``: every occurrence keeps exactly one embedding
    (no post-hoc dedup needed).  ``needs_injectivity``: some vertex pair is
    neither adjacent nor ordered by the constraint closure, so join rows may
    collapse pattern vertices and must be filtered."""

    constraints: Tuple[Tuple[int, int], ...]
    complete: bool
    needs_injectivity: bool


def _min_max_survivors(
    n: int,
    autos: Sequence[Tuple[int, ...]],
    constraints: Sequence[Tuple[int, int]],
) -> Tuple[int, int]:
    """Over all linear orders on V(P): min/max #automorphisms mapping the
    order onto one satisfying ``constraints``.  min ≥ 1 ⇔ sound;
    min = max = 1 ⇔ complete."""
    lo, hi = len(autos), 0
    rank = [0] * n
    for order in itertools.permutations(range(n)):
        for r, v in enumerate(order):
            rank[v] = r
        cnt = 0
        for s in autos:
            if all(rank[s[u]] < rank[s[v]] for u, v in constraints):
                cnt += 1
        if cnt < lo:
            lo = cnt
        if cnt > hi:
            hi = cnt
    return lo, hi


def _pairs_separated(
    pattern: Pattern, constraints: Sequence[Tuple[int, int]]
) -> bool:
    """True iff every vertex pair is adjacent or strictly ordered by the
    transitive closure of the constraints (⇒ join rows are injective)."""
    n = pattern.n_vertices
    lt = [[False] * n for _ in range(n)]
    for u, v in constraints:
        lt[u][v] = True
    for w in range(n):          # transitive closure (n ≤ 8)
        for u in range(n):
            if lt[u][w]:
                for v in range(n):
                    if lt[w][v]:
                        lt[u][v] = True
    eset = set(pattern.edges)
    for u in range(n):
        for v in range(u + 1, n):
            if (u, v) not in eset and not (lt[u][v] or lt[v][u]):
                return False
    return True


def plan_orientation(pattern: Pattern) -> OrientationPlan:
    """Greedily orient pattern edges while the constraint set stays sound.

    Cliques short-circuit to the total orientation along vertex ids (sound
    and complete by construction: any occurrence's vertices admit exactly one
    order-respecting assignment, and injectivity is implied).  Otherwise each
    edge is tried in both directions and kept oriented when the brute-force
    soundness check passes; patterns whose |V|!·|Aut| search exceeds the
    budget keep the (always sound) empty orientation and rely on dedup."""
    n = pattern.n_vertices
    if pattern.is_clique():
        return OrientationPlan(
            constraints=pattern.edges, complete=True, needs_injectivity=False
        )
    autos = automorphisms(pattern)
    constraints: List[Tuple[int, int]] = []
    cost = math.factorial(n) * len(autos) * 2 * max(1, len(pattern.edges))
    if cost <= _ORIENTATION_BUDGET:
        for u, v in pattern.edges:
            for cand in ((u, v), (v, u)):
                lo, _ = _min_max_survivors(n, autos, constraints + [cand])
                if lo >= 1:
                    constraints.append(cand)
                    break
        lo, hi = _min_max_survivors(n, autos, constraints)
        complete = lo == hi == 1
    else:
        complete = len(autos) == 1
    return OrientationPlan(
        constraints=tuple(constraints),
        complete=complete,
        needs_injectivity=not _pairs_separated(pattern, constraints),
    )


# -- post-hoc canonicalization ----------------------------------------------


def canonical_rows(
    rows: np.ndarray, autos: Sequence[Tuple[int, ...]]
) -> np.ndarray:
    """Map each assignment row to its lexicographically smallest automorphic
    image: row r (r[i] = value of pattern vertex i) has images r[σ] for
    σ ∈ Aut(P); two rows are the same occurrence iff their images coincide.
    Vectorized lex-min over the |Aut| candidates; dedup is the caller's
    ``np.unique(..., axis=0)``."""
    rows = np.asarray(rows, dtype=np.int64)
    if rows.shape[0] == 0 or len(autos) <= 1:
        return rows
    best = rows[:, list(autos[0])].copy()
    k = rows.shape[1]
    for sigma in autos[1:]:
        cand = rows[:, list(sigma)]
        lt = np.zeros(rows.shape[0], dtype=bool)
        decided = np.zeros(rows.shape[0], dtype=bool)
        for c in range(k):
            l = ~decided & (cand[:, c] < best[:, c])
            g = ~decided & (cand[:, c] > best[:, c])
            lt |= l
            decided |= l | g
        best[lt] = cand[lt]
    return best
