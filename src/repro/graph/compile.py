"""Pattern → JoinQuery compiler (the Sec. 1.4 reduction, made physical).

Every pattern vertex v becomes attribute ``V{v}``; every pattern edge becomes
a binary relation over its endpoints' attributes.  All relations are logical
copies of at most TWO physical tables, shared via ``Relation.table`` so the
engine's shared-input Scatter places each once:

  * ``oriented``  — G's edges with endpoints in ascending vertex-order rank
                    (|E| rows), bound by pattern edges carrying an
                    orientation constraint u → v (as scheme (V_u, V_v):
                    scheme order encodes the direction, so the reversed
                    constraint needs no second table);
  * ``symmetric`` — both orientations (2|E| rows), bound by unoriented
                    pattern edges.

The same ndarray object backs every copy — `compile_pattern` bypasses
``Relation.make``'s dedup (the tables are unique by construction) precisely
so backends can recognize the sharing by identity.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from ..core.query import JoinQuery, Relation
from .graphs import Graph, vertex_order_rank
from .patterns import OrientationPlan, Pattern, plan_orientation


def attr_name(v: int) -> str:
    return f"V{v}"


@dataclass(frozen=True)
class CompiledPattern:
    """A pattern bound to a graph: the join query + what postprocessing owes.

    ``attrs[v]`` is pattern vertex v's attribute; because patterns have ≤ 10
    vertices the sorted attset of the query equals ``attrs`` — join rows come
    back with column v holding the G-vertex bound to pattern vertex v."""

    pattern: Pattern
    graph: Graph
    orientation: OrientationPlan
    query: JoinQuery
    attrs: Tuple[str, ...]
    order_rank: np.ndarray        # rank[g_vertex] behind the oriented table

    @property
    def needs_dedup(self) -> bool:
        return not self.orientation.complete


def compile_pattern(
    graph: Graph, pattern: Pattern, orientation: str = "degree"
) -> CompiledPattern:
    """Bind ``pattern`` to ``graph``'s edge set as a simple binary JoinQuery.

    ``orientation`` picks the total vertex order behind the oriented table
    (``"degree"`` default, ``"id"``) — any strict order is correct; see
    :func:`repro.graph.graphs.vertex_order_rank`."""
    if len(pattern.edges) == 0:
        raise ValueError("pattern has no edges")
    plan = plan_orientation(pattern)
    rank = vertex_order_rank(graph, orientation)
    e = graph.edges
    if e.size:
        swap = rank[e[:, 0]] > rank[e[:, 1]]
        lo = np.where(swap, e[:, 1], e[:, 0])
        hi = np.where(swap, e[:, 0], e[:, 1])
        oriented = np.unique(np.stack([lo, hi], axis=1), axis=0)
        sym = np.unique(
            np.concatenate([oriented, oriented[:, ::-1]], axis=0), axis=0
        )
    else:
        oriented = np.zeros((0, 2), np.int64)
        sym = np.zeros((0, 2), np.int64)

    directed = {(min(u, v), max(u, v)): (u, v) for u, v in plan.constraints}
    rels = []
    for u, v in pattern.edges:
        c = directed.get((u, v))
        if c is None:
            rels.append(
                Relation(
                    scheme=(attr_name(u), attr_name(v)),
                    data=sym,
                    table=f"graph-sym:{orientation}",
                )
            )
        else:
            a, b = c
            rels.append(
                Relation(
                    scheme=(attr_name(a), attr_name(b)),
                    data=oriented,
                    table=f"graph-oriented:{orientation}",
                )
            )
    query = JoinQuery.make(rels)
    attrs = tuple(attr_name(v) for v in range(pattern.n_vertices))
    assert query.attset == attrs, "V-attribute order must equal vertex order"
    return CompiledPattern(
        pattern=pattern,
        graph=graph,
        orientation=plan,
        query=query,
        attrs=attrs,
        order_rank=rank,
    )
