"""End-to-end subgraph enumeration on the MPC join engine.

``enumerate_subgraphs`` runs the full pipeline — compile the pattern against
the graph, execute the Theorem 6.2 join on the chosen backend, then apply the
two row-level corrections the reduction owes (injectivity filter, automorphic
canonical dedup) — and returns every occurrence exactly once.

Backends mirror the engine's executors:

  * ``"simulator"`` — :func:`repro.mpc.engine.mpc_join`: shared-input Scatter,
    the 3-round distributed histogram, exact load metering;
  * ``"dataplane"`` — ``compile_plan`` + :class:`DataplaneExecutor` (stage-
    batched by default; pass ``executor=DataplaneExecutor(batch_stages=False)``
    for the per-stage schedule).

Passing ``session=`` (a :class:`repro.mpc.service.JoinSession`) routes the
join through the persistent service instead: repeated patterns over the same
graph hit the session's plan cache and warm executor
(``JoinSession.submit_pattern`` is the method-form of the same path).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..core.hypergraph import fractional_edge_cover
from ..core.planner import heavy_parameter
from ..core.taxonomy import compute_stats
from .compile import CompiledPattern, compile_pattern
from .graphs import Graph
from .patterns import Pattern, automorphisms, canonical_rows


@dataclass
class EnumerationResult:
    """Occurrences (each exactly once) + the engine run behind them.

    ``occurrences``: (count, k) int64, row = G-vertices bound to pattern
    vertices 0..k-1, canonicalized (lex-min automorphic image) and sorted.
    ``embeddings``: raw Join(Q) rows before injectivity/dedup — the
    homomorphism count the engine actually materialized."""

    pattern: Pattern
    backend: str
    occurrences: np.ndarray
    count: int
    embeddings: int
    compiled: CompiledPattern
    engine: object


def postprocess_rows(compiled: CompiledPattern, rows: np.ndarray) -> np.ndarray:
    """Join rows → exactly-once occurrence set.

    Injectivity: drop rows collapsing two pattern vertices (skipped when the
    orientation already separates every pair).  Dedup: canonicalize through
    Aut(P) and unique — when the orientation is complete this is a no-op on
    the row *set* but still normalizes each row to its canonical image (the
    oriented row order follows the degree order, not the value order)."""
    k = compiled.pattern.n_vertices
    rows = np.asarray(rows, dtype=np.int64).reshape(-1, k)
    if rows.shape[0] and compiled.orientation.needs_injectivity:
        keep = np.ones(rows.shape[0], dtype=bool)
        for i in range(k):
            for j in range(i + 1, k):
                keep &= rows[:, i] != rows[:, j]
        rows = rows[keep]
    canon = canonical_rows(rows, automorphisms(compiled.pattern))
    if canon.shape[0] == 0:
        return canon.reshape(0, k)
    return np.unique(canon, axis=0)


def enumerate_subgraphs(
    graph: Graph,
    pattern: Pattern,
    p: int = 8,
    backend: str = "simulator",
    lam: Optional[int] = None,
    orientation: str = "degree",
    executor=None,
    seed: int = 0,
    fuse_semijoin: bool = False,
    session=None,
) -> EnumerationResult:
    """Enumerate every occurrence of ``pattern`` in ``graph`` via the join.

    Args:
        graph: the data graph (its edge set becomes the shared physical table).
        pattern: the pattern to enumerate (≤ 8 vertices).
        p: the plan's machine count (the dataplane maps it onto however many
            devices the mesh has).
        backend: ``"simulator"`` or ``"dataplane"`` (ignored when ``session``
            is given — the session's backend is used).
        lam: heavy parameter; defaults to the paper's λ = Θ(p^{1/(2ρ)}).
        orientation: vertex order behind the oriented table (``"degree"``/``"id"``).
        executor: inject a configured :class:`DataplaneExecutor` (one-shot
            dataplane path only).
        seed: shared-randomness seed (one-shot simulator path only).
        fuse_semijoin: enable the beyond-paper semi-join fusion rewrite.
        session: a :class:`repro.mpc.service.JoinSession` to submit through —
            the persistent-service path with cross-query plan/compile reuse.

    Returns:
        An :class:`EnumerationResult`: exactly-once ``occurrences`` plus the
        engine run behind them.
    """
    compiled = compile_pattern(graph, pattern, orientation)
    q = compiled.query
    if session is not None:
        p, backend = session.p, session.backend    # the session's plans rule
    if lam is None:
        rho_val = float(fractional_edge_cover(q.hypergraph)[0])
        lam = heavy_parameter(p, rho_val)

    if session is not None:
        res = session.submit(q, lam=lam, fuse_semijoin=fuse_semijoin).result
    elif backend == "simulator":
        from ..mpc.engine import mpc_join

        res = mpc_join(q, p=p, seed=seed, lam=lam, fuse_semijoin=fuse_semijoin)
    elif backend == "dataplane":
        from ..mpc.executors import DataplaneExecutor
        from ..mpc.program import compile_plan, fuse_semijoin_pass

        stats = compute_stats(q, lam)
        program = compile_plan(q, stats, p)
        if fuse_semijoin:
            program = fuse_semijoin_pass(program)
        ex = executor if executor is not None else DataplaneExecutor()
        res = ex.run(program)
    else:
        raise ValueError(f"unknown backend {backend!r}")

    occ = postprocess_rows(compiled, res.rows)
    return EnumerationResult(
        pattern=pattern,
        backend=backend,
        occurrences=occ,
        count=int(occ.shape[0]),
        embeddings=int(res.count),
        compiled=compiled,
        engine=res,
    )
