"""Heavy/light taxonomy of the join result (paper Sec. 4).

Given heavy parameter λ: a value x is *heavy* iff some relation R and attribute
X ∈ scheme(R) have ≥ m/λ tuples with u(X) = x; *light* iff it appears but is not heavy.

A configuration η of H ⊆ attset(Q) assigns a heavy value to every attribute in H.
The residual relation R'_e(η) (for e active on H) keeps tuples of R_e that agree with η
on e∩H and are light on e\\H, projected to e\\H.

Everything here is *planner-side* metadata (heavy value sets, configuration enumeration,
statistics); the data movement happens in repro.mpc / repro.dataplane.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterator, List, Optional, Sequence, Set, Tuple

import numpy as np

from .hypergraph import Edge, Hypergraph
from .query import Attr, JoinQuery, Relation


@dataclass(frozen=True)
class HeavyStats:
    """Heavy-value statistics of a query for a fixed λ (the paper's 'histogram').

    - heavy[X]: sorted array of heavy values on attribute X (across all relations).
    - Extended records (see DESIGN.md §6) so m_η is exactly computable on every host:
      * cond[(e, X, x)]  = #tuples in R_e with u(X) = x (heavy x) and u(other) light
      * pair[(e, x, y)]  = #tuples in R_e equal to the heavy-heavy pair (x, y)
                           (key ordered by the relation's scheme)
      * light_cnt[e]     = #tuples in R_e that are light on both attributes
    """

    lam: int
    m: int
    heavy: Dict[Attr, np.ndarray]
    cond: Dict[Tuple[Edge, Attr, int], int]
    pair: Dict[Tuple[Edge, int, int], int]
    light_cnt: Dict[Edge, int]

    def is_heavy(self, attr: Attr, values: np.ndarray) -> np.ndarray:
        hv = self.heavy.get(attr)
        if hv is None or hv.size == 0:
            return np.zeros(values.shape, dtype=bool)
        idx = np.searchsorted(hv, values)
        idx = np.clip(idx, 0, hv.size - 1)
        return hv[idx] == values

    def n_heavy(self) -> int:
        return sum(int(v.size) for v in self.heavy.values())


def _unique_counts(rel: Relation, col: int, memo: Optional[Dict]):
    """np.unique(column, return_counts=True) with an optional cross-query memo.

    ``memo`` is keyed by (physical table id, column): queries in one service
    batch that bind the same ``Relation.table`` share the sort behind the
    unique-count pass — the expensive part of ``compute_stats`` — once per
    table instead of once per query.  Guarded by the same data-identity check
    as the shared-input Scatter, so a stray relation reusing a table id with
    different tuples falls back to its own computation."""
    if memo is None or rel.table is None:
        return np.unique(rel.data[:, col], return_counts=True)
    key = (rel.table, col)
    hit = memo.get(key)
    if hit is not None and (hit[0] is rel.data or np.array_equal(hit[0], rel.data)):
        return hit[1]
    out = np.unique(rel.data[:, col], return_counts=True)
    if key not in memo:
        memo[key] = (rel.data, out)
    return out


def compute_stats(
    query: JoinQuery, lam: int, unique_memo: Optional[Dict] = None
) -> HeavyStats:
    """Exact heavy statistics (the MPC protocol that distributes these is in
    repro.mpc.statistics; this is the ground-truth computation used by the planner
    and by tests).  ``unique_memo`` optionally shares the per-table unique-count
    pass across queries binding the same physical table (see
    :func:`_unique_counts` — the service layer's batch path)."""
    m = query.m
    threshold = max(1, -(-m // lam))  # ceil(m / lam)
    heavy_sets: Dict[Attr, Set[int]] = {}
    for rel in query.relations:
        for col, attr in enumerate(rel.scheme):
            vals, cnts = _unique_counts(rel, col, unique_memo)
            hv = vals[cnts >= threshold]
            if hv.size:
                heavy_sets.setdefault(attr, set()).update(hv.tolist())
    heavy = {a: np.array(sorted(s), dtype=np.int64) for a, s in heavy_sets.items()}

    stats = HeavyStats(lam=lam, m=m, heavy=heavy, cond={}, pair={}, light_cnt={})
    for rel in query.relations:
        e = rel.edge
        if rel.arity != 2:
            # general route: only the all-light count is meaningful — the
            # cond/pair extended records are binary-taxonomy machinery the
            # general compiler never reads.
            heavy_any = np.zeros(len(rel), dtype=bool)
            for attr in rel.scheme:
                heavy_any |= stats.is_heavy(attr, rel.column(attr))
            stats.light_cnt[e] = int((~heavy_any).sum())
            continue
        x_attr, y_attr = rel.scheme
        hx = stats.is_heavy(x_attr, rel.column(x_attr))
        hy = stats.is_heavy(y_attr, rel.column(y_attr))
        stats.light_cnt[e] = int((~hx & ~hy).sum())
        # heavy on X, light on Y
        sel = hx & ~hy
        vals, cnts = np.unique(rel.column(x_attr)[sel], return_counts=True)
        for v, c in zip(vals.tolist(), cnts.tolist()):
            stats.cond[(e, x_attr, v)] = c
        sel = hy & ~hx
        vals, cnts = np.unique(rel.column(y_attr)[sel], return_counts=True)
        for v, c in zip(vals.tolist(), cnts.tolist()):
            stats.cond[(e, y_attr, v)] = c
        sel = hx & hy
        if sel.any():
            pairs = rel.data[sel]
            uniq, cnts = np.unique(pairs, axis=0, return_counts=True)
            for (vx, vy), c in zip(uniq.tolist(), cnts.tolist()):
                stats.pair[(e, vx, vy)] = int(c)
    return stats


@dataclass(frozen=True)
class Configuration:
    """A configuration η of H: heavy value per attribute of H (paper Sec. 4)."""

    attrs: Tuple[Attr, ...]           # sorted H
    values: Tuple[int, ...]

    def value(self, attr: Attr) -> int:
        return self.values[self.attrs.index(attr)]

    def as_dict(self) -> Dict[Attr, int]:
        return dict(zip(self.attrs, self.values))


def configurations(stats: HeavyStats, h_set: Sequence[Attr]) -> Iterator[Configuration]:
    """Enumerate config(Q, H): all heavy-value combinations over H. O(λ^{|H|})."""
    attrs = tuple(sorted(h_set))
    if not attrs:
        yield Configuration(attrs=(), values=())
        return
    pools = []
    for a in attrs:
        hv = stats.heavy.get(a)
        if hv is None or hv.size == 0:
            return  # no configuration exists
        pools.append(hv.tolist())
    for combo in itertools.product(*pools):
        yield Configuration(attrs=attrs, values=tuple(combo))


# ---------------------------------------------------------------------------
# Structure of the residual query under H (paper Sec. 5.1) — depends on H only.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HPlan:
    """Combinatorial structure shared by all configurations of a fixed H."""

    h_set: Tuple[Attr, ...]           # heavy attributes (sorted)
    light: Tuple[Attr, ...]           # L = attset \ H (sorted)
    isolated: Tuple[Attr, ...]        # I ⊆ L (paper (5.3))
    border: Tuple[Attr, ...]          # light attrs on ≥1 cross edge
    light_edges: Tuple[Edge, ...]     # both endpoints light
    cross_edges: Tuple[Edge, ...]     # one endpoint heavy, one light
    heavy_edges: Tuple[Edge, ...]     # both endpoints heavy


def plan_for_h(query: JoinQuery, h_set: Sequence[Attr]) -> HPlan:
    h = set(h_set)
    attset = set(query.attset)
    if not h <= attset:
        raise ValueError("H must be a subset of attset(Q)")
    light = attset - h
    light_edges, cross_edges, heavy_edges = [], [], []
    for rel in query.relations:
        e = rel.edge
        n_heavy = len(e & h)
        if n_heavy == 0:
            light_edges.append(e)
        elif n_heavy == 1:
            cross_edges.append(e)
        else:
            heavy_edges.append(e)
    border = {next(iter(e - h)) for e in cross_edges}
    # isolated: light attrs not incident to any light edge
    non_isolated = {v for e in light_edges for v in e}
    isolated = light - non_isolated
    return HPlan(
        h_set=tuple(sorted(h)),
        light=tuple(sorted(light)),
        isolated=tuple(sorted(isolated)),
        border=tuple(sorted(border)),
        light_edges=tuple(sorted(light_edges, key=lambda e: sorted(e))),
        cross_edges=tuple(sorted(cross_edges, key=lambda e: sorted(e))),
        heavy_edges=tuple(sorted(heavy_edges, key=lambda e: sorted(e))),
    )


def residual_size(
    query: JoinQuery, stats: HeavyStats, plan: HPlan, eta: Configuration
) -> int:
    """m_η: total input size of Q'(η), computed exactly from the extended histogram
    (paper Step 1 requires every machine to know m_η; see DESIGN.md §6)."""
    h = set(plan.h_set)
    total = 0
    for rel in query.relations:
        e = rel.edge
        x_attr, y_attr = rel.scheme
        inter = e & h
        if len(inter) == 0:
            total += stats.light_cnt[e]
        elif len(inter) == 1:
            (hx,) = inter
            total += stats.cond.get((e, hx, eta.value(hx)), 0)
        # |e∩H| == 2 → inactive edge: contributes no residual relation
    return total


def config_feasible(
    query: JoinQuery, stats: HeavyStats, plan: HPlan, eta: Configuration
) -> bool:
    """Inactive-edge feasibility of η from the extended histogram: every edge
    with both attributes in H must actually contain the η-pair, else Q'(η) is
    empty.  Every machine holds the histogram, so ruled-out configurations
    cost no communication (paper Sec. 6; the IR compiler consumes this)."""
    return all(
        heavy_pair_present(stats, query.relation_for(e), eta) for e in plan.heavy_edges
    )


def heavy_pair_present(
    stats: HeavyStats, rel: Relation, eta: Configuration
) -> bool:
    """For an inactive edge (both attrs heavy): does R_e contain the η-pair? If not,
    Q'(η) is empty (paper Sec. 1.3 example, R'_{D,K})."""
    x_attr, y_attr = rel.scheme
    key = (rel.edge, eta.value(x_attr), eta.value(y_attr))
    return stats.pair.get(key, 0) > 0


def heavy_masks(
    query: JoinQuery, stats: HeavyStats
) -> Dict[Edge, Tuple[np.ndarray, np.ndarray]]:
    """Per-edge (hx, hy) heavy masks, computed once per run.

    A stage-heavy program calls :func:`residual_relations` once per (H, η)
    stage; without this cache every call recomputes the same O(m) masks.
    Relations sharing a physical ``table`` additionally share the mask of any
    (attribute, column) they have in common — the self-join fast path: k
    pattern-edge copies of one edge set pay for each distinct mask once.
    Sharing is guarded by the same data check as the shared-input Scatter
    (``place_inputs``): a stray relation reusing a table id with different
    tuples falls back to its own mask instead of silently borrowing one."""
    cache: Dict[Tuple[str, Attr, int], Tuple[np.ndarray, np.ndarray]] = {}
    out: Dict[Edge, Tuple[np.ndarray, np.ndarray]] = {}
    for rel in query.relations:
        ms = []
        for col, attr in enumerate(rel.scheme):
            key = (rel.table, attr, col) if rel.table is not None else None
            m = None
            if key is not None and key in cache:
                data_ref, cached = cache[key]
                if data_ref is rel.data or np.array_equal(data_ref, rel.data):
                    m = cached
            if m is None:
                m = stats.is_heavy(attr, rel.data[:, col])
                if key is not None and key not in cache:
                    cache[key] = (rel.data, m)
            ms.append(m)
        out[rel.edge] = (ms[0], ms[1])
    return out


def residual_relations(
    query: JoinQuery,
    stats: HeavyStats,
    plan: HPlan,
    eta: Configuration,
    masks: Optional[Dict[Edge, Tuple[np.ndarray, np.ndarray]]] = None,
) -> Optional[Dict[Tuple[Edge, Tuple[Attr, ...]], Relation]]:
    """Materialize Q'(η) in one process (oracle path for tests; the distributed path
    lives in repro.mpc.engine). Returns None if some inactive edge rules η out.

    Key: (original edge e, residual scheme e') — distinct cross edges can produce
    distinct unary relations over the same attribute, so e is part of the key.

    ``masks`` optionally supplies precomputed :func:`heavy_masks` so a caller
    evaluating many configurations does not recompute them per stage.
    """
    h = set(plan.h_set)
    out: Dict[Tuple[Edge, Tuple[Attr, ...]], Relation] = {}
    for rel in query.relations:
        e = rel.edge
        inter = e & h
        if len(inter) == 2:
            if not heavy_pair_present(stats, rel, eta):
                return None
            continue
        x_attr, y_attr = rel.scheme
        if masks is not None:
            hx, hy = masks[e]
        else:
            hx = stats.is_heavy(x_attr, rel.column(x_attr))
            hy = stats.is_heavy(y_attr, rel.column(y_attr))
        if len(inter) == 0:
            sel = ~hx & ~hy
            out[(e, rel.scheme)] = Relation.make(rel.scheme, rel.data[sel])
        else:
            (heavy_attr,) = inter
            light_attr = y_attr if heavy_attr == x_attr else x_attr
            heavy_col = rel.column(heavy_attr)
            light_is = ~(hy if light_attr == y_attr else hx)
            sel = (heavy_col == eta.value(heavy_attr)) & light_is
            out[(e, (light_attr,))] = Relation.make(
                (light_attr,), rel.column(light_attr)[sel].reshape(-1, 1)
            )
    return out
