"""Relations, join queries, and a reference (oracle) join evaluator.

Data model (paper Sec. 1.1): a relation is a set of tuples over a 2-attribute scheme;
values live in **dom** (encoded as int64 words). A simple binary query is a set of
binary relations with pairwise-distinct schemes.

The oracle ``reference_join`` computes Join(Q) exactly by pairwise hash joins over an
order that prefers connected relations (cartesian products only when the remainder is
disconnected). It is intended for validation on test-sized inputs, not for scale — the
scalable path is the MPC engine itself.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from .hypergraph import Edge, Hypergraph

Attr = str


def _dedup_rows(a: np.ndarray) -> np.ndarray:
    if a.size == 0:
        return a
    return np.unique(a, axis=0)


@dataclass(frozen=True)
class Relation:
    """A binary (or unary) relation with named attributes.

    ``data`` has shape (n, arity); column j holds values of ``scheme[j]``.
    Tuples are sets — constructors dedup rows.

    ``table`` optionally names the *physical* table behind this logical
    relation: self-join-shaped queries (e.g. the subgraph-enumeration
    reduction, where every pattern edge binds a copy of the graph's edge set)
    give all copies the same ``table`` id and the same ``data`` object, and
    backends place the shared tuples once instead of once per copy (the
    shared-input Scatter path — see ``SimulatorExecutor.place_inputs``).
    Statistics and planning still treat each copy as its own relation, as the
    paper's m = Σ_e |R_e| accounting requires.
    """

    scheme: Tuple[Attr, ...]
    data: np.ndarray
    table: Optional[str] = None

    @staticmethod
    def make(
        scheme: Sequence[Attr], data: np.ndarray, table: Optional[str] = None
    ) -> "Relation":
        scheme = tuple(scheme)
        data = np.asarray(data, dtype=np.int64).reshape(-1, len(scheme))
        if len(set(scheme)) != len(scheme):
            raise ValueError(f"duplicate attribute in scheme {scheme}")
        return Relation(scheme=scheme, data=_dedup_rows(data), table=table)

    @property
    def arity(self) -> int:
        return len(self.scheme)

    @property
    def edge(self) -> Edge:
        return frozenset(self.scheme)

    def __len__(self) -> int:
        return int(self.data.shape[0])

    def column(self, attr: Attr) -> np.ndarray:
        return self.data[:, self.scheme.index(attr)]

    def project(self, attrs: Sequence[Attr]) -> "Relation":
        idx = [self.scheme.index(a) for a in attrs]
        return Relation.make(tuple(attrs), self.data[:, idx])

    def rows_as_set(self) -> set:
        return set(map(tuple, self.data.tolist()))


@dataclass(frozen=True)
class JoinQuery:
    """A simple binary join query: relations with pairwise-distinct binary schemes."""

    relations: Tuple[Relation, ...]

    @staticmethod
    def make(relations: Sequence[Relation]) -> "JoinQuery":
        rels = tuple(relations)
        schemes = [r.edge for r in rels]
        if len(set(schemes)) != len(schemes):
            raise ValueError("query is not simple: duplicate schemes")
        for r in rels:
            if r.arity != 2:
                raise ValueError("simple binary query requires binary relations")
        return JoinQuery(relations=rels)

    @property
    def attset(self) -> Tuple[Attr, ...]:
        return tuple(sorted({a for r in self.relations for a in r.scheme}))

    @property
    def m(self) -> int:
        return sum(len(r) for r in self.relations)

    @property
    def hypergraph(self) -> Hypergraph:
        return Hypergraph.from_edges([r.edge for r in self.relations])

    def relation_for(self, e: Edge) -> Relation:
        for r in self.relations:
            if r.edge == frozenset(e):
                return r
        raise KeyError(e)


# ---------------------------------------------------------------------------
# Reference evaluator (oracle)
# ---------------------------------------------------------------------------


def _hash_join(a_scheme: Tuple[Attr, ...], a: np.ndarray, b_rel: Relation):
    """Join intermediate (a_scheme, a) with b_rel; returns (scheme, rows)."""
    common = [x for x in a_scheme if x in b_rel.scheme]
    b_new = [x for x in b_rel.scheme if x not in a_scheme]
    out_scheme = tuple(a_scheme) + tuple(b_new)
    if a.shape[0] == 0 or len(b_rel) == 0:
        return out_scheme, np.zeros((0, len(out_scheme)), dtype=np.int64)

    if not common:  # cartesian product
        na, nb = a.shape[0], len(b_rel)
        left = np.repeat(a, nb, axis=0)
        right = np.tile(b_rel.data, (na, 1))
        return out_scheme, np.concatenate([left, right], axis=1)

    b_key_cols = [b_rel.scheme.index(x) for x in common]
    b_new_cols = [b_rel.scheme.index(x) for x in b_new]
    index: Dict[tuple, List[int]] = {}
    for i, row in enumerate(b_rel.data):
        index.setdefault(tuple(row[b_key_cols].tolist()), []).append(i)

    a_key_cols = [a_scheme.index(x) for x in common]
    out_rows = []
    for row in a:
        key = tuple(row[a_key_cols].tolist())
        for i in index.get(key, ()):
            if b_new_cols:
                out_rows.append(np.concatenate([row, b_rel.data[i, b_new_cols]]))
            else:
                out_rows.append(row.copy())
    if not out_rows:
        return out_scheme, np.zeros((0, len(out_scheme)), dtype=np.int64)
    return out_scheme, np.stack(out_rows)


def reference_join(query: JoinQuery) -> Relation:
    """Exact Join(Q) over sorted(attset) — the correctness oracle."""
    rels = list(query.relations)
    if not rels:
        raise ValueError("empty query")
    # Greedy connected order: start from the smallest relation, prefer the join
    # sharing the MOST attributes with the current intermediate (a 2-shared
    # join filters instead of fanning out — on a clique pattern it closes
    # triangles instead of growing Σ deg^k star intermediates), cartesian
    # products only when the remainder is disconnected.
    rels.sort(key=len)
    first = rels.pop(0)
    scheme, rows = first.scheme, first.data
    while rels:
        j = max(
            range(len(rels)),
            key=lambda i: len(set(rels[i].scheme) & set(scheme)) * len(rels) - i,
        )
        scheme, rows = _hash_join(scheme, rows, rels.pop(j))
    out_attrs = query.attset
    perm = [scheme.index(a) for a in out_attrs]
    return Relation.make(out_attrs, rows[:, perm] if rows.size else rows.reshape(0, len(perm)))


# ---------------------------------------------------------------------------
# Query/data generators (shared by tests + benchmarks)
# ---------------------------------------------------------------------------


def query_from_pattern(edges: Sequence[Tuple[Attr, Attr]], tables: Dict[Tuple[Attr, Attr], np.ndarray]) -> JoinQuery:
    rels = [Relation.make(e, tables[e]) for e in edges]
    return JoinQuery.make(rels)


def pattern_edges(kind: str, n: int) -> List[Tuple[Attr, Attr]]:
    """Named query families from the paper: cycles, cliques, lines (paths), stars."""
    attrs = [f"X{i}" for i in range(n)]
    if kind == "cycle":
        return [(attrs[i], attrs[(i + 1) % n]) for i in range(n)]
    if kind == "clique":
        return [(attrs[i], attrs[j]) for i in range(n) for j in range(i + 1, n)]
    if kind == "line":
        return [(attrs[i], attrs[i + 1]) for i in range(n - 1)]
    if kind == "star":
        return [(attrs[0], attrs[i]) for i in range(1, n)]
    raise ValueError(kind)


def zipf_relation(
    rng: np.random.Generator,
    scheme: Tuple[Attr, Attr],
    n: int,
    dom_size: int,
    skew: float = 0.0,
) -> Relation:
    """n tuples; each column drawn Zipf(skew) over [0, dom_size) (skew=0 → uniform)."""
    cols = []
    for _ in range(2):
        if skew <= 0.0:
            cols.append(rng.integers(0, dom_size, size=n))
        else:
            ranks = np.arange(1, dom_size + 1, dtype=np.float64)
            probs = ranks ** (-skew)
            probs /= probs.sum()
            cols.append(rng.choice(dom_size, size=n, p=probs))
    return Relation.make(scheme, np.stack(cols, axis=1))


def random_query(
    rng: np.random.Generator,
    kind: str,
    n_attrs: int,
    tuples_per_rel: int,
    dom_size: int,
    skew: float = 0.0,
) -> JoinQuery:
    edges = pattern_edges(kind, n_attrs)
    rels = [zipf_relation(rng, e, tuples_per_rel, dom_size, skew) for e in edges]
    return JoinQuery.make(rels)


def hub_triangle_query(
    n: int,
    hub_n: int,
    dom_size: int,
    hub: int = 999,
    seed: int = 1,
) -> JoinQuery:
    """Triangle with one planted heavy value (``hub``) on X0 only: ``hub_n``
    tuples with distinct partners on each X0-edge (so dedup keeps them all)
    plus ``n`` uniform tuples per relation.  With λ chosen so that
    hub_n ≥ ⌈m/λ⌉ > per-value uniform counts, the taxonomy yields exactly the
    H=∅ stage (a cyclic light join) and an H={X0} stage (cross-edge
    semi-joins, no isolated attributes) — the canonical light-subquery
    exercise shared by tests and benchmarks."""
    rng = np.random.default_rng(seed)
    planted = np.stack([np.full(hub_n, hub), np.arange(hub_n)], axis=1)
    r01 = np.concatenate([planted, rng.integers(0, dom_size, (n, 2))])
    r02 = np.concatenate([planted, rng.integers(0, dom_size, (n, 2))])
    r12 = rng.integers(0, dom_size, size=(n, 2))
    return JoinQuery.make(
        [
            Relation.make(("X0", "X1"), r01),
            Relation.make(("X0", "X2"), r02),
            Relation.make(("X1", "X2"), r12),
        ]
    )


def hub_star_query(
    n: int,
    hub_n: int,
    dom_size: int,
    hub: int = 777,
    seed: int = 2,
    leaves: Sequence[Attr] = ("X1", "X2", "X3"),
) -> JoinQuery:
    """Star with a planted heavy hub on the center X0: ``hub_n`` tuples with
    distinct partners per leaf edge plus ``n`` uniform tuples.  With λ chosen
    so the hub is heavy, the H={X0} stage has *every* leaf isolated and no
    surviving light edges — the pure Lemma 3.1 CP-grid exercise shared by the
    parity tests, the multi-device checks, and the backend benchmark."""
    rng = np.random.default_rng(seed)
    rels = []
    for leaf in leaves:
        planted = np.stack([np.full(hub_n, hub), np.arange(hub_n) + 100], axis=1)
        noise = rng.integers(0, dom_size, size=(n, 2))
        rels.append(Relation.make(("X0", leaf), np.concatenate([planted, noise])))
    return JoinQuery.make(rels)


def disconnected_query(
    n: int, dom_size: int, skew: float = 0.0, seed: int = 11
) -> JoinQuery:
    """Two components (A,B) ⋈ (C,D): the H=∅ light subquery is disconnected
    (an in-cell cartesian across HyperCube components); with skew > 0 heavy
    values add stages mixing an isolated attribute with a light component."""
    rng = np.random.default_rng(seed)
    return JoinQuery.make(
        [
            zipf_relation(rng, ("A", "B"), n, dom_size, skew),
            zipf_relation(rng, ("C", "D"), n, dom_size, skew),
        ]
    )
