"""Relations, join queries, and a reference (oracle) join evaluator.

Data model (paper Sec. 1.1): a relation is a set of tuples over a named scheme;
values live in **dom** (encoded as int64 words). A simple query is a set of
relations with pairwise-distinct schemes.  The paper's own algorithm is binary
(2-attribute schemes); arbitrary-arity relations are accepted and route through
the general compiler (GYO join trees for acyclic queries, generalized HyperCube
shares for cyclic ones — see ``repro.core.jointree`` / ``repro.mpc.program``).

The oracle ``reference_join`` computes Join(Q) exactly by pairwise hash joins over an
order that prefers connected relations (cartesian products only when the remainder is
disconnected). It is intended for validation on test-sized inputs, not for scale — the
scalable path is the MPC engine itself.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from .hypergraph import Edge, Hypergraph

Attr = str


def _dedup_rows(a: np.ndarray) -> np.ndarray:
    if a.size == 0:
        return a
    return np.unique(a, axis=0)


@dataclass(frozen=True)
class Relation:
    """A binary (or unary) relation with named attributes.

    ``data`` has shape (n, arity); column j holds values of ``scheme[j]``.
    Tuples are sets — constructors dedup rows.

    ``table`` optionally names the *physical* table behind this logical
    relation: self-join-shaped queries (e.g. the subgraph-enumeration
    reduction, where every pattern edge binds a copy of the graph's edge set)
    give all copies the same ``table`` id and the same ``data`` object, and
    backends place the shared tuples once instead of once per copy (the
    shared-input Scatter path — see ``SimulatorExecutor.place_inputs``).
    Statistics and planning still treat each copy as its own relation, as the
    paper's m = Σ_e |R_e| accounting requires.
    """

    scheme: Tuple[Attr, ...]
    data: np.ndarray
    table: Optional[str] = None

    @staticmethod
    def make(
        scheme: Sequence[Attr], data: np.ndarray, table: Optional[str] = None
    ) -> "Relation":
        scheme = tuple(scheme)
        data = np.asarray(data, dtype=np.int64).reshape(-1, len(scheme))
        if len(set(scheme)) != len(scheme):
            raise ValueError(f"duplicate attribute in scheme {scheme}")
        return Relation(scheme=scheme, data=_dedup_rows(data), table=table)

    @property
    def arity(self) -> int:
        return len(self.scheme)

    @property
    def edge(self) -> Edge:
        return frozenset(self.scheme)

    def __len__(self) -> int:
        return int(self.data.shape[0])

    def column(self, attr: Attr) -> np.ndarray:
        return self.data[:, self.scheme.index(attr)]

    def project(self, attrs: Sequence[Attr]) -> "Relation":
        idx = [self.scheme.index(a) for a in attrs]
        return Relation.make(tuple(attrs), self.data[:, idx])

    def rows_as_set(self) -> set:
        return set(map(tuple, self.data.tolist()))


@dataclass(frozen=True)
class JoinQuery:
    """A simple join query: relations with pairwise-distinct schemes.

    ``force_general`` routes a binary query through the general (join-tree /
    HyperCube-shares) compiler instead of the paper's Theorem 6.2 pipeline —
    used to express e.g. a triangle as a generic 3-ary-capable plan.  Queries
    containing any non-binary relation always take the general route.
    """

    relations: Tuple[Relation, ...]
    force_general: bool = False

    @staticmethod
    def make(
        relations: Sequence[Relation], force_general: bool = False
    ) -> "JoinQuery":
        rels = tuple(relations)
        schemes = [r.edge for r in rels]
        if len(set(schemes)) != len(schemes):
            raise ValueError("query is not simple: duplicate schemes")
        for r in rels:
            if r.arity < 1:
                raise ValueError("relations need at least one attribute")
        return JoinQuery(relations=rels, force_general=force_general)

    @property
    def is_general(self) -> bool:
        """True when this query must take the general (non-Theorem-6.2) route."""
        return self.force_general or any(r.arity != 2 for r in self.relations)

    @property
    def attset(self) -> Tuple[Attr, ...]:
        return tuple(sorted({a for r in self.relations for a in r.scheme}))

    @property
    def m(self) -> int:
        return sum(len(r) for r in self.relations)

    @property
    def hypergraph(self) -> Hypergraph:
        return Hypergraph.from_edges([r.edge for r in self.relations])

    def relation_for(self, e: Edge) -> Relation:
        for r in self.relations:
            if r.edge == frozenset(e):
                return r
        raise KeyError(e)


# ---------------------------------------------------------------------------
# Reference evaluator (oracle)
# ---------------------------------------------------------------------------


def _hash_join(a_scheme: Tuple[Attr, ...], a: np.ndarray, b_rel: Relation):
    """Join intermediate (a_scheme, a) with b_rel; returns (scheme, rows)."""
    common = [x for x in a_scheme if x in b_rel.scheme]
    b_new = [x for x in b_rel.scheme if x not in a_scheme]
    out_scheme = tuple(a_scheme) + tuple(b_new)
    if a.shape[0] == 0 or len(b_rel) == 0:
        return out_scheme, np.zeros((0, len(out_scheme)), dtype=np.int64)

    if not common:  # cartesian product
        na, nb = a.shape[0], len(b_rel)
        left = np.repeat(a, nb, axis=0)
        right = np.tile(b_rel.data, (na, 1))
        return out_scheme, np.concatenate([left, right], axis=1)

    b_key_cols = [b_rel.scheme.index(x) for x in common]
    b_new_cols = [b_rel.scheme.index(x) for x in b_new]
    index: Dict[tuple, List[int]] = {}
    for i, row in enumerate(b_rel.data):
        index.setdefault(tuple(row[b_key_cols].tolist()), []).append(i)

    a_key_cols = [a_scheme.index(x) for x in common]
    out_rows = []
    for row in a:
        key = tuple(row[a_key_cols].tolist())
        for i in index.get(key, ()):
            if b_new_cols:
                out_rows.append(np.concatenate([row, b_rel.data[i, b_new_cols]]))
            else:
                out_rows.append(row.copy())
    if not out_rows:
        return out_scheme, np.zeros((0, len(out_scheme)), dtype=np.int64)
    return out_scheme, np.stack(out_rows)


def reference_join(query: JoinQuery) -> Relation:
    """Exact Join(Q) over sorted(attset) — the correctness oracle."""
    rels = list(query.relations)
    if not rels:
        raise ValueError("empty query")
    # Greedy connected order: start from the smallest relation, prefer the join
    # sharing the MOST attributes with the current intermediate (a multi-shared
    # join filters instead of fanning out — on a clique pattern it closes
    # triangles instead of growing Σ deg^k star intermediates), cartesian
    # products only when the remainder is disconnected.  Ranked over the full
    # k-ary schemes: shared-attribute count first (any arity, not capped at 2),
    # then fewest NEW attributes (bounds the intermediate width growth), then
    # input order for determinism.
    rels.sort(key=len)
    first = rels.pop(0)
    scheme, rows = first.scheme, first.data
    while rels:
        cur = set(scheme)
        j = max(
            range(len(rels)),
            key=lambda i: (
                len(set(rels[i].scheme) & cur),
                -len(set(rels[i].scheme) - cur),
                -i,
            ),
        )
        scheme, rows = _hash_join(scheme, rows, rels.pop(j))
    out_attrs = query.attset
    perm = [scheme.index(a) for a in out_attrs]
    return Relation.make(out_attrs, rows[:, perm] if rows.size else rows.reshape(0, len(perm)))


# ---------------------------------------------------------------------------
# Query/data generators (shared by tests + benchmarks)
# ---------------------------------------------------------------------------


def query_from_pattern(edges: Sequence[Tuple[Attr, Attr]], tables: Dict[Tuple[Attr, Attr], np.ndarray]) -> JoinQuery:
    rels = [Relation.make(e, tables[e]) for e in edges]
    return JoinQuery.make(rels)


def pattern_edges(kind: str, n: int) -> List[Tuple[Attr, Attr]]:
    """Named query families from the paper: cycles, cliques, lines (paths), stars."""
    attrs = [f"X{i}" for i in range(n)]
    if kind == "cycle":
        return [(attrs[i], attrs[(i + 1) % n]) for i in range(n)]
    if kind == "clique":
        return [(attrs[i], attrs[j]) for i in range(n) for j in range(i + 1, n)]
    if kind == "line":
        return [(attrs[i], attrs[i + 1]) for i in range(n - 1)]
    if kind == "star":
        return [(attrs[0], attrs[i]) for i in range(1, n)]
    raise ValueError(kind)


def zipf_relation(
    rng: np.random.Generator,
    scheme: Tuple[Attr, ...],
    n: int,
    dom_size: int,
    skew: float = 0.0,
) -> Relation:
    """n tuples; each column drawn Zipf(skew) over [0, dom_size) (skew=0 → uniform).
    Arity follows ``scheme`` (one sampled column per attribute)."""
    cols = []
    for _ in range(len(scheme)):
        if skew <= 0.0:
            cols.append(rng.integers(0, dom_size, size=n))
        else:
            ranks = np.arange(1, dom_size + 1, dtype=np.float64)
            probs = ranks ** (-skew)
            probs /= probs.sum()
            cols.append(rng.choice(dom_size, size=n, p=probs))
    return Relation.make(scheme, np.stack(cols, axis=1))


def random_query(
    rng: np.random.Generator,
    kind: str,
    n_attrs: int,
    tuples_per_rel: int,
    dom_size: int,
    skew: float = 0.0,
) -> JoinQuery:
    edges = pattern_edges(kind, n_attrs)
    rels = [zipf_relation(rng, e, tuples_per_rel, dom_size, skew) for e in edges]
    return JoinQuery.make(rels)


def hub_triangle_query(
    n: int,
    hub_n: int,
    dom_size: int,
    hub: int = 999,
    seed: int = 1,
) -> JoinQuery:
    """Triangle with one planted heavy value (``hub``) on X0 only: ``hub_n``
    tuples with distinct partners on each X0-edge (so dedup keeps them all)
    plus ``n`` uniform tuples per relation.  With λ chosen so that
    hub_n ≥ ⌈m/λ⌉ > per-value uniform counts, the taxonomy yields exactly the
    H=∅ stage (a cyclic light join) and an H={X0} stage (cross-edge
    semi-joins, no isolated attributes) — the canonical light-subquery
    exercise shared by tests and benchmarks."""
    rng = np.random.default_rng(seed)
    planted = np.stack([np.full(hub_n, hub), np.arange(hub_n)], axis=1)
    r01 = np.concatenate([planted, rng.integers(0, dom_size, (n, 2))])
    r02 = np.concatenate([planted, rng.integers(0, dom_size, (n, 2))])
    r12 = rng.integers(0, dom_size, size=(n, 2))
    return JoinQuery.make(
        [
            Relation.make(("X0", "X1"), r01),
            Relation.make(("X0", "X2"), r02),
            Relation.make(("X1", "X2"), r12),
        ]
    )


def hub_star_query(
    n: int,
    hub_n: int,
    dom_size: int,
    hub: int = 777,
    seed: int = 2,
    leaves: Sequence[Attr] = ("X1", "X2", "X3"),
) -> JoinQuery:
    """Star with a planted heavy hub on the center X0: ``hub_n`` tuples with
    distinct partners per leaf edge plus ``n`` uniform tuples.  With λ chosen
    so the hub is heavy, the H={X0} stage has *every* leaf isolated and no
    surviving light edges — the pure Lemma 3.1 CP-grid exercise shared by the
    parity tests, the multi-device checks, and the backend benchmark."""
    rng = np.random.default_rng(seed)
    rels = []
    for leaf in leaves:
        planted = np.stack([np.full(hub_n, hub), np.arange(hub_n) + 100], axis=1)
        noise = rng.integers(0, dom_size, size=(n, 2))
        rels.append(Relation.make(("X0", leaf), np.concatenate([planted, noise])))
    return JoinQuery.make(rels)


def general_pattern_schemes(kind: str) -> List[Tuple[Attr, ...]]:
    """Named arbitrary-arity query families (the general-join workloads).

    * ``star3``     — a 3-ary fact F(A,B,C) with one binary dimension per key:
                      the smallest k≥3 acyclic shape (TPC-H-ish star).
    * ``snowflake`` — star3 with one dimension normalized a level deeper.
    * ``path4``     — four relations chained in a path, mixing arities 2 and 3.
    * ``triangle``  — the binary triangle (cyclic; pair with force_general to
                      exercise the generalized HyperCube-shares route).
    """
    if kind == "star3":
        return [("A", "B", "C"), ("A", "A1"), ("B", "B1"), ("C", "C1")]
    if kind == "snowflake":
        return [("A", "B", "C"), ("A", "A1"), ("A1", "A2"), ("B", "B1"), ("C", "C1")]
    if kind == "path4":
        return [("X0", "X1"), ("X1", "X2", "X3"), ("X3", "X4"), ("X4", "X5", "X6")]
    if kind == "triangle":
        return [("X0", "X1"), ("X0", "X2"), ("X1", "X2")]
    raise ValueError(kind)


def general_query(
    kind: str,
    n: int,
    dom_size: int,
    skew: float = 0.0,
    seed: int = 7,
    force_general: bool = True,
) -> JoinQuery:
    """Instantiate a `general_pattern_schemes` family with zipf/uniform data."""
    rng = np.random.default_rng(seed)
    rels = [
        zipf_relation(rng, s, n, dom_size, skew)
        for s in general_pattern_schemes(kind)
    ]
    return JoinQuery.make(rels, force_general=force_general)


def random_general_query(
    rng: np.random.Generator,
    n_rels: int = 3,
    max_arity: int = 4,
    n_attrs: int = 5,
    tuples_per_rel: int = 24,
    dom_size: int = 8,
    skew: float = 0.0,
    share_tables: bool = False,
    allow_empty: bool = True,
) -> JoinQuery:
    """Random k-ary query for the differential harness: arities in [1, max_arity],
    pairwise-distinct schemes over ``n_attrs`` attributes (acyclic and cyclic
    shapes both arise), optional shared physical tables between same-scheme-size
    relations, and occasional empty/singleton relations."""
    attrs = [f"X{i}" for i in range(n_attrs)]
    schemes: List[Tuple[Attr, ...]] = []
    seen = set()
    guard = 0
    while len(schemes) < n_rels and guard < 200:
        guard += 1
        arity = int(rng.integers(1, max_arity + 1))
        arity = min(arity, n_attrs)
        s = tuple(sorted(rng.choice(n_attrs, size=arity, replace=False).tolist()))
        if s in seen:
            continue
        seen.add(s)
        schemes.append(tuple(attrs[i] for i in s))
    rels = []
    shared: Dict[int, Relation] = {}
    for s in schemes:
        if allow_empty and rng.random() < 0.08:
            n = 0
        elif rng.random() < 0.08:
            n = 1
        else:
            n = int(rng.integers(1, tuples_per_rel + 1))
        if share_tables and len(s) in shared and rng.random() < 0.5:
            src = shared[len(s)]
            rels.append(Relation.make(s, src.data, table=src.table))
            continue
        r = zipf_relation(rng, s, n, dom_size, skew)
        if share_tables:
            # name by relation index — unique even when several same-arity
            # relations are generated independently (only the first of each
            # arity is kept as the reusable shared table)
            r = Relation.make(s, r.data, table=f"t{len(s)}_{len(rels)}")
            shared.setdefault(len(s), r)
        rels.append(r)
    return JoinQuery.make(rels)


def disconnected_query(
    n: int, dom_size: int, skew: float = 0.0, seed: int = 11
) -> JoinQuery:
    """Two components (A,B) ⋈ (C,D): the H=∅ light subquery is disconnected
    (an in-cell cartesian across HyperCube components); with skew > 0 heavy
    values add stages mixing an isolated attribute with a light component."""
    rng = np.random.default_rng(seed)
    return JoinQuery.make(
        [
            zipf_relation(rng, ("A", "B"), n, dom_size, skew),
            zipf_relation(rng, ("C", "D"), n, dom_size, skew),
        ]
    )
