"""Semi-join reduction Q'(η) → Q''(η) (paper Sec. 5.2) — planner-side oracle.

Two steps (quoting the paper):
  1. For every border attribute X: R''_X(η) = ∩ over cross edges e ∋ X of R'_e(η).
  2. For every light edge e = {X, Y}: R''_e(η) keeps tuples whose X-value is in
     R''_X(η) (if X is border) and Y-value is in R''_Y(η) (if Y is border).

The distributed implementation is in repro.mpc.engine (hash-partitioned, load-metered);
this module is the small-data oracle used for validation and for the ICP benchmarks.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import reduce
from typing import Dict, Optional, Tuple

import numpy as np

from .hypergraph import Edge
from .query import Attr, JoinQuery, Relation
from .taxonomy import Configuration, HPlan, HeavyStats, residual_relations


@dataclass(frozen=True)
class ReducedQuery:
    """Q''(η) = Q''_isolated ∪ Q''_light, plus the R''_X for border attrs (5.4)-(5.7)."""

    eta: Configuration
    unary: Dict[Attr, np.ndarray]          # R''_X(η) for every border attribute X
    light_rels: Dict[Edge, Relation]       # R''_e(η) for light edges e
    isolated: Tuple[Attr, ...]             # I

    def isolated_sizes(self) -> Dict[Attr, int]:
        return {a: int(self.unary[a].size) for a in self.isolated}

    def isolated_cp_size(self) -> int:
        out = 1
        for a in self.isolated:
            out *= int(self.unary[a].size)
        return out if self.isolated else 1


def _intersect_sorted(arrays) -> np.ndarray:
    arrays = list(arrays)
    if not arrays:
        return np.zeros(0, dtype=np.int64)
    return reduce(lambda a, b: np.intersect1d(a, b, assume_unique=False), arrays)


def semijoin_reduce(
    query: JoinQuery,
    stats: HeavyStats,
    plan: HPlan,
    eta: Configuration,
) -> Optional[ReducedQuery]:
    """Oracle semi-join reduction. Returns None if η is ruled out by an inactive edge
    (missing heavy-heavy pair) — Q'(η) is then empty."""
    residuals = residual_relations(query, stats, plan, eta)
    if residuals is None:
        return None

    # Step 1: unary intersections per border attribute.
    unary: Dict[Attr, np.ndarray] = {}
    for x in plan.border:
        lists = [
            rel.data[:, 0]
            for (e, scheme), rel in residuals.items()
            if scheme == (x,)
        ]
        unary[x] = _intersect_sorted(lists)

    # Step 2: shrink light edges by border-attribute membership.
    light_rels: Dict[Edge, Relation] = {}
    for e in plan.light_edges:
        rel = residuals[(e, next(s for (ee, s) in residuals if ee == e))]
        sel = np.ones(len(rel), dtype=bool)
        for attr in rel.scheme:
            if attr in unary:
                sel &= np.isin(rel.column(attr), unary[attr])
        light_rels[e] = Relation.make(rel.scheme, rel.data[sel])

    return ReducedQuery(
        eta=eta, unary=unary, light_rels=light_rels, isolated=plan.isolated
    )


def join_reduced(reduced: ReducedQuery, plan: HPlan) -> np.ndarray:
    """Oracle evaluation of Join(Q''(η)) = Join(Q''_isolated) × Join(Q''_light) (5.8).
    Output columns ordered by sorted(L). Used to validate the MPC engine per-config."""
    from .query import JoinQuery as JQ
    from .query import reference_join

    light_attrs = sorted(set(plan.light) - set(plan.isolated))
    if light_attrs:
        sub = JQ.make(tuple(reduced.light_rels[e] for e in plan.light_edges))
        light_join = reference_join(sub)
        light_rows = light_join.data  # columns sorted(light_attrs)
        if light_rows.shape[0] == 0:
            return np.zeros((0, len(plan.light)), dtype=np.int64)
    else:
        light_rows = np.zeros((1, 0), dtype=np.int64)

    rows = light_rows
    cols = list(light_attrs)
    for a in plan.isolated:
        vals = reduced.unary[a]
        if vals.size == 0:
            return np.zeros((0, len(plan.light)), dtype=np.int64)
        n = rows.shape[0]
        rows = np.repeat(rows, vals.size, axis=0)
        tiled = np.tile(vals, n).reshape(-1, 1)
        rows = np.concatenate([rows, tiled], axis=1)
        cols.append(a)
    perm = [cols.index(a) for a in sorted(plan.light)]
    return rows[:, perm] if rows.size else rows.reshape(0, len(plan.light))
