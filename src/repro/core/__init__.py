# The paper's primary contribution — planner-side machinery of the MPC join:
# hypergraph LPs (Sec. 2), heavy/light taxonomy (Sec. 4), semi-join reduction (Sec. 5.2),
# isolated cartesian product accounting (Sec. 5.3-5.5), machine allocation (Sec. 6).
# The execution substrates live in repro.mpc (exact-cost simulator) and repro.dataplane
# (JAX shard_map data plane).
from .hypergraph import (
    Hypergraph,
    fractional_edge_cover,
    fractional_edge_packing,
    quasi_packing_number,
    rho,
    tau,
    zero_one_packing,
)
from .query import JoinQuery, Relation, reference_join, random_query, pattern_edges
from .taxonomy import HeavyStats, compute_stats, configurations, plan_for_h
from .planner import heavy_parameter
