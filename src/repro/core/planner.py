"""Query planner: machine allocation for Theorem 6.2 (paper Sec. 6).

The planner is host-side, O(poly(λ, 2^k)) metadata work (like a query optimizer):

  - heavy parameter       λ = Θ(p^{1/(2ρ)})                       [Sec. 6]
  - Step-1 groups         p'_η  = ⌈p · m_η / (m · λ^{k-2})⌉        [Step 1]
  - Step-3 groups         p''_η = Θ(λ^{|L|} + p·Σ_J |CP_J(η)| / (λ^{2ρ-|J|-|L|} m^{|J|}))
                                                                  [(6.1)]
  - HyperCube share       λ per attribute of L \\ I                [Lemma 6.1]
  - CP grid machines      p''_η / λ^{|L|-|I|}                      [Lemma 6.1]

Virtual machine groups are mapped onto the p physical machines by a deterministic salted
hash (virtual id v of group g → (base(g) + v) mod p). Σ_η p''_η = O(p) (via Lemma 5.5)
keeps physical loads balanced up to constants; the simulator meters the truth.
"""

from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

from .hypergraph import fractional_edge_cover
from .query import Attr, JoinQuery
from .taxonomy import Configuration, HPlan, HeavyStats


def heavy_parameter(p: int, rho_val: Fraction | float, c: float = 1.0) -> int:
    """λ = Θ(p^{1/(2ρ)}), at least 2 so 'heavy' is meaningful."""
    lam = int(max(2, round(c * p ** (1.0 / (2.0 * float(rho_val))))))
    return lam


def _stable_base(p: int, *key) -> int:
    h = hashlib.blake2b(repr(key).encode(), digest_size=8).digest()
    return int.from_bytes(h, "little") % p


@dataclass(frozen=True)
class MachineGroup:
    """A virtual group of `size` machines hashed onto physical ids (mod p)."""

    base: int
    size: int
    p: int

    def phys(self, virtual: int) -> int:
        if not 0 <= virtual < self.size:
            raise IndexError(virtual)
        return (self.base + virtual) % self.p


@dataclass
class ConfigPlan:
    eta: Configuration
    m_eta: int
    step1_group: MachineGroup           # p'_η machines holding Q'(η)
    # step-3 geometry (filled after sizes are known):
    hc_shape: Tuple[int, ...] = ()      # λ per attr of L\I (possibly empty)
    cp_machines: int = 1
    step3_group: Optional[MachineGroup] = None

    @property
    def hc_machines(self) -> int:
        out = 1
        for s in self.hc_shape:
            out *= s
        return out


@dataclass
class HPlanWithAlloc:
    plan: HPlan
    configs: List[ConfigPlan] = field(default_factory=list)


def step1_allocation(
    query: JoinQuery,
    stats: HeavyStats,
    plan: HPlan,
    etas_with_sizes: Sequence[Tuple[Configuration, int]],
    p: int,
) -> List[ConfigPlan]:
    """p'_η = ⌈p · m_η / (m λ^{k-2})⌉, hashed onto physical machines."""
    k = len(query.attset)
    lam = stats.lam
    denom = max(1.0, float(stats.m) * float(lam) ** max(0, k - 2))
    out = []
    for eta, m_eta in etas_with_sizes:
        if m_eta <= 0:
            continue
        size = max(1, math.ceil(p * m_eta / denom))
        size = min(size, p)
        grp = MachineGroup(base=_stable_base(p, "s1", plan.h_set, eta.values), size=size, p=p)
        out.append(ConfigPlan(eta=eta, m_eta=m_eta, step1_group=grp))
    return out


def step3_allocation(
    query: JoinQuery,
    stats: HeavyStats,
    plan: HPlan,
    cfg: ConfigPlan,
    isolated_sizes: Dict[Attr, int],
    p: int,
    rho_val: float,
) -> None:
    """Fill cfg.hc_shape / cp_machines / step3_group per (6.1) + Lemma 6.1 geometry."""
    lam = stats.lam
    l_minus_i = [a for a in plan.light if a not in plan.isolated]
    n_iso = len(plan.isolated)

    # (6.1): p''_η = Θ(λ^{|L|} + p Σ_J |CP_J| / (λ^{2ρ-|J|-|L|} m^{|J|}))
    base_term = float(lam) ** len(plan.light)
    sum_term = 0.0
    sizes = [max(0, isolated_sizes[a]) for a in plan.isolated]
    # Σ over non-empty J ⊆ I of Π_{X∈J}|R''_X| / (λ^{2ρ-|J|-|L|} m^{|J|})
    import itertools as _it

    for jr in range(1, n_iso + 1):
        for combo in _it.combinations(range(n_iso), jr):
            prod = 1.0
            for i in combo:
                prod *= float(sizes[i])
            denom = float(lam) ** (2 * rho_val - jr - len(plan.light)) * float(stats.m) ** jr
            sum_term += prod / max(denom, 1e-30)
    p_eta = max(1, math.ceil(base_term + p * sum_term))

    cfg.hc_shape = tuple(lam for _ in l_minus_i)
    hc = cfg.hc_machines
    cp = max(1, math.ceil(p_eta / max(1, lam ** max(0, len(plan.light) - n_iso))))
    cfg.cp_machines = cp
    total = hc * cp
    cfg.step3_group = MachineGroup(
        base=_stable_base(p, "s3", plan.h_set, cfg.eta.values), size=total, p=p
    )


def grid_dims(sizes: Sequence[int], p_grid: int) -> Tuple[List[int], int, float]:
    """Lemma 3.1 geometry: given |R_1| ≥ ... ≥ |R_t| and p machines, choose t' and the
    grid p_1 × ... × p_{t'}. Returns (dims for the first t' lists, t', L_{t'}).

    Invariant (the Lemma 3.1 machine budget): Π dims ≤ p_grid and every dim ≥ 1,
    unconditionally — the rounding guard only ever decrements dims that are > 1,
    so a dimension can never reach 0 and the worst case is the all-ones grid
    (product 1 ≤ p_grid).  The previous guard decremented the overall max and
    clamped afterwards, which could reinstate Π dims > p_grid after driving a
    dimension to 0."""
    t = len(sizes)
    if p_grid < 1:
        raise ValueError(f"p_grid must be >= 1, got {p_grid}")
    if t == 0 or any(s <= 0 for s in sizes):
        raise ValueError("empty list ⇒ empty CP; caller must skip")
    assert all(sizes[i] >= sizes[i + 1] for i in range(t - 1)), "sizes must be sorted desc"

    def load_i(i: int) -> float:  # L_i = (Π_{j≤i} |R_j| / p)^{1/i}
        prod = 1.0
        for j in range(i):
            prod *= float(sizes[j])
        return (prod / float(p_grid)) ** (1.0 / i)

    t_prime = 1
    for i in range(1, t + 1):
        if all(sizes[j] >= load_i(i) for j in range(i)):
            t_prime = i
    l_t = max(load_i(t_prime), 1.0)
    dims = [max(1, int(sizes[i] // l_t)) for i in range(t_prime)]
    # rounding guard: decrement the largest dim that is still > 1 (identical
    # choice to the old guard while the max exceeds 1, so established grids
    # are unchanged) until the budget holds.
    while math.prod(dims) > p_grid:
        i_dec = max(
            (i for i, d in enumerate(dims) if d > 1), key=lambda i: dims[i], default=None
        )
        if i_dec is None:
            break  # all dims are 1 ⇒ product is 1 ≤ p_grid
        dims[i_dec] -= 1
    return dims, t_prime, l_t


@dataclass
class QueryPlan:
    """Everything Theorem 6.2 needs, for all H ⊆ attset(Q)."""

    p: int
    lam: int
    rho_val: float
    h_plans: Dict[Tuple[Attr, ...], HPlanWithAlloc]
