"""Hypergraphs of join queries + fractional edge covers/packings (paper Sec. 2).

All queries here are *constant-size* (data complexity), so the LPs are tiny and are
solved exactly on the launcher host:

  - ``fractional_edge_cover``   -> (rho, weights)    [min  sum w_e  s.t. vertex weight >= 1]
  - ``fractional_edge_packing`` -> (tau, weights)    [max  sum w_e  s.t. vertex weight <= 1]
  - ``zero_one_packing``        -> Lemma 2.1(2): an optimal packing whose *vertex* weights
    are all 0 or 1, and the zero-weight set Z satisfies rho - tau = |Z|.

For binary graphs the LP polytopes have half-integral vertices whose half-weight support
is a disjoint union of odd cycles; the simplex method therefore returns solutions with
0/1 vertex weights, which we verify (and re-solve with a perturbed objective if a
degenerate non-vertex optimum sneaks through).

Edges of arbitrary arity (the general-join route) are supported: the LP vertices
are then rational but not necessarily half-integral, so the solutions are
recovered as small-denominator fractions (checked for feasibility + optimality)
instead of the binary half-integral rounding.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Dict, FrozenSet, List, Sequence, Set, Tuple

import numpy as np
from scipy.optimize import linprog

Vertex = str
Edge = FrozenSet[Vertex]


def _as_edge(e) -> Edge:
    e = frozenset(e)
    if len(e) < 1:
        raise ValueError("edges need at least one vertex")
    return e


@dataclass(frozen=True)
class Hypergraph:
    """A hypergraph with edges of any arity ≥ 1; every vertex incident to >= 1 edge.

    The paper's Theorem 6.2 machinery only consumes unary/binary graphs
    (``is_binary``); k-ary edges arise from general join queries and feed the
    GYO/join-tree and HyperCube-shares route."""

    vertices: Tuple[Vertex, ...]
    edges: Tuple[Edge, ...]

    @staticmethod
    def from_edges(edges: Sequence) -> "Hypergraph":
        es = tuple(sorted({_as_edge(e) for e in edges}, key=lambda e: sorted(e)))
        vs = tuple(sorted({v for e in es for v in e}))
        return Hypergraph(vertices=vs, edges=es)

    def __post_init__(self):
        covered = {v for e in self.edges for v in e}
        missing = set(self.vertices) - covered
        if missing:
            raise ValueError(f"vertices with no incident edge: {missing}")

    @property
    def is_binary(self) -> bool:
        return all(len(e) == 2 for e in self.edges)

    def incident(self, v: Vertex) -> List[Edge]:
        return [e for e in self.edges if v in e]

    def adjacent(self, v: Vertex) -> Set[Vertex]:
        return {u for e in self.edges for u in e if v in e} - {v}

    def induced(self, subset: Sequence[Vertex]) -> "Hypergraph":
        """Subgraph induced by ``subset`` (paper Sec. 2): edges e∩U, dropping empties."""
        u = set(subset)
        es = {frozenset(e & u) for e in self.edges if e & u}
        vs = tuple(sorted(v for v in self.vertices if v in u))
        return Hypergraph(vertices=vs, edges=tuple(sorted(es, key=lambda e: sorted(e))))

    def remove_vertices(self, removed: Sequence[Vertex]) -> "Hypergraph":
        """G_\\U of the quasi-packing definition: strip U from every edge."""
        u = set(removed)
        es = {frozenset(e - u) for e in self.edges if e - u}
        vs = tuple(sorted({v for e in es for v in e}))
        return Hypergraph(vertices=vs, edges=tuple(sorted(es, key=lambda e: sorted(e))))


# ---------------------------------------------------------------------------
# LP solvers
# ---------------------------------------------------------------------------


def _vertex_weights(g: Hypergraph, w: Dict[Edge, Fraction]) -> Dict[Vertex, Fraction]:
    out = {v: Fraction(0) for v in g.vertices}
    for e, we in w.items():
        for v in e:
            out[v] += we
    return out


def _round_half(x: float) -> Fraction:
    return Fraction(round(x * 2), 2)


_GENERAL_DENOMS = (1, 2, 3, 4, 5, 6, 8, 12, 24, 60, 120)


def _recover_rational(g: Hypergraph, edges, x, obj: float, cover: bool):
    """Round a float LP solution to exact Fractions, checked for feasibility and
    optimality.  Binary graphs have half-integral vertices (the Lemma 2.1 fact
    the taxonomy relies on); general (k-ary-edge) graphs get a small-denominator
    search — basic solutions of constant-size LPs have small rational entries."""
    denoms = (2,) if g.is_binary else _GENERAL_DENOMS
    for d in denoms:
        w = {e: Fraction(round(v * d), d) for e, v in zip(edges, x)}
        total = sum(w.values())
        if abs(float(total) - obj) > 1e-6:
            continue
        vw = _vertex_weights(g, w)
        if all((vw[v] >= 1 if cover else vw[v] <= 1) for v in g.vertices):
            return total, w
    return None


def _solve_lp(g: Hypergraph, *, cover: bool, rng_seed: int = 0):
    """Shared LP: cover (minimize, >=1) or packing (maximize, <=1). Returns Fractions."""
    edges = list(g.edges)
    nv, ne = len(g.vertices), len(edges)
    vidx = {v: i for i, v in enumerate(g.vertices)}
    A = np.zeros((nv, ne))
    for j, e in enumerate(edges):
        for v in e:
            A[vidx[v], j] = 1.0
    # linprog minimizes c @ x with A_ub x <= b_ub.
    for attempt in range(3):
        c = np.ones(ne)
        if attempt > 0:  # nudge the objective to force a unique vertex optimum
            rng = np.random.default_rng(rng_seed + attempt)
            c = c + rng.uniform(0, 1e-7, size=ne)
        if cover:
            res = linprog(c, A_ub=-A, b_ub=-np.ones(nv), bounds=(0, 1), method="highs-ds")
        else:
            res = linprog(-c, A_ub=A, b_ub=np.ones(nv), bounds=(0, 1), method="highs-ds")
        if not res.success:
            raise RuntimeError(f"LP failed on {g}: {res.message}")
        obj = float(sum(res.x))
        recovered = _recover_rational(g, edges, res.x, obj, cover)
        if recovered is not None:
            return recovered
    raise RuntimeError(f"could not recover a rational LP optimum for {g}")


def fractional_edge_cover(g: Hypergraph) -> Tuple[Fraction, Dict[Edge, Fraction]]:
    """rho(G) and an optimal half-integral fractional edge cover."""
    return _solve_lp(g, cover=True)


def fractional_edge_packing(g: Hypergraph) -> Tuple[Fraction, Dict[Edge, Fraction]]:
    """tau(G) and an optimal half-integral fractional edge packing."""
    return _solve_lp(g, cover=False)


def rho(g) -> Fraction:
    """ρ: the fractional edge cover number (exact, as a Fraction).

    Accepts either a :class:`Hypergraph` or any object exposing a
    ``.hypergraph`` attribute (a :class:`repro.core.query.JoinQuery`,
    duck-typed to avoid a circular import) — so ρ call sites stop
    hand-building ``fractional_edge_cover(query.hypergraph)[0]``."""
    if not isinstance(g, Hypergraph):
        hg = getattr(g, "hypergraph", None)
        if not isinstance(hg, Hypergraph):
            raise TypeError(
                f"rho() wants a Hypergraph or an object with a .hypergraph "
                f"attribute, got {type(g).__name__}"
            )
        g = hg
    return fractional_edge_cover(g)[0]


def tau(g: Hypergraph) -> Fraction:
    return fractional_edge_packing(g)[0]


def zero_one_packing(
    g: Hypergraph,
) -> Tuple[Fraction, Dict[Edge, Fraction], Set[Vertex]]:
    """Lemma 2.1 bullet 2: an optimal fractional edge packing W whose vertex weights are
    all 0/1; returns (tau, W, Z) with Z = zero-weight vertices and rho - tau = |Z|.

    Simplex returns a vertex of the fractional matching polytope; for (multi)graphs those
    are half-integral with half-edges forming vertex-disjoint odd cycles, hence vertex
    weights 0/1. We assert this (with perturbation retries inside _solve_lp).
    """
    for seed in range(5):
        t, w = _solve_lp(g, cover=False, rng_seed=seed * 17)
        vw = _vertex_weights(g, w)
        if all(x in (Fraction(0), Fraction(1)) for x in vw.values()):
            z = {v for v, x in vw.items() if x == 0}
            return t, w, z
    raise RuntimeError(f"no 0/1-vertex-weight optimal packing found for {g}")


def quasi_packing_number(g: Hypergraph) -> Fraction:
    """psi(G) = max over U ⊆ V of tau(G_\\U) (paper Sec. 2). Exponential in |V| — fine,
    queries are constant-size. Used only for analysis/benchmarks."""
    best = Fraction(0)
    for r in range(len(g.vertices) + 1):
        for u in itertools.combinations(g.vertices, r):
            sub = g.remove_vertices(u)
            if not sub.edges:
                continue
            best = max(best, tau(sub))
    return best


def agm_bound(g: Hypergraph, sizes: Dict[Edge, int], w: Dict[Edge, Fraction]) -> float:
    """AGM bound (Lemma 2.2): prod_e |R_e|^{W(e)} for a fractional edge cover W."""
    out = 1.0
    for e, we in w.items():
        if we > 0:
            out *= float(sizes[e]) ** float(we)
    return out
