"""GYO reduction, acyclicity detection, and join trees for general queries.

An (α-)acyclic hypergraph is one that GYO-reduces to a single edge: repeatedly
remove an *ear* — an edge e whose vertices are each either exclusive to e or
all contained in a single witness edge f — until one edge remains.  Recording
(ear, witness) pairs during the reduction yields a **join tree**: nodes are the
query's relations and every tree edge (child, parent) is labeled with
scheme(child) ∩ scheme(parent).  The classic result (Beeri–Fagin–Maier–
Yannakakis) gives the *running intersection property*: for any two nodes, the
attributes they share appear on every node along the unique tree path between
them — exactly the invariant that makes Yannakakis' two semijoin sweeps a full
reducer.  GYO is confluent: greedy ear removal in any order succeeds iff the
hypergraph is acyclic (tests/test_jointree.py brute-forces all removal orders
to confirm).

Disconnected acyclic queries reduce component-by-component; the components are
stitched into one tree with empty-label edges (a semijoin over ∅ shared
attributes degenerates to "keep the parent iff the child is non-empty", which
is exactly the cartesian-product semantics the executor implements).

The join tree drives the general compiler in ``repro.mpc.program``
(Yannakakis semijoin sweeps + tree-ordered bottom-up join) and is re-checked
structurally by the ``join-tree`` rule in ``repro.mpc.verify``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

Attrs = FrozenSet[str]


@dataclass(frozen=True)
class JoinTree:
    """A rooted join tree over relation indices 0..n-1.

    ``edges`` lists (child, parent, shared_attrs) in **GYO removal order** —
    children always appear before any edge that removes their parent, so the
    sequence read forward is a valid leaves-to-root semijoin sweep (the "up"
    sweep) and read backward a valid root-to-leaves sweep (the "down" sweep).
    ``root`` is the single surviving node.  A query with one relation has no
    edges.
    """

    n_nodes: int
    root: int
    edges: Tuple[Tuple[int, int, Attrs], ...]

    @property
    def parent(self) -> Dict[int, int]:
        return {c: p for c, p, _ in self.edges}

    def path(self, a: int, b: int) -> List[int]:
        """Nodes on the unique tree path from a to b (inclusive)."""
        up: Dict[int, Optional[int]] = self.parent  # type: ignore[assignment]
        anc_a = [a]
        while anc_a[-1] in up:
            anc_a.append(up[anc_a[-1]])
        anc_b = [b]
        while anc_b[-1] in up:
            anc_b.append(up[anc_b[-1]])
        seen = set(anc_a)
        meet = next(x for x in anc_b if x in seen)
        pa = anc_a[: anc_a.index(meet) + 1]
        pb = anc_b[: anc_b.index(meet)]
        return pa + pb[::-1]


def _find_ear(
    alive: List[int], schemes: Sequence[Attrs]
) -> Optional[Tuple[int, int]]:
    """One GYO step over the still-alive edges: return (ear, witness) or None.

    A vertex is *exclusive* to e if no other alive edge contains it; e is an
    ear with witness f when every non-exclusive vertex of e lies in f.  An edge
    whose vertices are all exclusive (an isolated component remnant) takes any
    other alive edge as witness with an empty shared label.  Deterministic:
    lowest ear index first, then lowest witness index."""
    for i in alive:
        others = [j for j in alive if j != i]
        if not others:
            return None
        shared = {
            v for v in schemes[i]
            if any(v in schemes[j] for j in others)
        }
        if not shared:
            return i, others[0]
        for j in others:
            if shared <= schemes[j]:
                return i, j
    return None


def gyo_reduction(
    schemes: Sequence[Attrs],
) -> Optional[List[Tuple[int, int, Attrs]]]:
    """Run GYO to completion.  Returns the (ear, witness, shared) removal
    sequence when the hypergraph is acyclic, None when it is cyclic.
    ``shared`` is scheme(ear) ∩ scheme(witness) *at removal time's original
    schemes* — the semijoin attributes of the corresponding join-tree edge."""
    schemes = [frozenset(s) for s in schemes]
    alive = list(range(len(schemes)))
    out: List[Tuple[int, int, Attrs]] = []
    while len(alive) > 1:
        step = _find_ear(alive, schemes)
        if step is None:
            return None
        ear, witness = step
        # ear ∩ witness equals the ear's non-exclusive vertex set (the ear
        # condition puts every shared vertex inside the witness), so the label
        # is exactly the semijoin attribute set of this tree edge.
        out.append((ear, witness, frozenset(schemes[ear] & schemes[witness])))
        alive.remove(ear)
    return out


def is_acyclic(schemes: Sequence[Attrs]) -> bool:
    return gyo_reduction(schemes) is not None


def build_join_tree(schemes: Sequence[Attrs]) -> Optional[JoinTree]:
    """GYO-derived join tree over relation indices, or None when cyclic."""
    seq = gyo_reduction(schemes)
    if seq is None:
        return None
    n = len(schemes)
    if n == 1:
        return JoinTree(n_nodes=1, root=0, edges=())
    removed = {ear for ear, _, _ in seq}
    root = next(i for i in range(n) if i not in removed)
    return JoinTree(n_nodes=n, root=root, edges=tuple(seq))


def running_intersection_ok(
    schemes: Sequence[Attrs], tree: JoinTree
) -> bool:
    """Direct check of the running intersection property: for every node pair
    (a, b), scheme(a) ∩ scheme(b) ⊆ scheme(x) for every x on path(a, b).
    Also validates the tree's structural integrity (labels match the schemes,
    every non-root node has exactly one parent edge, no cycles)."""
    schemes = [frozenset(s) for s in schemes]
    n = tree.n_nodes
    if n != len(schemes) or not (0 <= tree.root < n):
        return False
    parent = {}
    for c, p, shared in tree.edges:
        if c in parent or c == tree.root or not (0 <= c < n and 0 <= p < n):
            return False
        parent[c] = p
        if not frozenset(shared) <= (schemes[c] & schemes[p]):
            return False
    if set(parent) != set(range(n)) - {tree.root}:
        return False
    # acyclicity of the parent pointers (root reachable from everywhere)
    for c in parent:
        seen = {c}
        while c in parent:
            c = parent[c]
            if c in seen:
                return False
            seen.add(c)
    for a in range(n):
        for b in range(a + 1, n):
            common = schemes[a] & schemes[b]
            if not common:
                continue
            for x in tree.path(a, b):
                if not common <= schemes[x]:
                    return False
    return True


def brute_force_acyclic(schemes: Sequence[Attrs]) -> bool:
    """Reference acyclicity: does ANY ear-removal order reduce to one edge?
    Exponential — test-only (GYO's greedy confluence is what it validates)."""
    schemes = [frozenset(s) for s in schemes]

    def ears(alive: Tuple[int, ...]) -> List[int]:
        out = []
        for i in alive:
            others = [j for j in alive if j != i]
            shared = {v for v in schemes[i] if any(v in schemes[j] for j in others)}
            if not shared or any(shared <= schemes[j] for j in others):
                out.append(i)
        return out

    def solve(alive: Tuple[int, ...]) -> bool:
        if len(alive) <= 1:
            return True
        return any(
            solve(tuple(j for j in alive if j != i)) for i in ears(alive)
        )

    return solve(tuple(range(len(schemes))))
