"""Isolated cartesian product theorem accounting (paper Sec. 5.3-5.5).

These functions compute both sides of:

  Theorem 5.1 :  Σ_η |Join(Q''_isolated(η))| ≤ λ^{|H| - W_I} · m^{|I|}
  Theorem 5.4 :  Σ_η |Join(Q''_J(η))|        ≤ λ^{|H| - W_J} · m^{|J|}   (J ⊆ I)
  Lemma   5.5 :  Σ_η |Join(Q''_J(η))|        ≤ λ^{2ρ - |J| - |L|} · m^{|J|}

used by benchmarks (empirical verification of the paper's central theorem) and by the
engine's machine-allocation sanity checks. The left-hand sides are exact sums over all
configurations; the right-hand sides come from the LP machinery in hypergraph.py.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from fractions import Fraction
from typing import Dict, Iterable, Sequence, Tuple

from .hypergraph import Edge, Hypergraph, fractional_edge_cover, zero_one_packing
from .query import Attr, JoinQuery
from .semijoin import semijoin_reduce
from .taxonomy import Configuration, HPlan, HeavyStats, configurations, plan_for_h


def packing_weight_of(
    w: Dict[Edge, Fraction], vertices: Iterable[Attr]
) -> Fraction:
    """W_J = Σ_{Y∈J} (weight of Y under W)  (paper (5.10)/(5.15))."""
    total = Fraction(0)
    vs = set(vertices)
    for e, we in w.items():
        total += we * len(e & vs)
    return total


@dataclass
class ICPCheck:
    h_set: Tuple[Attr, ...]
    j_set: Tuple[Attr, ...]
    lhs: int                  # Σ_η |Join(Q''_J(η))|
    rhs_thm54: float          # λ^{|H|-W_J} m^{|J|}
    rhs_lem55: float          # λ^{2ρ-|J|-|L|} m^{|J|}

    @property
    def ok(self) -> bool:
        # Lemma 5.5's rhs is the weaker (larger) bound used by the allocator.
        return self.lhs <= self.rhs_lem55 + 1e-9


def icp_lhs(
    query: JoinQuery,
    stats: HeavyStats,
    plan: HPlan,
    j_set: Sequence[Attr],
) -> int:
    """Exact Σ_η Π_{X∈J} |R''_X(η)| over every configuration η of H."""
    total = 0
    for eta in configurations(stats, plan.h_set):
        reduced = semijoin_reduce(query, stats, plan, eta)
        if reduced is None:
            continue
        prod = 1
        for x in j_set:
            prod *= int(reduced.unary[x].size)
        total += prod
    return total


def icp_check(
    query: JoinQuery,
    stats: HeavyStats,
    h_set: Sequence[Attr],
    j_set: Sequence[Attr] | None = None,
) -> ICPCheck:
    """Empirically verify Theorem 5.4 / Lemma 5.5 for (H, J). J defaults to I."""
    g = query.hypergraph
    plan = plan_for_h(query, h_set)
    j = tuple(sorted(j_set)) if j_set is not None else plan.isolated
    if not set(j) <= set(plan.isolated):
        raise ValueError("J must be a subset of the isolated attributes I")

    lam, m = stats.lam, stats.m
    rho_val, _ = fractional_edge_cover(g)
    _, packing, _ = zero_one_packing(g)
    w_j = packing_weight_of(packing, j)

    lhs = icp_lhs(query, stats, plan, j) if j else 0
    rhs54 = float(lam) ** float(len(plan.h_set) - w_j) * float(m) ** len(j)
    exp55 = 2 * float(rho_val) - len(j) - len(plan.light)
    rhs55 = float(lam) ** exp55 * float(m) ** len(j)
    return ICPCheck(
        h_set=tuple(sorted(h_set)), j_set=j, lhs=lhs, rhs_thm54=rhs54, rhs_lem55=rhs55
    )


def all_icp_checks(query: JoinQuery, stats: HeavyStats) -> list[ICPCheck]:
    """Every (H, J ⊆ I) pair with J non-empty — the full hypothesis of Thm 5.4."""
    out = []
    attrs = query.attset
    for r in range(len(attrs) + 1):
        for h in itertools.combinations(attrs, r):
            plan = plan_for_h(query, h)
            iso = plan.isolated
            for jr in range(1, len(iso) + 1):
                for j in itertools.combinations(iso, jr):
                    out.append(icp_check(query, stats, h, j))
    return out
