"""External-memory (EM) model adapter — the paper's second concluding remark (Sec. 7).

The reduction of [13] converts a p-machine MPC algorithm with load L into an EM
algorithm: simulate the p machines on one host with M words of memory, p = Θ(m/M)
so each "machine"'s state fits in memory; every MPC round costs O(p · (L/B + 1))
I/Os of block size B (spill + reload each machine's received words).

With our engine's load L = Õ(m/p^{1/ρ}) and p = Θ(m/M) this gives

    I/Os  =  Õ( (m/M)^ρ · M / B )  =  Õ( m^ρ / (B · M^{ρ-1}) )

(matching the paper's stated bound, optimal up to polylog by [11, 18]).
``em_cost_from_run`` instantiates the reduction on an actual metered simulator run,
giving *concrete* I/O counts rather than asymptotics — usable to size a single-host
spill-to-disk join. Validated in tests/test_em_model.py against the closed form.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .hypergraph import rho
from .query import JoinQuery


@dataclass(frozen=True)
class EMCost:
    m: int
    memory_words: int          # M
    block_words: int           # B
    p_simulated: int           # Θ(m/M) machines simulated
    rounds: int
    total_load_words: int      # Σ per-round max loads of the MPC run
    io_blocks: int             # concrete I/O count from the reduction
    io_bound_closed_form: float  # m^ρ / (B · M^{ρ-1})

    @property
    def ratio(self) -> float:
        return self.io_blocks / max(1.0, self.io_bound_closed_form)


def simulated_p(m: int, memory_words: int, safety: float = 4.0) -> int:
    """p = Θ(m/M): each simulated machine's Θ(m/p) input + received load must fit in
    M with `safety` headroom."""
    return max(2, math.ceil(safety * m / memory_words))


def em_cost_from_run(query: JoinQuery, result, memory_words: int, block_words: int) -> EMCost:
    """Instantiate the MPC→EM reduction on a metered run (`result` = MPCJoinResult
    whose simulator ran with p ≈ simulated_p(m, M))."""
    sim = result.sim
    p = result.p
    io = 0
    for name, load in sim.merged_round_loads().items():
        # write + read each machine's received words in blocks, one pass per round
        io += 2 * p * (math.ceil(load / block_words) + 1)
    rho_val = float(rho(query))
    bound = query.m ** rho_val / (block_words * memory_words ** (rho_val - 1))
    return EMCost(
        m=query.m,
        memory_words=memory_words,
        block_words=block_words,
        p_simulated=p,
        rounds=len(sim.merged_round_loads()),
        total_load_words=result.load,
        io_blocks=io,
        io_bound_closed_form=bound,
    )
