"""Scan-body probes: trip-count-corrected HLO costs.

XLA's HloCostAnalysis visits each instruction once — a `lax.scan` body (and the
collectives inside it) is counted a single time no matter the trip count (verified
empirically; see EXPERIMENTS §Roofline methodology). The dry-run therefore lowers, per
cell, a standalone *body probe* — one pattern-group application with the same shapes,
shardings, remat policy, and (for train) its VJP — and reports

    total_X = module_X + Σ_probes (R_probe - 1) · probe_X ,  X ∈ {flops, bytes, coll}

which is exact up to boundary fusion effects. Probes per cell: the decoder pattern
group (R = cfg.n_repeats) and, for enc-dec archs, the encoder block (R = n_enc_layers).
"""

from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, BlockSpec, ShapeSpec
from ..distributed.specs import to_shardings
from ..models.model import _block_apply, _block_decode, _remat_wrap
from .roofline import collective_bytes


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _strip_stack(tree):
    return jax.tree.map(lambda l: _sds(l.shape[1:], l.dtype), tree)


def _strip_stack_specs(spec_tree):
    from jax.sharding import PartitionSpec as P

    return jax.tree.map(
        lambda s: P(*s[1:]) if len(s) >= 1 else s,
        spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )


def _cost_triple(lowered) -> Dict[str, float]:
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())

    def get(key):
        try:
            return float(cost.get(key, 0.0))
        except Exception:
            return 0.0

    return {
        "flops": get("flops"),
        "bytes": get("bytes accessed"),
        "coll_bytes": float(coll["total_bytes"]),
    }


def probe_costs(
    cfg: ArchConfig,
    shape: ShapeSpec,
    kind: str,
    mesh,
    axes,
    params_sds,
    p_specs,
    cache_sds=None,
    cache_specs=None,
) -> List[Tuple[int, Dict[str, float]]]:
    """Returns [(extra_repeats, {flops, bytes, coll_bytes}), ...] — lowered under the
    ambient mesh/axes context the caller has installed."""
    out: List[Tuple[int, Dict[str, float]]] = []
    dt = jnp.dtype(cfg.dtype)
    b = shape.batch
    s_total = shape.seq
    d = cfg.d_model

    group_sds = _strip_stack(params_sds["blocks"])
    group_specs = _strip_stack_specs(p_specs["blocks"])
    group_sh = to_shardings(group_specs, mesh)

    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = axes.data if len(axes.data) > 1 else axes.data[0]
    dp_size = int(np.prod([mesh.shape[a] for a in axes.data]))
    dp_ok = b % dp_size == 0
    x_spec = P(dp if dp_ok else None, None, None)
    x_sh = NamedSharding(mesh, x_spec)

    enc_inputs = ()
    enc_sh = ()
    if cfg.is_encdec:
        enc_inputs = (_sds((b, cfg.n_frontend, d), dt),)
        enc_sh = (x_sh,)

    if kind in ("train", "prefill"):
        x_sds = _sds((b, s_total, d), dt)

        def group_fwd(x, gp, *enc):
            positions = jnp.arange(x.shape[1])[None, :]
            enc_out = enc[0] if enc else None
            enc_pos = (
                jnp.arange(enc[0].shape[1])[None, :] if enc else None
            )
            for i, spec in enumerate(cfg.pattern):
                x, _ = _block_apply(
                    cfg, spec, gp[f"pos{i}"], x, positions,
                    enc_out=enc_out, enc_positions=enc_pos,
                )
            return x

        if kind == "train":
            wrapped = _remat_wrap(cfg, group_fwd)

            def probe(x, ybar, gp, *enc):
                y, vjp = jax.vjp(lambda xx, pp: wrapped(xx, pp, *enc), x, gp)
                return vjp(ybar)

            lowered = jax.jit(
                probe, in_shardings=(x_sh, x_sh, group_sh) + enc_sh
            ).lower(x_sds, x_sds, group_sds, *enc_inputs)
        else:
            lowered = jax.jit(
                group_fwd, in_shardings=(x_sh, group_sh) + enc_sh
            ).lower(x_sds, group_sds, *enc_inputs)
        out.append((cfg.n_repeats - 1, _cost_triple(lowered)))

        if cfg.is_encdec and cfg.n_enc_layers > 1:
            enc_spec_blk = BlockSpec(mixer="attn", window=0)
            enc_blk_sds = _strip_stack(params_sds["encoder"]["blocks"])
            enc_blk_specs = _strip_stack_specs(p_specs["encoder"]["blocks"])
            enc_blk_sh = to_shardings(enc_blk_specs, mesh)
            xe_sds = _sds((b, cfg.n_frontend, d), dt)

            def enc_fwd(x, bp):
                positions = jnp.arange(x.shape[1])[None, :]
                y, _ = _block_apply(cfg, enc_spec_blk, bp, x, positions, causal=False)
                return y

            if kind == "train":
                wrapped_e = _remat_wrap(cfg, enc_fwd)

                def probe_e(x, ybar, bp):
                    y, vjp = jax.vjp(wrapped_e, x, bp)
                    return vjp(ybar)

                lowered = jax.jit(
                    probe_e, in_shardings=(x_sh, x_sh, enc_blk_sh)
                ).lower(xe_sds, xe_sds, enc_blk_sds)
            else:
                lowered = jax.jit(enc_fwd, in_shardings=(x_sh, enc_blk_sh)).lower(
                    xe_sds, enc_blk_sds
                )
            out.append((cfg.n_enc_layers - 1, _cost_triple(lowered)))
        return out

    # decode: one-token pass through one pattern group with its cache slice
    x_sds = _sds((b, 1, d), dt)
    cache_grp_sds = _strip_stack(cache_sds["blocks"])
    cache_grp_specs = _strip_stack_specs(cache_specs["blocks"])
    cache_grp_sh = to_shardings(cache_grp_specs, mesh)
    x1_sh = NamedSharding(mesh, P(dp if dp_ok else None, None, None))

    def dec_group(x, gp, gc, *enc):
        pos = jnp.array(s_total - 1, jnp.int32)
        enc_out = enc[0] if enc else None
        new_cache = {}
        for i, spec in enumerate(cfg.pattern):
            x, c2 = _block_decode(cfg, spec, gp[f"pos{i}"], gc[f"pos{i}"], x, pos, enc_out)
            new_cache[f"pos{i}"] = c2
        return x, new_cache

    lowered = jax.jit(
        dec_group, in_shardings=(x1_sh, group_sh, cache_grp_sh) + enc_sh
    ).lower(x_sds, group_sds, cache_grp_sds, *enc_inputs)
    out.append((cfg.n_repeats - 1, _cost_triple(lowered)))
    return out
