"""Roofline terms from a compiled (AOT) step.

  compute  = FLOPs_dev / peak_flops         (197 TFLOP/s bf16 per TPU v5e chip)
  memory   = Bytes_dev / hbm_bw             (819 GB/s HBM per chip)
  collective = CollBytes_dev / link_bw      (~50 GB/s/link ICI)

``cost_analysis()`` is per-device for an SPMD module (chips × per-device = global).
collective_bytes sums the *result* operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute in the compiled HLO — a serial-sum
convention (no overlap credit), i.e. an upper bound on ICI time; the same convention
is applied to baseline and optimized variants so deltas are meaningful.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
# result shapes appear left of ` = ... <op>(`; handles tuple results
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\w+\[[^\]]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind result bytes (per device) + op counts. ``-start`` ops counted once
    (their ``-done`` twin carries no payload of its own)."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        shape_str, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(shape_str)
        counts[op] += 1
    return {**{f"{k}_bytes": v for k, v in out.items()},
            **{f"{k}_count": v for k, v in counts.items()},
            "total_bytes": sum(out.values())}


@dataclass(frozen=True)
class HW:
    """TPU v5e-class chip (targets per assignment)."""

    peak_flops: float = 197e12    # bf16
    hbm_bw: float = 819e9         # B/s
    link_bw: float = 50e9         # B/s per ICI link


def roofline_terms(
    flops_dev: float,
    bytes_dev: float,
    coll_bytes_dev: float,
    hw: HW = HW(),
) -> Dict[str, float]:
    t_c = flops_dev / hw.peak_flops
    t_m = bytes_dev / hw.hbm_bw
    t_x = coll_bytes_dev / hw.link_bw
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])
    return {
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_collective_s": t_x,
        "bottleneck": dom[0],
        "t_bound_s": dom[1],
    }


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS: 6·N·D (dense) / 6·N_active·D (MoE) per step; decode: D = batch
    tokens (one step)."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.batch
