"""Symbolic per-round load model for compiled RoundPrograms (Theorem 6.2).

Derives, *without executing anything*, a closed-form bound on the per-machine
load of every metered round of a compiled
:class:`~repro.mpc.program.RoundProgram`.  Inputs are exactly the compile-time
quantities — the query structure (ρ via :func:`repro.core.hypergraph.rho`),
the histogram essentials (m), and p — so the model is a pure function of the
same key that makes :func:`~repro.mpc.program.compile_plan` cacheable.

The shape of every data-round bound is the paper's headline with explicit
lower-order terms:

    bound  =  C · ( L* + F + √L*·lg + lg² )          [words per machine]

      L*  = m / p^{1/ρ}          the Theorem 6.2 ideal load
      lg  = log₂(p) + 1          one polylog factor (Õ hides it)
      √L*·lg                     binomial deviation of hashed routing
      F                          round-specific skew term, see below

Round-specific F:

  * ``step1`` / ``step2-unary`` — F = 0.  Residual routing and unary hashing
    spread uniformly at random; only the deviation terms apply.
  * ``step2-bx`` / ``step2-by`` / ``step2-fused`` — F = m/λ*, with
    λ* = Θ(p^{1/(2ρ)}) the *canonical* heavy parameter
    (:func:`~repro.core.planner.heavy_parameter`).  Semi-join rounds hash
    light edges by attribute value, so a single light value may land its full
    frequency — up to the taxonomy threshold m/λ — on one machine.  A program
    compiled with the canonical λ keeps this term at m/p^{1/(2ρ)}·polylog and
    the total within Õ(m/p^{1/ρ}); a mis-planned λ (heavy values left
    untagged) blows straight through it — which is exactly what the
    ``load-bound`` verifier rule catches.
  * ``step3-route`` — F = m/λ*.  The Lemma 6.1 CP×HyperCube route replicates
    residual tuples across grid slices; the replication the allocator (6.1)
    admits is bounded by the same λ-threshold.

``step3-sizes`` is metadata, not data: each of a stage's ≤ p'_η piece holders
broadcasts t_η = |I(η)| piece sizes to the stage's step-3 group, so the bound
is the static  C·(max_η t_η·p'_η + lg·Σ_η t_η·p'_η / p + lg²).

General-route rounds (arbitrary-arity programs, ``program.general`` set)
swap m for an explicit volume V: the Yannakakis sweeps ``yan-up``/
``yan-down`` merge one semijoin per join-tree edge (V = edges·(w+2)·m, w the
widest arity), and ``hc-route`` replicates each relation across the share
grid (V = Σ_e m_e·w_e·rep_e over g = Π shares cells) — with LP-optimal
shares the skew-free per-cell volume collapses to the AGM form
O(m/p^{1/ρ}).  Both keep the V/λ* skew term: the general route does no
heavy/light splitting, so its Õ(·) promise assumes λ-bounded frequencies.

The multiplicative constant C (:data:`MODEL_CONSTANT`) is calibrated once
against the simulator battery (docs/design/11-verification.md has the table):
well-planned programs across {uniform, zipf} × {triangle, 4-cycle, star} ×
p ∈ 8…256 measure ≤ 0.6× of each bound, while a deliberately mis-planned
program (λ = 2 hub triangle) exceeds the step2-bx bound by ≥ 1.7× at p = 256.

Everything here is host-side numpy/stdlib; no jax, no execution.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List

from ..core.planner import heavy_parameter

#: Calibrated multiplicative constant of every bound (see module docstring).
MODEL_CONSTANT = 4.0

#: Rounds that move relation data (and therefore follow the m/p^{1/ρ} form).
DATA_ROUNDS = (
    "step1",
    "step2-unary",
    "step2-bx",
    "step2-by",
    "step2-fused",
    "step3-route",
    "yan-up",
    "yan-down",
    "hc-route",
)

#: Rounds the simulator meters at zero load (host-side placement / local work).
FREE_ROUNDS = ("scatter", "output")


@dataclass(frozen=True)
class RoundBound:
    """One round's symbolic bound: ``words`` plus the formula it came from."""

    round: str
    words: float
    formula: str


def ideal_load(m: int, p: int, rho_val: float) -> float:
    """L* = m / p^{1/ρ}: the Theorem 6.2 per-round target."""
    return float(m) / float(p) ** (1.0 / float(rho_val))


def round_bounds(program, constant: float = MODEL_CONSTANT) -> List[RoundBound]:
    """Symbolic per-round load bounds for ``program``, in round order.

    Pure metadata work — reads (m, p, ρ, stage allocation) off the compiled
    program and never touches relation data.  Rounds in :data:`FREE_ROUNDS`
    are omitted (the simulator meters them at zero)."""
    m = int(program.stats.m)
    p = int(program.p)
    rho_val = float(program.rho_val)
    lstar = ideal_load(m, p, rho_val)
    lg = math.log2(p) + 1.0
    lam_star = heavy_parameter(p, rho_val)
    freq = float(m) / float(lam_star)
    dev = math.sqrt(max(lstar, 1.0)) * lg
    base = lstar + dev + lg * lg

    # step3-sizes metadata volume, statically from the step-1 allocation
    # (binary route only — general programs have no step-3 size round and
    # their GeneralStage carries no step-1 allocation).
    gen = getattr(program, "general", None)
    s_max, s_tot = 0.0, 0.0
    if gen is None:
        for st in program.stages:
            t = len(st.plan.isolated)
            holders = st.cfg.step1_group.size
            s_max = max(s_max, float(t * holders))
            s_tot += float(t * holders)

    # General-route volumes (metadata only: arities, row counts, shares).
    # ``yan-up``/``yan-down`` merge one hash-partitioned semijoin per tree
    # edge into a single logical round, so the sweep bound scales with the
    # edge count and the widest relation (+1 for the appended key column).
    # ``hc-route`` replicates each relation Π_{a∉e} share_a times over the
    # share grid g = Π shares ≤ p; with LP-optimal shares the skew-free
    # per-cell volume is Σ_e m_e·w_e / Π_{a∈e} share_a — the AGM form
    # k·w·m/p^{1/ρ} of the Theorem 6.2 headline.
    sweep_vol = route_vol = 0.0
    gsize = 1
    if gen is not None:
        q = program.query
        wmax = max(len(rel.scheme) for rel in q.relations) + 1
        n_edges = max(1, len(gen.tree_edges))
        shares = dict(gen.shares)
        for s in shares.values():
            gsize *= int(s)
        sweep_vol = float(n_edges) * float(wmax + 1) * float(m)
        for rel in q.relations:
            rep = 1
            for a, s in shares.items():
                if a not in rel.scheme:
                    rep *= int(s)
            route_vol += float(len(rel)) * float(len(rel.scheme) + 1) * float(rep)
    gdenom = float(max(1, min(p, gsize)))

    out: List[RoundBound] = []
    seen = set()
    for name in program.round_names:
        if name in seen or name in FREE_ROUNDS:
            continue
        seen.add(name)
        if name == "step3-sizes":
            words = constant * (s_max + lg * s_tot / p + lg * lg)
            formula = (
                f"{constant:g}*(max t*p' + lg*sum(t*p')/p + lg^2)"
                f"  [max={s_max:.0f}, sum={s_tot:.0f}]"
            )
        elif name in ("step2-bx", "step2-by", "step2-fused", "step3-route"):
            words = constant * (base + freq)
            formula = (
                f"{constant:g}*(L* + m/lam* + sqrt(L*)*lg + lg^2)"
                f"  [L*={lstar:.0f}, m/lam*={freq:.0f}, lam*={lam_star}]"
            )
        elif name in ("yan-up", "yan-down"):
            v = sweep_vol
            words = constant * (
                v / p + v / lam_star + math.sqrt(max(v / p, 1.0)) * lg + lg * lg
            )
            formula = (
                f"{constant:g}*(V/p + V/lam* + sqrt(V/p)*lg + lg^2)"
                f"  [V={v:.0f} = edges*(w+2)*m, lam*={lam_star}]"
            )
        elif name == "hc-route":
            v = route_vol
            words = constant * (
                v / gdenom + v / lam_star
                + math.sqrt(max(v / gdenom, 1.0)) * lg + lg * lg
            )
            formula = (
                f"{constant:g}*(V/g + V/lam* + sqrt(V/g)*lg + lg^2)"
                f"  [V={v:.0f} = sum_e m_e*w_e*rep_e, g={gdenom:.0f}, "
                f"skew-free ideal m/p^(1/rho)={lstar:.0f}]"
            )
        elif name in ("step1", "step2-unary"):
            words = constant * base
            formula = f"{constant:g}*(L* + sqrt(L*)*lg + lg^2)  [L*={lstar:.0f}]"
        else:  # pragma: no cover - unknown custom round: fall back to base
            words = constant * base
            formula = f"{constant:g}*(L* + sqrt(L*)*lg + lg^2)  [L*={lstar:.0f}]"
        out.append(RoundBound(round=name, words=words, formula=formula))
    return out


def round_bounds_by_name(program, constant: float = MODEL_CONSTANT) -> Dict[str, RoundBound]:
    """:func:`round_bounds` keyed by round name (what ``check_load`` joins on)."""
    return {b.round: b for b in round_bounds(program, constant=constant)}


def predicted_load(program, constant: float = MODEL_CONSTANT) -> float:
    """Σ of the per-round bounds: the symbolic analogue of the simulator's
    ``parallel_total_load`` (an upper envelope, not an estimate)."""
    return sum(b.words for b in round_bounds(program, constant=constant))
