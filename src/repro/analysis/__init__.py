"""Roofline analysis from compiled dry-run artifacts + the symbolic
per-round load model that backs the static verifier's ``load-bound`` rule."""

from .loadmodel import (
    DATA_ROUNDS,
    MODEL_CONSTANT,
    RoundBound,
    ideal_load,
    predicted_load,
    round_bounds,
    round_bounds_by_name,
)
from .roofline import collective_bytes, roofline_terms, HW
