"""Roofline analysis from compiled dry-run artifacts."""

from .roofline import collective_bytes, roofline_terms, HW
