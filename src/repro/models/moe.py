"""Mixture-of-Experts FFN: shared experts + fine-grained routed experts (top-k).

Three dispatch paths (cfg.moe_dispatch):

  * "a2a"   — production path: shard_map over the model axis; tokens are packed into
              per-expert capacity buffers locally, exchanged with a single
              ``all_to_all`` to the expert owners, processed batched, and returned with
              a second all_to_all. This is the join paper's mechanism transplanted:
              a skew-aware partitioned exchange with capacity bounds playing the role
              of the engine's padded relation buffers (DESIGN.md §4). Requires a mesh.
  * "dense" — einsum-only fallback: computes every expert on every token and combines
              with sparse gates. No data-dependent comm (pure GSPMD), ~E/top_k compute
              waste; kept as the naive baseline for §Perf.
  * "loop"  — single-device reference used by smoke tests and as the numerical oracle
              for the a2a path (python loop over experts, exact dropless).

Capacity: cap = ceil(T_local · top_k / E · capacity_factor), tokens beyond an expert's
capacity are dropped (their combine weight is zero) — the standard GShard contract; the
"loop" oracle is dropless, so tests compare with capacity_factor large enough to make
drops impossible.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed.ctx import current_axes, shard


def moe_params(cfg, key, dtype) -> dict:
    d, dff, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s,
        "w_gate": jax.random.normal(ks[1], (e, d, dff), dtype) * s,
        "w_up": jax.random.normal(ks[2], (e, d, dff), dtype) * s,
        "w_out": jax.random.normal(ks[3], (e, dff, d), dtype) * (dff ** -0.5),
    }
    if cfg.n_shared_experts:
        dsh = cfg.d_ff_expert * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": jax.random.normal(k1, (d, dsh), dtype) * s,
            "w_up": jax.random.normal(k2, (d, dsh), dtype) * s,
            "w_out": jax.random.normal(k3, (dsh, d), dtype) * (dsh ** -0.5),
        }
    return p


def _expert_ffn(p, x, e_idx=None):
    """x (..., d) through expert weights; if e_idx is None, weights are (E,d,f)."""
    wg, wu, wo = p["w_gate"], p["w_up"], p["w_out"]
    if e_idx is not None:
        wg, wu, wo = wg[e_idx], wu[e_idx], wo[e_idx]
        h = jax.nn.silu(x @ wg) * (x @ wu)
        return h @ wo
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x, wg)) * jnp.einsum("td,edf->tef", x, wu)
    return jnp.einsum("tef,efd->ted", h, wo)


def _router(cfg, p, x_flat):
    """x (T, d) → (probs (T,E) fp32, topk_idx (T,k), topk_w (T,k) normalized).
    fp32 accumulation via the dot (no fp32 copy of the token stream)."""
    logits = jnp.einsum(
        "td,de->te", x_flat, p["router"].astype(x_flat.dtype),
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, cfg.top_k)
    topk_w = topk_w / jnp.sum(topk_w, axis=-1, keepdims=True)
    return probs, topk_idx, topk_w


def _aux_loss(cfg, probs, topk_idx):
    """Switch-style load-balance loss: E · Σ_e f_e · P_e."""
    e = cfg.n_experts
    f = jnp.mean(
        jnp.sum(jax.nn.one_hot(topk_idx, e, dtype=jnp.float32), axis=1), axis=0
    ) / cfg.top_k
    pmean = jnp.mean(probs, axis=0)
    return e * jnp.sum(f * pmean)


# ---------------------------------------------------------------------------


def _moe_loop(cfg, p, x_flat):
    """Dropless python-loop oracle (single device / smoke tests)."""
    probs, topk_idx, topk_w = _router(cfg, p, x_flat)
    out = jnp.zeros_like(x_flat)
    for e in range(cfg.n_experts):
        w_e = jnp.sum(jnp.where(topk_idx == e, topk_w, 0.0), axis=-1)  # (T,)
        y = _expert_ffn(p, x_flat, e_idx=e)
        out = out + y * w_e[:, None].astype(x_flat.dtype)
    return out, _aux_loss(cfg, probs, topk_idx)


def _moe_dense(cfg, p, x_flat):
    """Every expert on every token; sparse combine. Naive §Perf baseline."""
    probs, topk_idx, topk_w = _router(cfg, p, x_flat)
    onehot = jax.nn.one_hot(topk_idx, cfg.n_experts, dtype=jnp.float32)  # (T,k,E)
    gates = jnp.einsum("tk,tke->te", topk_w, onehot)
    y = _expert_ffn(p, x_flat)  # (T,E,d)
    out = jnp.einsum("te,ted->td", gates.astype(x_flat.dtype), y)
    return out, _aux_loss(cfg, probs, topk_idx)


def _pack_capacity(cfg, x_flat, topk_idx, topk_w, cap):
    """Pack tokens into per-expert capacity buffers (E, cap, d) + combine metadata.

    Returns (buffers, (slot_pos (T,k), keep (T,k))) where slot_pos is each (token,
    slot)'s position inside its expert buffer; dropped entries have keep=False."""
    t, k = topk_idx.shape
    e = cfg.n_experts
    flat_expert = topk_idx.reshape(-1)                       # (T*k,) expert per entry
    # position within expert via cumsum over one-hot (GShard trick)
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot            # 1-based where routed
    slot = jnp.sum(pos_in_e, axis=-1) - 1                     # (T*k,)
    keep = (slot >= 0) & (slot < cap)
    buffers = jnp.zeros((e, cap, x_flat.shape[-1]), x_flat.dtype)
    src = jnp.repeat(x_flat, k, axis=0)                       # (T*k, d)
    buffers = buffers.at[flat_expert, jnp.clip(slot, 0, cap - 1)].set(
        jnp.where(keep[:, None], src, 0.0), mode="drop"
    )
    return buffers, (slot.reshape(t, k), keep.reshape(t, k))


def _moe_a2a(cfg, p, x_flat, axes):
    """shard_map all_to_all dispatch over the model axis (expert parallelism)."""
    tp = axes.model
    mesh = jax.sharding.get_abstract_mesh()
    tp_size = mesh.shape[tp]
    e = cfg.n_experts
    assert e % tp_size == 0, (e, tp_size)
    e_loc = e // tp_size
    t = x_flat.shape[0]

    probs, topk_idx, topk_w = _router(cfg, p, x_flat)
    aux = _aux_loss(cfg, probs, topk_idx)

    # tokens partitioned over dp AND tp: each device dispatches its own token slice;
    # with sequence parallelism on, this is exactly the residual sharding (no reshard).
    # Decode batches are small: fall back to tp-only sharding (dp groups dispatch
    # redundantly — standard decode EP) or, for tiny T, to the dense path.
    import numpy as np

    dp = axes.data
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    n_tok = x_flat.shape[0]
    if n_tok % (dp_size * tp_size) == 0:
        tok_spec: tuple = tuple(dp) + (tp,)
    elif n_tok % tp_size == 0:
        tok_spec = (tp,)
    else:
        return _moe_dense(cfg, p, x_flat)

    def body(x_loc, idx_loc, w_loc, wg, wu, wo):
        t_loc = x_loc.shape[0]
        cap = int(math.ceil(t_loc * cfg.top_k / e * cfg.capacity_factor))
        # small local batches (decode): pad capacity toward dropless
        cap = max(cap, min(t_loc, 8), 1)
        buffers, (slot, keep) = _pack_capacity(cfg, x_loc, idx_loc, w_loc, cap)
        # (E, cap, d) → (tp, E_loc, cap, d) → a2a → (tp, E_loc, cap, d) from all peers
        buffers = buffers.reshape(tp_size, e_loc, cap, -1)
        recv = jax.lax.all_to_all(buffers, tp, split_axis=0, concat_axis=0, tiled=False)
        # recv: (tp, E_loc, cap, d) — tokens from every peer for MY experts
        recv = recv.transpose(1, 0, 2, 3).reshape(e_loc, tp_size * cap, -1)
        hs = []
        for j in range(e_loc):
            hs.append(_expert_ffn({"w_gate": wg, "w_up": wu, "w_out": wo}, recv[j], e_idx=j))
        y = jnp.stack(hs, axis=0)  # (E_loc, tp*cap, d)
        y = y.reshape(e_loc, tp_size, cap, -1).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(y, tp, split_axis=0, concat_axis=0, tiled=False)
        back = back.reshape(e, cap, -1)  # my tokens, processed by their experts
        # combine: gather each (token, slot)'s row
        flat_e = idx_loc.reshape(-1)
        flat_s = jnp.clip(slot.reshape(-1), 0, cap - 1)
        picked = back[flat_e, flat_s]  # (T*k, d)
        w_flat = jnp.where(keep.reshape(-1), w_loc.reshape(-1), 0.0)
        out = jnp.sum(
            (picked * w_flat[:, None].astype(picked.dtype)).reshape(t_loc, cfg.top_k, -1),
            axis=1,
        )
        return out

    from jax.experimental.shard_map import shard_map

    body_sm = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(tok_spec, None),         # x (T, d): tokens sharded over dp × tp
            P(tok_spec, None),
            P(tok_spec, None),
            P(tp, None, None),         # expert weights sharded over model axis (EP)
            P(tp, None, None),
            P(tp, None, None),
        ),
        out_specs=P(tok_spec, None),
        check_rep=False,
    )
    out = body_sm(x_flat, topk_idx, topk_w, p["w_gate"], p["w_up"], p["w_out"])
    return out, aux


def moe_apply(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) → (out (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    axes = current_axes()
    dispatch = cfg.moe_dispatch
    if axes is None and dispatch == "a2a":
        dispatch = "loop"
    if dispatch == "a2a":
        out, aux = _moe_a2a(cfg, p, x_flat, axes)
    elif dispatch in ("dense", "einsum"):
        out, aux = _moe_dense(cfg, p, x_flat)
    elif dispatch == "loop":
        out, aux = _moe_loop(cfg, p, x_flat)
    else:
        raise ValueError(f"unknown moe_dispatch {dispatch!r}")

    if cfg.n_shared_experts:
        sp = p["shared"]
        h = jax.nn.silu(x_flat @ sp["w_gate"]) * (x_flat @ sp["w_up"])
        out = out + h @ sp["w_out"]
    return out.reshape(b, s, d), aux
