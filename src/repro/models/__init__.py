"""Model substrate: the 10 assigned architectures as pure-pytree JAX models.

Layout convention: scanned blocks hold parameters stacked over pattern repeats
(leading dim R); `prefix` blocks are unrolled. Forward = embed → prefix blocks →
lax.scan(pattern blocks × R) → norm → logits. Decode carries a stacked cache through
the same scan.
"""

from .model import (
    init_params,
    model_forward,
    init_cache,
    prefill,
    decode_step,
)
