"""Mamba-2 / SSD (state-space duality) block [arXiv:2405.21060], TPU-adapted.

TPU adaptation (DESIGN.md §2): the CUDA selective-scan kernel is replaced by the
*chunked SSD* formulation — within a chunk of Q tokens the recurrence is a masked
matmul (MXU work), across chunks a short associative scan carries the (H, P, N) state.
This is the published SSD algorithm and is exactly the structure the Pallas kernel in
repro/kernels/ssd.py tiles into VMEM.

The depthwise causal conv is applied separately to the x / B / C streams (identical
math to the fused conv — depthwise means per-channel — but keeps the tensor-parallel
sharding of x clean; DESIGN.md §6).

Shapes: x (B,S,H,P) with H = d_inner/headdim SSD heads, P = headdim; B̃/C (B,S,G,N)
with G groups and N = d_state; dt (B,S,H) after softplus; A (H,) negative.

Decode carries (conv states (B,k-1,·) per stream, ssm_state (B,H,P,N)) — O(1) per
token, which is why the SSM archs run the long_500k cell.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..distributed.ctx import shard
from .layers import rms_norm


def mamba_params(cfg, key, dtype) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    g, n, nh = cfg.ssm_ngroups, cfg.d_state, cfg.ssm_nheads
    ks = jax.random.split(key, 10)
    s = d ** -0.5
    return {
        "w_z": jax.random.normal(ks[0], (d, di), dtype) * s,
        "w_x": jax.random.normal(ks[1], (d, di), dtype) * s,
        "w_B": jax.random.normal(ks[2], (d, g * n), dtype) * s,
        "w_C": jax.random.normal(ks[3], (d, g * n), dtype) * s,
        "w_dt": jax.random.normal(ks[4], (d, nh), dtype) * s,
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "conv_x": jax.random.normal(ks[5], (cfg.conv_k, di), dtype) * 0.1,
        "conv_B": jax.random.normal(ks[6], (cfg.conv_k, g * n), dtype) * 0.1,
        "conv_C": jax.random.normal(ks[7], (cfg.conv_k, g * n), dtype) * 0.1,
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "w_out": jax.random.normal(ks[8], (di, d), dtype) * (di ** -0.5),
    }


def _causal_conv(xs: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv + SiLU: xs (B,S,CH), w (K,CH)."""
    k = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xs)
    for i in range(k):
        out = out + pad[:, i : i + xs.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu(out)


def _conv_step(window: jax.Array, w: jax.Array) -> jax.Array:
    """Single-position depthwise conv: window (B,K,CH), w (K,CH) → (B,1,CH)."""
    return jax.nn.silu(jnp.sum(window * w[None], axis=1, keepdims=True))


def _project(cfg, p, u):
    """u (B,S,d) → z, x_pre, b_pre, c_pre, dt (pre-conv streams)."""
    z = u @ p["w_z"]
    x = u @ p["w_x"]
    b = u @ p["w_B"]
    c = u @ p["w_C"]
    dt = jax.nn.softplus(
        (u @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"][None, None, :]
    )
    return z, x, b, c, dt


def ssd_chunked(
    x: jax.Array,      # (B,S,H,P)
    dt: jax.Array,     # (B,S,H) fp32
    a: jax.Array,      # (H,) negative fp32
    b_ssm: jax.Array,  # (B,S,G,N)
    c_ssm: jax.Array,  # (B,S,G,N)
    chunk: int,
    init_state=None,   # (B,H,P,N) or None
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, pdim = x.shape
    g, n = b_ssm.shape[2], b_ssm.shape[3]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    rep = h // g

    xq = x.reshape(bsz, nc, q, h, pdim)
    dtq = dt.reshape(bsz, nc, q, h)
    bq = b_ssm.reshape(bsz, nc, q, g, n)
    cq = c_ssm.reshape(bsz, nc, q, g, n)

    da = dtq * a[None, None, None, :]                  # (B,nc,Q,H) fp32, ≤ 0
    cum = jnp.cumsum(da, axis=2)                       # within-chunk cumulative
    seg_sum = cum[:, :, -1, :]                         # (B,nc,H)

    # decay L[i,j] = exp(cum_i - cum_j) for i ≥ j (intra-chunk)
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    mask = (iota_i >= iota_j)[None, None, :, :, None]
    # mask BEFORE exp: the i<j half has li>0 (exp→inf, and 0·inf=NaN in the VJP);
    # valid entries are ≤ 0 so exp never overflows.
    decay = jnp.exp(jnp.where(mask, li, -jnp.inf))

    # Group-aware einsums: B̃/C are never expanded to H heads (ngroups=1 with 128
    # heads would otherwise materialize S·H·N tensors — the §Perf jamba fix).
    # Heads factor as H = G groups × R heads-per-group.
    xg = xq.reshape(bsz, nc, q, g, rep, pdim)
    dtg = dtq.reshape(bsz, nc, q, g, rep)

    # intra-chunk: y[i] += Σ_j≤i (C_i·B_j)[g] decay(i,j)[g,r] dt_j[g,r] x_j[g,r]
    cb = jnp.einsum(
        "bcign,bcjgn->bcijg", cq, bq, preferred_element_type=jnp.float32
    )                                                   # (B,nc,Q,Q,G) — no H expansion
    w_ij = cb[..., None] * decay.reshape(bsz, nc, q, q, g, rep) \
        * dtg[:, :, None, :, :, :]                      # (B,nc,Q,Q,G,R)
    y_diag = jnp.einsum("bcijgr,bcjgrp->bcigrp", w_ij.astype(x.dtype), xg)

    # chunk summaries: S_c = Σ_j exp(seg - cum_j) dt_j B_j ⊗ x_j   (B,nc,G,R,P,N)
    decay_tail = jnp.exp(seg_sum[:, :, None, :] - cum)  # (B,nc,Q,H)
    wdt = (decay_tail * dtq).reshape(bsz, nc, q, g, rep)
    s_c = jnp.einsum(
        "bcjgr,bcjgn,bcjgrp->bcgrpn", wdt.astype(x.dtype), bq, xg
    ).reshape(bsz, nc, h, pdim, n)

    # inter-chunk recurrence: states[c] = exp(seg_c)·states[c-1] + S_c
    gamma = jnp.exp(seg_sum)                            # (B,nc,H)

    def combine(left, right):
        gl, sl = left
        gr, sr = right
        return gl * gr, sr + sl * gr[..., None, None].astype(sl.dtype)

    if init_state is None:
        init_state = jnp.zeros((bsz, h, pdim, n), x.dtype)
    g_scan, s_scan = jax.lax.associative_scan(combine, (gamma, s_c), axis=1)
    # state entering chunk c: inclusive-scan of chunks < c, with init_state decayed in
    prev = jnp.concatenate(
        [
            init_state[:, None],
            s_scan[:, :-1]
            + init_state[:, None] * g_scan[:, :-1][..., None, None].astype(x.dtype),
        ],
        axis=1,
    )

    # inter-chunk contribution: y[i] += C_i · exp(cum_i) · prev_state
    decay_head = jnp.exp(cum).reshape(bsz, nc, q, g, rep)  # fp32
    prev_g = prev.reshape(bsz, nc, g, rep, pdim, n)
    y_off = jnp.einsum(
        "bcign,bcigr,bcgrpn->bcigrp",
        cq.astype(x.dtype), decay_head.astype(x.dtype), prev_g,
    )

    y = (y_diag + y_off).reshape(bsz, s, h, pdim)
    final_state = s_scan[:, -1] + init_state * g_scan[:, -1][..., None, None].astype(x.dtype)
    return y, final_state


def ssd_reference(x, dt, a, b_ssm, c_ssm, init_state=None):
    """Naive per-token recurrence (the oracle for the chunked path and the Pallas
    kernel): h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T ; y_t = C_t · h_t."""
    bsz, s, h, pdim = x.shape
    g, n = b_ssm.shape[2], b_ssm.shape[3]
    rep = h // g
    if init_state is None:
        init_state = jnp.zeros((bsz, h, pdim, n), jnp.float32)
    init_state = init_state.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P) (B,H) (B,G,N) (B,G,N)
        btg = jnp.repeat(bt, rep, axis=1)
        ctg = jnp.repeat(ct, rep, axis=1)
        decay = jnp.exp(dtt * a[None, :])[..., None, None]
        upd = dtt[..., None, None] * jnp.einsum("bhp,bhn->bhpn", xt, btg)
        state = decay * state + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, ctg)
        return state, y

    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2),
        b_ssm.transpose(1, 0, 2, 3).astype(jnp.float32),
        c_ssm.transpose(1, 0, 2, 3).astype(jnp.float32),
    )
    final, ys = jax.lax.scan(step, init_state, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final.astype(x.dtype)


def _ssd_run(cfg, p, z, x_conv, b_conv, c_conv, dt, init_state=None):
    bsz, s, _ = x_conv.shape
    h, pdim = cfg.ssm_nheads, cfg.ssm_headdim
    x4 = shard(x_conv.reshape(bsz, s, h, pdim), "dp", None, "tp", None)
    b4 = b_conv.reshape(bsz, s, cfg.ssm_ngroups, cfg.d_state)
    c4 = c_conv.reshape(bsz, s, cfg.ssm_ngroups, cfg.d_state)
    a = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(x4, dt, a, b4, c4, cfg.ssd_chunk, init_state=init_state)
    y = y + x4 * p["D"][None, None, :, None].astype(x4.dtype)
    y = y.reshape(bsz, s, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["w_out"], state


def mamba_apply(cfg, p: dict, u: jax.Array) -> jax.Array:
    """Train forward (B,S,d) → (B,S,d)."""
    z, x, b, c, dt = _project(cfg, p, u)
    x = shard(_causal_conv(x, p["conv_x"]), "dp", None, "tp")
    b = _causal_conv(b, p["conv_B"])
    c = _causal_conv(c, p["conv_C"])
    out, _ = _ssd_run(cfg, p, z, x, b, c, dt)
    return out


def mamba_prefill(cfg, p, u):
    """Forward + decode state (conv windows are the last k-1 *pre-conv* positions)."""
    z, x, b, c, dt = _project(cfg, p, u)
    k = cfg.conv_k
    conv_state = {
        "x": x[:, -(k - 1) :, :],
        "B": b[:, -(k - 1) :, :],
        "C": c[:, -(k - 1) :, :],
    }
    xc = _causal_conv(x, p["conv_x"])
    bc = _causal_conv(b, p["conv_B"])
    cc = _causal_conv(c, p["conv_C"])
    out, state = _ssd_run(cfg, p, z, xc, bc, cc, dt)
    return out, conv_state, state


def mamba_decode(
    cfg, p: dict, u: jax.Array, conv_state: dict, ssm_state: jax.Array
) -> Tuple[jax.Array, dict, jax.Array]:
    """One token: u (B,1,d); conv_state {x,B,C: (B,k-1,·)}; ssm_state (B,H,P,N)."""
    bsz = u.shape[0]
    h, pdim = cfg.ssm_nheads, cfg.ssm_headdim
    z, x_new, b_new, c_new, dt = _project(cfg, p, u)

    new_conv = {}
    outs = {}
    for name, new, w in (
        ("x", x_new, p["conv_x"]),
        ("B", b_new, p["conv_B"]),
        ("C", c_new, p["conv_C"]),
    ):
        window = jnp.concatenate([conv_state[name], new], axis=1)  # (B,k,CH)
        new_conv[name] = window[:, 1:, :]
        outs[name] = _conv_step(window, w)

    x = outs["x"].reshape(bsz, h, pdim)
    rep = h // cfg.ssm_ngroups
    bt = jnp.repeat(outs["B"].reshape(bsz, cfg.ssm_ngroups, cfg.d_state), rep, axis=1)
    ct = jnp.repeat(outs["C"].reshape(bsz, cfg.ssm_ngroups, cfg.d_state), rep, axis=1)
    a = -jnp.exp(p["A_log"])
    dtt = dt[:, 0, :]                              # (B,H)
    decay = jnp.exp(dtt * a[None, :])[..., None, None].astype(ssm_state.dtype)
    upd = (
        dtt[..., None, None]
        * jnp.einsum("bhp,bhn->bhpn", x.astype(jnp.float32), bt.astype(jnp.float32))
    ).astype(ssm_state.dtype)
    ssm_state = decay * ssm_state + upd
    y = jnp.einsum("bhpn,bhn->bhp", ssm_state, ct.astype(ssm_state.dtype)).astype(u.dtype)
    y = y + x * p["D"][None, :, None].astype(x.dtype)
    y = y.reshape(bsz, 1, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    return y @ p["w_out"], new_conv, ssm_state
