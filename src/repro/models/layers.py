"""Shared layers: norms, rotary embeddings, MLPs, embedding/logits, loss."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.ctx import shard


@jax.custom_vjp
def _bf16_barrier(x):
    return x


def _bf16_barrier_fwd(x):
    return x, None


def _bf16_barrier_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


_bf16_barrier.defvjp(_bf16_barrier_fwd, _bf16_barrier_bwd)


def grad_dtype_barrier(x: jax.Array) -> jax.Array:
    """Identity forward; casts the cotangent to bf16 on the way back.

    fp32 'contagion': any fp32-accumulating op (norm statistics, attention scores)
    emits an fp32 cotangent contribution; the accumulated residual-stream gradient
    then promotes to fp32 and every backward collective/HBM pass moves 2× bytes.
    A per-block barrier caps the promotion — the standard bf16-gradient-stream
    discipline (§Perf mistral iteration 4: halved the dominant collective term)."""
    if x.dtype != jnp.bfloat16:
        return x
    return _bf16_barrier(x)


@jax.custom_vjp
def _rms_core(x, scale):
    d = x.shape[-1]
    ss = jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(ss[..., None] / d + 1e-6)
    return x * inv.astype(x.dtype) * (1.0 + scale).astype(x.dtype)


def _rms_core_fwd(x, scale):
    d = x.shape[-1]
    ss = jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(ss[..., None] / d + 1e-6)
    y = x * inv.astype(x.dtype) * (1.0 + scale).astype(x.dtype)
    return y, (x, inv, scale)


def _rms_core_bwd(res, g):
    """Closed-form backward in the stream dtype (fp32 only for the (…,1) stats and
    the scale grad): d_x = s·inv·g − x·inv³·⟨s·g, x⟩/d. Keeping d_x in bf16 stops the
    fp32-cotangent contagion of the residual stream (§Perf mistral iteration 4)."""
    x, inv, scale = res
    d = x.shape[-1]
    s1 = (1.0 + scale).astype(x.dtype)
    gy = g.astype(x.dtype) * s1
    dot = jnp.einsum("...d,...d->...", gy, x, preferred_element_type=jnp.float32)
    coef = (inv ** 3) * (dot[..., None] / d)
    d_x = gy * inv.astype(x.dtype) - x * coef.astype(x.dtype)
    # scale grad: fp32 accumulation over all batch dims
    xin = x * inv.astype(x.dtype)
    bdims = tuple(range(g.ndim - 1))
    d_scale = jnp.sum(
        g.astype(jnp.float32) * xin.astype(jnp.float32), axis=bdims
    ).astype(scale.dtype)
    return d_x, d_scale


_rms_core.defvjp(_rms_core_fwd, _rms_core_bwd)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 accumulation but no fp32 materialization of x (forward) and
    a custom bf16 backward (see _rms_core_bwd)."""
    return _rms_core(x, scale)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    d = x.shape[-1]
    ones = jnp.ones((d,), x.dtype)
    s1 = jnp.einsum("...d,d->...", x, ones, preferred_element_type=jnp.float32)
    ss = jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)
    mu = s1[..., None] / d
    var = ss[..., None] / d - jnp.square(mu)
    inv = jax.lax.rsqrt(var + eps)
    mu_c = mu.astype(x.dtype)
    return (x - mu_c) * inv.astype(x.dtype) * scale.astype(x.dtype) + bias.astype(x.dtype)


def apply_norm(cfg, x: jax.Array, p: dict) -> jax.Array:
    # the barrier sits at the norm output: the SP all-gather (fwd) / reduce-scatter
    # (bwd transpose) lives here, and the fp32 score/stat cotangents arrive here —
    # casting at this edge keeps every stream collective in bf16 (§Perf).
    if cfg.norm == "rms":
        return grad_dtype_barrier(rms_norm(x, p["scale"]))
    return grad_dtype_barrier(layer_norm(x, p["scale"], p["bias"]))


def norm_params(cfg, d: int, dtype) -> dict:
    if cfg.norm == "rms":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# -- rotary ------------------------------------------------------------------


def rope_cos_sin(
    positions: jax.Array, dim: int, theta: float
) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) int32 → cos/sin (..., dim/2) float32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, D); cos/sin (..., S, D/2) broadcast over heads (half-rotation)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(dt)


# -- MLP ----------------------------------------------------------------------


def mlp_params(cfg, key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale = d_model ** -0.5
    p = {"w_out": jax.random.normal(k2, (d_ff, d_model), dtype) * (d_ff ** -0.5)}
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(k1, (d_model, d_ff), dtype) * scale
        p["w_up"] = jax.random.normal(k3, (d_model, d_ff), dtype) * scale
    else:
        p["w_up"] = jax.random.normal(k1, (d_model, d_ff), dtype) * scale
    return p


def mlp_apply(cfg, p: dict, x: jax.Array) -> jax.Array:
    """x (B, S, d) → (B, S, d); hidden sharded over tp."""
    if cfg.act in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = shard(h, "dp", None, "tp")
    return h @ p["w_out"]


# -- embedding / logits / loss -------------------------------------------------


def embed_params(cfg, key, dtype) -> dict:
    e = jax.random.normal(key, (cfg.vocab_padded, cfg.d_model), dtype) * 0.02
    return {"embedding": e}


def embed_apply(cfg, p: dict, tokens: jax.Array) -> jax.Array:
    x = p["embedding"][tokens]  # gather over vocab-sharded table
    return shard(x, "dp", None, None)


def logits_apply(cfg, p: dict, x: jax.Array) -> jax.Array:
    """(B, S, d) → (B, S, vocab_padded), vocab sharded over tp."""
    logits = x @ p["embedding"].T.astype(x.dtype)
    return shard(logits, "dp", None, "tp")


def cross_entropy(cfg, logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over all positions; padded vocab ids masked out of the logsumexp.
    logits stay vocab-sharded: logsumexp and the one-hot pick are sharded reductions
    (GSPMD inserts partial-reduce + all-reduce; no full-vocab gather materializes)."""
    v = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (v,), 0)
    logits = jnp.where(iota[None, None, :] < cfg.vocab, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # label pick via masked sum (NOT take_along_axis: a gather on the vocab-sharded
    # axis would make GSPMD all-gather the logits; the masked sum stays sharded)
    picked = jnp.sum(
        jnp.where(iota[None, None, :] == labels[..., None], logits, 0.0), axis=-1
    )
    return jnp.mean(lse - picked)
