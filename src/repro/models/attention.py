"""Attention: GQA (full / sliding-window / bidirectional), MLA, cross-attention,
and single-token decode paths.

Train/prefill attention is *q-chunked with static KV spans*: the query axis is split
into Python-unrolled chunks; each chunk attends to a statically-sliced KV span
([0, (i+1)·C) for causal, an aligned window for SWA). This keeps peak memory at
O(C · span) instead of O(S²) and gives SWA true O(S·w) compute — the jnp analogue of a
flash kernel (the Pallas kernel in repro/kernels mirrors the same tiling).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.ctx import shard
from .layers import apply_rope, rope_cos_sin


def _attn_chunk(q, k, v, bias):
    """q (B,Cq,H,Dk), k (B,Sk,KV,Dk), v (B,Sk,KV,Dv) → (B,Cq,H,Dv). Softmax in fp32.
    Dv may differ from Dk (MLA)."""
    b, cq, h, d = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]
    rep = h // kvh
    qg = q.reshape(b, cq, kvh, rep, d)
    scores = jnp.einsum(
        "bqkrd,bskd->bkrqs", qg, k, preferred_element_type=jnp.float32
    )  # fp32 accumulation, no separate convert pass over the S² tensor
    scores = scores * (d ** -0.5)
    if bias is not None:
        scores = scores + bias  # (1,1,1,Cq,Sk) additive mask
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", w, v)
    return out.reshape(b, cq, h, dv)


def _causal_bias(q_start: int, cq: int, k_start: int, sk: int, window: int) -> Optional[jnp.ndarray]:
    """Additive -inf mask for chunk rows [q_start, q_start+cq) over kv [k_start,
    k_start+sk). Built from iota (never materialized as an HLO constant); returns None
    when the whole span is statically visible to every row."""
    fully_causal = (k_start + sk - 1) <= q_start
    fully_in_window = window == 0 or k_start > (q_start + cq - 1) - window
    if fully_causal and fully_in_window:
        return None
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (cq, sk), 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (cq, sk), 1)
    ok = kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, -1e30)[None, None, None, :, :].astype(jnp.float32)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    chunk: int = 2048,
) -> jax.Array:
    """q (B,S,H,D), k/v (B,S,KV,D). Python-unrolled q chunks, static KV spans."""
    b, s, h, d = q.shape
    c = min(chunk, s)
    while s % c != 0:
        c //= 2
    n_chunks = s // c
    outs = []
    for i in range(n_chunks):
        q_start = i * c
        qc = q[:, q_start : q_start + c]
        if not causal:
            k_start, k_end = 0, s
        elif window > 0:
            lo = max(0, (q_start - window + 1) // c * c)
            k_start, k_end = lo, q_start + c
        else:
            k_start, k_end = 0, q_start + c
        ks = k[:, k_start:k_end]
        vs = v[:, k_start:k_end]
        bias = (
            _causal_bias(q_start, c, k_start, k_end - k_start, window) if causal else None
        )
        outs.append(_attn_chunk(qc, ks, vs, bias))
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def attn_params(cfg, key, dtype, kv_heads: Optional[int] = None) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    kv = kv_heads if kv_heads is not None else cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(k1, (d, h * hd), dtype) * s,
        "wk": jax.random.normal(k2, (d, kv * hd), dtype) * s,
        "wv": jax.random.normal(k3, (d, kv * hd), dtype) * s,
        "wo": jax.random.normal(k4, (h * hd, d), dtype) * ((h * hd) ** -0.5),
    }


def _split_heads(cfg, x, n):
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def _shard_heads(cfg, x):
    if cfg.shard_attn_heads:
        return shard(x, "dp", None, "tp", None)
    return shard(x, "dp", None, None, None)


def attn_apply(
    cfg,
    p: dict,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool,
    window: int,
    rope_theta: float,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> jax.Array:
    """Full GQA block (train/prefill). kv_override supplies cross-attention memory."""
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _shard_heads(cfg, _split_heads(cfg, x @ p["wq"], h))
    if kv_override is None:
        k = _split_heads(cfg, x @ p["wk"], kv)
        v = _split_heads(cfg, x @ p["wv"], kv)
        cos, sin = rope_cos_sin(positions, hd, rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    else:
        mem, mem_positions = kv_override
        k = _split_heads(cfg, mem @ p["wk"], kv)
        v = _split_heads(cfg, mem @ p["wv"], kv)
        cos, sin = rope_cos_sin(positions, hd, rope_theta)
        q = apply_rope(q, cos, sin)
        mcos, msin = rope_cos_sin(mem_positions, hd, rope_theta)
        k = apply_rope(k, mcos, msin)
    k = shard(k, "dp", None, None, None)
    v = shard(v, "dp", None, None, None)
    out = chunked_attention(q, k, v, causal=causal, window=window)
    out = _shard_heads(cfg, out)
    b, s, _, _ = out.shape
    return out.reshape(b, s, h * hd) @ p["wo"]


def attn_kv_for_cache(cfg, p, x, positions, rope_theta):
    """Project + rope k/v for prefill cache construction."""
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    k = _split_heads(cfg, x @ p["wk"], kv)
    v = _split_heads(cfg, x @ p["wv"], kv)
    cos, sin = rope_cos_sin(positions, hd, rope_theta)
    return apply_rope(k, cos, sin), v


def attn_decode(
    cfg,
    p: dict,
    x: jax.Array,                 # (B, 1, d)
    k_cache: jax.Array,           # (B, S, KV, hd) — seq sharded over tp ("sp"-like)
    v_cache: jax.Array,
    pos: jax.Array,               # scalar: current length
    *,
    window: int,
    rope_theta: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. The cache is a rotating buffer of size S_max (window layers:
    S_max = window). Returns (out, new_k_cache, new_v_cache).

    The softmax over the seq-sharded cache lowers to partial reductions + a small
    all-reduce (flash-decoding split-KV; GSPMD derives it from the shardings)."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s_max = k_cache.shape[1]

    q = _shard_heads(cfg, _split_heads(cfg, x @ p["wq"], h))
    k_new = _split_heads(cfg, x @ p["wk"], kv)
    v_new = _split_heads(cfg, x @ p["wv"], kv)
    cos, sin = rope_cos_sin(pos[None], hd, rope_theta)
    q = apply_rope(q, cos[None], sin[None])
    k_new = apply_rope(k_new, cos[None], sin[None])

    slot = jnp.where(window > 0, pos % s_max, jnp.minimum(pos, s_max - 1))
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new, (0, slot, 0, 0))

    rep = h // kv
    qg = q.reshape(b, 1, kv, rep, hd)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, k_cache).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    # validity: slots beyond the current position are padding until the buffer is
    # full/rotating (pos ≥ s_max), after which every slot is live.
    kpos = jnp.arange(s_max)
    valid = (kpos[None, None, None, None, :] <= pos) | (pos >= s_max)
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", w, v_cache).reshape(b, 1, h * hd)
    return out @ p["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV cache
# ---------------------------------------------------------------------------


def mla_params(cfg, key, dtype) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    r, nd, vd, rd = cfg.kv_lora, cfg.qk_nope_dim, cfg.v_head_dim, cfg.qk_rope_dim
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, h * (nd + rd)), dtype) * s,
        "w_dkv": jax.random.normal(ks[1], (d, r + rd), dtype) * s,   # latent + shared k_rope
        "w_uk": jax.random.normal(ks[2], (r, h * nd), dtype) * (r ** -0.5),
        "w_uv": jax.random.normal(ks[3], (r, h * vd), dtype) * (r ** -0.5),
        "wo": jax.random.normal(ks[4], (h * vd, d), dtype) * ((h * vd) ** -0.5),
    }


def mla_apply(cfg, p, x, *, positions, rope_theta) -> jax.Array:
    """Train/prefill MLA (expanded form)."""
    b, s, d = x.shape
    h = cfg.n_heads
    r, nd, vd, rd = cfg.kv_lora, cfg.qk_nope_dim, cfg.v_head_dim, cfg.qk_rope_dim

    q = (x @ p["wq"]).reshape(b, s, h, nd + rd)
    q = shard(q, "dp", None, "tp", None)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    ckv = x @ p["w_dkv"]                    # (B,S,r+rd)
    c, k_rope = ckv[..., :r], ckv[..., r:]
    cos, sin = rope_cos_sin(positions, rd, rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[..., None, :], cos, sin)  # (B,S,1,rd) shared head

    k_nope = (c @ p["w_uk"]).reshape(b, s, h, nd)
    v = (c @ p["w_uv"]).reshape(b, s, h, vd)
    k_nope = shard(k_nope, "dp", None, "tp", None)
    v = shard(v, "dp", None, "tp", None)

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, rd))], axis=-1)
    out = chunked_attention(q_full, k_full, v, causal=True, window=0)
    return out.reshape(b, s, h * vd) @ p["wo"]


def mla_decode(
    cfg, p, x, c_cache, kr_cache, pos, *, rope_theta
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed-matrix MLA decode: scores against the 512-d latent cache directly —
    the cache per token is (kv_lora + rope_dim) values, the paper's headline saving."""
    b = x.shape[0]
    h = cfg.n_heads
    r, nd, vd, rd = cfg.kv_lora, cfg.qk_nope_dim, cfg.v_head_dim, cfg.qk_rope_dim
    s_max = c_cache.shape[1]

    q = (x @ p["wq"]).reshape(b, 1, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    cos, sin = rope_cos_sin(pos[None], rd, rope_theta)
    q_rope = apply_rope(q_rope, cos[None], sin[None])

    ckv = x @ p["w_dkv"]
    c_new, kr_new = ckv[..., :r], ckv[..., r:]
    kr_new = apply_rope(kr_new[..., None, :], cos[None], sin[None])[..., 0, :]
    slot = jnp.minimum(pos, s_max - 1)
    c_cache = jax.lax.dynamic_update_slice(c_cache, c_new, (0, slot, 0))
    kr_cache = jax.lax.dynamic_update_slice(kr_cache, kr_new, (0, slot, 0))

    # absorb W_uk into q: q_eff (B,h,r)
    w_uk = p["w_uk"].reshape(r, h, nd)
    q_eff = jnp.einsum("bqhn,rhn->bhr", q_nope, w_uk)
    scores = jnp.einsum("bhr,bsr->bhs", q_eff, c_cache).astype(jnp.float32)
    scores = scores + jnp.einsum("bqhd,bsd->bhs", q_rope, kr_cache).astype(jnp.float32)
    scores = scores * ((nd + rd) ** -0.5)
    kpos = jnp.arange(s_max)
    valid = (kpos[None, None, :] <= pos) | (pos >= s_max)
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhs,bsr->bhr", w, c_cache)
    w_uv = p["w_uv"].reshape(r, h, vd)
    out = jnp.einsum("bhr,rhv->bhv", ctx, w_uv).reshape(b, 1, h * vd)
    return out @ p["wo"], c_cache, kr_cache
