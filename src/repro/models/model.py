"""Model orchestration: embed → prefix blocks → scan(pattern × R) → norm → logits.

Parameters:
  {"embed": …, "final_norm": …,
   "prefix":  [block_params, …]                    # unrolled (e.g. DeepSeek layer 0)
   "blocks":  {"pos0": stacked(R), "pos1": …},     # one entry per pattern position
   "encoder": {"frames_norm": …, "blocks": stacked(R_enc), "final_norm": …}}  # encdec

Caches mirror "blocks"/"prefix" with stacked leading R; decode runs the same scan with
the cache threaded as scan xs/ys. Whisper decoder blocks carry self-attn + cross-attn
(cross K/V precomputed at prefill)."""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.ctx import shard
from .attention import (
    attn_apply,
    attn_decode,
    attn_kv_for_cache,
    attn_params,
    mla_apply,
    mla_decode,
    mla_params,
)
from .layers import (
    apply_norm,
    cross_entropy,
    embed_apply,
    embed_params,
    grad_dtype_barrier,
    logits_apply,
    mlp_apply,
    mlp_params,
    norm_params,
)
from .mamba import mamba_apply, mamba_decode, mamba_params, mamba_prefill
from .moe import moe_apply, moe_params


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _block_params(cfg, spec, key, with_cross: bool):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": norm_params(cfg, cfg.d_model, dt)}
    if spec.mixer == "attn":
        p["mixer"] = attn_params(cfg, ks[0], dt)
    elif spec.mixer == "mla":
        p["mixer"] = mla_params(cfg, ks[0], dt)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_params(cfg, ks[0], dt)
    else:
        raise ValueError(spec.mixer)
    if with_cross and spec.mixer in ("attn", "mla"):
        p["norm_cross"] = norm_params(cfg, cfg.d_model, dt)
        p["cross"] = attn_params(cfg, ks[1], dt)
    if spec.ffn:
        p["norm2"] = norm_params(cfg, cfg.d_model, dt)
        if spec.moe:
            p["moe"] = moe_params(cfg, ks[2], dt)
        else:
            p["ffn"] = mlp_params(cfg, ks[2], cfg.d_model, cfg.d_ff, dt)
    return p


def init_params(cfg, key) -> Dict[str, Any]:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_params(cfg, keys[0], dt),
        "final_norm": norm_params(cfg, cfg.d_model, dt),
    }
    with_cross = cfg.is_encdec
    params["prefix"] = [
        _block_params(cfg, spec, k, with_cross)
        for spec, k in zip(cfg.prefix, jax.random.split(keys[1], max(1, len(cfg.prefix))))
    ]
    blocks = {}
    r = cfg.n_repeats
    for i, spec in enumerate(cfg.pattern):
        pos_keys = jax.random.split(jax.random.fold_in(keys[2], i), r)
        blocks[f"pos{i}"] = jax.vmap(
            lambda k, s=spec: _block_params(cfg, s, k, with_cross)
        )(pos_keys)
    params["blocks"] = blocks
    if cfg.is_encdec:
        from ..configs.base import BlockSpec

        enc_spec = BlockSpec(mixer="attn", window=0)
        enc_keys = jax.random.split(keys[3], cfg.n_enc_layers)
        params["encoder"] = {
            "blocks": jax.vmap(
                lambda k: _block_params(cfg, enc_spec, k, with_cross=False)
            )(enc_keys),
            "final_norm": norm_params(cfg, cfg.d_model, dt),
        }
    return params


# ---------------------------------------------------------------------------
# forward (train / eval)
# ---------------------------------------------------------------------------


def _block_apply(
    cfg,
    spec,
    p,
    x,
    positions,
    *,
    causal: bool = True,
    enc_out=None,
    enc_positions=None,
):
    """Returns (x, aux_loss). With sp_boundary="layer", the residual is re-sharded
    on the sequence axis only once per block (1 all-gather + 1 reduce-scatter instead
    of one pair per sub-block) — §Perf mistral iteration 3."""
    aux = jnp.zeros((), jnp.float32)
    sub = cfg.sp_boundary != "layer"

    def reshard(t):
        return shard(t, "dp", "sp", None) if sub else t

    h = apply_norm(cfg, x, p["norm1"])
    if spec.mixer == "attn":
        h = attn_apply(
            cfg, p["mixer"], h,
            positions=positions, causal=causal,
            window=spec.window, rope_theta=spec.rope_theta,
        )
    elif spec.mixer == "mla":
        h = mla_apply(cfg, p["mixer"], h, positions=positions, rope_theta=spec.rope_theta)
    else:
        h = mamba_apply(cfg, p["mixer"], h)
    x = reshard(x + h)

    if enc_out is not None and "cross" in p:
        h = apply_norm(cfg, x, p["norm_cross"])
        h = attn_apply(
            cfg, p["cross"], h,
            positions=positions, causal=False, window=0,
            rope_theta=spec.rope_theta,
            kv_override=(enc_out, enc_positions),
        )
        x = reshard(x + h)

    if spec.ffn:
        h = apply_norm(cfg, x, p["norm2"])
        if spec.moe:
            h, aux = moe_apply(cfg, p["moe"], h)
        else:
            h = mlp_apply(cfg, p["ffn"], h)
        x = x + h
        x = shard(x, "dp", "sp", None)   # block boundary: always constrained
    else:
        x = shard(x, "dp", "sp", None)
    x = grad_dtype_barrier(x)            # cap fp32 cotangent contagion per block
    return x, aux


def _remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def _run_encoder(cfg, params, frames):
    """Whisper-style encoder over stub frame embeddings (B, F, d)."""
    x = frames.astype(_dtype(cfg))
    x = shard(x, "dp", None, None)
    positions = jnp.arange(frames.shape[1])[None, :]
    enc = params["encoder"]
    from ..configs.base import BlockSpec

    spec = BlockSpec(mixer="attn", window=0)

    def body(carry, layer_params):
        y, _ = _block_apply(cfg, spec, layer_params, carry, positions, causal=False)
        return y, None

    body = _remat_wrap(cfg, body)
    x, _ = jax.lax.scan(body, x, enc["blocks"])
    return apply_norm(cfg, x, enc["final_norm"])


def _embed_input(cfg, params, batch):
    """tokens (+ frontend stubs) → x (B, S_total, d), positions (B or 1, S_total)."""
    tokens = batch["tokens"]
    x = embed_apply(cfg, params["embed"], tokens)
    if cfg.frontend == "prefix_embeds":
        vis = batch["vision_embeds"].astype(x.dtype)   # (B, F, d)
        x = jnp.concatenate([vis, x], axis=1)
    positions = jnp.arange(x.shape[1])[None, :]
    return shard(x, "dp", "sp", None), positions


def model_forward(cfg, params, batch) -> Tuple[jax.Array, jax.Array]:
    """→ (logits (B, S_total, vocab_padded), aux_loss scalar)."""
    x, positions = _embed_input(cfg, params, batch)
    enc_out = enc_positions = None
    if cfg.is_encdec:
        enc_out = _run_encoder(cfg, params, batch["frames"])
        enc_positions = jnp.arange(enc_out.shape[1])[None, :]

    aux_total = jnp.zeros((), jnp.float32)
    for spec, p in zip(cfg.prefix, params["prefix"]):
        x, aux = _block_apply(
            cfg, spec, p, x, positions, enc_out=enc_out, enc_positions=enc_positions
        )
        aux_total = aux_total + aux

    def body(carry, layer_params):
        y, aux_acc = carry
        aux_step = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(cfg.pattern):
            y, aux = _block_apply(
                cfg, spec, layer_params[f"pos{i}"], y, positions,
                enc_out=enc_out, enc_positions=enc_positions,
            )
            aux_step = aux_step + aux
        return (y, aux_acc + aux_step), None

    body = _remat_wrap(cfg, body)
    (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["blocks"])

    x = apply_norm(cfg, x, params["final_norm"])
    logits = logits_apply(cfg, params["embed"], x)
    return logits, aux_total


def loss_fn(cfg, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = model_forward(cfg, params, batch)
    # next-token CE on the text region (frontend prefix positions excluded)
    s_text = batch["labels"].shape[1]
    logits_text = logits[:, -s_text:, :]
    ce = cross_entropy(cfg, logits_text[:, :-1], batch["labels"][:, 1:])
    loss = ce + 0.01 * aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# cache + decode
# ---------------------------------------------------------------------------


def _block_cache(cfg, spec, batch: int, s_max: int, dt, with_cross: bool, n_frontend: int):
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    s_c = min(spec.window, s_max) if spec.window > 0 else s_max
    if spec.mixer == "attn":
        c = {
            "k": jnp.zeros((batch, s_c, kv, hd), dt),
            "v": jnp.zeros((batch, s_c, kv, hd), dt),
        }
    elif spec.mixer == "mla":
        c = {
            "c": jnp.zeros((batch, s_max, cfg.kv_lora), dt),
            "kr": jnp.zeros((batch, s_max, cfg.qk_rope_dim), dt),
        }
    else:
        g, n = cfg.ssm_ngroups, cfg.d_state
        c = {
            "conv_x": jnp.zeros((batch, cfg.conv_k - 1, cfg.d_inner), dt),
            "conv_B": jnp.zeros((batch, cfg.conv_k - 1, g * n), dt),
            "conv_C": jnp.zeros((batch, cfg.conv_k - 1, g * n), dt),
            "state": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim, n), dt),
        }
    if with_cross and spec.mixer in ("attn", "mla"):
        c["cross_k"] = jnp.zeros((batch, n_frontend, kv, hd), dt)
        c["cross_v"] = jnp.zeros((batch, n_frontend, kv, hd), dt)
    return c


def init_cache(cfg, batch: int, s_max: int) -> Dict[str, Any]:
    """Zero cache sized for a context of s_max tokens."""
    dt = _dtype(cfg)
    cross = cfg.is_encdec
    cache: Dict[str, Any] = {
        "pos": jnp.zeros((), jnp.int32),
        "prefix": [
            _block_cache(cfg, spec, batch, s_max, dt, cross, cfg.n_frontend)
            for spec in cfg.prefix
        ],
        "blocks": {},
    }
    r = cfg.n_repeats
    for i, spec in enumerate(cfg.pattern):
        one = _block_cache(cfg, spec, batch, s_max, dt, cross, cfg.n_frontend)
        cache["blocks"][f"pos{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (r,) + a.shape), one
        )
    if cfg.is_encdec:
        cache["enc_out"] = jnp.zeros((batch, cfg.n_frontend, cfg.d_model), dt)
    return cache


def _block_decode(cfg, spec, p, c, x, pos, enc_out):
    """One-token decode through one block. Returns (x, new_cache)."""
    h = apply_norm(cfg, x, p["norm1"])
    new_c = dict(c)
    if spec.mixer == "attn":
        h, k2, v2 = attn_decode(
            cfg, p["mixer"], h, c["k"], c["v"], pos,
            window=spec.window, rope_theta=spec.rope_theta,
        )
        new_c["k"], new_c["v"] = k2, v2
    elif spec.mixer == "mla":
        h, c2, kr2 = mla_decode(
            cfg, p["mixer"], h, c["c"], c["kr"], pos, rope_theta=spec.rope_theta
        )
        new_c["c"], new_c["kr"] = c2, kr2
    else:
        conv = {"x": c["conv_x"], "B": c["conv_B"], "C": c["conv_C"]}
        h, conv2, st2 = mamba_decode(cfg, p["mixer"], h, conv, c["state"])
        new_c["conv_x"], new_c["conv_B"], new_c["conv_C"] = conv2["x"], conv2["B"], conv2["C"]
        new_c["state"] = st2
    x = x + h

    if enc_out is not None and "cross" in p:
        h = apply_norm(cfg, x, p["norm_cross"])
        # cross attention against the precomputed (cached) encoder K/V
        b = x.shape[0]
        hq = cfg.n_heads
        from .attention import _shard_heads, _split_heads
        from .layers import rope_cos_sin
        from .attention import apply_rope

        q = _shard_heads(cfg, _split_heads(cfg, h @ p["cross"]["wq"], hq))
        cos, sin = rope_cos_sin(pos[None], cfg.head_dim, spec.rope_theta)
        q = apply_rope(q, cos[None], sin[None])
        k, v = c["cross_k"], c["cross_v"]
        rep = hq // cfg.n_kv_heads
        qg = q.reshape(b, 1, cfg.n_kv_heads, rep, cfg.head_dim)
        scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, k).astype(jnp.float32)
        w = jax.nn.softmax(scores * (cfg.head_dim ** -0.5), axis=-1).astype(x.dtype)
        o = jnp.einsum("bkrqs,bskd->bqkrd", w, v).reshape(b, 1, hq * cfg.head_dim)
        x = x + o @ p["cross"]["wo"]

    if spec.ffn:
        h = apply_norm(cfg, x, p["norm2"])
        if spec.moe:
            h, _ = moe_apply(cfg, p["moe"], h)
        else:
            h = mlp_apply(cfg, p["ffn"], h)
        x = x + h
    return x, new_c


def decode_step(cfg, params, cache, tokens_last: jax.Array):
    """tokens_last (B,) → (logits (B, vocab_padded), new cache). One serve step."""
    pos = cache["pos"]
    x = embed_apply(cfg, params["embed"], tokens_last[:, None])  # (B,1,d)
    enc_out = cache.get("enc_out") if cfg.is_encdec else None

    new_prefix = []
    for spec, p, c in zip(cfg.prefix, params["prefix"], cache["prefix"]):
        x, c2 = _block_decode(cfg, spec, p, c, x, pos, enc_out)
        new_prefix.append(c2)

    def body(x, xs):
        layer_params, layer_cache = xs
        new_cache = {}
        for i, spec in enumerate(cfg.pattern):
            x, c2 = _block_decode(
                cfg, spec, layer_params[f"pos{i}"], layer_cache[f"pos{i}"], x, pos, enc_out
            )
            new_cache[f"pos{i}"] = c2
        return x, new_cache

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))

    x = apply_norm(cfg, x, params["final_norm"])
    logits = logits_apply(cfg, params["embed"], x)[:, 0, :]
    new_cache = dict(cache)
    new_cache["prefix"] = new_prefix
    new_cache["blocks"] = new_blocks
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill: forward + cache construction
# ---------------------------------------------------------------------------


def prefill(cfg, params, batch, cache_len: Optional[int] = None) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the context through the model, returning (last-token logits, cache).
    ``cache_len`` reserves decode headroom (defaults to the context length — the
    steady-state serving shapes, where each new token recycles the last slot)."""
    x, positions = _embed_input(cfg, params, batch)
    bsz, s_total = x.shape[0], x.shape[1]
    c_len = cache_len if cache_len is not None else s_total
    enc_out = enc_positions = None
    if cfg.is_encdec:
        enc_out = _run_encoder(cfg, params, batch["frames"])
        enc_positions = jnp.arange(enc_out.shape[1])[None, :]

    def run_block(spec, p, x):
        """Returns (x, cache_entry) for one block."""
        h = apply_norm(cfg, x, p["norm1"])
        entry = {}
        if spec.mixer == "attn":
            k, v = attn_kv_for_cache(cfg, p["mixer"], h, positions, spec.rope_theta)
            s_c = min(spec.window, c_len) if spec.window > 0 else c_len
            if s_total >= s_c:
                k_c, v_c = k[:, -s_c:], v[:, -s_c:]
                if 0 < spec.window and s_total % s_c:
                    # rotating-buffer layout: position q lives at slot q % s_c
                    shift = s_total % s_c
                    k_c = jnp.roll(k_c, shift, axis=1)
                    v_c = jnp.roll(v_c, shift, axis=1)
            else:
                pad = [(0, 0), (0, s_c - s_total), (0, 0), (0, 0)]
                k_c, v_c = jnp.pad(k, pad), jnp.pad(v, pad)
            entry["k"], entry["v"] = k_c, v_c
            h = attn_apply(
                cfg, p["mixer"], h,
                positions=positions, causal=True,
                window=spec.window, rope_theta=spec.rope_theta,
            )
            x = x + h
        elif spec.mixer == "mla":
            ckv = h @ p["mixer"]["w_dkv"]
            c_lat, k_rope = ckv[..., : cfg.kv_lora], ckv[..., cfg.kv_lora :]
            from .attention import apply_rope
            from .layers import rope_cos_sin

            cos, sin = rope_cos_sin(positions, cfg.qk_rope_dim, spec.rope_theta)
            kr = apply_rope(k_rope[..., None, :], cos, sin)[..., 0, :]
            if c_len > s_total:
                pad2 = [(0, 0), (0, c_len - s_total), (0, 0)]
                c_lat, kr = jnp.pad(c_lat, pad2), jnp.pad(kr, pad2)
            entry["c"] = c_lat
            entry["kr"] = kr
            h = mla_apply(cfg, p["mixer"], h, positions=positions, rope_theta=spec.rope_theta)
            x = x + h
        else:
            h, conv_state, st = mamba_prefill(cfg, p["mixer"], h)
            entry["conv_x"], entry["conv_B"], entry["conv_C"] = (
                conv_state["x"], conv_state["B"], conv_state["C"],
            )
            entry["state"] = st
            x = x + h
        x = shard(x, "dp", "sp", None)

        if enc_out is not None and "cross" in p:
            hc = apply_norm(cfg, x, p["norm_cross"])
            ck, cv = attn_kv_for_cache(cfg, p["cross"], enc_out, enc_positions, spec.rope_theta)
            entry["cross_k"], entry["cross_v"] = ck, cv
            hc = attn_apply(
                cfg, p["cross"], hc,
                positions=positions, causal=False, window=0,
                rope_theta=spec.rope_theta,
                kv_override=(enc_out, enc_positions),
            )
            x = x + hc
            x = shard(x, "dp", "sp", None)

        if spec.ffn:
            h2 = apply_norm(cfg, x, p["norm2"])
            if spec.moe:
                h2, _ = moe_apply(cfg, p["moe"], h2)
            else:
                h2 = mlp_apply(cfg, p["ffn"], h2)
            x = x + h2
            x = shard(x, "dp", "sp", None)
        return x, entry

    prefix_cache = []
    for spec, p in zip(cfg.prefix, params["prefix"]):
        x, entry = run_block(spec, p, x)
        prefix_cache.append(entry)

    def body(x, layer_params):
        entries = {}
        for i, spec in enumerate(cfg.pattern):
            x, entry = run_block(spec, layer_params[f"pos{i}"], x)
            entries[f"pos{i}"] = entry
        return x, entries

    body = _remat_wrap(cfg, body)
    x, block_cache = jax.lax.scan(body, x, params["blocks"])

    x = apply_norm(cfg, x, params["final_norm"])
    logits = logits_apply(cfg, params["embed"], x[:, -1:, :])[:, 0, :]

    cache: Dict[str, Any] = {
        "pos": jnp.array(s_total, jnp.int32),
        "prefix": prefix_cache,
        "blocks": block_cache,
    }
    if cfg.is_encdec:
        cache["enc_out"] = enc_out
    return logits, cache
