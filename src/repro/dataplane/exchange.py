"""Capacity-padded hash exchange over a mesh axis (the MPC routing round on TPU).

Each device holds `rows (cap_in, w)` with the first `count` rows valid. The exchange:
  1. partition ids via the hash_partition Pallas kernel (shared-seed hashing ⇒ every
     device agrees, the paper's footnote-2 common randomness);
  2. sort rows by destination, place into a (P, cap_slot, w) send buffer;
  3. one `all_to_all` over the axis;
  4. receive (P, cap_slot, w) + per-source counts; compact back to (cap_out, w).

Capacity: the paper guarantees Õ(m/p) received rows w.h.p. for its routing steps, so
cap_slot = c·ceil(cap_in/P) with slack c. Overflow is *detected and returned*, never
silently dropped — the engine's retry doubles capacity, replacing the paper's 1/p^c
failure probability. Overflow is reported on two separate channels so the retry can
scale only the buffer that actually overflowed:

  * *slot* overflow — a destination's send slot exceeded ``cap_slot`` (routing
    imbalance; fixed by bigger routing buffers and/or fresh routing randomness);
  * *out* overflow — the compacted receive side exceeded ``cap_out`` (the output
    estimate was too small; fixed by a bigger output buffer alone)."""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.ops import hash_partition, hash_partition_pack, probe_use_pallas


def _partition_ids(keys: jax.Array, n_parts: int) -> jax.Array:
    """Partition id per key: the hash_partition Pallas kernel on TPU, its
    bit-identical jnp mix elsewhere (the interpreter would only emulate the
    kernel at a large trace-size cost; equality is asserted in tests)."""
    if probe_use_pallas():
        return hash_partition(keys, n_parts)[0]
    from ..kernels.ref import hash_u32_ref

    return (hash_u32_ref(keys) % jnp.uint32(n_parts)).astype(jnp.int32)


@dataclass
class PaddedShard:
    """Device-local padded relation block (used inside shard_map bodies)."""

    rows: jax.Array    # (cap, w) int32
    count: jax.Array   # scalar int32 — valid prefix length

    @property
    def cap(self) -> int:
        return self.rows.shape[0]


def _valid_mask(cap: int, count: jax.Array) -> jax.Array:
    return jnp.arange(cap) < count


def blockify(rows, p: int, cap: Optional[int] = None, to_device: bool = True):
    """Host-side staging: split an (n, w) numpy array into evenly-spread
    per-device blocks.  Returns (blocks (p, cap, w) int32, counts (p,) int32).
    Values must fit int32 (the device word contract; INT32_MAX is reserved).
    ``to_device=False`` keeps the blocks as numpy — the stage-batched
    scheduler stacks many stages host-side and ships one buffer per bucket."""
    import numpy as np

    rows = np.asarray(rows)
    if rows.ndim == 1:
        rows = rows.reshape(-1, 1)
    n, w = rows.shape
    if n and (rows.max() >= np.iinfo(np.int32).max or rows.min() < np.iinfo(np.int32).min):
        raise ValueError("values exceed the int32 device word contract")
    per = -(-n // p) if n else 0
    if cap is None:
        cap = max(1, per)
    if per > cap:
        raise ValueError(f"cap {cap} < required {per}")
    blocks = np.zeros((p, cap, w), np.int32)
    counts = np.zeros((p,), np.int32)
    for i in range(p):
        part = rows[i * per : (i + 1) * per]
        blocks[i, : len(part)] = part
        counts[i] = len(part)
    if not to_device:
        return blocks, counts
    return jnp.asarray(blocks), jnp.asarray(counts)


def unblockify(blocks, counts):
    """Inverse of `blockify` (after any exchanges): concatenate the valid
    prefixes of all device blocks into one (n, w) int64 numpy array."""
    import numpy as np

    b = np.asarray(blocks)
    c = np.asarray(counts)
    parts = [b[i, : int(c[i])] for i in range(b.shape[0])]
    out = np.concatenate(parts, axis=0) if parts else np.zeros((0, b.shape[2]), b.dtype)
    return out.astype(np.int64)


def pack_by_partition(
    rows: jax.Array, count: jax.Array, part: jax.Array, n_parts: int, cap_slot: int,
    slot: Optional[jax.Array] = None, send_counts: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """→ (send (P, cap_slot, w), send_counts (P,), overflow scalar).
    Rows beyond a destination's cap_slot overflow (counted, not sent).

    Sort-free: a row's slot is its rank among same-destination rows in input
    order — exactly what the former stable argsort produced — computed by a
    masked running count, so the scatter into the (P, cap_slot, w) send buffer
    needs no reordering pass.  When the fused `hash_partition_pack` kernel
    already produced (slot, send_counts) (the TPU path), both are accepted
    precomputed and the one-hot pass is skipped entirely."""
    cap, w = rows.shape
    if slot is None:
        valid = _valid_mask(cap, count)
        part = jnp.where(valid, part, n_parts)          # invalid → ghost partition
        onehot = jax.nn.one_hot(part, n_parts + 1, dtype=jnp.int32)
        slot = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
        send_counts = onehot.sum(0)[:n_parts]
    overflow = jnp.maximum(send_counts - cap_slot, 0).sum()
    keep = (part < n_parts) & (slot < cap_slot)
    send = jnp.zeros((n_parts, cap_slot, w), rows.dtype)
    # ghost/overflowing rows get an out-of-bounds destination and are dropped
    send = send.at[part, jnp.where(keep, slot, cap_slot)].set(rows, mode="drop")
    return send, jnp.minimum(send_counts, cap_slot), overflow


def compact(recv: jax.Array, recv_counts: jax.Array, cap_out: int):
    """(P, cap_slot, w) + (P,) → (cap_out, w), total, overflow.

    Sort-free: each valid row scatters to its rank among valid rows (stable
    prefix-sum destination); invalid and beyond-cap rows scatter out of bounds
    and are dropped — same output as the former stable argsort."""
    p, cap_slot, w = recv.shape
    valid = jnp.arange(cap_slot)[None, :] < recv_counts[:, None]
    flat = recv.reshape(p * cap_slot, w)
    vflat = valid.reshape(-1)
    total = vflat.sum()
    overflow = jnp.maximum(total - cap_out, 0)
    dest = jnp.where(vflat, jnp.cumsum(vflat) - 1, cap_out)
    out = jnp.zeros((cap_out, w), recv.dtype).at[dest].set(flat, mode="drop")
    return out, jnp.minimum(total, cap_out), overflow


def salt_offset(salt: int) -> int:
    """Additive key offset derived from a routing salt (Knuth multiplicative
    mix).  Computed host-side so it can be fed to a jitted exchange as a traced
    scalar — one compiled executable serves every salt."""
    return salt * 2654435761 % (2**31)


def exchange_by_partition(
    rows: jax.Array,
    count: jax.Array,
    part: jax.Array,
    axis_name: str,
    n_parts: int,
    cap_slot: int,
    cap_out: int,
    slot: Optional[jax.Array] = None,
    slot_counts: Optional[jax.Array] = None,
):
    """Inside shard_map: route rows to explicit destinations `part` (cap,) over
    `axis_name`.  Returns (rows_out (cap_out, w), count_out, ovf_slot, ovf_out).
    ``slot``/``slot_counts`` accept the fused `hash_partition_pack` kernel's
    precomputed send layout (see `pack_by_partition`)."""
    send, send_counts, ovf_slot = pack_by_partition(
        rows, count, part, n_parts, cap_slot, slot, slot_counts
    )
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0, tiled=False)
    recv_counts = jax.lax.all_to_all(
        send_counts.reshape(n_parts, 1), axis_name, split_axis=0, concat_axis=0, tiled=False
    ).reshape(n_parts)
    out, count_out, ovf_out = compact(recv, recv_counts, cap_out)
    return out, count_out, ovf_slot, ovf_out


def batched_exchange_by_partition(
    rows: jax.Array,
    counts: jax.Array,
    part: jax.Array,
    axis_name: str,
    n_parts: int,
    cap_slot: int,
    cap_out: int,
    slot: Optional[jax.Array] = None,
    slot_counts: Optional[jax.Array] = None,
):
    """Inside shard_map: the stage-batched twin of `exchange_by_partition`.

    ``rows`` (s, cap, w), ``counts`` (s,), ``part`` (s, cap): s independent
    stages share **one** ``all_to_all`` — the pack/compact halves are vmapped
    over the stage axis and the send buffers ride the collective stacked, so a
    whole geometry bucket costs a single dispatch instead of s.  Returns
    (rows_out (s, cap_out, w), counts_out (s,), ovf_slot (s,), ovf_out (s,))
    — per-stage overflow so the retry can re-run only the stages that
    tripped."""
    s = rows.shape[0]
    if slot is None:
        send, send_counts, ovf_slot = jax.vmap(
            pack_by_partition, in_axes=(0, 0, 0, None, None)
        )(rows, counts, part, n_parts, cap_slot)
    else:
        send, send_counts, ovf_slot = jax.vmap(
            pack_by_partition, in_axes=(0, 0, 0, None, None, 0, 0)
        )(rows, counts, part, n_parts, cap_slot, slot, slot_counts)
    recv = jax.lax.all_to_all(
        send, axis_name, split_axis=1, concat_axis=1, tiled=False
    )
    recv_counts = jax.lax.all_to_all(
        send_counts.reshape(s, n_parts, 1),
        axis_name, split_axis=1, concat_axis=1, tiled=False,
    ).reshape(s, n_parts)
    out, count_out, ovf_out = jax.vmap(compact, in_axes=(0, 0, None))(
        recv, recv_counts, cap_out
    )
    return out, count_out, ovf_slot, ovf_out


def batched_hash_exchange(
    rows: jax.Array,
    counts: jax.Array,
    key_col: int,
    axis_name: str,
    n_parts: int,
    cap_slot: int,
    cap_out: int,
    offs: jax.Array,
):
    """Inside shard_map: stage-batched `hash_exchange` — s stages exchanged by
    hash(key + per-stage offset) through one collective.  ``offs`` (s,) holds
    the per-stage traced salt offsets (`salt_offset`), so stages with
    different routing salts still share the executable.  Returns
    (rows_out (s, cap_out, w), counts (s,), ovf_slot (s,), ovf_out (s,))."""
    s, cap, _ = rows.shape
    keys = rows[:, :, key_col].astype(jnp.int32) + offs[:, None].astype(jnp.int32)
    if probe_use_pallas():
        # fused kernel: hash + partition + slot + send counts in one pass,
        # vmapped over the stage axis (bit-identical to the jnp path below)
        part, slot, slot_counts = jax.vmap(
            lambda k, c: hash_partition_pack(k, c, n_parts)
        )(keys, counts)
        return batched_exchange_by_partition(
            rows, counts, part, axis_name, n_parts, cap_slot, cap_out,
            slot, slot_counts,
        )
    # the partition hash is per-key, so the flattened batch partitions
    # identically to s separate calls (the unbatched path's exact function).
    part = _partition_ids(keys.reshape(s * cap), n_parts)
    return batched_exchange_by_partition(
        rows, counts, part.reshape(s, cap), axis_name, n_parts, cap_slot, cap_out
    )


def hash_exchange(
    rows: jax.Array,
    count: jax.Array,
    key_col: int,
    axis_name: str,
    n_parts: int,
    cap_slot: int,
    cap_out: int,
    salt=0,
):
    """Inside shard_map: route rows by hash(key) over `axis_name`.
    Returns (rows_out (cap_out, w), count_out, ovf_slot, ovf_out).

    ``salt`` is either a Python int (mixed via `salt_offset` at trace time) or
    a traced int32 scalar already holding the offset."""
    if isinstance(salt, int):
        off = jnp.int32(salt_offset(salt))
    else:
        off = salt.astype(jnp.int32)
    keys = rows[:, key_col].astype(jnp.int32) + off
    if probe_use_pallas():
        part, slot, slot_counts = hash_partition_pack(keys, count, n_parts)
        return exchange_by_partition(
            rows, count, part, axis_name, n_parts, cap_slot, cap_out,
            slot, slot_counts,
        )
    part = _partition_ids(keys, n_parts)
    return exchange_by_partition(rows, count, part, axis_name, n_parts, cap_slot, cap_out)
