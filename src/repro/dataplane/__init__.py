"""JAX data plane: the engine's communication phases on a real device mesh.

Static-shape MPC (DESIGN.md §2.3): relations are capacity-padded per-device buffers
(rows + validity count); exchanges are single all_to_all collectives sized by the
paper's own w.h.p. load bounds, with overflow surfaced as a counter. Validated
bit-for-bit against the exact-cost simulator in tests/test_dataplane_subprocess.py.
"""

from .exchange import PaddedShard, blockify, exchange_by_partition, hash_exchange, unblockify
from .grid import (
    CPRouteSpec,
    HCRouteSpec,
    cp_route_spec,
    hc_route_spec,
    sharded_grid_route,
)
from .join import (
    hypercube_binary_join,
    local_join_count,
    local_join_filtered,
    local_semijoin,
    local_sorted_join,
    local_unique,
    sharded_colocated_join,
    sharded_intersect,
    sharded_join_step,
    sharded_semijoin,
)
