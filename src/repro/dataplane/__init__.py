"""JAX data plane: the engine's communication phases on a real device mesh.

Static-shape MPC (DESIGN.md §2.3): relations are capacity-padded per-device buffers
(rows + validity count); exchanges are single all_to_all collectives sized by the
paper's own w.h.p. load bounds, with overflow surfaced as a counter. Validated
bit-for-bit against the exact-cost simulator in tests/test_dataplane_subprocess.py.
"""

from .exchange import PaddedShard, blockify, hash_exchange, unblockify
from .join import (
    hypercube_binary_join,
    local_join_filtered,
    local_semijoin,
    local_sorted_join,
    local_unique,
    sharded_intersect,
    sharded_join_step,
    sharded_semijoin,
)
