"""Step-3 grid routing on the device mesh (the GridRoute op's dataplane lowering).

The Lemma 3.1 cartesian grid over the isolated R''_X lists is composed with
the Lemma 3.3 HyperCube over L \\ I via the Lemma 3.2 matrix: virtual machine
``v = cp_cell * hc_size + hc_cell``.  `sharded_grid_route` realizes both sides
of that composition with one primitive: every row is *replicated* to its set
of destination virtual cells (a static per-fragment fan-out), tagged with the
cell id in a new leading column, and exchanged with the same capacity-padded
``all_to_all`` the hash exchange uses — virtual cell ``v`` lives on device
``v % p``.  Afterwards all fragments of a cell are co-located, so the LocalJoin
op lowers to communication-free `sharded_colocated_join` steps keyed on the
cell column.

Destination sets come from the *same* geometry the simulator uses:

  * isolated pieces — global tuple ids ``offset(device) + arange(count)``
    (offsets derived from the BroadcastSizes piece counts in sorted-device
    order, see ``stage_geometry``), mapped through
    ``CartesianGrid.cells_for_ids`` (lists beyond t' are broadcast to every
    CP cell), then replicated across every HyperCube column;
  * light-edge residents — per-attribute salted coordinate hashes mapped
    through ``HyperCubeGrid.cells_for`` (free dims enumerated), then
    replicated across every CP row.

Both sides share the static cell-contribution helpers (`cp_cell_contribs`,
`hc_cell_contribs`) with the grids' numpy/jnp coordinate methods, so the
dataplane and the simulator enumerate identical cells by construction.

Overflow contract matches repro.dataplane.join: ``ovf`` is (p, 2) with
column 0 = send-slot overflow, column 1 = output overflow.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..mpc.cartesian import CartesianGrid, cp_cell_contribs, cp_cells_dev
from ..mpc.hypercube import HyperCubeGrid, hc_cell_contribs, hc_cells_dev
from .exchange import batched_exchange_by_partition, exchange_by_partition


# ---------------------------------------------------------------------------
# Route specs (static, hashable — they key the jit/shard_map cache)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CPRouteSpec:
    """Destination rule for one isolated R''_X list (Lemma 3.1 side)."""

    dims: Tuple[int, ...]       # CP grid dimensions (size-desc list order)
    list_idx: int               # this list's position in the size-desc order
    t_prime: int                # lists ≥ t' are broadcast to every CP cell
    hc_size: int                # HyperCube columns to replicate across

    @property
    def fanout(self) -> int:
        cp_size = math.prod(self.dims) if self.dims else 1
        if self.list_idx < self.t_prime:
            n_other = cp_size // self.dims[self.list_idx]
        else:
            n_other = cp_size
        return n_other * self.hc_size


@dataclass(frozen=True)
class HCRouteSpec:
    """Destination rule for one light-edge fragment (Lemma 3.3 side)."""

    fixed: Tuple[Tuple[int, int, int], ...]   # (column, share, flat stride)
    free_contribs: Tuple[int, ...]            # flat ids of the free-dim combos
    cp_size: int                              # CP rows to replicate across
    hc_size: int

    @property
    def fanout(self) -> int:
        return len(self.free_contribs) * self.cp_size


def cp_route_spec(grid: CartesianGrid, list_idx: int, hc_size: int) -> CPRouteSpec:
    return CPRouteSpec(
        dims=tuple(grid.dims), list_idx=list_idx, t_prime=grid.t_prime,
        hc_size=hc_size,
    )


def hc_route_spec(
    grid: HyperCubeGrid, scheme: Sequence[str], cp_size: int
) -> HCRouteSpec:
    """Spec for a fragment over ``scheme``: every scheme attribute present in
    the grid becomes a hashed (fixed) coordinate, the rest enumerate."""
    fixed_attrs = [a for a in scheme if a in grid.attrs]
    strides, contribs = hc_cell_contribs(grid.attrs, grid.dims, fixed_attrs)
    fixed = tuple(
        (list(scheme).index(a), grid.share(a), strides[a]) for a in fixed_attrs
    )
    return HCRouteSpec(
        fixed=fixed, free_contribs=contribs, cp_size=cp_size, hc_size=grid.size
    )


# ---------------------------------------------------------------------------
# Device-side pieces
# ---------------------------------------------------------------------------


def coord_hash(vals: jax.Array, salt: jax.Array) -> jax.Array:
    """Per-attribute coordinate hash: uint32 avalanche mix of (value, salt).
    Every device evaluates the same function (shared randomness, paper
    footnote 2); the salt is traced so a retry's fresh randomness does not
    retrace the executable."""
    h = vals.astype(jnp.uint32) * jnp.uint32(2654435761) + salt.astype(jnp.uint32)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(2246822519)
    h = h ^ (h >> 13)
    return h


def replicate_to_cells(
    rows: jax.Array,        # (cap, w) valid-prefix padded
    count: jax.Array,       # scalar
    dests: jax.Array,       # (cap, R) destination virtual cells per row
    axis_name: str,
    p: int,
    cap_slot: int,
    cap_out: int,
):
    """Inside shard_map: send one copy of each row to every destination cell,
    tagged with the cell id in a new leading column; cell v → device v % p.
    Returns (out (cap_out, 1+w), count, ovf_slot, ovf_out)."""
    cap, w = rows.shape
    fanout = dests.shape[1]
    rep = jnp.repeat(rows, fanout, axis=0)              # keeps prefix validity
    v = dests.reshape(-1).astype(jnp.int32)
    tagged = jnp.concatenate([v[:, None], rep], axis=1)
    return exchange_by_partition(
        tagged, count * fanout, v % p, axis_name, p, cap_slot, cap_out
    )


@lru_cache(maxsize=512)
def _cp_route_fn(mesh, axis_name, spec: CPRouteSpec, cap_slot, cap_out):
    from jax.experimental.shard_map import shard_map

    p = mesh.shape[axis_name]
    cp_size = math.prod(spec.dims) if spec.dims else 1

    def body(rows, cnts, offs):
        rows, cnt, off = rows[0], cnts[0], offs[0]
        cap = rows.shape[0]
        ids = off.astype(jnp.int32) + jnp.arange(cap, dtype=jnp.int32)
        if spec.list_idx < spec.t_prime:
            cells = cp_cells_dev(ids, spec.dims, spec.list_idx)
        else:   # too small to matter: broadcast to every CP cell (Lemma 3.1)
            cells = jnp.broadcast_to(
                jnp.arange(cp_size, dtype=jnp.int32)[None, :], (cap, cp_size)
            )
        dests = (
            cells[:, :, None] * spec.hc_size
            + jnp.arange(spec.hc_size, dtype=jnp.int32)[None, None, :]
        ).reshape(cap, -1)
        out, c, o_s, o_o = replicate_to_cells(
            rows, cnt, dests, axis_name, p, cap_slot, cap_out
        )
        return out[None], c[None], jnp.stack([o_s, o_o]).astype(jnp.int32)[None]

    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name, None, None), P(axis_name), P(axis_name)),
        out_specs=(P(axis_name, None, None), P(axis_name), P(axis_name, None)),
        check_rep=False,
    ))


@lru_cache(maxsize=512)
def _hc_route_fn(mesh, axis_name, spec: HCRouteSpec, cap_slot, cap_out):
    from jax.experimental.shard_map import shard_map

    p = mesh.shape[axis_name]

    def body(rows, cnts, salts):
        rows, cnt = rows[0], cnts[0]
        cap = rows.shape[0]
        coords = [
            (coord_hash(rows[:, col], salts[i]) % jnp.uint32(share), stride)
            for i, (col, share, stride) in enumerate(spec.fixed)
        ]
        cells = hc_cells_dev(coords, spec.free_contribs, cap)
        dests = (
            jnp.arange(spec.cp_size, dtype=jnp.int32)[None, :, None] * spec.hc_size
            + cells[:, None, :]
        ).reshape(cap, -1)
        out, c, o_s, o_o = replicate_to_cells(
            rows, cnt, dests, axis_name, p, cap_slot, cap_out
        )
        return out[None], c[None], jnp.stack([o_s, o_o]).astype(jnp.int32)[None]

    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name, None, None), P(axis_name), P(None)),
        out_specs=(P(axis_name, None, None), P(axis_name), P(axis_name, None)),
        check_rep=False,
    ))


# ---------------------------------------------------------------------------
# Stage-batched grid routing (one fused dispatch per geometry bucket)
#
# The batched twins make the *geometry itself* traced data: a stage's grid
# dims, cell strides, and enumeration tables arrive as per-stage arrays
# instead of compile-time constants, and the per-row copy count is padded to
# a bucket-wide pow2 ``fanout`` with -1 sentinel entries (ghosted by the
# exchange, never sent).  One compiled executable therefore serves *every*
# stage whose route has the same static shape bundle — (fixed hash columns,
# padded fanout, block caps) — no matter what CP grid or HyperCube shares the
# broadcast sizes produced; cold time stops scaling with the number of
# distinct stage geometries.
#
# The destination algebra is an exact refactoring of the unbatched
# enumeration (same host helpers `cp_cell_contribs` / `hc_cell_contribs`,
# same copy order):
#
#   CP side:  v = (id mod dim) · S + T_k,   S = stride·hc_size,
#             T = [contrib_j·hc_size + h]   (j outer, h inner)
#   HC side:  v = Σ_f coord_f·stride_f + T_k,
#             T = [cp_row·hc_size + free_contrib_j]   (cp_row outer)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CPBatchSig:
    """Static shape bundle of a batched CP-side route: only the padded
    fanout — dims, strides, and tables are traced per-stage data."""

    fanout: int


@dataclass(frozen=True)
class HCBatchSig:
    """Static shape bundle of a batched HC-side route: which row columns are
    hashed into coordinates, and the padded fanout."""

    cols: Tuple[int, ...]
    fanout: int


def _pad_table(t, fanout: int):
    """Pad a destination-offset table to ``fanout`` with -1 sentinels."""
    import numpy as np

    out = np.full((fanout,), -1, dtype=np.int32)
    out[: len(t)] = t
    return out


def cp_batch_params(grid: Optional[CartesianGrid], list_idx: int, hc_size: int):
    """Per-stage traced operands of the batched CP route for one isolated
    list: (sig fanout source, dim, scale S, offset table T).  Lists beyond t'
    broadcast to every CP cell (dim = 1, S = 0, T enumerates the full grid)."""
    if grid is not None and list_idx < grid.t_prime:
        stride, contribs = cp_cell_contribs(grid.dims, list_idx)
        dim = grid.dims[list_idx]
        scale = stride * hc_size
        table = [c * hc_size + h for c in contribs for h in range(hc_size)]
    else:
        cp_size = grid.size if grid is not None else 1
        dim, scale = 1, 0
        table = [c * hc_size + h for c in range(cp_size) for h in range(hc_size)]
    return dim, scale, table


def hc_batch_params(grid: HyperCubeGrid, scheme: Sequence[str], cp_size: int):
    """Per-stage traced operands of the batched HC route for one light
    fragment: (fixed column indices, shares, strides, offset table T)."""
    fixed_attrs = [a for a in scheme if a in grid.attrs]
    strides, contribs = hc_cell_contribs(grid.attrs, grid.dims, fixed_attrs)
    cols = tuple(list(scheme).index(a) for a in fixed_attrs)
    shares = [grid.share(a) for a in fixed_attrs]
    stride_list = [strides[a] for a in fixed_attrs]
    table = [cp * grid.size + fc for cp in range(cp_size) for fc in contribs]
    return cols, shares, stride_list, table


def batched_replicate_to_cells(
    rows: jax.Array,        # (s, cap, w) valid-prefix padded
    counts: jax.Array,      # (s,)
    dests: jax.Array,       # (s, cap, F) destination cells; -1 = sentinel copy
    axis_name: str,
    p: int,
    cap_slot: int,
    cap_out: int,
):
    """Inside shard_map: stage-batched `replicate_to_cells` — every stage's
    rows are fanned out to their destination cells and the whole stack shares
    one `all_to_all`.  Sentinel (-1) destinations are ghosted: the copy is
    never sent, so pow2 fanout padding cannot change results or overflow.
    Returns (out (s, cap_out, 1+w), counts (s,), ovf_slot (s,), ovf_out (s,))."""
    s, cap, w = rows.shape
    fanout = dests.shape[2]
    rep = jnp.repeat(rows, fanout, axis=1)              # keeps prefix validity
    v = dests.reshape(s, cap * fanout).astype(jnp.int32)
    tagged = jnp.concatenate([v[:, :, None], rep], axis=2)
    part = jnp.where(v < 0, p, v % p)                   # sentinel → ghost
    return batched_exchange_by_partition(
        tagged, counts * fanout, part, axis_name, p, cap_slot, cap_out
    )


@lru_cache(maxsize=512)
def _batched_cp_route_fn(mesh, axis_name, sig: CPBatchSig, cap_slot, cap_out):
    from jax.experimental.shard_map import shard_map

    p = mesh.shape[axis_name]

    def body(rows, cnts, offs, dims, scales, table):
        rows, cnt, off = rows[:, 0], cnts[:, 0], offs[:, 0]     # (s, cap, w) ...
        s, cap, _ = rows.shape
        ids = off[:, None].astype(jnp.int32) + jnp.arange(cap, dtype=jnp.int32)
        own = (ids % dims[:, None]).astype(jnp.int32)
        dests = own[:, :, None] * scales[:, None, None] + table[:, None, :]
        dests = jnp.where(table[:, None, :] < 0, -1, dests)
        out, c, o_s, o_o = batched_replicate_to_cells(
            rows, cnt, dests, axis_name, p, cap_slot, cap_out
        )
        ovf = jnp.stack([o_s.astype(jnp.int32), o_o.astype(jnp.int32)], axis=-1)
        return out[:, None], c[:, None], ovf[:, None, :]

    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(None, axis_name, None, None), P(None, axis_name), P(None, axis_name),
            P(None), P(None), P(None, None),
        ),
        out_specs=(
            P(None, axis_name, None, None), P(None, axis_name), P(None, axis_name, None),
        ),
        check_rep=False,
    ), donate_argnums=(0,))


@lru_cache(maxsize=512)
def _batched_hc_route_fn(mesh, axis_name, sig: HCBatchSig, cap_slot, cap_out):
    from jax.experimental.shard_map import shard_map

    p = mesh.shape[axis_name]

    def body(rows, cnts, salts, shares, strides, table):
        rows, cnt = rows[:, 0], cnts[:, 0]      # (s, cap, w); rest replicated
        s, cap, _ = rows.shape
        flat = jnp.zeros((s, cap), jnp.int32)
        for f, col in enumerate(sig.cols):
            coord = coord_hash(rows[:, :, col], salts[:, f, None]) % shares[:, f, None]
            flat = flat + coord.astype(jnp.int32) * strides[:, f, None]
        dests = flat[:, :, None] + table[:, None, :]
        dests = jnp.where(table[:, None, :] < 0, -1, dests)
        out, c, o_s, o_o = batched_replicate_to_cells(
            rows, cnt, dests, axis_name, p, cap_slot, cap_out
        )
        ovf = jnp.stack([o_s.astype(jnp.int32), o_o.astype(jnp.int32)], axis=-1)
        return out[:, None], c[:, None], ovf[:, None, :]

    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(None, axis_name, None, None), P(None, axis_name),
            P(None, None), P(None, None), P(None, None), P(None, None),
        ),
        out_specs=(
            P(None, axis_name, None, None), P(None, axis_name), P(None, axis_name, None),
        ),
        check_rep=False,
    ), donate_argnums=(0,))


def _dest_hist(counts: jax.Array, dests: jax.Array, p: int) -> jax.Array:
    """(s,) valid row counts + (s, cap, F) destination cells (-1 = ghost) →
    (s, p) per-destination-device copy histogram: exactly the send-slot
    occupancy the emit pass's `pack_by_partition` will see, so its column sums
    across source devices are the exact receive sizes."""
    s, cap, fanout = dests.shape
    v = dests.reshape(s, cap * fanout)
    valid = (
        jnp.arange(cap * fanout, dtype=jnp.int32)[None, :]
        < (counts * fanout)[:, None]
    )
    dst = jnp.where(valid & (v >= 0), v % p, p)
    return jax.vmap(lambda d: jnp.zeros((p + 1,), jnp.int32).at[d].add(1))(dst)[:, :p]


@lru_cache(maxsize=512)
def _batched_cp_route_count_fn(mesh, axis_name, sig: CPBatchSig):
    from jax.experimental.shard_map import shard_map

    p = mesh.shape[axis_name]

    def body(rows, cnts, offs, dims, scales, table):
        rows, cnt, off = rows[:, 0], cnts[:, 0], offs[:, 0]
        s, cap, _ = rows.shape
        ids = off[:, None].astype(jnp.int32) + jnp.arange(cap, dtype=jnp.int32)
        own = (ids % dims[:, None]).astype(jnp.int32)
        dests = own[:, :, None] * scales[:, None, None] + table[:, None, :]
        dests = jnp.where(table[:, None, :] < 0, -1, dests)
        return (_dest_hist(cnt, dests, p)[:, None],)

    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(None, axis_name, None, None), P(None, axis_name), P(None, axis_name),
            P(None), P(None), P(None, None),
        ),
        out_specs=(P(None, axis_name, None),),
        check_rep=False,
    ))


@lru_cache(maxsize=512)
def _batched_hc_route_count_fn(mesh, axis_name, sig: HCBatchSig):
    from jax.experimental.shard_map import shard_map

    p = mesh.shape[axis_name]

    def body(rows, cnts, salts, shares, strides, table):
        rows, cnt = rows[:, 0], cnts[:, 0]
        s, cap, _ = rows.shape
        flat = jnp.zeros((s, cap), jnp.int32)
        for f, col in enumerate(sig.cols):
            coord = coord_hash(rows[:, :, col], salts[:, f, None]) % shares[:, f, None]
            flat = flat + coord.astype(jnp.int32) * strides[:, f, None]
        dests = flat[:, :, None] + table[:, None, :]
        dests = jnp.where(table[:, None, :] < 0, -1, dests)
        return (_dest_hist(cnt, dests, p)[:, None],)

    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(None, axis_name, None, None), P(None, axis_name),
            P(None, None), P(None, None), P(None, None), P(None, None),
        ),
        out_specs=(P(None, axis_name, None),),
        check_rep=False,
    ))


def batched_sharded_grid_route_count(
    mesh,
    axis_name: str,
    rows: jax.Array,
    counts: jax.Array,
    sig,
    *,
    offsets=None,
    dims=None,
    scales=None,
    salts=None,
    shares=None,
    strides=None,
    table=None,
    invoke: bool = True,
):
    """Count-only twin of `batched_sharded_grid_route`: the exact per-stage
    (p_src, p_dst) copy histograms with **no collective** — the destination
    algebra is identical (same traced geometry operands, same salts), only the
    exchange is replaced by a per-device histogram.  The executor's
    count-then-emit pass sizes the emit's cap_slot (max entry) and cap_out
    (max column sum) exactly from the result.  Returns ``(hist (s, p, p),)``;
    with ``invoke=False`` returns ``(jitted_fn, args)``."""
    import numpy as np

    if isinstance(sig, CPBatchSig):
        fn = _batched_cp_route_count_fn(mesh, axis_name, sig)
        args = (
            rows, counts,
            np.asarray(offsets, dtype=np.int32),
            np.asarray(dims, dtype=np.int32),
            np.asarray(scales, dtype=np.int32),
            np.asarray(table, dtype=np.int32),
        )
    elif isinstance(sig, HCBatchSig):
        fn = _batched_hc_route_count_fn(mesh, axis_name, sig)
        args = (
            rows, counts,
            np.asarray(salts, dtype=np.uint32),
            np.asarray(shares, dtype=np.uint32),
            np.asarray(strides, dtype=np.int32),
            np.asarray(table, dtype=np.int32),
        )
    else:
        raise TypeError(f"unknown grid-route signature {sig!r}")
    if not invoke:
        return fn, args
    return fn(*args)


def batched_sharded_grid_route(
    mesh,
    axis_name: str,
    rows: jax.Array,            # (s, p, cap, w) stage-stacked padded blocks
    counts: jax.Array,          # (s, p)
    sig,                        # CPBatchSig | HCBatchSig (shared by the bucket)
    *,
    offsets=None,               # (s, p) global-id bases          (CP side)
    dims=None,                  # (s,) own-list grid dimension    (CP side)
    scales=None,                # (s,) stride · hc_size           (CP side)
    salts=None,                 # (s, n_fixed) coordinate salts   (HC side)
    shares=None,                # (s, n_fixed) attribute shares   (HC side)
    strides=None,               # (s, n_fixed) flat-cell strides  (HC side)
    table=None,                 # (s, sig.fanout) cell-offset table, -1-padded
    cap_slot: int,
    cap_out: int,
    invoke: bool = True,
):
    """Stage-batched `sharded_grid_route`: every stage of a geometry bucket
    is fanned out to its virtual cells through one dispatch and one
    `all_to_all`; the grid geometry rides along as traced per-stage operands
    (see `cp_batch_params` / `hc_batch_params`).  Returns
    (out (s, p, cap_out, 1+w), counts (s, p), ovf (s, p, 2)); with
    ``invoke=False`` returns ``(jitted_fn, args)`` for AOT compilation."""
    import numpy as np

    if isinstance(sig, CPBatchSig):
        fn = _batched_cp_route_fn(mesh, axis_name, sig, cap_slot, cap_out)
        args = (
            rows, counts,
            np.asarray(offsets, dtype=np.int32),
            np.asarray(dims, dtype=np.int32),
            np.asarray(scales, dtype=np.int32),
            np.asarray(table, dtype=np.int32),
        )
    elif isinstance(sig, HCBatchSig):
        fn = _batched_hc_route_fn(mesh, axis_name, sig, cap_slot, cap_out)
        args = (
            rows, counts,
            np.asarray(salts, dtype=np.uint32),
            np.asarray(shares, dtype=np.uint32),
            np.asarray(strides, dtype=np.int32),
            np.asarray(table, dtype=np.int32),
        )
    else:
        raise TypeError(f"unknown grid-route signature {sig!r}")
    if not invoke:
        return fn, args
    return fn(*args)


def sharded_grid_route(
    mesh,
    axis_name: str,
    rows: jax.Array,            # (p, cap, w) device-sharded padded blocks
    counts: jax.Array,          # (p,)
    spec,                       # CPRouteSpec | HCRouteSpec
    *,
    offsets: Optional[jax.Array] = None,    # (p,) global-id bases (CP side)
    salts: Optional[Sequence[int]] = None,  # per-fixed-attr salts (HC side)
    cap_slot: int,
    cap_out: int,
):
    """Route one fragment to its step-3 virtual grid cells (GridRoute lowering).

    Returns (out (p, cap_out, 1+w), counts (p,), ovf (p, 2)); column 0 of every
    output row is the destination cell id (the Lemma 3.2 virtual machine),
    columns 1.. are the original row."""
    if isinstance(spec, CPRouteSpec):
        if offsets is None:
            raise ValueError("CP-side grid route needs per-device id offsets")
        fn = _cp_route_fn(mesh, axis_name, spec, cap_slot, cap_out)
        return fn(rows, counts, jnp.asarray(offsets, dtype=jnp.int32))
    if isinstance(spec, HCRouteSpec):
        if salts is None:
            raise ValueError("HC-side grid route needs per-attribute salts")
        fn = _hc_route_fn(mesh, axis_name, spec, cap_slot, cap_out)
        return fn(rows, counts, jnp.asarray(list(salts), dtype=jnp.uint32))
    raise TypeError(f"unknown grid-route spec {spec!r}")
