"""Distributed equi-join on a device mesh: exchange + local sorted join.

`hypercube_binary_join` is the one-round routed join R(A,B) ⋈ S(B,C) → (A,B,C):
both relations are hash-exchanged on B over the machines axis, then each device runs
the local sorted join (sort by key + merge_join_counts Pallas probe + static-size
expansion). Output stays device-local (the MPC model's contract: every result tuple
materializes on some machine).

This is the engine's Lemma 3.3 data path on real devices; the simulator remains the
load oracle, and tests/test_dataplane_subprocess.py checks both produce identical
result sets on 8 fake host devices."""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..kernels.ops import merge_join_counts
from .exchange import hash_exchange


def local_sorted_join(
    a_rows: jax.Array, a_count: jax.Array,      # (capA, wa): join key in col ka
    b_rows: jax.Array, b_count: jax.Array,      # (capB, wb): join key in col kb
    ka: int, kb: int, cap_out: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """→ (out (cap_out, wa+wb-1), count, overflow). Key written once (A's columns,
    then B's non-key columns)."""
    capa, wa = a_rows.shape
    capb, wb = b_rows.shape
    big = jnp.iinfo(jnp.int32).max

    a_keys = jnp.where(jnp.arange(capa) < a_count, a_rows[:, ka], big)
    b_keys = jnp.where(jnp.arange(capb) < b_count, b_rows[:, kb], big)
    a_ord = jnp.argsort(a_keys)
    b_ord = jnp.argsort(b_keys)
    a_sorted = a_rows[a_ord]
    b_sorted = b_rows[b_ord]
    a_k = a_keys[a_ord]
    b_k = b_keys[b_ord]

    lower, upper = merge_join_counts(a_k, b_k)
    # sentinel keys must not match each other
    real_a = a_k < big
    counts = jnp.where(real_a, upper - lower, 0)
    starts = jnp.cumsum(counts) - counts           # output offset per a-row
    total = counts.sum()
    overflow = jnp.maximum(total - cap_out, 0)

    # expansion: out row t ← (a_idx(t) = searchsorted(starts, t, 'right')-1,
    #                         b_idx(t) = lower[a_idx] + (t - starts[a_idx]))
    t = jnp.arange(cap_out)
    a_idx = jnp.clip(jnp.searchsorted(starts, t, side="right") - 1, 0, capa - 1)
    within = t - starts[a_idx]
    b_idx = jnp.clip(lower[a_idx] + within, 0, capb - 1)
    valid = t < jnp.minimum(total, cap_out)

    a_part = a_sorted[a_idx]                                        # (cap_out, wa)
    b_cols = [c for c in range(wb) if c != kb]
    b_part = b_sorted[b_idx][:, jnp.array(b_cols, jnp.int32)] if b_cols else jnp.zeros(
        (cap_out, 0), b_rows.dtype
    )
    out = jnp.concatenate([a_part, b_part], axis=1)
    out = jnp.where(valid[:, None], out, 0)
    return out, jnp.minimum(total, cap_out), overflow


def hypercube_binary_join(
    mesh,
    axis_name: str,
    a_global: jax.Array, a_counts: jax.Array,   # (p, capA, wa), (p,) device-sharded
    b_global: jax.Array, b_counts: jax.Array,
    ka: int, kb: int,
    cap_slot: int, cap_mid: int, cap_out: int,
):
    """Full distributed join under shard_map. Inputs/outputs sharded over axis 0.
    Returns (out (p, cap_out, w), counts (p,), overflow (p,))."""
    from jax.experimental.shard_map import shard_map

    p = mesh.shape[axis_name]

    def body(a_rows, a_cnt, b_rows, b_cnt):
        a_rows, a_cnt, b_rows, b_cnt = a_rows[0], a_cnt[0], b_rows[0], b_cnt[0]
        a2, ca, o1 = hash_exchange(a_rows, a_cnt, ka, axis_name, p, cap_slot, cap_mid)
        b2, cb, o2 = hash_exchange(b_rows, b_cnt, kb, axis_name, p, cap_slot, cap_mid)
        out, cnt, o3 = local_sorted_join(a2, ca, b2, cb, ka, kb, cap_out)
        return out[None], cnt[None], (o1 + o2 + o3)[None]

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name, None, None), P(axis_name), P(axis_name, None, None), P(axis_name)),
        out_specs=(P(axis_name, None, None), P(axis_name), P(axis_name)),
        check_rep=False,
    )
    return fn(a_global, a_counts, b_global, b_counts)
