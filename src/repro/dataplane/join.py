"""Distributed equi-join on a device mesh: exchange + local sorted join.

The local primitives (`local_sorted_join`, `local_semijoin`, `local_unique`)
all run on the merge_join_counts Pallas probe with static shapes; the sharded
primitives (`sharded_join_step`, `sharded_semijoin`, `sharded_intersect`,
`sharded_colocated_join`) wrap them in `shard_map` bodies around
capacity-padded `hash_exchange` collectives (`sharded_colocated_join` is the
communication-free member: fragments already co-located by a grid route).
Together with `repro.dataplane.grid` they lower every stage emitted by the
round-program compiler (repro.mpc.program) onto a device mesh — the
`DataplaneExecutor` (repro.mpc.executors) drives one primitive per RoundOp.

Overflow contract: every sharded primitive returns ``ovf`` of shape (p, 2) —
column 0 counts *slot* (routing-buffer) overflow, column 1 counts *output*
overflow — so the executor's retry can double only the capacity that actually
overflowed (and re-randomize routing for slot overflow).

`hypercube_binary_join` is the original one-round routed join
R(A,B) ⋈ S(B,C) → (A,B,C), now a thin wrapper over `sharded_join_step`.
Output stays device-local (the MPC model's contract: every result tuple
materializes on some machine).

The simulator remains the load oracle; tests/test_dataplane_subprocess.py
checks both produce identical result sets on 8 fake host devices.

Device word contract: values are int32 with INT32_MAX reserved as the padding
sentinel (same convention as the kernels)."""

from __future__ import annotations

from functools import lru_cache, partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..kernels.ops import merge_join_counts, merge_join_pairs, probe_use_pallas
from .exchange import batched_hash_exchange, hash_exchange, salt_offset


def local_sorted_join(
    a_rows: jax.Array, a_count: jax.Array,      # (capA, wa): join key in col ka
    b_rows: jax.Array, b_count: jax.Array,      # (capB, wb): join key in col kb
    ka: int, kb: int, cap_out: int,
    a_keys: Optional[jax.Array] = None,         # optional precomputed (capA,)
    b_keys: Optional[jax.Array] = None,         # join keys (pads may be any value)
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """→ (out (cap_out, wa+wb-1), count, overflow). Key written once (A's columns,
    then B's non-key columns).  ``a_keys``/``b_keys`` override the key columns
    (composite-key joins rank their key tuples densely and pass the ranks)."""
    capa, wa = a_rows.shape
    capb, wb = b_rows.shape
    big = jnp.iinfo(jnp.int32).max

    a_keys = a_rows[:, ka] if a_keys is None else a_keys
    b_keys = b_rows[:, kb] if b_keys is None else b_keys
    a_keys = jnp.where(jnp.arange(capa) < a_count, a_keys, big)
    b_keys = jnp.where(jnp.arange(capb) < b_count, b_keys, big)
    a_ord = jnp.argsort(a_keys)
    b_ord = jnp.argsort(b_keys)
    a_k = a_keys[a_ord]
    b_k = b_keys[b_ord]

    lower, upper = merge_join_counts(a_k, b_k, use_pallas=probe_use_pallas())
    # sentinel keys must not match each other
    real_a = a_k < big
    counts = jnp.where(real_a, upper - lower, 0)
    starts = (jnp.cumsum(counts) - counts).astype(jnp.int32)  # output offset per a-row
    total = counts.sum()
    overflow = jnp.maximum(total - cap_out, 0)

    # range expansion (merge_join_pairs kernel): out row t ← a_idx(t) =
    # max{i : starts[i] <= t}, b_idx(t) = lower[a_idx] + (t - starts[a_idx])
    t = jnp.arange(cap_out)
    a_idx, b_idx = merge_join_pairs(
        lower.astype(jnp.int32), starts, cap_out, use_pallas=probe_use_pallas()
    )
    b_idx = jnp.clip(b_idx, 0, capb - 1)
    valid = t < jnp.minimum(total, cap_out)

    # gather output rows through the sort permutation (composed index gathers —
    # the full sorted row matrices are never materialized)
    a_part = a_rows[a_ord[a_idx]]                                   # (cap_out, wa)
    b_cols = [c for c in range(wb) if c != kb]
    b_part = b_rows[b_ord[b_idx]][:, jnp.array(b_cols, jnp.int32)] if b_cols else jnp.zeros(
        (cap_out, 0), b_rows.dtype
    )
    out = jnp.concatenate([a_part, b_part], axis=1)
    out = jnp.where(valid[:, None], out, 0)
    return out, jnp.minimum(total, cap_out), overflow


def _compact_prefix(rows: jax.Array, keep: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Stable-compact kept rows to a zero-padded valid prefix. rows (cap, ...).

    Sort-free: the destination of a kept row is its rank among kept rows
    (exclusive prefix sum); dropped rows scatter out of bounds and vanish
    (`mode="drop"`), leaving zeros — identical output to the former stable
    argsort at O(n) instead of O(n log n)."""
    cap = rows.shape[0]
    cnt = keep.sum()
    dest = jnp.where(keep, jnp.cumsum(keep) - 1, cap)
    out = jnp.zeros_like(rows).at[dest].set(rows, mode="drop")
    return out, cnt


def local_unique(vals: jax.Array, count: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(cap,) padded value list → sorted distinct values in a valid prefix."""
    cap = vals.shape[0]
    big = jnp.iinfo(jnp.int32).max
    v = jnp.sort(jnp.where(jnp.arange(cap) < count, vals, big))
    first = jnp.concatenate([jnp.ones((1,), bool), v[1:] != v[:-1]])
    return _compact_prefix(v, first & (v < big))


def local_semijoin(
    rows: jax.Array, count: jax.Array, col: int, keys: jax.Array, kcount: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Keep rows whose rows[:, col] appears in keys[:kcount] (device-local
    semi-join via the merge_join_counts probe). Output rows are reordered by
    key and compacted to a valid prefix (multiset semantics)."""
    cap, _ = rows.shape
    capk = keys.shape[0]
    big = jnp.iinfo(jnp.int32).max
    rk = jnp.where(jnp.arange(cap) < count, rows[:, col], big)
    order = jnp.argsort(rk)
    rows_s, rk_s = rows[order], rk[order]
    kv = jnp.sort(jnp.where(jnp.arange(capk) < kcount, keys, big))
    lower, upper = merge_join_counts(rk_s, kv, use_pallas=probe_use_pallas())
    member = (upper > lower) & (rk_s < big)
    return _compact_prefix(rows_s, member)


def _composite_rank_keys(
    a_cols: Sequence[jax.Array], a_valid: jax.Array,
    b_cols: Sequence[jax.Array], b_valid: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Dense lexicographic rank of key *tuples* across both sides.

    Equal tuples (on either side) get equal ranks, so a single-column sorted
    join on the ranks is exactly the multi-column equi-join.  Ranks fit int32
    (< capA + capB); invalid rows sort last and never produce a rank that a
    valid row carries, so the caller's sentinel masking stays correct."""
    na = a_valid.shape[0]
    big = jnp.iinfo(jnp.int32).max
    valid = jnp.concatenate([a_valid, b_valid])
    cols = [
        jnp.where(valid, jnp.concatenate([ac, bc]), big)
        for ac, bc in zip(a_cols, b_cols)
    ]
    order = jnp.lexsort(tuple(reversed(cols)))   # lexsort: LAST key is primary
    scols = [c[order] for c in cols]
    diff = scols[0][1:] != scols[0][:-1]
    for c in scols[1:]:
        diff = diff | (c[1:] != c[:-1])
    first = jnp.concatenate([jnp.ones((1,), bool), diff])
    gid = (jnp.cumsum(first) - 1).astype(jnp.int32)
    ranks = jnp.zeros_like(gid).at[order].set(gid)
    return ranks[:na], ranks[na:]


def _packed_keys(rows: jax.Array, cols: Sequence[int], mults: jax.Array) -> jax.Array:
    """Mixed-radix int32 packing of the key tuple rows[:, cols]:
    key = ((c0·m0 + c1)·m1 + c2)···.  ``mults`` is a traced (len(cols)-1,)
    vector of per-position radices (strict bounds on the column values, shared
    by both join sides).  Collision-free iff every value is in [0, m_i) and the
    product of radices (times max c0 + 1) stays below 2^31 — the host-side
    eligibility check the executor performs before choosing this path."""
    k = rows[:, cols[0]].astype(jnp.int32)
    for i, c in enumerate(cols[1:]):
        k = k * mults[i] + rows[:, c].astype(jnp.int32)
    return k


def local_join_count(
    a_rows: jax.Array, a_count: jax.Array,
    b_rows: jax.Array, b_count: jax.Array,
    ka: int, kb: int,
    dup_pairs: Tuple[Tuple[int, int], ...] = (),
    key_mults: Optional[jax.Array] = None,
) -> jax.Array:
    """Exact device-local match count for `local_join_filtered` — no expansion,
    no row gathers (keys only, `jnp.sort` instead of argsort).  The executor's
    count-then-emit pass runs this to size the emit's cap_out exactly."""
    capa, _ = a_rows.shape
    capb, _ = b_rows.shape
    big = jnp.iinfo(jnp.int32).max
    a_valid = jnp.arange(capa) < a_count
    b_valid = jnp.arange(capb) < b_count
    if not dup_pairs:
        a_keys, b_keys = a_rows[:, ka], b_rows[:, kb]
    elif key_mults is not None:
        a_keys = _packed_keys(a_rows, [ka] + [ca for ca, _ in dup_pairs], key_mults)
        b_keys = _packed_keys(b_rows, [kb] + [cb for _, cb in dup_pairs], key_mults)
    else:
        a_keys, b_keys = _composite_rank_keys(
            [a_rows[:, ka]] + [a_rows[:, ca] for ca, _ in dup_pairs], a_valid,
            [b_rows[:, kb]] + [b_rows[:, cb] for _, cb in dup_pairs], b_valid,
        )
    a_k = jnp.sort(jnp.where(a_valid, a_keys, big))
    b_k = jnp.sort(jnp.where(b_valid, b_keys, big))
    lower, upper = merge_join_counts(a_k, b_k, use_pallas=probe_use_pallas())
    return jnp.where(a_k < big, upper - lower, 0).sum().astype(jnp.int32)


def local_join_filtered(
    a_rows: jax.Array, a_count: jax.Array,
    b_rows: jax.Array, b_count: jax.Array,
    ka: int, kb: int, cap_out: int,
    dup_pairs: Tuple[Tuple[int, int], ...] = (),
    key_mults: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """`local_sorted_join` with duplicated attributes folded into the key.

    ``dup_pairs`` lists (a_col, b_col) pairs (b_col ≠ kb) of attributes shared
    beyond the join key — the cyclic-subquery case.  The full key tuple
    (key, dup_1, dup_2, ...) is folded to one int32 key and the join runs on
    the folded keys, so ``cap_out`` (and the output-overflow channel) meters
    only TRUE matches.  Two folding strategies:

    * ``key_mults`` given — mixed-radix *packing* (`_packed_keys`): one
      multiply-add per extra column, no sorting.  Only valid when the caller
      has checked the key space fits int32 (the executor's key-compression
      eligibility check); radices are traced so one executable serves every
      bucket that passes the check.
    * otherwise — dense lexicographic *ranking* (`_composite_rank_keys`), the
      checked fallback: always fits int32 (ranks < capA + capB) at the price
      of a lexsort over both sides.

    The previous implementation materialized the key-only join and
    equality-filtered afterwards, which made the capacity requirement the
    per-cell *cartesian* size — on self-join-shaped queries (every LocalJoin
    chain level of a clique pattern) that overflowed every reasonable output
    cap.  The duplicate B-side columns are equal by construction and dropped;
    output scheme is A's columns then B's columns minus kb and minus the dup
    b_cols."""
    if not dup_pairs:
        return local_sorted_join(a_rows, a_count, b_rows, b_count, ka, kb, cap_out)
    capa, wa = a_rows.shape
    capb, wb = b_rows.shape
    a_valid = jnp.arange(capa) < a_count
    b_valid = jnp.arange(capb) < b_count
    if key_mults is not None:
        a_keys = _packed_keys(a_rows, [ka] + [ca for ca, _ in dup_pairs], key_mults)
        b_keys = _packed_keys(b_rows, [kb] + [cb for _, cb in dup_pairs], key_mults)
    else:
        a_keys, b_keys = _composite_rank_keys(
            [a_rows[:, ka]] + [a_rows[:, ca] for ca, _ in dup_pairs], a_valid,
            [b_rows[:, kb]] + [b_rows[:, cb] for _, cb in dup_pairs], b_valid,
        )
    out, cnt, ovf = local_sorted_join(
        a_rows, a_count, b_rows, b_count, ka, kb, cap_out,
        a_keys=a_keys, b_keys=b_keys,
    )
    b_cols = [c for c in range(wb) if c != kb]
    drop = {wa + b_cols.index(cb) for _, cb in dup_pairs}
    keep_cols = [c for c in range(out.shape[1]) if c not in drop]
    return out[:, jnp.array(keep_cols, jnp.int32)], cnt, ovf


@lru_cache(maxsize=512)
def _join_step_fn(mesh, axis_name, ka, kb, cap_slot, cap_mid, cap_out, dup_pairs):
    """Build (once per static structure) the jitted shard_map join step.
    jit's own cache handles input-shape variation, and the salt rides along as
    a traced scalar — one compiled executable serves every (H, η) stage of the
    same shape; this cache keeps repeated executor calls from re-tracing."""
    from jax.experimental.shard_map import shard_map

    p = mesh.shape[axis_name]

    def body(a_rows, a_cnt, b_rows, b_cnt, off):
        a_rows, a_cnt, b_rows, b_cnt = a_rows[0], a_cnt[0], b_rows[0], b_cnt[0]
        a2, ca, s1, m1 = hash_exchange(a_rows, a_cnt, ka, axis_name, p, cap_slot, cap_mid, off)
        b2, cb, s2, m2 = hash_exchange(b_rows, b_cnt, kb, axis_name, p, cap_slot, cap_mid, off)
        out, cnt, o3 = local_join_filtered(a2, ca, b2, cb, ka, kb, cap_out, dup_pairs)
        # exchange-receive (cap_mid) overflow counts as routing, not output
        ovf = jnp.stack([s1 + s2 + m1 + m2, o3]).astype(jnp.int32)
        return out[None], cnt[None], ovf[None]

    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name, None, None), P(axis_name), P(axis_name, None, None), P(axis_name), P()),
        out_specs=(P(axis_name, None, None), P(axis_name), P(axis_name, None)),
        check_rep=False,
    ))


def sharded_join_step(
    mesh,
    axis_name: str,
    a_global: jax.Array, a_counts: jax.Array,   # (p, capA, wa), (p,) device-sharded
    b_global: jax.Array, b_counts: jax.Array,
    ka: int, kb: int,
    cap_slot: int, cap_mid: int, cap_out: int,
    dup_pairs: Tuple[Tuple[int, int], ...] = (),
    salt: int = 0,
):
    """One distributed binary-join step under shard_map: both sides are
    hash-exchanged on their key column, then joined locally (with optional
    duplicate-attribute filtering).  Inputs/outputs sharded over axis 0.
    Returns (out (p, cap_out, w), counts (p,), overflow (p, 2) [slot, out])."""
    fn = _join_step_fn(
        mesh, axis_name, ka, kb, cap_slot, cap_mid, cap_out, tuple(dup_pairs)
    )
    return fn(a_global, a_counts, b_global, b_counts, jnp.int32(salt_offset(salt)))


@lru_cache(maxsize=512)
def _semijoin_fn(mesh, axis_name, cols, cap_slot, cap_out):
    from jax.experimental.shard_map import shard_map

    p = mesh.shape[axis_name]

    def body(rows, cnt, offs, *pieces):
        rows, cnt = rows[0], cnt[0]
        ovf_slot = jnp.zeros((), jnp.int32)
        ovf_out = jnp.zeros((), jnp.int32)
        for i, col in enumerate(cols):
            pv, pc = pieces[2 * i][0], pieces[2 * i + 1][0]
            rows, cnt, o_s, o_o = hash_exchange(
                rows, cnt, col, axis_name, p, cap_slot, cap_out, offs[i]
            )
            ovf_slot += o_s.astype(jnp.int32)
            ovf_out += o_o.astype(jnp.int32)
            rows, cnt = local_semijoin(rows, cnt, col, pv, pc)
        return rows[None], cnt[None], jnp.stack([ovf_slot, ovf_out])[None]

    piece_specs = []
    for _ in cols:
        piece_specs += [P(axis_name, None), P(axis_name)]
    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name, None, None), P(axis_name), P(None), *piece_specs),
        out_specs=(P(axis_name, None, None), P(axis_name), P(axis_name, None)),
        check_rep=False,
    ))


def sharded_semijoin(
    mesh,
    axis_name: str,
    rows_global: jax.Array, counts: jax.Array,          # (p, cap, w), (p,)
    filters: Sequence[Tuple[int, int, jax.Array, jax.Array]],
    cap_slot: int, cap_out: int,
):
    """Semi-join a sharded relation against co-located unary pieces.

    ``filters`` is a static sequence of (col, salt, piece_vals (p, capx),
    piece_counts (p,)): for each entry the rows are hash-exchanged on ``col``
    with ``salt`` (the same salt that distributed the piece, so piece and rows
    land on the same device) and filtered by membership.  Lowers the SemiJoin
    op of the round-program IR.  Returns (rows, counts, overflow (p, 2))."""
    cols = tuple(int(col) for col, _, _, _ in filters)
    offs = jnp.asarray([salt_offset(int(s)) for _, s, _, _ in filters], jnp.int32)
    piece_args = []
    for _, _, pv, pc in filters:
        piece_args += [pv, pc]
    fn = _semijoin_fn(mesh, axis_name, cols, cap_slot, cap_out)
    return fn(rows_global, counts, offs, *piece_args)


@lru_cache(maxsize=512)
def _intersect_fn(mesh, axis_name, n, cap_slot, cap_out):
    from jax.experimental.shard_map import shard_map

    p = mesh.shape[axis_name]

    def body(off, *flat):
        ovf_slot = jnp.zeros((), jnp.int32)
        ovf_out = jnp.zeros((), jnp.int32)
        cur = None
        cur_cnt = None
        for i in range(n):
            v, c = flat[2 * i][0], flat[2 * i + 1][0]
            ex, exc, o_s, o_o = hash_exchange(
                v[:, None], c, 0, axis_name, p, cap_slot, cap_out, off
            )
            ovf_slot += o_s.astype(jnp.int32)
            ovf_out += o_o.astype(jnp.int32)
            uv, uc = local_unique(ex[:, 0], exc)
            if cur is None:
                cur, cur_cnt = uv, uc
            else:
                kept, kc = local_semijoin(cur[:, None], cur_cnt, 0, uv, uc)
                cur, cur_cnt = kept[:, 0], kc
        return cur[None], cur_cnt[None], jnp.stack([ovf_slot, ovf_out])[None]

    specs = [P()]
    for _ in range(n):
        specs += [P(axis_name, None), P(axis_name)]
    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(specs),
        out_specs=(P(axis_name, None), P(axis_name), P(axis_name, None)),
        check_rep=False,
    ))


def sharded_intersect(
    mesh,
    axis_name: str,
    pieces: Sequence[Tuple[jax.Array, jax.Array]],      # [(vals (p, cap_i), counts (p,))]
    salt: int,
    cap_slot: int, cap_out: int,
):
    """Distributed intersection of unary relations (the R''_X(η) step).

    Every piece is hash-exchanged on its value with the shared ``salt`` (all
    copies of a value meet on one device), deduplicated, and intersected
    locally via the merge_join_counts membership probe.  Lowers the
    HashPartition op of the round-program IR.  Returns
    (vals (p, cap_out), counts (p,), overflow (p, 2)) distributed by
    hash(value, salt) — ready to serve as a `sharded_semijoin` filter."""
    args = []
    for pv, pc in pieces:
        args += [pv, pc]
    fn = _intersect_fn(mesh, axis_name, len(pieces), cap_slot, cap_out)
    return fn(jnp.int32(salt_offset(salt)), *args)


@lru_cache(maxsize=512)
def _colocated_join_fn(mesh, axis_name, ka, kb, cap_out, dup_pairs):
    from jax.experimental.shard_map import shard_map

    def body(a_rows, a_cnt, b_rows, b_cnt):
        out, cnt, ovf = local_join_filtered(
            a_rows[0], a_cnt[0], b_rows[0], b_cnt[0], ka, kb, cap_out, dup_pairs
        )
        # no exchange ⇒ no slot channel; only output capacity can overflow
        return out[None], cnt[None], jnp.stack(
            [jnp.zeros((), jnp.int32), ovf.astype(jnp.int32)]
        )[None]

    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_name, None, None), P(axis_name), P(axis_name, None, None), P(axis_name)),
        out_specs=(P(axis_name, None, None), P(axis_name), P(axis_name, None)),
        check_rep=False,
    ))


def sharded_colocated_join(
    mesh,
    axis_name: str,
    a_global: jax.Array, a_counts: jax.Array,   # (p, capA, wa), (p,) device-sharded
    b_global: jax.Array, b_counts: jax.Array,
    ka: int, kb: int,
    cap_out: int,
    dup_pairs: Tuple[Tuple[int, int], ...] = (),
):
    """A purely device-local join step under shard_map — **no communication**.

    Lowers the LocalJoin op of the round-program IR: after `sharded_grid_route`
    every fragment of a virtual grid cell lives on device ``cell % p`` tagged
    with the cell id in column 0, so joining on the cell-id columns (with
    ``dup_pairs`` folding the attributes shared inside the cell into the
    composite join key) reproduces each cell's local join without moving a
    byte.  Returns
    (out (p, cap_out, w), counts (p,), overflow (p, 2) [always-0 slot, out])."""
    fn = _colocated_join_fn(mesh, axis_name, ka, kb, cap_out, tuple(dup_pairs))
    return fn(a_global, a_counts, b_global, b_counts)


# ---------------------------------------------------------------------------
# Stage-batched twins (one fused dispatch per geometry bucket)
#
# Each `batched_sharded_*` takes the same operands as its per-stage twin with
# one extra leading *stage* axis (s, p, ...) plus per-stage traced salts, and
# performs the whole bucket in a single jitted shard_map call: local compute is
# vmapped over the stage axis and the exchanges share one `all_to_all`
# (`batched_hash_exchange`).  Overflow comes back per stage — (s, p, 2) with
# the usual [slot, out] channels — so the executor's retry re-runs only the
# stages that tripped, at doubled caps and fresh attempt salts.
# ---------------------------------------------------------------------------


@lru_cache(maxsize=512)
def _batched_intersect_fn(mesh, axis_name, n, cap_slot, cap_out):
    from jax.experimental.shard_map import shard_map

    p = mesh.shape[axis_name]

    def body(offs, *flat):
        s = offs.shape[0]                       # offs (s,) replicated
        ovf_slot = jnp.zeros((s,), jnp.int32)
        ovf_out = jnp.zeros((s,), jnp.int32)
        cur = None
        cur_cnt = None
        for i in range(n):
            v, c = flat[2 * i][:, 0, :], flat[2 * i + 1][:, 0]   # (s, cap_i), (s,)
            ex, exc, o_s, o_o = batched_hash_exchange(
                v[:, :, None], c, 0, axis_name, p, cap_slot, cap_out, offs
            )
            ovf_slot += o_s.astype(jnp.int32)
            ovf_out += o_o.astype(jnp.int32)
            uv, uc = jax.vmap(local_unique)(ex[:, :, 0], exc)
            if cur is None:
                cur, cur_cnt = uv, uc
            else:
                kept, kc = jax.vmap(local_semijoin, in_axes=(0, 0, None, 0, 0))(
                    cur[:, :, None], cur_cnt, 0, uv, uc
                )
                cur, cur_cnt = kept[:, :, 0], kc
        ovf = jnp.stack([ovf_slot, ovf_out], axis=-1)            # (s, 2)
        return cur[:, None, :], cur_cnt[:, None], ovf[:, None, :]

    specs = [P(None)]
    for _ in range(n):
        specs += [P(None, axis_name, None), P(None, axis_name)]
    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(specs),
        out_specs=(P(None, axis_name, None), P(None, axis_name), P(None, axis_name, None)),
        check_rep=False,
    ))


def batched_sharded_intersect(
    mesh,
    axis_name: str,
    pieces: Sequence[Tuple[jax.Array, jax.Array]],  # [(vals (s, p, cap_i), counts (s, p))]
    offs: jax.Array,                                # (s,) per-stage salt offsets
    cap_slot: int, cap_out: int,
    invoke: bool = True,
):
    """Stage-batched `sharded_intersect`: s stages' R''_X intersections through
    one dispatch.  Returns (vals (s, p, cap_out), counts (s, p), ovf (s, p, 2));
    with ``invoke=False`` returns ``(jitted_fn, args)`` instead, so the
    scheduler can AOT-compile distinct signatures concurrently and execute
    serially (concurrent collective *executions* deadlock the rendezvous)."""
    args = []
    for pv, pc in pieces:
        args += [pv, pc]
    fn = _batched_intersect_fn(mesh, axis_name, len(pieces), cap_slot, cap_out)
    if not invoke:
        return fn, (offs, *args)
    return fn(offs, *args)


@lru_cache(maxsize=512)
def _batched_semijoin_fn(mesh, axis_name, col, cap_slot, cap_out):
    from jax.experimental.shard_map import shard_map

    p = mesh.shape[axis_name]

    def body(rows, cnt, offs, pv, pc):
        rows, cnt = rows[:, 0], cnt[:, 0]       # offs (s,) replicated
        pv, pc = pv[:, 0], pc[:, 0]
        rows, cnt, o_s, o_o = batched_hash_exchange(
            rows, cnt, col, axis_name, p, cap_slot, cap_out, offs
        )
        rows, cnt = jax.vmap(local_semijoin, in_axes=(0, 0, None, 0, 0))(
            rows, cnt, col, pv, pc
        )
        ovf = jnp.stack([o_s.astype(jnp.int32), o_o.astype(jnp.int32)], axis=-1)
        return rows[:, None], cnt[:, None], ovf[:, None, :]

    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(None, axis_name, None, None), P(None, axis_name), P(None),
            P(None, axis_name, None), P(None, axis_name),
        ),
        out_specs=(
            P(None, axis_name, None, None), P(None, axis_name), P(None, axis_name, None),
        ),
        check_rep=False,
    ))


def batched_sharded_semijoin(
    mesh,
    axis_name: str,
    rows_global: jax.Array, counts: jax.Array,      # (s, p, cap, w), (s, p)
    col: int,
    offs: jax.Array,                                # (s,) piece-distribution offsets
    piece_vals: jax.Array, piece_counts: jax.Array, # (s, p, capx), (s, p)
    cap_slot: int, cap_out: int,
    invoke: bool = True,
):
    """Stage-batched `sharded_semijoin` (single filter — the executor's shape):
    every stage's rows are exchanged on ``col`` with its own pinned piece salt
    and membership-filtered against its co-located piece, in one dispatch.
    Returns (rows (s, p, cap_out, w), counts (s, p), ovf (s, p, 2)); with
    ``invoke=False`` returns ``(jitted_fn, args)`` for AOT compilation."""
    fn = _batched_semijoin_fn(mesh, axis_name, col, cap_slot, cap_out)
    if not invoke:
        return fn, (rows_global, counts, offs, piece_vals, piece_counts)
    return fn(rows_global, counts, offs, piece_vals, piece_counts)


@lru_cache(maxsize=512)
def _batched_colocated_join_fn(mesh, axis_name, ka, kb, cap_out, dup_pairs, packed):
    from jax.experimental.shard_map import shard_map

    def body(a_rows, a_cnt, b_rows, b_cnt, mults):
        # mults (s, ndup) replicated; packed is static, so the unpacked variant
        # traces no use of it (it rides along as a zero-size dummy)
        out, cnt, ovf = jax.vmap(
            lambda ar, ac, br, bc, m: local_join_filtered(
                ar, ac, br, bc, ka=ka, kb=kb, cap_out=cap_out,
                dup_pairs=dup_pairs, key_mults=m if packed else None,
            )
        )(a_rows[:, 0], a_cnt[:, 0], b_rows[:, 0], b_cnt[:, 0], mults)
        ovf2 = jnp.stack(
            [jnp.zeros_like(ovf, jnp.int32), ovf.astype(jnp.int32)], axis=-1
        )
        return out[:, None], cnt[:, None], ovf2[:, None, :]

    # the stacked input blocks are rebuilt host-side per dispatch, so their
    # device copies are single-use: donating them lets XLA reuse the pages
    # for the (equally large) expansion buffers
    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(None, axis_name, None, None), P(None, axis_name),
            P(None, axis_name, None, None), P(None, axis_name), P(None, None),
        ),
        out_specs=(
            P(None, axis_name, None, None), P(None, axis_name), P(None, axis_name, None),
        ),
        check_rep=False,
    ), donate_argnums=(0, 2))


def batched_sharded_colocated_join(
    mesh,
    axis_name: str,
    a_global: jax.Array, a_counts: jax.Array,   # (s, p, capA, wa), (s, p)
    b_global: jax.Array, b_counts: jax.Array,
    ka: int, kb: int,
    cap_out: int,
    dup_pairs: Tuple[Tuple[int, int], ...] = (),
    key_mults: Optional[jax.Array] = None,      # (s, ndup) int32 packing radices
    invoke: bool = True,
):
    """Stage-batched `sharded_colocated_join`: s communication-free per-cell
    joins in one dispatch (vmapped `local_join_filtered`; the slot channel is
    structurally zero).  ``key_mults`` selects the packed int32 composite-key
    path (see `local_join_filtered`); radices are traced, so packed buckets of
    one shape share an executable.  Returns (out (s, p, cap_out, w),
    counts (s, p), ovf (s, p, 2)); with ``invoke=False`` returns
    ``(jitted_fn, args)`` for AOT compilation."""
    packed = key_mults is not None
    if key_mults is None:
        key_mults = jnp.zeros((a_global.shape[0], max(1, len(dup_pairs))), jnp.int32)
    fn = _batched_colocated_join_fn(
        mesh, axis_name, ka, kb, cap_out, tuple(dup_pairs), packed
    )
    if not invoke:
        return fn, (a_global, a_counts, b_global, b_counts, key_mults)
    return fn(a_global, a_counts, b_global, b_counts, key_mults)


@lru_cache(maxsize=512)
def _batched_colocated_count_fn(mesh, axis_name, ka, kb, dup_pairs, packed):
    from jax.experimental.shard_map import shard_map

    def body(a_rows, a_cnt, b_rows, b_cnt, mults):
        cnt = jax.vmap(
            lambda ar, ac, br, bc, m: local_join_count(
                ar, ac, br, bc, ka=ka, kb=kb,
                dup_pairs=dup_pairs, key_mults=m if packed else None,
            )
        )(a_rows[:, 0], a_cnt[:, 0], b_rows[:, 0], b_cnt[:, 0], mults)
        s = cnt.shape[0]
        return cnt[:, None], jnp.zeros((s, 1, 2), jnp.int32)

    return jax.jit(shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(None, axis_name, None, None), P(None, axis_name),
            P(None, axis_name, None, None), P(None, axis_name), P(None, None),
        ),
        out_specs=(P(None, axis_name), P(None, axis_name, None)),
        check_rep=False,
    ))


def batched_sharded_colocated_join_count(
    mesh,
    axis_name: str,
    a_global: jax.Array, a_counts: jax.Array,   # (s, p, capA, wa), (s, p)
    b_global: jax.Array, b_counts: jax.Array,
    ka: int, kb: int,
    dup_pairs: Tuple[Tuple[int, int], ...] = (),
    key_mults: Optional[jax.Array] = None,
    invoke: bool = True,
):
    """Count-only twin of `batched_sharded_colocated_join`: the exact per-device
    match totals (s, p) with no expansion, so the executor can size the emit
    pass's cap_out exactly (count-then-emit).  Returns (counts (s, p),
    ovf (s, p, 2) structurally zero); ``invoke=False`` → ``(jitted_fn, args)``."""
    packed = key_mults is not None
    if key_mults is None:
        key_mults = jnp.zeros((a_global.shape[0], max(1, len(dup_pairs))), jnp.int32)
    fn = _batched_colocated_count_fn(mesh, axis_name, ka, kb, tuple(dup_pairs), packed)
    if not invoke:
        return fn, (a_global, a_counts, b_global, b_counts, key_mults)
    return fn(a_global, a_counts, b_global, b_counts, key_mults)


def hypercube_binary_join(
    mesh,
    axis_name: str,
    a_global: jax.Array, a_counts: jax.Array,   # (p, capA, wa), (p,) device-sharded
    b_global: jax.Array, b_counts: jax.Array,
    ka: int, kb: int,
    cap_slot: int, cap_mid: int, cap_out: int,
):
    """The one-round routed join R(A,B) ⋈ S(B,C): a single `sharded_join_step`
    with no duplicate attributes (kept as the named Lemma 3.3 entry point;
    overflow is reported as a single combined (p,) counter)."""
    out, cnt, ovf = sharded_join_step(
        mesh, axis_name, a_global, a_counts, b_global, b_counts,
        ka, kb, cap_slot, cap_mid, cap_out,
    )
    return out, cnt, ovf.sum(axis=-1)
