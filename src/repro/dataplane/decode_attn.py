"""Explicit split-KV distributed decode attention (flash-decoding across the model
axis) — the shard_map twin of the GSPMD-derived path in models/attention.py.

Each model-shard holds a sequence slice of the KV cache; it computes partial
(m_i = max score, l_i = Σ exp, acc_i = Σ exp·V) over its slice, then one psum-style
combine with global max stabilization reconstructs the exact softmax:

    m = pmax(m_i);  l = Σ_i l_i·e^{m_i-m};  out = Σ_i acc_i·e^{m_i-m} / l

Communication per step: O(B·H·(2 + hd)) — independent of sequence length, which is
what makes 500k-token decode collective-light (see the long_500k roofline rows)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def split_kv_decode_attention(
    mesh,
    axis_name: str,
    q: jax.Array,          # (B, H, hd) — replicated over the model axis
    k_cache: jax.Array,    # (B, S, KV, hd) — S sharded over the model axis
    v_cache: jax.Array,
):
    from jax.experimental.shard_map import shard_map

    def body(q, k, v):
        b, h, hd = q.shape
        kv = k.shape[2]
        rep = h // kv
        qg = q.reshape(b, kv, rep, hd)
        s = jnp.einsum("bkrd,bskd->bkrs", qg, k).astype(jnp.float32) * (hd ** -0.5)
        m_loc = s.max(axis=-1)                                   # (B,KV,rep)
        m = jax.lax.pmax(m_loc, axis_name)
        e = jnp.exp(s - m[..., None])
        l_loc = e.sum(axis=-1)
        acc_loc = jnp.einsum("bkrs,bskd->bkrd", e.astype(v.dtype), v)
        l = jax.lax.psum(l_loc, axis_name)
        acc = jax.lax.psum(acc_loc, axis_name)
        out = acc / l[..., None].astype(acc.dtype)
        return out.reshape(b, h, hd)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(None, axis_name, None, None), P(None, axis_name, None, None)),
        out_specs=P(),
        check_rep=False,
    )
    return fn(q, k_cache, v_cache)


def reference_decode_attention(q, k_cache, v_cache):
    """Single-device oracle."""
    b, h, hd = q.shape
    kv = k_cache.shape[2]
    rep = h // kv
    qg = q.reshape(b, kv, rep, hd)
    s = jnp.einsum("bkrd,bskd->bkrs", qg, k_cache).astype(jnp.float32) * (hd ** -0.5)
    w = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkrs,bskd->bkrd", w, v_cache)
    return out.reshape(b, h, hd)
