"""Deterministic cartesian-product grid (Lemma 3.1).

Tuples of R_i carry ids 1..|R_i|; machines form a p_1 × ... × p_{t'} grid; the id-j
tuple of R_i goes to every machine whose dim-i coordinate is (j mod p_i); relations
beyond t' (too small to matter) are broadcast. Every combination is assembled at
exactly one machine, with load O(max_i (Π_{j≤i}|R_j|/p)^{1/i}) = the paper's (3.2).
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.planner import grid_dims
from ..core.query import Attr, Relation
from .simulator import MPCSimulator, scatter_input


def cp_cell_contribs(dims: Sequence[int], list_idx: int) -> Tuple[int, Tuple[int, ...]]:
    """Static (host-side) half of `cells_for_ids`: the flat-cell stride of
    ``list_idx``'s own coordinate plus the flat contribution of every
    combination of the *other* dimensions.  Shared by the numpy and the jnp
    routing paths so both enumerate the exact same cells."""
    dims = list(dims)
    stride = math.prod(dims[list_idx + 1:]) if list_idx + 1 < len(dims) else 1
    other_dims = [d for i, d in enumerate(dims) if i != list_idx]
    n_other = math.prod(other_dims) if other_dims else 1
    contribs = np.zeros((n_other,), dtype=np.int64)
    if other_dims:
        grid = np.indices(other_dims).reshape(len(other_dims), -1).T
        j = 0
        for di in range(len(dims)):
            if di == list_idx:
                continue
            s = math.prod(dims[di + 1:]) if di + 1 < len(dims) else 1
            contribs += grid[:, j] * s
            j += 1
    return stride, tuple(int(c) for c in contribs)


def cp_cells_dev(ids, dims: Sequence[int], list_idx: int):
    """jnp cell enumeration for list ``list_idx``: traced (n,) ids → (n,
    n_other) flat cells.  The single device-side implementation — both
    `CartesianGrid.cells_for_ids_dev` and the dataplane GridRoute lowering
    call it, so route math cannot diverge from the grid geometry."""
    import jax.numpy as jnp

    stride, contribs = cp_cell_contribs(dims, list_idx)
    coords = (ids % dims[list_idx]).astype(jnp.int32)
    return coords[:, None] * stride + jnp.asarray(contribs, dtype=jnp.int32)[None, :]


class CartesianGrid:
    """Grid geometry + routing for Lemma 3.1. Lists must be sorted by size desc."""

    def __init__(self, sizes: Sequence[int], p: int):
        self.sizes = list(sizes)
        self.p = p
        self.dims, self.t_prime, self.load_bound = grid_dims(self.sizes, p)
        self.size = math.prod(self.dims) if self.dims else 1

    def cells_for_ids(self, list_idx: int, ids: np.ndarray) -> np.ndarray:
        """(n, n_other) flat cell ids for tuples of list ``list_idx`` (< t')."""
        coords = ids % self.dims[list_idx]
        other_dims = [d for i, d in enumerate(self.dims) if i != list_idx]
        n_other = math.prod(other_dims) if other_dims else 1
        combos = np.zeros((n_other, len(self.dims)), dtype=np.int64)
        if other_dims:
            grid = np.indices(other_dims).reshape(len(other_dims), -1).T
            j = 0
            for di in range(len(self.dims)):
                if di != list_idx:
                    combos[:, di] = grid[:, j]
                    j += 1
        flat = np.zeros((ids.shape[0], n_other), dtype=np.int64)
        for di in range(len(self.dims)):
            stride = math.prod(self.dims[di + 1 :]) if di + 1 < len(self.dims) else 1
            if di == list_idx:
                flat += coords.reshape(-1, 1) * stride
            else:
                flat += combos[:, di].reshape(1, -1) * stride
        return flat

    def cells_for_ids_dev(self, list_idx: int, ids) -> "jax.Array":  # noqa: F821
        """jnp twin of `cells_for_ids` for device-side routing: ``ids`` is a
        traced (n,) int array, the grid structure is static (baked into the
        trace).  Returns (n, n_other) flat cell ids identical to the numpy
        version — delegates to `cp_cells_dev`, the same function the dataplane
        GridRoute lowering traces, so simulator and device routing agree on
        the Lemma 3.1 geometry by construction."""
        return cp_cells_dev(ids, self.dims, list_idx)

    def theoretical_load(self) -> float:
        """The bound (3.2): O(max_i |Join(R_1..R_i)|^{1/i} / p^{1/i})."""
        best = 0.0
        prod = 1.0
        for i, s in enumerate(self.sizes, start=1):
            prod *= float(s)
            best = max(best, (prod / self.p) ** (1.0 / i))
        return best


def route_cartesian(
    sim: MPCSimulator,
    grid: CartesianGrid,
    lists: Sequence[Tuple[object, np.ndarray, np.ndarray]],
    deliver: Callable[[int, object, np.ndarray], None],
    broadcast_cells: Sequence[int],
) -> None:
    """Route id-carrying rows. ``lists[i] = (out_tag, ids, rows)`` sorted desc by size;
    lists with index ≥ t' are broadcast to every cell in ``broadcast_cells``.
    Must be called inside an open round."""
    for i, (tag, ids, rows) in enumerate(lists):
        if rows.ndim == 1:
            rows = rows.reshape(-1, 1)
        if rows.shape[0] == 0:
            continue
        if i < grid.t_prime:
            cells = grid.cells_for_ids(i, ids)
            for combo in range(cells.shape[1]):
                flat = cells[:, combo]
                order = np.argsort(flat, kind="stable")
                fs, rs = flat[order], rows[order]
                uniq = np.unique(fs)
                bounds = np.append(np.searchsorted(fs, uniq), fs.shape[0])
                for u_i, cell in enumerate(uniq.tolist()):
                    deliver(int(cell), tag, rs[bounds[u_i] : bounds[u_i + 1]])
        else:
            for cell in broadcast_cells:
                deliver(int(cell), tag, rows)


def cartesian_product_mpc(
    relations: Sequence[Relation],
    p: int,
    seed: int = 0,
    materialize: bool = False,
) -> Tuple[MPCSimulator, int, Optional[np.ndarray]]:
    """Standalone Lemma 3.1: unary/any-arity relations with disjoint schemes.
    Returns (sim, |CP| assembled, rows if materialize). Used by bench_cartesian."""
    rels = sorted(relations, key=len, reverse=True)
    sizes = [len(r) for r in rels]
    assert all(s > 0 for s in sizes)
    grid = CartesianGrid(sizes, p)

    sim = MPCSimulator(p, seed=seed)
    # input placement: even spread, ids assigned by global position (simulating the
    # paper's 'tuples have been labeled with ids' precondition).
    id_rows = []
    for i, r in enumerate(rels):
        ids = np.arange(len(r), dtype=np.int64)
        id_rows.append(np.concatenate([ids.reshape(-1, 1), r.data], axis=1))
        scatter_input(sim, ("cp-in", i), id_rows[-1], seed=seed + i)

    sim.begin_round("cartesian")
    for mid in range(sim.p):
        lists = []
        for i in range(len(rels)):
            local = sim.local(mid, ("cp-in", i), arity=1 + rels[i].arity)
            lists.append((("cp", i), local[:, 0], local[:, 1:]))
        route_cartesian(
            sim,
            grid,
            lists,
            deliver=lambda cell, tag, rows: sim.send(cell, tag, rows),
            broadcast_cells=range(grid.size),
        )
    sim.end_round()

    total = 0
    out = []
    for cell in range(grid.size):
        frags = [sim.local(cell, ("cp", i), arity=rels[i].arity) for i in range(len(rels))]
        if any(f.shape[0] == 0 for f in frags):
            continue
        count = math.prod(f.shape[0] for f in frags)
        total += count
        if materialize:
            prod = frags[0]
            for f in frags[1:]:
                n_a, n_b = prod.shape[0], f.shape[0]
                prod = np.concatenate(
                    [np.repeat(prod, n_b, axis=0), np.tile(f, (n_a, 1))], axis=1
                )
            out.append(prod)
    rows = np.concatenate(out, axis=0) if (materialize and out) else None
    return sim, total, rows
