"""Execution backends for the round-program IR (repro.mpc.program).

One verified plan, many backends: ``compile_plan`` fixes *which rounds with
which routes*; an :class:`Executor` decides *who executes them*.

* :class:`SimulatorExecutor` interprets every op on the exact-cost
  :class:`~repro.mpc.simulator.MPCSimulator` — the load oracle.  It reproduces
  the pre-IR monolithic engine bit for bit: identical hash keys, identical
  per-machine RNG streams, identical loop order, hence byte-identical
  ``per_h_counts`` and ``parallel_total_load`` (locked by
  tests/test_program_ir.py golden values).

* :class:`DataplaneExecutor` lowers every op of every compiled program onto
  the JAX data plane — one lowering rule per :class:`RoundOp`, dispatched over
  ``program.ops``: capacity-padded ``hash_exchange`` / ``sharded_grid_route``
  collectives + the merge_join_counts Pallas probe under ``shard_map``.
  Stages with isolated attributes run the Lemma 3.1 cartesian grid composed
  with the Lemma 3.3 HyperCube (the Lemma 3.2 cell mapping lives in
  :class:`~repro.mpc.program.StageGeometry`, shared with the simulator), so
  the device backend covers the whole of Theorem 6.2 (docs/DESIGN.md §7).
"""

from __future__ import annotations

import hashlib
import math
import time
import warnings
from collections import defaultdict
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Tuple

import numpy as np

# The batched dataplane primitives donate their big stacked input buffers
# (jax.jit donate_argnums) so multi-op rounds reuse device memory in place.
# Backends without donation support (CPU) warn per call; the fallback copy is
# exactly the old behavior, so the warning is noise here.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable", category=UserWarning
)

from ..core.query import Attr, JoinQuery, Relation, reference_join
from ..core.taxonomy import heavy_masks, residual_relations
from .faults import DeadlineExceededError, RetryExhaustedError
from .hypercube import HyperCubeGrid, route_hypercube
from .program import (
    BroadcastSizes,
    CellJoin,
    GridRoute,
    HashPartition,
    LocalJoin,
    ProgramStage,
    RoundOp,
    RoundProgram,
    RouteResidual,
    RunConfig,
    Scatter,
    SemiJoin,
    ShareRoute,
    StageGeometry,
    TreeSemiJoin,
    stage_geometry,
)
from .simulator import MPCSimulator, scatter_input


@dataclass
class MPCJoinResult:
    p: int
    lam: int
    rho: float
    m: int
    count: int
    rows: Optional[np.ndarray]          # over sorted(attset), if materialized
    sim: MPCSimulator
    per_h_counts: Dict[Tuple[Attr, ...], int]

    @property
    def bound(self) -> float:
        """The claimed load bound m / p^{1/ρ} (polylog factors not included)."""
        return self.m / (self.p ** (1.0 / self.rho))

    @property
    def load(self) -> int:
        return self.sim.parallel_total_load

    @property
    def load_ratio(self) -> float:
        return self.load / max(1.0, self.bound)


def _send_grouped(sim: MPCSimulator, phys: np.ndarray, tag, rows: np.ndarray) -> None:
    """Group rows by destination and send one message per destination."""
    if rows.ndim == 1:
        rows = rows.reshape(-1, 1)
    if rows.shape[0] == 0:
        return
    order = np.argsort(phys, kind="stable")
    ps, rs = phys[order], rows[order]
    uniq = np.unique(ps)
    bounds = np.append(np.searchsorted(ps, uniq), ps.shape[0])
    for i, dst in enumerate(uniq.tolist()):
        sim.send(int(dst), tag, rs[bounds[i] : bounds[i + 1]])


# ---------------------------------------------------------------------------
# Simulator backend
# ---------------------------------------------------------------------------


class SimulatorExecutor:
    """Runs a compiled :class:`RoundProgram` on the exact-cost simulator.

    May be handed an existing simulator (so the statistics preprocessing and
    the program execution meter into the same round ledger — the ``mpc_join``
    path), or a bare ``p`` to own a fresh one."""

    def __init__(
        self, sim: Optional[MPCSimulator] = None, p: Optional[int] = None, seed: int = 0
    ):
        if sim is None:
            if p is None:
                raise ValueError("need either a simulator or p")
            sim = MPCSimulator(p, seed=seed)
        self.sim = sim
        self.seed = seed

    # -- input placement (Scatter semantics; idempotent) ---------------------

    def place_inputs(
        self,
        query: JoinQuery,
        seed_offset: int = 17,
        scatter_cache: Optional[Dict] = None,
    ) -> None:
        """Scatter every input relation evenly (Θ(m/p) per machine).

        Shared-input path: relations carrying the same ``Relation.table`` id
        and the same tuple set are physically one table (the subgraph
        reduction binds k pattern edges to one edge set), so the tuples are
        shuffled and placed ONCE and the per-edge ``("in", e)`` tags alias the
        same numpy blocks — k logical copies cost one placement.  Aliasing is
        invisible to the MPC accounting (Scatter is load-free initial
        placement) and to downstream ops, which only ever read these tags;
        it also matches the unshared behavior bit for bit, because every
        relation was already scattered with the same seed.

        ``scatter_cache`` extends the sharing *across* simulators: a
        :class:`~repro.mpc.service.JoinSession` batch passes its session dict
        keyed by (table, p, seed), and queries binding the same physical
        table reuse the first query's shuffled placement instead of
        re-shuffling — bit-identical, because ``scatter_input`` is
        deterministic in (data, seed, p)."""
        placed: Dict[str, Tuple[object, np.ndarray]] = {}
        for rel in query.relations:
            tag = ("in", rel.edge)
            if self.sim.machines_with(tag):
                continue
            shared = placed.get(rel.table) if rel.table is not None else None
            if shared is not None and (
                shared[1] is rel.data or np.array_equal(shared[1], rel.data)
            ):
                src = shared[0]
                for mid in range(self.sim.p):
                    parts = self.sim.stores[mid].get(src)
                    if parts:
                        self.sim.stores[mid][tag] = list(parts)
                continue
            ckey = None
            if scatter_cache is not None and rel.table is not None:
                ckey = (rel.table, self.sim.p, self.seed + seed_offset)
                hit = scatter_cache.get(ckey)
                if hit is not None and (
                    hit[0] is rel.data or np.array_equal(hit[0], rel.data)
                ):
                    for mid, parts in enumerate(hit[1]):
                        if parts:
                            self.sim.stores[mid][tag] = list(parts)
                    placed.setdefault(rel.table, (tag, rel.data))
                    continue
            scatter_input(self.sim, tag, rel.data, seed=self.seed + seed_offset)
            if ckey is not None and ckey not in scatter_cache:
                scatter_cache[ckey] = (
                    rel.data,
                    [
                        list(self.sim.stores[mid].get(tag) or [])
                        for mid in range(self.sim.p)
                    ],
                )
            if rel.table is not None and rel.table not in placed:
                placed[rel.table] = (tag, rel.data)

    # -- program interpretation ----------------------------------------------

    def run(self, program: RoundProgram, materialize: bool = True) -> MPCJoinResult:
        if self.sim.p != program.p:
            raise ValueError(f"simulator has p={self.sim.p}, program wants {program.p}")
        self._program = program
        self._materialize = materialize
        self._geo: Dict[int, StageGeometry] = {}
        self._outputs: Dict[int, List[np.ndarray]] = defaultdict(list)
        self._counts: Dict[Tuple[Attr, ...], int] = defaultdict(int)
        # general route: per-relation working tag (TreeSemiJoin sweeps move a
        # relation's surviving rows under fresh tags as they filter it)
        self._gtags: Dict[int, Tuple] = {
            i: ("in", rel.edge) for i, rel in enumerate(program.query.relations)
        }
        self._ggrid: Optional[HyperCubeGrid] = None

        # H = attset(Q) emits: host-side placement, zero communication.
        for mid, row in program.emit:
            self._outputs[mid].append(row)
        for hkey, c in program.emit_counts.items():
            self._counts[hkey] += c

        for op in program.ops:
            self._dispatch(op)

        rows_out = None
        if materialize:
            chunks = [r for parts in self._outputs.values() for r in parts]
            rows_out = (
                np.concatenate(chunks, axis=0)
                if chunks
                else np.zeros((0, len(program.out_cols)), dtype=np.int64)
            )
        return MPCJoinResult(
            p=program.p,
            lam=program.lam,
            rho=program.rho_val,
            m=program.stats.m,
            count=sum(self._counts.values()),
            rows=rows_out,
            sim=self.sim,
            per_h_counts=dict(self._counts),
        )

    def _dispatch(self, op: RoundOp) -> None:
        if isinstance(op, Scatter):
            self.place_inputs(self._program.query, op.seed_offset)
        elif isinstance(op, RouteResidual):
            self._op_route_residual()
        elif isinstance(op, HashPartition):
            self._op_hash_partition()
        elif isinstance(op, SemiJoin):
            self._op_semijoin(op)
        elif isinstance(op, BroadcastSizes):
            self._op_broadcast_sizes()
        elif isinstance(op, GridRoute):
            self._op_grid_route()
        elif isinstance(op, LocalJoin):
            self._op_local_join()
        elif isinstance(op, TreeSemiJoin):
            self._op_tree_semijoin(op)
        elif isinstance(op, ShareRoute):
            self._op_share_route()
        elif isinstance(op, CellJoin):
            self._op_cell_join()
        else:
            raise NotImplementedError(f"unknown op {op!r}")

    # -- step 1: route residual tuples ---------------------------------------

    def _op_route_residual(self) -> None:
        sim, program = self.sim, self._program
        query, stats, p = program.query, program.stats, program.p
        sim.begin_round("step1")
        for mid in range(sim.p):
            mrng = np.random.default_rng(self.seed * 1_000_003 + mid)
            local_cache: Dict = {}
            for rel in query.relations:
                local = sim.local(mid, ("in", rel.edge))
                if local.shape[0] == 0:
                    continue
                x_attr, y_attr = rel.scheme
                hx = stats.is_heavy(x_attr, local[:, 0])
                hy = stats.is_heavy(y_attr, local[:, 1])
                local_cache[rel.edge] = (local, hx, hy)
            for st in program.stages:
                plan, cfg = st.plan, st.cfg
                h = set(plan.h_set)
                grp = cfg.step1_group
                for rel in query.relations:
                    if rel.edge not in local_cache:
                        continue
                    local, hx, hy = local_cache[rel.edge]
                    x_attr, y_attr = rel.scheme
                    inter = rel.edge & h
                    if len(inter) == 2:
                        continue
                    if len(inter) == 0:
                        sel = ~hx & ~hy
                        rows = local[sel]
                    else:
                        (heavy_attr,) = inter
                        if heavy_attr == x_attr:
                            sel = (local[:, 0] == cfg.eta.value(x_attr)) & ~hy
                            rows = local[sel][:, 1:2]   # project to light attr
                        else:
                            sel = (local[:, 1] == cfg.eta.value(y_attr)) & ~hx
                            rows = local[sel][:, 0:1]
                    if rows.shape[0] == 0:
                        continue
                    virt = mrng.integers(0, grp.size, size=rows.shape[0])
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("r1", st.hkey, st.ekey, rel.edge), rows)
        sim.end_round()

    # -- step 2a: unary partition + intersection -----------------------------

    def _op_hash_partition(self) -> None:
        sim, program = self.sim, self._program
        query, p = program.query, program.p
        sim.begin_round("step2-unary")
        for st in program.stages:
            plan, cfg = st.plan, st.cfg
            grp = cfg.step1_group
            for e in plan.cross_edges:
                light_attr = next(iter(e - set(plan.h_set)))
                tag_in = ("r1", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=1)
                    virt = sim.hashes.hash(
                        (st.hkey, st.ekey, "sj", light_attr), rows[:, 0], grp.size
                    )
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("u", st.hkey, st.ekey, light_attr, e), rows)
        sim.end_round()

        # local intersection → R''_X pieces (no communication)
        for st in program.stages:
            plan = st.plan
            for x in plan.border:
                es = [e for e in plan.cross_edges if x in e]
                for mid in range(sim.p):
                    pieces = []
                    ok = True
                    for e in es:
                        vals = sim.local(mid, ("u", st.hkey, st.ekey, x, e), arity=1)
                        if vals.shape[0] == 0:
                            ok = False
                            break
                        pieces.append(np.unique(vals[:, 0]))
                    if not ok:
                        continue
                    inter = pieces[0]
                    for arr in pieces[1:]:
                        inter = np.intersect1d(inter, arr, assume_unique=True)
                    if inter.size:
                        sim.stores[mid][("ux", st.hkey, st.ekey, x)] = [inter.reshape(-1, 1)]

    # -- step 2b/2c: semi-join light edges -----------------------------------

    def _filter_by_membership(self, mid, rows, col, attr, st):
        """Keep rows whose rows[:, col] is in the machine-local R''_attr piece."""
        piece = self.sim.local(mid, ("ux", st.hkey, st.ekey, attr), arity=1)[:, 0]
        if piece.size == 0:
            return rows[:0]
        return rows[np.isin(rows[:, col], piece)]

    def _op_semijoin(self, op: SemiJoin) -> None:
        if op.phase == "x":
            self._semijoin_x()
        elif op.phase == "y":
            self._semijoin_y(fused=False)
            self._semijoin_local_y_filter()
        elif op.phase == "fused-route":
            self._semijoin_fused_route()
        elif op.phase == "fused-filter":
            self._semijoin_y(fused=True)
            self._semijoin_local_y_filter()
        else:
            raise NotImplementedError(f"SemiJoin phase {op.phase!r}")

    def _semijoin_x(self) -> None:
        sim, program = self.sim, self._program
        query, p = program.query, program.p
        sim.begin_round("step2-bx")
        for st in program.stages:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr = rel.scheme[0]
                tag_in = ("r1", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    virt = sim.hashes.hash(
                        (st.hkey, st.ekey, "sj", x_attr), rows[:, 0], grp.size
                    )
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("bx", st.hkey, st.ekey, e), rows)
        sim.end_round()

    def _semijoin_fused_route(self) -> None:
        # Beyond-paper fusion: route directly to the Y partition; X-filtering
        # happens at the Y-side against a replicated X piece fetched in the same
        # round — saves one full data round when X is not a border attribute,
        # else falls back to the two-hop detour.  See EXPERIMENTS §Perf.
        sim, program = self.sim, self._program
        query, p = program.query, program.p
        sim.begin_round("step2-fused")
        for st in program.stages:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr, y_attr = rel.scheme
                tag_in = ("r1", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    if x_attr not in st.plan.border:
                        virt = sim.hashes.hash(
                            (st.hkey, st.ekey, "sj", y_attr), rows[:, 1], grp.size
                        )
                        phys = (grp.base + virt) % p
                        _send_grouped(sim, phys, ("rr", st.hkey, st.ekey, e), rows)
                    else:
                        virt = sim.hashes.hash(
                            (st.hkey, st.ekey, "sj", x_attr), rows[:, 0], grp.size
                        )
                        phys = (grp.base + virt) % p
                        _send_grouped(sim, phys, ("bx", st.hkey, st.ekey, e), rows)
        sim.end_round()

    def _semijoin_y(self, fused: bool) -> None:
        sim, program = self.sim, self._program
        query, p = program.query, program.p
        sim.begin_round("step2-by")
        for st in program.stages:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr, y_attr = rel.scheme
                if fused and x_attr not in st.plan.border:
                    continue
                tag_in = ("bx", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    if x_attr in st.plan.border:
                        rows = self._filter_by_membership(mid, rows, 0, x_attr, st)
                    if rows.shape[0] == 0:
                        continue
                    virt = sim.hashes.hash(
                        (st.hkey, st.ekey, "sj", y_attr), rows[:, 1], grp.size
                    )
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("rr", st.hkey, st.ekey, e), rows)
        sim.end_round()

    def _semijoin_local_y_filter(self) -> None:
        # Y-side filtering is local (the piece lives where the hash sent the row).
        sim, program = self.sim, self._program
        query = program.query
        for st in program.stages:
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                y_attr = rel.scheme[1]
                if y_attr not in st.plan.border:
                    continue
                tag = ("rr", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag):
                    rows = sim.local(mid, tag, arity=2)
                    rows = self._filter_by_membership(mid, rows, 1, y_attr, st)
                    sim.stores[mid][tag] = [rows]

    # -- step 3 sizes: broadcast |R''_X| pieces ------------------------------

    def _op_broadcast_sizes(self) -> None:
        sim, program = self.sim, self._program
        attset = program.query.attset
        stages = program.stages
        sim.begin_round("step3-sizes")
        cfg_index = {(st.hkey, st.ekey): i for i, st in enumerate(stages)}
        attr_index = {a: i for i, a in enumerate(attset)}
        for st in stages:
            for x in st.plan.isolated:
                tag = ("ux", st.hkey, st.ekey, x)
                for mid in sim.machines_with(tag):
                    cnt = sim.local(mid, tag, arity=1).shape[0]
                    msg = np.array(
                        [[cfg_index[(st.hkey, st.ekey)], attr_index[x], mid, cnt]],
                        dtype=np.int64,
                    )
                    sim.broadcast(("sz",), msg)
        sim.end_round()

        size_rows = (
            sim.local(0, ("sz",), arity=4)
            if sim.machines_with(("sz",))
            else np.zeros((0, 4), np.int64)
        )
        piece_sizes: Dict[Tuple[int, int], List[Tuple[int, int]]] = defaultdict(list)
        for ci, ai, mid, cnt in size_rows.tolist():
            piece_sizes[(ci, ai)].append((mid, cnt))

        for i, st in enumerate(stages):
            entries = {
                x: piece_sizes.get((i, attr_index[x]), []) for x in st.plan.isolated
            }
            self._geo[i] = stage_geometry(program, st, entries)

    # -- step 3 route: Lemma 3.1 grid × Lemma 3.3 HyperCube ------------------

    def _op_grid_route(self) -> None:
        sim, program = self.sim, self._program
        query = program.query
        sim.begin_round("step3-route")
        for i, st in enumerate(program.stages):
            geo = self._geo[i]
            if geo.skip:
                continue
            grp = geo.step3_group
            hc_size, cp_size = geo.hc_size, geo.cp_size

            # CP side: every grid cell is instantiated in every HC column.
            if geo.grid:
                for li, x in enumerate(geo.iso_order):
                    tag = ("ux", st.hkey, st.ekey, x)
                    for mid in sim.machines_with(tag):
                        vals = sim.local(mid, tag, arity=1)
                        ids = geo.offsets[(x, mid)] + np.arange(
                            vals.shape[0], dtype=np.int64
                        )
                        if li < geo.grid.t_prime:
                            cells = geo.grid.cells_for_ids(li, ids)
                            for combo in range(cells.shape[1]):
                                flat = cells[:, combo]
                                for cell in np.unique(flat).tolist():
                                    rows = vals[flat == cell]
                                    for h_cell in range(hc_size):
                                        v = geo.cell(cell, h_cell)
                                        sim.send(
                                            grp.phys(v),
                                            ("cp", st.hkey, st.ekey, v, x),
                                            rows,
                                        )
                        else:
                            for cell in range(cp_size):
                                for h_cell in range(hc_size):
                                    v = geo.cell(cell, h_cell)
                                    sim.send(
                                        grp.phys(v), ("cp", st.hkey, st.ekey, v, x), vals
                                    )

            # HC side: every HC cell instantiated in every CP row.
            if geo.hc_grid:
                for e in st.plan.light_edges:
                    rel = query.relation_for(e)
                    tag = ("rr", st.hkey, st.ekey, e)
                    for mid in sim.machines_with(tag):
                        rows = sim.local(mid, tag, arity=2)

                        def deliver(
                            h_cell, out_tag, rs, _grp=grp, _geo=geo, _cp=cp_size, _st=st
                        ):
                            for c in range(_cp):
                                v = _geo.cell(c, h_cell)
                                sim.send(
                                    _grp.phys(v), ("hc", _st.hkey, _st.ekey, v, out_tag), rs
                                )

                        route_hypercube(
                            sim,
                            geo.hc_grid,
                            [(rel.scheme, e, rows)],
                            salt=(st.hkey, st.ekey, "hc"),
                            deliver=deliver,
                        )
        sim.end_round()

    # -- output: local joins, exactly-once -----------------------------------

    def _op_local_join(self) -> None:
        sim, program = self.sim, self._program
        query = program.query
        out_cols = list(program.out_cols)
        materialize = self._materialize
        for i, st in enumerate(program.stages):
            geo = self._geo[i]
            if geo.skip:
                continue
            plan = st.plan
            grp = geo.step3_group
            l_minus_i = [a for a in plan.light if a not in plan.isolated]
            h_count = 0
            for v in range(grp.size):
                mid = grp.phys(v)
                # light side
                if plan.light_edges:
                    frags = []
                    ok = True
                    for e in plan.light_edges:
                        rel = query.relation_for(e)
                        rows = sim.local(mid, ("hc", st.hkey, st.ekey, v, e), arity=2)
                        if rows.shape[0] == 0:
                            ok = False
                            break
                        frags.append(Relation.make(rel.scheme, rows))
                    if not ok:
                        continue
                    light_join = reference_join(JoinQuery.make(frags))
                    light_rows = light_join.data  # over sorted(l_minus_i)
                    if light_rows.shape[0] == 0:
                        continue
                else:
                    light_rows = np.zeros((1, 0), dtype=np.int64)

                # CP side
                cp_lists = []
                ok = True
                for x in geo.iso_order:
                    vals = sim.local(mid, ("cp", st.hkey, st.ekey, v, x), arity=1)
                    vals = np.unique(vals[:, 0])
                    if vals.size == 0:
                        ok = False
                        break
                    cp_lists.append(vals)
                if not ok:
                    continue

                n_cp = math.prod(arr.size for arr in cp_lists) if cp_lists else 1
                n_here = light_rows.shape[0] * n_cp
                h_count += n_here
                if materialize and n_here:
                    rows = light_rows
                    cols = sorted(l_minus_i)
                    for x, vals in zip(geo.iso_order, cp_lists):
                        nn = rows.shape[0]
                        rows = np.repeat(rows, vals.size, axis=0)
                        rows = np.concatenate(
                            [rows, np.tile(vals, nn).reshape(-1, 1)], axis=1
                        )
                        cols.append(x)
                    for a in plan.h_set:
                        rows = np.concatenate(
                            [
                                rows,
                                np.full((rows.shape[0], 1), st.cfg.eta.value(a), np.int64),
                            ],
                            axis=1,
                        )
                        cols.append(a)
                    perm = [cols.index(a) for a in out_cols]
                    self._outputs[mid].append(rows[:, perm])
            self._counts[st.hkey] += h_count

    # -- general route: Yannakakis sweeps + generalized HyperCube -------------

    def _op_tree_semijoin(self, op: TreeSemiJoin) -> None:
        """One semijoin sweep along the join tree (general acyclic route).

        Each tree edge is its own communication round (the next edge's filter
        reads this edge's output); same-named rounds merge in the parallel
        load accounting, matching the paper's process-all-in-parallel model.
        Both sides of an edge are hash-partitioned on the first shared
        attribute (same hash key ⇒ co-located), then the filtered side keeps
        exactly the rows whose full shared-attribute tuple appears in the
        filtering side.  An empty shared label degenerates to a non-emptiness
        filter: both sides key on the constant 0, so the filtered relation
        survives iff the filtering one has any row (the cartesian stitch
        between disconnected components)."""
        sim, program = self.sim, self._program
        query, gen = program.query, program.general
        edges = gen.tree_edges
        if op.phase == "down":
            edges = tuple(reversed(edges))
        for ei, (child, parent, shared) in enumerate(edges):
            if op.phase == "up":
                tgt, src = parent, child
            else:
                tgt, src = child, parent
            tgt_rel, src_rel = query.relations[tgt], query.relations[src]
            hkey = ("gsj", op.phase, ei)
            tag_f = ("gsjf", op.phase, ei)      # filtering-side key tuples
            tag_e = ("gsje", op.phase, ei)      # filtered-side rows
            new_tag = ("gsj", op.phase, ei, tgt)
            sim.begin_round(op.round)
            for mid in range(sim.p):
                srows = sim.local(mid, self._gtags[src], arity=src_rel.arity)
                if srows.shape[0]:
                    if shared:
                        scols = [src_rel.scheme.index(a) for a in shared]
                        proj = np.unique(srows[:, scols], axis=0)
                    else:
                        proj = np.zeros((1, 1), dtype=np.int64)
                    hv = sim.hashes.hash(hkey, proj[:, 0], sim.p)
                    _send_grouped(sim, hv, tag_f, proj)
                trows = sim.local(mid, self._gtags[tgt], arity=tgt_rel.arity)
                if trows.shape[0]:
                    if shared:
                        tcols = [tgt_rel.scheme.index(a) for a in shared]
                        keyvals = trows[:, tcols[0]]
                    else:
                        keyvals = np.zeros(trows.shape[0], dtype=np.int64)
                    hv = sim.hashes.hash(hkey, keyvals, sim.p)
                    _send_grouped(sim, hv, tag_e, trows)
            sim.end_round()
            for mid in sim.machines_with(tag_e):
                trows = sim.local(mid, tag_e, arity=tgt_rel.arity)
                fl = sim.local(mid, tag_f, arity=max(1, len(shared)))
                if shared:
                    tcols = [tgt_rel.scheme.index(a) for a in shared]
                    fset = set(map(tuple, fl.tolist()))
                    keep = np.fromiter(
                        (tuple(r) in fset for r in trows[:, tcols].tolist()),
                        dtype=bool,
                        count=trows.shape[0],
                    )
                else:
                    keep = np.full(trows.shape[0], fl.shape[0] > 0)
                sim.stores[mid][new_tag] = [trows[keep]]
            self._gtags[tgt] = new_tag

    def _op_share_route(self) -> None:
        """Generalized HyperCube route: every attribute is a grid dimension
        (shares from the compiled plan, Π ≤ p), every relation's tuples go to
        all cells agreeing with their hashed coordinates — one round."""
        sim, program = self.sim, self._program
        query, gen = program.query, program.general
        grid = HyperCubeGrid(program.out_cols, gen.shares_dict)
        self._ggrid = grid
        sim.begin_round("hc-route")
        for mid in range(sim.p):
            frags = []
            for i, rel in enumerate(query.relations):
                local = sim.local(mid, self._gtags[i], arity=rel.arity)
                frags.append((rel.scheme, i, local))
            route_hypercube(
                sim,
                grid,
                frags,
                salt="ghc",
                deliver=lambda cell, i, rows: sim.send(cell, ("gcell", i), rows),
            )
        sim.end_round()

    def _op_cell_join(self) -> None:
        """Output round of the general route: each cell joins its co-located
        fragments locally (every attribute is a grid dimension, so each result
        tuple materializes at exactly one cell — no communication)."""
        sim, program = self.sim, self._program
        query, gen = program.query, program.general
        grid = self._ggrid
        total = 0
        for cell in range(grid.size):
            frags = []
            empty = False
            for i in gen.join_order:
                rel = query.relations[i]
                rows = sim.local(cell, ("gcell", i), arity=rel.arity)
                if rows.shape[0] == 0:
                    empty = True
                    break
                frags.append(Relation.make(rel.scheme, rows))
            if empty:
                continue
            local_join = reference_join(JoinQuery.make(frags))
            total += len(local_join)
            if self._materialize and len(local_join):
                self._outputs[cell].append(local_join.data)
        self._counts[("*",)] += total


# ---------------------------------------------------------------------------
# JAX dataplane backend
# ---------------------------------------------------------------------------


@dataclass
class DataplaneJoinResult:
    """Result of running a program on the device mesh.  ``rows`` is the full
    exactly-once result multiset (over sorted(attset)); there is no simulator,
    so no metered load — wall-clock is the backend's figure of merit.

    The scheduler-observability fields describe the stage-batched dispatch:
    ``dispatches`` counts fused shard_map calls (one per (op, bucket, attempt)),
    ``jit_cache_hits``/``jit_cache_misses`` meter the compiled-executable cache
    (a miss ⇒ a fresh trace+compile; O(#buckets), not O(#stages)), and
    ``bucket_stage_counts`` maps each op round to the per-dispatch batch sizes
    — how many stages rode each fused call."""

    p: int
    count: int
    rows: Optional[np.ndarray]
    per_h_counts: Dict[Tuple[Attr, ...], int]
    retries: int = 0    # capacity-doubling retries triggered by overflow
    # one entry per retry: ((H, η), op round name, "slot" | "out" | "slot+out")
    retry_log: List[Tuple[Tuple, str, str]] = field(default_factory=list)
    dispatches: int = 0
    jit_cache_hits: int = 0
    jit_cache_misses: int = 0
    #: learned-caps store outcomes for this run, distinct from the plan LRU
    #: and the executable cache: a caps hit means a work item started at the
    #: capacities a previous run converged to (the no-overflow warm path).
    caps_hits: int = 0
    caps_misses: int = 0
    caps_evictions: int = 0
    bucket_stage_counts: Dict[str, List[int]] = field(default_factory=dict)
    #: coarse per-phase wall time (µs) across the whole run: "host_prep"
    #: (dispatch building: host stacking + staging), "compile" (AOT
    #: trace+compile of cache misses), "launch" (dispatching executables;
    #: async — device work overlaps the schedule), "sync" (the one deferred
    #: device→host readback per bucket — where collective+kernel time
    #: actually surfaces on the host clock).
    phase_us: Dict[str, float] = field(default_factory=dict)
    #: per-round wall time (µs), keyed by op round name — count rounds appear
    #: under "<round>/count".  Routing rounds ≈ argsort/rank-key + all_to_all;
    #: "output" rounds ≈ the local merge-join kernels.
    round_us: Dict[str, float] = field(default_factory=dict)


class DataplaneUnsupported(NotImplementedError):
    """The program contains an op type with no dataplane lowering rule.

    Every op `compile_plan` emits has one (the acceptance bar of the per-op
    lowering layer); this fires only for op types introduced by a rewrite pass
    the dataplane has not been taught about — loudly, never silently."""


class ExecutableCache:
    """Bounded LRU of AOT-compiled XLA executables, keyed by dispatch signature.

    One entry per distinct fused-dispatch signature (mesh, axis, round, bucket
    key, caps, padded stage count).  The cache outlives any single ``run()``
    — by default all executors share one process-wide instance
    (:data:`EXECUTABLE_CACHE`), so a long-lived service process re-executes
    warm queries with zero recompiles.  Eviction is LRU: long-lived processes
    running many distinct programs drop the oldest executables instead of
    accumulating XLA binaries forever.

    ``hits`` / ``misses`` / ``evictions`` meter the cache's whole lifetime
    (per-run counts live on :class:`DataplaneJoinResult`)."""

    def __init__(self, capacity: int = 1024):
        from collections import OrderedDict

        self.capacity = capacity
        self._entries: "OrderedDict" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, sig) -> bool:
        return sig in self._entries

    def get(self, sig):
        """Return the executable for ``sig`` (refreshing its LRU slot), or
        None on a miss.  Counts lifetime hits/misses."""
        exe = self._entries.get(sig)
        if exe is None:
            self.misses += 1
            return None
        self._entries.move_to_end(sig)
        self.hits += 1
        return exe

    def put(self, sig, exe) -> None:
        self._entries[sig] = exe
        self._entries.move_to_end(sig)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._entries.clear()


#: default process-wide executable cache shared by every DataplaneExecutor —
#: the jit half of the service layer's warm path (a JoinSession's repeat
#: queries hit it even across executor instances).
EXECUTABLE_CACHE = ExecutableCache(capacity=1024)


def _salt(*key, attempt: int = 0) -> int:
    """Stable 31-bit salt for the routing hashes (shared randomness: every
    host derives the same salt from the stage key alone).  ``attempt`` threads
    the overflow-retry count into the salt so a capacity-doubling retry also
    re-randomizes the routing — the paper draws fresh randomness per attempt,
    which is what makes the 1/p^c failure probability per-attempt independent."""
    h = hashlib.blake2b(repr((key, attempt)).encode(), digest_size=8).digest()
    return int.from_bytes(h, "little") % (1 << 31)


def _pow2(n: int) -> int:
    """Round a capacity up to a power of two (≥ 16): retries double caps, so
    pow2 buckets make repeated executor calls hit the jit cache."""
    return 1 << max(4, int(n - 1).bit_length() if n > 1 else 0)


def _quant(n: int) -> int:
    """Round an *exactly counted* capacity up onto the {2^k, 3·2^(k-1)} grid
    (≥ 16).  Denser than pow2 (≤ 33% padding instead of ≤ 100%) — counted
    capacities are exact, so the grid exists only to keep the executable
    signature count bounded; doubling a grid value stays on the grid, so the
    (rare) retry after a salt change still hits the cache."""
    p2 = _pow2(n)
    if p2 >= 32 and 3 * (p2 // 4) >= n:
        return 3 * (p2 // 4)
    return p2


def _pack_radices(a_blocks, b_blocks, dup_pairs) -> Optional[np.ndarray]:
    """Host-side eligibility check for packed int32 composite join keys.

    The colocated join matches on (cell, dup-attr...) tuples; when every key
    column is non-negative and the mixed-radix product (max_cell + 1) ·
    Π (max_dup_i + 1) fits int32, the tuple packs collision-free into one
    int32 word and the device join can sort scalar keys instead of ranking
    64-bit composites (see `local_join_filtered`).  Returns the per-dup-column
    radices, or None for the ranked fallback.  Padding rows are zeros — they
    can't hide a negative and can't raise a max — so block-level min/max are
    exact bounds for the valid prefixes."""
    if not dup_pairs:
        return None
    cols_a = [0] + [ca for ca, _ in dup_pairs]
    cols_b = [0] + [cb for _, cb in dup_pairs]
    lim = np.iinfo(np.int32).max
    space = 1
    rads = []
    for i, (ca, cb) in enumerate(zip(cols_a, cols_b)):
        av = np.asarray(a_blocks)[:, :, ca]
        bv = np.asarray(b_blocks)[:, :, cb]
        if int(np.min(av, initial=0)) < 0 or int(np.min(bv, initial=0)) < 0:
            return None
        hi = int(max(np.max(av, initial=0), np.max(bv, initial=0))) + 1
        if i == 0:
            space = hi
        else:
            rads.append(hi)
            space *= hi
        if space > lim:
            return None
    return np.asarray(rads, dtype=np.int32)


@dataclass
class BatchRunStats:
    """Scheduler-level counters of one (possibly multi-program) executor run.

    A coalesced :meth:`DataplaneExecutor.run_many` shares every dispatch,
    executable, and phase timer across all member queries, so these counters
    exist once per *batch* — summing them per member query would multi-count.
    The per-query :class:`DataplaneJoinResult` carries them too (documented
    as batch-level when coalesced) plus its own per-query retries."""

    queries: int = 1
    dispatches: int = 0
    jit_cache_hits: int = 0
    jit_cache_misses: int = 0
    retries: int = 0
    retry_log: List[Tuple[Tuple, str, str]] = field(default_factory=list)
    caps_hits: int = 0
    caps_misses: int = 0
    caps_evictions: int = 0
    caps_quarantined: int = 0
    bucket_stage_counts: Dict[str, List[int]] = field(default_factory=dict)
    phase_us: Dict[str, float] = field(default_factory=dict)
    round_us: Dict[str, float] = field(default_factory=dict)


@dataclass
class _StageState:
    """Device-resident state of one (H, η) stage as it flows through the ops.

    ``skip_count`` mirrors the simulator's geo.skip rule exactly: a stage whose
    isolated R''_X is empty never reaches LocalJoin, so it contributes *no*
    per-H count entry; every other stage contributes one (possibly 0).

    ``program``/``qi`` bind the stage back to its owning program in a
    coalesced :meth:`DataplaneExecutor.run_many` run — ``skey`` deliberately
    stays query-*unqualified* so the routing salts (and hence the result
    bytes) of a coalesced stage are identical to a serial run of the same
    program."""

    stage: ProgramStage
    skey: Tuple
    program: Optional[RoundProgram] = None
    qi: int = 0
    light: Optional[List] = None          # [(scheme, blocks, counts, n_rows)]
    unary: Optional[Dict[Attr, List]] = None   # x -> [(vals, counts, n)] staged
    host_piece_n: Optional[Dict[Attr, int]] = None  # |R''_X| (host cross-check)
    pieces: Dict[Attr, Tuple] = field(default_factory=dict)   # x -> (vals, counts)
    piece_salt: Dict[Attr, int] = field(default_factory=dict)
    piece_n: Dict[Attr, int] = field(default_factory=dict)
    geo: Optional[StageGeometry] = None
    routed: Optional[List] = None    # [(scheme incl. cell col, blocks, counts, n)]
    parts: Optional[List] = None     # LocalJoin chain worklist
    #: general route: per-relation staged host blocks, indexed by relation
    #: position — [(scheme, blocks, counts, n)], updated in place by the
    #: TreeSemiJoin sweeps.
    gparts: Optional[List] = None
    n_out: int = 0
    rows: Optional[np.ndarray] = None
    empty: bool = False
    skip_count: bool = False


@dataclass
class _WorkItem:
    """One schedulable unit of an op — a (stage, fragment) pair.

    ``key`` is the static bucket signature: everything that shapes the
    compiled executable except the capacities (op kind, route spec, input
    block shapes).  Items sharing (key, caps) form one *geometry bucket* and
    ride a single fused dispatch.  ``group`` is the retry unit: when a *slot*
    overflow re-randomizes the routing, every member re-runs at the next
    attempt (fresh salts) — HC grid routes group all light fragments of a
    stage because their per-attribute salts must advance together.  An
    *out*-only overflow re-runs just the tripped members with a grown output
    buffer and the salts untouched, so row order stays independent of
    capacity history.  ``attempt`` indexes the salts; ``retries`` counts a
    member's re-runs (growth pacing + the max_retries limit)."""

    state: _StageState
    key: Tuple
    caps: Dict[str, int]
    payload: Dict
    group: Tuple
    attempt: int = 0
    retries: int = 0
    result: object = None


class DataplaneExecutor:
    """Runs every compiled :class:`RoundProgram` on a JAX device mesh.

    The backend is a *per-op lowering layer* that mirrors the IR vocabulary:
    one lowering rule per :class:`RoundOp`, dispatched over ``program.ops``
    exactly like the simulator's interpreter — a program rewrite (e.g.
    ``fuse_semijoin_pass``) changes device execution without executor edits.

      Scatter          host no-op (inputs are host-resident; the histogram is
                       shared metadata in the paper's model)
      RouteResidual    host carves Q'(η) per stage and blockifies the padded
                       residual blocks evenly onto the devices
      HashPartition    `sharded_intersect`: unary residuals exchanged by
                       hash(value) and intersected on-device into R''_X(η)
      SemiJoin         `sharded_semijoin`: phase x/fused-route filters the
                       light edges' X column, phase y/fused-filter the Y
                       column, against the co-located pieces
      BroadcastSizes   device piece counts pulled to host (the O(p²) size
                       round); `stage_geometry` — shared verbatim with the
                       simulator — turns them into the CP × HyperCube shape
      GridRoute        `sharded_grid_route`: isolated pieces get global ids
                       from the broadcast counts and go to their
                       `CartesianGrid.cells_for_ids` cells, light residents to
                       their `HyperCubeGrid` shares, every copy tagged with
                       its Lemma 3.2 virtual cell and exchanged by cell % p
      LocalJoin        a chain of communication-free `sharded_colocated_join`
                       steps keyed on the cell column (attributes shared
                       beyond the cell folded into the join key by composite
                       ranking, CP lists appended as per-cell cartesian
                       factors)

    Every primitive call is *stage-batched*: the executor collects one work
    item per (stage, fragment), groups items into **geometry buckets** —
    identical static signature: op kind, route spec, input block shapes, and
    pow2-padded capacities — stacks each bucket's inputs along a leading
    stage axis, and lowers the whole bucket as ONE fused jitted ``shard_map``
    dispatch (the ``batched_sharded_*`` twins in ``repro.dataplane``) sharing
    a single ``all_to_all``.  Stages within a round are independent in the
    MPC model (the load bound charges communication per round, not per
    stage), so the fusion is free parallelism.  pow2 bucketing of both the
    capacities and the stage axis bounds the number of compiled executables
    by the geometry-signature count, not the stage count.

    Overflow is detected (never dropped) per stage and channel: every fused
    dispatch returns an (s, p, 2) overflow tensor read back **once per
    (op, bucket)** — the scheduler's only host sync.  The retry re-runs just
    the overflowed stages at doubled caps (only the channel that tripped:
    *slot* re-randomizes the routing salts with a fresh attempt, *out* grows
    the output buffer alone) — replacing the paper's 1/p^c failure
    probability with deterministic retry.  Set ``batch_stages=False`` to
    dispatch every work item as its own singleton bucket (the per-stage
    schedule); results and retry behavior are identical by construction.
    """

    _LOWERING = {
        Scatter: "_lower_scatter",
        RouteResidual: "_lower_route_residual",
        HashPartition: "_lower_hash_partition",
        SemiJoin: "_lower_semijoin",
        BroadcastSizes: "_lower_broadcast_sizes",
        GridRoute: "_lower_grid_route",
        LocalJoin: "_lower_local_join",
        TreeSemiJoin: "_lower_tree_semijoin",
        ShareRoute: "_lower_share_route",
        CellJoin: "_lower_cell_join",
    }

    #: executor-lifetime learned-caps entries kept before LRU eviction; each
    #: entry is a tiny dict, so the bound only matters to truly long-lived
    #: service processes churning through many distinct query shapes.
    _LEARNED_CAPS_CAPACITY = 1 << 16

    # Robustness state, defaulted at class level so scheduler-only harnesses
    # (tests building the executor via ``__new__``) inherit the fault-free /
    # no-deadline behavior without setting every attribute:
    #: executor-default :class:`~repro.mpc.faults.FaultPlan` (None = inject
    #: nothing); a per-run ``RunConfig.fault_plan`` overrides it.
    fault_plan = None
    #: lifetime count of learned-caps entries quarantined after faults.
    caps_quarantined = 0
    _deadline: Optional[float] = None       # absolute monotonic budget, per run
    _fault_plan_run = None                  # plan resolved for the active run
    _touched_caps: Optional[set] = None     # learned-caps keys read/written
    _tainted_caps: Optional[set] = None     # keys that saw injected overflow
    _caps_quarantined = 0                   # per-run quarantine count
    _run_fps: Tuple[str, ...] = ()          # per-program data fingerprints

    def __init__(
        self,
        mesh=None,
        axis_name: str = "join",
        slack: int = 4,
        max_retries: int = 6,
        batch_stages: bool = True,
        compiled_cache: Optional[ExecutableCache] = None,
        exact_caps: bool = True,
        fault_plan=None,
    ):
        """Args: ``mesh`` — JAX device mesh (default: one axis over all
        devices); ``slack`` — initial capacity headroom multiplier;
        ``max_retries`` — capacity-doubling attempts before giving up;
        ``batch_stages`` — stage-batched (True) vs per-stage scheduling;
        ``compiled_cache`` — executable cache to use (default: the
        process-wide :data:`EXECUTABLE_CACHE`); ``exact_caps`` — size
        GridRoute/LocalJoin buffers with a collective-free counting pass
        (count-then-emit) instead of heuristic estimates + overflow retry
        (``False`` restores the estimate-based sizing); ``fault_plan`` —
        default :class:`~repro.mpc.faults.FaultPlan` consulted at every
        injection site (None = no injection)."""
        import jax

        if mesh is None:
            n = len(jax.devices())
            mesh = jax.make_mesh((n,), (axis_name,))
        else:
            axis_name = mesh.axis_names[0]
        self.mesh = mesh
        self.axis_name = axis_name
        self.p = mesh.shape[axis_name]
        self.slack = slack
        self.max_retries = max_retries
        self.batch_stages = batch_stages
        #: AOT-compiled executable cache (see :class:`ExecutableCache`); the
        #: process-wide default is shared across executors so warm queries
        #: recompile nothing even through a fresh executor.
        self.compiled_cache = (
            compiled_cache if compiled_cache is not None else EXECUTABLE_CACHE
        )
        #: grid-route fanouts within this pow2 ratio of their group max merge
        #: into the max's executable (sentinel-padded); beyond it they keep
        #: their own pow2 fanout.
        self.fanout_merge_ratio = 2
        #: capacities learned from previous runs' overflow retries, keyed by
        #: (round, group, static key, data fingerprint): a repeat run of the
        #: *same data* starts each work item at its last successful caps, so
        #: steady-state runs retry zero times.  The fingerprint keeps
        #: same-shaped queries over different tables from inheriting caps
        #: that their data may exceed (see `_program_fingerprint`).
        #: Purely a function of earlier runs' outcomes (identical under
        #: batched and unbatched scheduling), hence parity-safe.  Executor-
        #: lifetime state with an LRU bound (`_LEARNED_CAPS_CAPACITY`) so a
        #: service executor serving many shapes cannot grow without bound.
        from collections import OrderedDict

        self._learned_caps: "OrderedDict" = OrderedDict()
        #: executor-lifetime learned-caps meters (per-run counts land on
        #: :class:`DataplaneJoinResult`); split from the plan-LRU and
        #: executable-cache counters so cache provenance is unambiguous.
        self.caps_hits = 0
        self.caps_misses = 0
        self.caps_evictions = 0
        #: exact-cap mode: GridRoute/LocalJoin work items without learned caps
        #: run a cheap collective-free counting dispatch first and size their
        #: buffers exactly (`_quant` grid) — steady state has zero overflow
        #: retries by construction, and cold runs stop paying for oversized
        #: heuristic buffers.
        self.exact_caps = exact_caps
        self.fault_plan = fault_plan
        self.caps_quarantined = 0
        self._phase_us: Dict[str, float] = {}
        self._round_us: Dict[str, float] = {}

    # -- capacity guesses (pow2-bucketed so retries and repeat runs hit the
    # -- jit cache; all of them are starting points for the doubling retry) ---

    def _cap(self, n_total: int) -> int:
        """Per-device receive/output capacity for n_total rows spread over p."""
        return _pow2(self.slack * (-(-max(1, n_total) // self.p)))

    def _slot_cap(self, n_total: int) -> int:
        """Per-(src, dst) send-slot capacity: a device holds ~n/p rows and
        spreads them over p destinations."""
        return _pow2(self.slack * (-(-max(1, n_total) // (self.p * self.p))))

    def _block_cap(self, n_total: int) -> int:
        """Host-staging block capacity (pow2 so geometry buckets coincide)."""
        return _pow2(-(-max(1, n_total) // self.p))

    # -- public entry ---------------------------------------------------------

    def run(
        self,
        program: RoundProgram,
        materialize: bool = True,
        config: Optional[RunConfig] = None,
    ) -> DataplaneJoinResult:
        results, _ = self.run_many([program], materialize=materialize, config=config)
        return results[0]

    def run_many(
        self,
        programs: List[RoundProgram],
        materialize: bool = True,
        config: Optional[RunConfig] = None,
    ) -> Tuple[List[DataplaneJoinResult], BatchRunStats]:
        """Run several compiled programs through ONE pass of the scheduler.

        This is the cross-query half of the stage-batched scheduler: every
        program's stages become work items of the *same* op rounds, so stages
        from different queries landing in the same geometry bucket ride one
        fused ``shard_map`` dispatch — the collective stream stays strictly
        serial (concurrent collective executions deadlock) while each
        dispatch serves many queries.  The programs must be coalescible
        (identical op sequences — see
        :func:`repro.mpc.program.coalesce_signature`); anything else raises.

        Results demultiplex exactly: each query keeps its own counts, rows,
        ``per_h_counts`` and per-query retries (attributed through the
        owning stage), and a coalesced stage produces byte-identical rows to
        a serial :meth:`run` of its program — salts derive from the
        query-unqualified stage key, and capacities never change result
        content (padding is sliced off by the tracked counts).

        Returns ``(results, batch)`` where ``batch`` carries the shared
        scheduler counters exactly once (each result also carries them,
        documented as batch-level).

        ``config`` (a :class:`~repro.mpc.program.RunConfig`) adds the per-run
        robustness knobs: a monotonic-clock ``deadline`` enforced between
        dispatches (:class:`~repro.mpc.faults.DeadlineExceededError`) and a
        per-run ``fault_plan`` override.  On ANY failure the run's touched
        learned-caps entries are quarantined (dropped from the store) before
        the exception propagates, so a faulted attempt cannot poison the
        zero-retry steady state of later clean runs."""
        if config is not None:
            materialize = config.materialize
        if not programs:
            return [], BatchRunStats(queries=0)
        ops = programs[0].ops
        for prog in programs[1:]:
            if prog.ops != ops:
                raise ValueError(
                    "run_many needs coalescible programs (identical op "
                    f"sequences); got {programs[0].op_sequence()} vs "
                    f"{prog.op_sequence()}"
                )
        if config is not None and config.verify:
            from .verify import verify_program  # local: verify imports program

            for prog in programs:
                verify_program(prog, caps=self._learned_caps)
        self._retries = 0
        self._retry_log: List[Tuple[Tuple, str, str]] = []
        self._qi_retries: Dict[int, int] = defaultdict(int)
        self._qi_retry_log: Dict[int, List] = defaultdict(list)
        self._materialize = materialize
        self._dispatches = 0
        self._jit_hits = 0
        self._jit_misses = 0
        self._caps_hits = 0
        self._caps_misses = 0
        self._caps_evictions = 0
        self._caps_quarantined = 0
        self._bucket_log: Dict[str, List[int]] = {}
        self._phase_us = {"host_prep": 0.0, "compile": 0.0, "launch": 0.0, "sync": 0.0}
        self._round_us = {}
        self._deadline = config.deadline if config is not None else None
        self._fault_plan_run = (
            config.fault_plan if config is not None and config.fault_plan is not None
            else self.fault_plan
        )
        self._touched_caps = set()
        self._tainted_caps = set()
        self._run_fps = tuple(self._program_fingerprint(p) for p in programs)
        states = [
            _StageState(stage=st, skey=(st.hkey, st.ekey), program=prog, qi=qi)
            for qi, prog in enumerate(programs)
            for st in prog.stages
        ]

        try:
            for op in ops:
                try:
                    lower = getattr(self, self._LOWERING[type(op)])
                except KeyError:
                    raise DataplaneUnsupported(
                        f"op {op!r} has no dataplane lowering rule"
                    ) from None
                live = [state for state in states if not state.empty]
                if live:
                    lower(programs[0], live, op)
        except BaseException:
            # cache quarantine: a failed attempt may have written (or left
            # half-doubled) learned caps anywhere it ran — drop every entry
            # this run touched so the next clean run re-derives exact caps
            # from scratch instead of inheriting fault-inflated buffers.
            self._quarantine_touched()
            raise
        finally:
            self._deadline = None
            self._fault_plan_run = None
            self._touched_caps = None
            self._tainted_caps = None
            self._run_fps = ()

        batch = BatchRunStats(
            queries=len(programs),
            dispatches=self._dispatches,
            jit_cache_hits=self._jit_hits,
            jit_cache_misses=self._jit_misses,
            retries=self._retries,
            retry_log=list(self._retry_log),
            caps_hits=self._caps_hits,
            caps_misses=self._caps_misses,
            caps_evictions=self._caps_evictions,
            caps_quarantined=self._caps_quarantined,
            bucket_stage_counts={k: list(v) for k, v in self._bucket_log.items()},
            phase_us=dict(self._phase_us),
            round_us=dict(self._round_us),
        )
        results: List[DataplaneJoinResult] = []
        for qi, program in enumerate(programs):
            counts: Dict[Tuple[Attr, ...], int] = defaultdict(int)
            chunks: List[np.ndarray] = []
            for mid, row in program.emit:
                chunks.append(row)
            for hkey, c in program.emit_counts.items():
                counts[hkey] += c
            for state in states:
                if state.qi != qi or state.skip_count:
                    continue
                counts[state.stage.hkey] += state.n_out
                if state.rows is not None and state.rows.shape[0]:
                    chunks.append(state.rows)

            rows_out = None
            if materialize:
                rows_out = (
                    np.concatenate(chunks, axis=0)
                    if chunks
                    else np.zeros((0, len(program.out_cols)), dtype=np.int64)
                )
            results.append(DataplaneJoinResult(
                p=self.p,
                count=sum(counts.values()),
                rows=rows_out,
                per_h_counts=dict(counts),
                retries=self._qi_retries.get(qi, 0),
                retry_log=list(self._qi_retry_log.get(qi, [])),
                dispatches=batch.dispatches,
                jit_cache_hits=batch.jit_cache_hits,
                jit_cache_misses=batch.jit_cache_misses,
                caps_hits=batch.caps_hits,
                caps_misses=batch.caps_misses,
                caps_evictions=batch.caps_evictions,
                bucket_stage_counts={
                    k: list(v) for k, v in batch.bucket_stage_counts.items()
                },
                phase_us=dict(batch.phase_us),
                round_us=dict(batch.round_us),
            ))
        return results, batch

    # -- robustness hooks ------------------------------------------------------

    def _check_deadline(self, round_name: str) -> None:
        """Raise :class:`DeadlineExceededError` once the run's monotonic
        budget is spent.  Called only *between* dispatches — a collective in
        flight is never abandoned mid-rendezvous — so the overshoot is
        bounded by one bucket dispatch."""
        dl = self._deadline
        if dl is not None and time.monotonic() > dl:
            raise DeadlineExceededError(
                f"deadline exceeded before op round {round_name!r} dispatch",
                op_round=round_name,
                deadline_s=dl,
            )

    def _quarantine_touched(self) -> None:
        """Drop every learned-caps entry the active run touched (failed-run
        cache quarantine)."""
        for k in self._touched_caps or ():
            if self._learned_caps.pop(k, None) is not None:
                self._caps_quarantined += 1
                self.caps_quarantined += 1

    @staticmethod
    def _program_fingerprint(program) -> str:
        """Content digest of a program's bound input tables.

        The learned-caps store keys on this in addition to the stage's
        structural key: exact caps learned from one dataset are only
        guaranteed sufficient for *that* dataset.  Two same-shaped queries
        over different tables share plans and executables, but if the second
        inherited the first's slot caps it would skip the count pass, trip a
        real overflow, and re-salt — reordering its rows relative to an
        isolated run.  Keying on content confines the count-skip fast path
        to true resubmissions, which is the steady state it exists for."""
        h = hashlib.blake2b(digest_size=8)
        for rel in program.query.relations:
            h.update(repr(tuple(rel.scheme)).encode())
            d = np.ascontiguousarray(rel.data)
            h.update(str(d.dtype).encode())
            h.update(repr(d.shape).encode())
            h.update(d.tobytes())
        return h.hexdigest()

    def _caps_key(self, round_name: str, it) -> Tuple:
        """Learned-caps store key for a work item: structural slot plus the
        owning program's data fingerprint (empty for scheduler-only
        harnesses that never ran ``run_many``)."""
        fps = self._run_fps
        fp = fps[it.state.qi] if it.state.qi < len(fps) else None
        return (round_name, it.group, it.key, fp)

    # -- stage-batched scheduler ----------------------------------------------

    @staticmethod
    def _pow2_stages(s: int) -> int:
        """Pad the stage axis to a power of two: retries shrink buckets, so
        pow2 stage counts keep re-dispatches inside the executable cache."""
        return 1 << max(0, int(s - 1).bit_length())

    @staticmethod
    def _stack(arrs, s_pad: int) -> np.ndarray:
        """Stack per-stage host blocks along a new leading stage axis and
        zero-pad to ``s_pad`` (padded stages carry count 0 — inert rows that
        cannot overflow).  All inter-op state is host numpy: slicing a
        stage's result out of a bucket is a free view, and each fused
        dispatch ships exactly one buffer per operand — no eager device ops
        on the schedule's critical path."""
        arrs = list(arrs)
        x = np.stack(arrs)
        if x.shape[0] < s_pad:
            x = np.concatenate(
                [x, np.zeros((s_pad - x.shape[0],) + x.shape[1:], x.dtype)]
            )
        return x

    @staticmethod
    def _rows_counts_post(outs, s: int):
        """Shared dispatch postprocessor for (rows, counts, ovf) primitives:
        slice off the stage padding and defer the host pull to ``finalize``."""
        out, c, ovf = outs

        def finalize(out=out, c=c):
            out, c = np.asarray(out), np.asarray(c)
            return [(out[i], c[i]) for i in range(s)]

        return finalize, ovf[:s]

    @staticmethod
    def _hist_post(outs, s: int):
        """Dispatch postprocessor for count-only routes: a single (s, p_src,
        p_dst) histogram, structurally overflow-free."""
        (hist,) = outs

        def finalize(hist=hist):
            h = np.asarray(hist)
            return [h[i] for i in range(s)]

        return finalize, np.zeros((s, 1, 2), np.int32)

    @staticmethod
    def _count_post(outs, s: int):
        """Dispatch postprocessor for count-only joins: (s, p) match totals
        plus a structurally-zero overflow channel."""
        cnt, ovf = outs

        def finalize(cnt=cnt):
            c = np.asarray(cnt)
            return [c[i] for i in range(s)]

        return finalize, ovf[:s]

    def _run_buckets(self, round_name: str, items: List[_WorkItem], dispatch):
        """The one scheduling + retry harness every lowering rule runs on.

        Groups ``items`` by (static key, caps) into geometry buckets, calls
        ``dispatch(bucket) -> (finalize, ovf (s, p, 2))`` once per bucket —
        ``finalize()`` pulls the bucket's outputs host-side and returns the
        per-item results — then performs **one deferred readback per
        bucket**, after every bucket's collectives are in flight.
        A *slot* trip re-buckets the whole retry group at ``attempt + 1``
        (fresh salts); an *out*-only trip re-buckets just the tripped items
        with their output channel grown and the salts untouched; one
        retry-log entry per (group, pass) carries the union of the group's
        channels, exactly like the per-stage harness it replaces.  With
        ``batch_stages=False`` every item forms a singleton bucket — the
        unbatched schedule, same code path."""
        if not items:
            return items
        self._check_deadline(round_name)
        fp = self._fault_plan_run
        t_round = time.perf_counter()
        phase = self._phase_us

        # Learned capacities: start each item at the caps its (round, group,
        # key) slot ended the previous run with — steady-state runs never
        # rediscover the same overflow.  Note the fixed point can take two
        # runs to reach: if a strict subset of a bucket retried, the next
        # run's key-group harmonization below merges everyone at the higher
        # caps — a (key, caps, stage-count) signature the first run never
        # compiled — so that run pays one compile and stores the converged
        # caps; from then on signatures, caps, and retry counts are stable.
        for it in items:
            k = self._caps_key(round_name, it)
            learned = self._learned_caps.get(k)
            if self._touched_caps is not None and it.caps:
                self._touched_caps.add(k)
            if learned:
                self._learned_caps.move_to_end(k)
                for ch in it.caps:
                    it.caps[ch] = max(it.caps[ch], learned[ch])
            # meter the learned-caps store separately from the plan LRU /
            # executable cache (count-only items carry no capacities and are
            # not capacity consumers, so they don't meter)
            if it.caps:
                if learned:
                    self._caps_hits += 1
                    self.caps_hits += 1
                else:
                    self._caps_misses += 1
                    self.caps_misses += 1
        # Cap harmonization: items sharing a static key start from the group
        # max per channel.  A pure function of the round's item set — NOT of
        # the bucketing — so batched and unbatched schedules see identical
        # capacities and hence identical overflow/retry behavior, while
        # same-key items coalesce into one bucket instead of one per pow2 cap.
        # Scoped per query index: in a coalesced multi-program run each
        # program harmonizes only against itself, so its capacities (and the
        # learned caps written back) are exactly what its serial run would
        # produce — cross-query items still fuse whenever their caps coincide
        # naturally, which is the same-shape case coalescing targets.
        by_key: Dict[Tuple, List[_WorkItem]] = {}
        for it in items:
            by_key.setdefault((it.state.qi, it.key), []).append(it)
        for group in by_key.values():
            for ch in group[0].caps:
                m = max(g.caps[ch] for g in group)
                for g in group:
                    g.caps[ch] = m
        pending = list(items)
        while pending:
            self._check_deadline(round_name)
            buckets: Dict[Tuple, List[_WorkItem]] = {}
            for it in pending:
                bkey = (it.key, tuple(sorted(it.caps.items())))
                if not self.batch_stages:
                    bkey = bkey + (id(it),)     # force singleton buckets
                buckets.setdefault(bkey, []).append(it)

            bucket_list = list(buckets.values())
            prepared = []
            to_compile: Dict[Tuple, Tuple] = {}
            cache = self.compiled_cache
            executables: Dict[Tuple, object] = {}
            t0 = time.perf_counter()
            for bucket in bucket_list:
                sig = (
                    self.mesh,
                    self.axis_name,
                    round_name,
                    bucket[0].key,
                    tuple(sorted(bucket[0].caps.items())),
                    self._pow2_stages(len(bucket)),
                )
                fn, args, post = dispatch(bucket)
                if sig not in executables and sig not in to_compile:
                    exe = cache.get(sig)
                    if exe is not None:
                        executables[sig] = exe
                if sig in executables or sig in to_compile:
                    self._jit_hits += 1
                else:
                    to_compile[sig] = (fn, args)
                    self._jit_misses += 1
                self._dispatches += 1
                self._bucket_log.setdefault(round_name, []).append(len(bucket))
                prepared.append((bucket, sig, args, post))
            phase["host_prep"] = phase.get("host_prep", 0.0) + (
                time.perf_counter() - t0
            ) * 1e6

            # AOT-compile the round's unseen signatures concurrently: XLA
            # compilation releases the GIL, so distinct executables compile
            # in parallel and cold time pays max, not sum, per round.
            # Execution stays strictly serial — concurrent executions of
            # different collective programs interleave their all_to_all
            # rendezvous across the device threads and deadlock.
            if to_compile:
                t0 = time.perf_counter()

                def compile_one(item):
                    sig, (fn, args) = item
                    if fp is not None:
                        fp.at_compile(round_name)
                    return sig, fn.lower(*args).compile()

                todo = list(to_compile.items())
                if len(todo) > 1:
                    import os
                    from concurrent.futures import ThreadPoolExecutor

                    workers = min(len(todo), max(2, os.cpu_count() or 2))
                    with ThreadPoolExecutor(max_workers=workers) as pool:
                        for sig, comp in pool.map(compile_one, todo):
                            cache.put(sig, comp)
                            executables[sig] = comp
                else:
                    sig, comp = compile_one(todo[0])
                    cache.put(sig, comp)
                    executables[sig] = comp
                phase["compile"] = phase.get("compile", 0.0) + (
                    time.perf_counter() - t0
                ) * 1e6

            t0 = time.perf_counter()
            launched = []
            for bucket, sig, args, post in prepared:
                self._check_deadline(round_name)
                if fp is not None:
                    fp.at_dispatch(round_name)
                launched.append((bucket, *post(executables[sig](*args))))
            phase["launch"] = phase.get("launch", 0.0) + (
                time.perf_counter() - t0
            ) * 1e6

            # one deferred readback per (op, bucket): the scheduler's only
            # host sync — every bucket's collectives are already in flight.
            t0 = time.perf_counter()
            tripped: Dict[int, set] = {}
            for bucket, finalize, ovf in launched:
                ovf_np = np.asarray(ovf)
                results = finalize()
                for i, it in enumerate(bucket):
                    tot = ovf_np[i].reshape(-1, 2).sum(axis=0)
                    kinds = set()
                    if int(tot[0]):
                        kinds.add("slot")
                    if int(tot[1]):
                        kinds.add("out")
                    if fp is not None:
                        # injected overflow: forced channels read exactly like
                        # real trips (doubling, re-salting, retry accounting),
                        # but the item's learned-caps slot is tainted so the
                        # inflated caps are never written back.
                        forced = {
                            ch for ch in fp.overflow(round_name) if ch in it.caps
                        }
                        if forced:
                            kinds |= forced
                            if self._tainted_caps is not None:
                                self._tainted_caps.add(
                                    self._caps_key(round_name, it)
                                )
                    tripped[id(it)] = kinds
                    it.result = results[i]
            phase["sync"] = phase.get("sync", 0.0) + (
                time.perf_counter() - t0
            ) * 1e6

            group_kinds: Dict[Tuple, set] = {}
            for it in pending:
                if tripped[id(it)]:
                    group_kinds.setdefault(it.group, set()).update(tripped[id(it)])

            retry: List[_WorkItem] = []
            logged = set()
            for it in pending:          # original item order → deterministic log
                kinds = group_kinds.get(it.group)
                if not kinds:
                    continue
                # *slot* overflow re-randomizes the routing: the whole group
                # advances to fresh attempt salts together (their per-attribute
                # salts must stay consistent).  An *out*-only overflow grows
                # the output buffer of just the tripped members — the salts
                # (and hence row destinations and order) are untouched, so
                # untripped groupmates keep their finished results and the
                # retried members produce the exact bytes a run that started
                # at the larger cap would have.  Row order therefore never
                # depends on capacity history — the invariant the cross-query
                # coalescing layer's byte-identity guarantee rests on.
                resalt = "slot" in kinds
                if not resalt and not tripped[id(it)]:
                    continue
                if it.group not in logged:
                    logged.add(it.group)
                    self._retries += 1
                    entry = (
                        it.state.skey,
                        round_name,
                        "+".join(sorted(kinds)),
                    )
                    self._retry_log.append(entry)
                    # per-query attribution: a retry group normally belongs to
                    # one query; identical coalesced queries can share one
                    # (same stage key ⇒ same salts), in which case the retry
                    # is charged to every member that actually re-ran.
                    for qi in sorted(
                        {
                            x.state.qi
                            for x in pending
                            if x.group == it.group
                            and (resalt or tripped[id(x)])
                        }
                    ):
                        self._qi_retries[qi] += 1
                        self._qi_retry_log[qi].append(entry)
                # grow only the tripped channels: ×2 on the first retry, ×4
                # afterwards — a repeat trip means the guess was far off, and
                # every extra attempt is a fresh trace+compile at a new shape,
                # which costs far more than the padding it saves
                for ch in tripped[id(it)]:
                    it.caps[ch] *= 2 if it.retries == 0 else 4
                if resalt:
                    it.attempt += 1
                it.retries += 1
                if it.retries > self.max_retries:
                    raise RetryExhaustedError(
                        f"stage {it.state.skey} op {round_name} still overflows "
                        f"after {self.max_retries} capacity doublings",
                        stage=it.state.skey,
                        op_round=round_name,
                        attempts=it.retries,
                        attempt_log=tuple(self._retry_log),
                    )
                retry.append(it)
            pending = retry
        quarantined: set = set()
        for it in items:
            if not it.caps:        # count-only rounds carry no capacities
                continue
            k = self._caps_key(round_name, it)
            if self._tainted_caps is not None and k in self._tainted_caps:
                # this slot's caps were doubled by *injected* overflow — the
                # data never needed them, so writing them back would pin the
                # steady state at fault-inflated buffer sizes
                if k not in quarantined:
                    quarantined.add(k)
                    self._learned_caps.pop(k, None)
                    self._caps_quarantined += 1
                    self.caps_quarantined += 1
                continue
            self._learned_caps[k] = dict(it.caps)
            self._learned_caps.move_to_end(k)
        while len(self._learned_caps) > self._LEARNED_CAPS_CAPACITY:
            self._learned_caps.popitem(last=False)
            self._caps_evictions += 1
            self.caps_evictions += 1
        self._round_us[round_name] = self._round_us.get(round_name, 0.0) + (
            time.perf_counter() - t_round
        ) * 1e6
        return items

    def _apply_exact_caps(self, round_name, items, count_dispatch, caps_from_count,
                          floor):
        """Count-then-emit capacity sizing (``exact_caps=True``).

        Items whose learned-caps slot (round, group, key, data fingerprint)
        is empty are run
        through a collective-free ``<round>/count`` pass — same destination /
        key algebra as the emit, same attempt-0 salts, but a histogram or
        scalar count instead of an exchange — and their emit caps are set
        exactly from the result via ``caps_from_count(result)``.  Items that
        DO have learned caps skip the count and start at ``floor``:
        `_run_buckets` applies learned caps with a per-channel ``max()``, so
        the floor must sit below any learned value for the learned (exact)
        caps to win — starting them at the heuristic guess would re-inflate
        every steady-state run.  Exactly-sized caps cannot overflow, so the
        emit pass runs with zero retries and the warm executable set is
        stable from run 2 onward."""
        fresh = [
            it for it in items
            if not self._learned_caps.get(self._caps_key(round_name, it))
        ]
        fresh_ids = {id(it) for it in fresh}
        for it in items:
            if id(it) not in fresh_ids:
                it.caps = dict(floor)
        if not fresh:
            return
        counters = [
            _WorkItem(state=it.state, key=it.key, caps={},
                      payload=it.payload, group=it.group)
            for it in fresh
        ]
        self._run_buckets(round_name + "/count", counters, count_dispatch)
        for cit, it in zip(counters, fresh):
            it.caps = caps_from_count(cit.result)

    # -- per-op lowering rules (each batches every live stage of the op) ------

    def _lower_scatter(self, program: RoundProgram, states, op) -> None:
        """Scatter costs no load in the MPC model; the dataplane holds the
        inputs host-side (the histogram is shared metadata), so placement
        happens when RouteResidual stages the carved residuals."""

    def _lower_route_residual(self, program, states, op) -> None:
        # Residual carving is per program: group the live stages by owning
        # query (run_many coalescing) and stage each program with its own
        # histogram, masks, and program-wide unary caps — block shapes are
        # then identical to a serial run of that program, which is what keeps
        # coalesced results byte-identical to serial submits.
        groups: Dict[int, List[_StageState]] = {}
        for state in states:
            groups.setdefault(state.qi, []).append(state)
        for qi in sorted(groups):
            pstates = groups[qi]
            self._route_residual_one(pstates[0].program, pstates)

    def _route_residual_one(self, program, states) -> None:
        from ..dataplane.exchange import blockify

        query, stats = program.query, program.stats
        masks = heavy_masks(query, stats)   # once per run, not once per stage
        staged_states = []
        for state in states:
            plan = state.stage.plan
            residuals = residual_relations(
                query, stats, plan, state.stage.cfg.eta, masks=masks
            )
            if residuals is None:
                raise RuntimeError(
                    f"stage {state.skey} compiled for an infeasible η — compiler bug"
                )

            # Host view of R''_X = ∩ unary pieces: decides the stage's fate the
            # same way the simulator's geometry does (empty isolated piece ⇒
            # geo.skip ⇒ no per-H count entry; any other empty input ⇒ a normal
            # zero-count stage).
            host_piece: Dict[Attr, np.ndarray] = {}
            for x in plan.border:
                vals = None
                for e in plan.cross_edges:
                    if x not in e:
                        continue
                    pv = np.unique(residuals[(e, (x,))].data[:, 0])
                    vals = pv if vals is None else np.intersect1d(
                        vals, pv, assume_unique=True
                    )
                host_piece[x] = vals
            if any(host_piece[x].size == 0 for x in plan.isolated):
                state.empty, state.skip_count = True, True
                continue
            if any(v.size == 0 for v in host_piece.values()):
                state.empty = True
                continue
            empty = False
            for e in plan.light_edges:
                if len(residuals[(e, query.relation_for(e).scheme)]) == 0:
                    state.empty = empty = True
                    break
            if empty:
                continue
            state.host_piece_n = {x: int(v.size) for x, v in host_piece.items()}
            staged_states.append((state, residuals))

        # Program-wide unary block capacity and piece count (pure functions of
        # the program's residual sizes, independent of scheduling): every
        # stage's staged R''_X inputs share one shape, so the HashPartition
        # intersects coalesce into a single geometry bucket.  Light blocks
        # keep per-fragment pow2 caps — they are the big rows and padding them
        # to a global max would inflate every downstream exchange.
        unary_cap, n_pieces = 1, 1
        for state, residuals in staged_states:
            plan = state.stage.plan
            for x in plan.border:
                es = [e for e in plan.cross_edges if x in e]
                n_pieces = max(n_pieces, len(es))
                for e in es:
                    unary_cap = max(
                        unary_cap, self._block_cap(len(residuals[(e, (x,))]))
                    )

        for state, residuals in staged_states:
            plan = state.stage.plan
            state.light = []
            for e in plan.light_edges:
                rel = residuals[(e, query.relation_for(e).scheme)]
                blocks, cnts = blockify(
                    rel.data, self.p, self._block_cap(len(rel)), to_device=False
                )
                state.light.append(
                    (list(query.relation_for(e).scheme), blocks, cnts, len(rel))
                )
            state.unary = {}
            for x in plan.border:
                staged = []
                for e in plan.cross_edges:
                    if x not in e:
                        continue
                    r = residuals[(e, (x,))]
                    bv, bc = blockify(r.data[:, 0], self.p, unary_cap, to_device=False)
                    staged.append((bv[:, :, 0], bc, len(r)))
                # Padding to the program-wide piece count with a repeat of the
                # last piece is an intersection no-op (A ∩ A = unique(A)) —
                # it buys every stage the same executable.
                while len(staged) < n_pieces:
                    staged.append(staged[-1])
                state.unary[x] = staged

    def _lower_hash_partition(self, program, states, op) -> None:
        from ..dataplane.exchange import salt_offset
        from ..dataplane.join import batched_sharded_intersect

        items: List[_WorkItem] = []
        for state in states:
            for x, staged in state.unary.items():
                n_max = max(n for _, _, n in staged)
                caps = {"slot": self._slot_cap(n_max), "out": self._cap(n_max)}
                items.append(_WorkItem(
                    state=state,
                    key=("intersect", tuple(bv.shape for bv, _, _ in staged)),
                    caps=caps,
                    payload={"x": x, "staged": staged},
                    group=("intersect", state.skey, x),
                ))

        def dispatch(bucket):
            s, s_pad = len(bucket), self._pow2_stages(len(bucket))
            n_pieces = len(bucket[0].payload["staged"])
            pieces = [
                (
                    self._stack([it.payload["staged"][i][0] for it in bucket], s_pad),
                    self._stack([it.payload["staged"][i][1] for it in bucket], s_pad),
                )
                for i in range(n_pieces)
            ]
            salts = [
                _salt(it.state.skey, it.payload["x"], attempt=it.attempt)
                for it in bucket
            ]
            offs = np.asarray(
                [salt_offset(v) for v in salts] + [0] * (s_pad - s), np.int32
            )
            caps = bucket[0].caps
            fn, args = batched_sharded_intersect(
                self.mesh, self.axis_name, pieces, offs,
                cap_slot=caps["slot"], cap_out=caps["out"], invoke=False,
            )

            def post(outs, salts=salts, s=s):
                vals, cnts, ovf = outs

                def finalize(vals=vals, cnts=cnts):
                    vals, cnts = np.asarray(vals), np.asarray(cnts)
                    return [(vals[i], cnts[i], salts[i]) for i in range(s)]

                return finalize, ovf[:s]

            return fn, args, post

        for it in self._run_buckets(op.round, items, dispatch):
            state, x = it.state, it.payload["x"]
            vals, cnts, salt = it.result
            total = int(cnts.sum())
            if total != state.host_piece_n[x]:
                raise RuntimeError(
                    f"stage {state.skey}: device |R''_{x}| = {total} != host "
                    f"{state.host_piece_n[x]} — routing bug"
                )
            state.pieces[x] = (vals, cnts)
            state.piece_salt[x] = salt
            state.piece_n[x] = total

    def _lower_semijoin(self, program, states, op) -> None:
        """Phase x (and its fused-route twin) filters column 0, phase y (and
        fused-filter) column 1 — the fused rewrite reorders the detour but the
        per-attribute filters are the same, so both program shapes lower
        through this one rule."""
        from ..dataplane.exchange import salt_offset
        from ..dataplane.join import batched_sharded_semijoin

        if op.phase in ("x", "fused-route"):
            col = 0
        elif op.phase in ("y", "fused-filter"):
            col = 1
        else:
            raise DataplaneUnsupported(f"SemiJoin phase {op.phase!r}")

        items: List[_WorkItem] = []
        for state in states:
            for idx, (scheme, blocks, cnts, n) in enumerate(state.light):
                attr = scheme[col]
                if attr not in state.pieces:
                    continue
                pv, pc = state.pieces[attr]
                caps = {"slot": self._slot_cap(n), "out": self._cap(n)}
                items.append(_WorkItem(
                    state=state,
                    key=("semijoin", col, tuple(blocks.shape), tuple(pv.shape)),
                    caps=caps,
                    payload={"idx": idx, "attr": attr, "blocks": blocks,
                             "cnts": cnts, "pv": pv, "pc": pc},
                    group=("semijoin", state.skey, idx),
                ))

        def dispatch(bucket):
            s, s_pad = len(bucket), self._pow2_stages(len(bucket))
            rows = self._stack([it.payload["blocks"] for it in bucket], s_pad)
            cnts = self._stack([it.payload["cnts"] for it in bucket], s_pad)
            pv = self._stack([it.payload["pv"] for it in bucket], s_pad)
            pc = self._stack([it.payload["pc"] for it in bucket], s_pad)
            # the exchange salt is pinned to the piece's distribution salt
            # (rows must land where HashPartition put the piece), so only
            # capacities scale on retry here.
            offs = np.asarray(
                [salt_offset(it.state.piece_salt[it.payload["attr"]])
                 for it in bucket] + [0] * (s_pad - s),
                np.int32,
            )
            caps = bucket[0].caps
            fn, args = batched_sharded_semijoin(
                self.mesh, self.axis_name, rows, cnts, col, offs, pv, pc,
                cap_slot=caps["slot"], cap_out=caps["out"], invoke=False,
            )
            return fn, args, partial(self._rows_counts_post, s=s)

        for it in self._run_buckets(op.round, items, dispatch):
            state, idx = it.state, it.payload["idx"]
            scheme = state.light[idx][0]
            blocks, cnts = it.result
            n2 = int(cnts.sum())
            state.light[idx] = (scheme, blocks, cnts, n2)
            if n2 == 0:
                state.empty = True

    def _lower_broadcast_sizes(self, program, states, op) -> None:
        """The O(p²) size round: the per-device piece counts already crossed
        to the host with the HashPartition readback; `stage_geometry` (shared
        verbatim with the simulator) turns them into the stage's CP grid ×
        HyperCube shape and the global-id offsets."""
        for state in states:
            entries: Dict[Attr, List[Tuple[int, int]]] = {
                x: list(enumerate(int(c) for c in state.pieces[x][1].tolist()))
                for x in state.stage.plan.isolated
            }
            state.geo = stage_geometry(state.program, state.stage, entries)
            if state.geo.skip:
                state.empty, state.skip_count = True, True

    def _lower_grid_route(self, program, states, op) -> None:
        from ..dataplane.grid import (
            CPBatchSig,
            HCBatchSig,
            _pad_table,
            batched_sharded_grid_route,
            batched_sharded_grid_route_count,
            cp_batch_params,
            hc_batch_params,
        )

        # Pass 1: per-fragment route parameters.  Pass 2 pads each group's
        # fanout to the group max pow2 (sentinel copies are ghosted, so the
        # padding is semantics-free and a pure function of the round's item
        # set — identical under batched and unbatched scheduling), which
        # merges all CP routes into one executable per block shape and all HC
        # routes into one per (hashed columns, block shape).
        raw = []
        for state in states:
            geo = state.geo
            if geo is None:
                raise DataplaneUnsupported("GridRoute before BroadcastSizes")
            if geo.cp_size * geo.hc_size >= 1 << 31:
                raise RuntimeError(f"stage {state.skey}: virtual grid exceeds int32")
            n_parts = (len(state.light) if state.light else 0) + len(geo.iso_order)
            state.routed = [None] * n_parts
            pos = 0

            # HC side first (join order: light join, then CP cartesian
            # factors).  All light fragments of a stage share one retry group:
            # the per-attribute coordinate salts must stay consistent across
            # edges, so a fresh attempt re-routes every fragment of the stage.
            for scheme, blocks, cnts, n in state.light or []:
                cols, shares, strides, table = hc_batch_params(
                    geo.hc_grid, scheme, geo.cp_size
                )
                raw.append((state, "hc", pos, {
                    "scheme": scheme, "blocks": blocks, "cnts": cnts,
                    "cols": cols, "shares": shares, "strides": strides,
                    "table": table, "n": n,
                }))
                pos += 1

            # CP side: id-deterministic routing (no salts), per-piece retry.
            for li, x in enumerate(geo.iso_order):
                vals, cnts = state.pieces[x]
                dim, scale, table = cp_batch_params(geo.grid, li, geo.hc_size)
                offsets = np.asarray(
                    [geo.offsets[(x, dev)] for dev in range(self.p)],
                    dtype=np.int64,
                )
                raw.append((state, "cp", pos, {
                    "x": x, "vals": vals, "cnts": cnts, "offsets": offsets,
                    "dim": dim, "scale": scale, "table": table,
                    "n": state.piece_n[x],
                }))
                pos += 1

        # Fanout merging is scoped per query index: a coalesced multi-program
        # round must give each program the same fanout pow2s (and hence the
        # same bucket keys and learned-caps slots) as its own serial run, so
        # one query's huge broadcast never inflates another query's routes.
        group_fanout: Dict[Tuple, int] = {}
        for state, kind, pos, pl in raw:
            gk = (state.qi, kind, pl.get("cols"))
            group_fanout[gk] = max(group_fanout.get(gk, 1), len(pl["table"]))

        items: List[_WorkItem] = []
        for state, kind, pos, pl in raw:
            # Merge into the group's max fanout only when within
            # ``fanout_merge_ratio`` of it — nearby fanouts share one
            # executable at bounded sentinel padding, while a small fragment
            # next to a huge broadcast keeps its own pow2 instead of paying
            # the giant's table.
            f_max = _pow2(group_fanout[(state.qi, kind, pl.get("cols"))])
            own = _pow2(len(pl["table"]))
            fanout = f_max if own * self.fanout_merge_ratio >= f_max else own
            n = pl["n"]
            # Replicating routes are lumpier than hash exchanges — every
            # source concentrates cap·fanout copies on few cells — so start
            # the slot channel at double slack instead of discovering the
            # same doubling through a retry (and its extra executable) on
            # every fresh program.
            caps = {
                "slot": 2 * self._slot_cap(n * len(pl["table"])),
                "out": self._cap(n * len(pl["table"])),
            }
            if kind == "hc":
                sig = HCBatchSig(cols=pl["cols"], fanout=fanout)
                key = ("hc", sig, tuple(pl["blocks"].shape))
                group = ("hc", state.skey)
            else:
                sig = CPBatchSig(fanout=fanout)
                key = ("cp", sig, tuple(pl["vals"].shape))
                group = ("cp", state.skey, pl["x"])
            items.append(_WorkItem(
                state=state, key=key, caps=caps,
                payload={"pos": pos, "sig": sig, **pl}, group=group,
            ))

        def make_dispatch(count: bool):
            def dispatch(bucket):
                s, s_pad = len(bucket), self._pow2_stages(len(bucket))
                sig = bucket[0].payload["sig"]
                caps = bucket[0].caps
                pad = s_pad - s
                cnts = self._stack([it.payload["cnts"] for it in bucket], s_pad)
                table = np.stack(
                    [_pad_table(it.payload["table"], sig.fanout) for it in bucket]
                    + [np.full((sig.fanout,), -1, np.int32)] * pad
                )
                route = (
                    batched_sharded_grid_route_count
                    if count else batched_sharded_grid_route
                )
                kw = {} if count else {
                    "cap_slot": caps["slot"], "cap_out": caps["out"],
                }
                if bucket[0].key[0] == "hc":
                    rows = self._stack([it.payload["blocks"] for it in bucket], s_pad)
                    nf = len(sig.cols)
                    salts = np.ones((s_pad, nf), dtype=np.uint32)
                    shares = np.ones((s_pad, nf), dtype=np.uint32)
                    strides = np.zeros((s_pad, nf), dtype=np.int32)
                    for i, it in enumerate(bucket):
                        scheme = it.payload["scheme"]
                        salts[i] = [
                            _salt(it.state.skey, "hc", scheme[c], attempt=it.attempt)
                            for c in sig.cols
                        ]
                        shares[i] = it.payload["shares"]
                        strides[i] = it.payload["strides"]
                    fn, args = route(
                        self.mesh, self.axis_name, rows, cnts, sig,
                        salts=salts, shares=shares, strides=strides, table=table,
                        invoke=False, **kw,
                    )
                else:
                    rows = self._stack(
                        [it.payload["vals"][:, :, None] for it in bucket], s_pad
                    )
                    offsets = self._stack(
                        [np.asarray(it.payload["offsets"], np.int32) for it in bucket],
                        s_pad,
                    )
                    dims = np.asarray(
                        [it.payload["dim"] for it in bucket] + [1] * pad, np.int32
                    )
                    scales = np.asarray(
                        [it.payload["scale"] for it in bucket] + [0] * pad, np.int32
                    )
                    fn, args = route(
                        self.mesh, self.axis_name, rows, cnts, sig,
                        offsets=offsets, dims=dims, scales=scales, table=table,
                        invoke=False, **kw,
                    )
                if count:
                    return fn, args, partial(self._hist_post, s=s)
                return fn, args, partial(self._rows_counts_post, s=s)
            return dispatch

        if self.exact_caps:
            self._apply_exact_caps(
                op.round, items, make_dispatch(count=True),
                caps_from_count=lambda h: {
                    "slot": _quant(max(1, int(h.max()))),
                    "out": _quant(max(1, int(h.sum(axis=0).max()))),
                },
                floor={"slot": 16, "out": 16},
            )

        for it in self._run_buckets(op.round, items, make_dispatch(count=False)):
            rows, cnts = it.result
            n = int(cnts.sum())
            if it.key[0] == "hc":
                scheme = ["#cell"] + list(it.payload["scheme"])
            else:
                scheme = ["#cell", it.payload["x"]]
            it.state.routed[it.payload["pos"]] = (scheme, rows, cnts, n)

    def _make_colocated_dispatch(self, count: bool):
        """Bucket dispatch for one level of in-cell colocated joins — shared
        by the binary LocalJoin chain and the general CellJoin chain (both
        stage identical payloads: a/b blocks+counts, dup_pairs, mults)."""
        from ..dataplane.join import (
            batched_sharded_colocated_join,
            batched_sharded_colocated_join_count,
        )

        def dispatch(bucket):
            s, s_pad = len(bucket), self._pow2_stages(len(bucket))
            a = self._stack([it.payload["a"][0] for it in bucket], s_pad)
            ac = self._stack([it.payload["a"][1] for it in bucket], s_pad)
            b = self._stack([it.payload["b"][0] for it in bucket], s_pad)
            bc = self._stack([it.payload["b"][1] for it in bucket], s_pad)
            km = None
            if bucket[0].key[4]:
                # padded stages carry radix 1: their rows are all
                # zeros, so the packed key stays 0 and in-bounds
                km = np.stack(
                    [it.payload["mults"] for it in bucket]
                    + [np.ones_like(bucket[0].payload["mults"])]
                    * (s_pad - s)
                )
            if count:
                fn, args = batched_sharded_colocated_join_count(
                    self.mesh, self.axis_name, a, ac, b, bc, 0, 0,
                    dup_pairs=bucket[0].payload["dup_pairs"],
                    key_mults=km, invoke=False,
                )
                return fn, args, partial(self._count_post, s=s)
            fn, args = batched_sharded_colocated_join(
                self.mesh, self.axis_name, a, ac, b, bc, 0, 0,
                cap_out=bucket[0].caps["out"],
                dup_pairs=bucket[0].payload["dup_pairs"],
                key_mults=km, invoke=False,
            )
            return fn, args, partial(self._rows_counts_post, s=s)
        return dispatch

    def _lower_local_join(self, program, states, op) -> None:
        """Communication-free output: all fragments of a virtual cell live on
        device cell % p, so the per-cell join is a chain of colocated joins on
        the cell column — attributes shared beyond the cell are folded into
        the join key via dup_pairs (composite rank keys, so cap_out meters
        true matches), disconnected components and CP lists combined as
        in-cell cartesian factors.  Each chain level batches every stage still joining; a
        stage's chain advances as soon as its level lands (counts feed the
        next level's capacity guess).  The chain is ordered greedily by
        connectivity: each level joins the fragment sharing the most
        attributes with the accumulated intermediate (self-join-shaped
        queries expose the difference — on a clique pattern a 2-shared join
        *filters* wedges into triangles, where the old lexicographic order
        grew Σ deg^k star intermediates that overflowed every output cap)."""
        from ..dataplane.exchange import unblockify

        for state in states:
            if state.routed is None:
                raise DataplaneUnsupported("LocalJoin before GridRoute")
            state.parts = list(state.routed)

        while True:
            active = [state for state in states if len(state.parts) >= 2]
            if not active:
                break
            items: List[_WorkItem] = []
            for state in active:
                a_scheme = state.parts[0][0]
                # most-shared-attributes partner (ties → first, so programs
                # without multi-shared fragments keep the old chain exactly)
                n_parts = len(state.parts)
                j_best = max(
                    range(1, n_parts),
                    key=lambda j: len(
                        [a for a in a_scheme[1:] if a in state.parts[j][0]]
                    ) * n_parts - j,
                )
                if j_best != 1:
                    state.parts[1], state.parts[j_best] = (
                        state.parts[j_best], state.parts[1],
                    )
                a_scheme, a_blocks, a_cnts, n_a = state.parts[0]
                b_scheme, b_blocks, b_cnts, n_b = state.parts[1]
                common = [a for a in a_scheme[1:] if a in b_scheme]
                dup_pairs = tuple(
                    (a_scheme.index(a), b_scheme.index(a)) for a in common
                )
                out_scheme = a_scheme + [
                    a for i, a in enumerate(b_scheme) if i != 0 and a not in common
                ]
                mults = _pack_radices(a_blocks, b_blocks, dup_pairs)
                items.append(_WorkItem(
                    state=state,
                    key=("join", tuple(a_blocks.shape), tuple(b_blocks.shape),
                         dup_pairs, mults is not None),
                    caps={"out": self._cap(4 * (n_a + n_b))},
                    payload={"a": (a_blocks, a_cnts), "b": (b_blocks, b_cnts),
                             "dup_pairs": dup_pairs, "scheme": out_scheme,
                             "mults": mults},
                    group=("join", state.skey),
                ))

            if self.exact_caps:
                self._apply_exact_caps(
                    op.round, items, self._make_colocated_dispatch(count=True),
                    caps_from_count=lambda c: {
                        "out": _quant(max(1, int(c.max()))),
                    },
                    floor={"out": 16},
                )

            for it in self._run_buckets(
                op.round, items, self._make_colocated_dispatch(count=False)
            ):
                blocks, cnts = it.result
                n = int(cnts.sum())
                it.state.parts[0:2] = [(it.payload["scheme"], blocks, cnts, n)]

        for state in states:
            scheme, blocks, cnts, n = state.parts[0]
            state.n_out = n
            if not self._materialize or n == 0:
                continue
            rows = unblockify(blocks, cnts)[:, 1:]     # drop the cell column
            out_scheme = scheme[1:]
            for a in state.stage.plan.h_set:
                rows = np.concatenate(
                    [
                        rows,
                        np.full(
                            (rows.shape[0], 1), state.stage.cfg.eta.value(a), np.int64
                        ),
                    ],
                    axis=1,
                )
                out_scheme = out_scheme + [a]
            perm = [out_scheme.index(a) for a in state.program.out_cols]
            state.rows = rows[:, perm]

    # -- general-route lowering rules (arbitrary-arity programs) --------------

    def _ensure_general_staged(self, states) -> None:
        """Stage every general program's base relations as host blocks.

        The general route has no residual carving: the whole input is the
        working set, so staging happens lazily at the first general op that
        needs device data (TreeSemiJoin for acyclic programs, ShareRoute for
        cyclic ones).  An empty base relation empties the join outright —
        the state keeps its per-H count entry at 0 (``skip_count`` stays
        False), matching the simulator."""
        from ..dataplane.exchange import blockify

        for state in states:
            if state.gparts is not None or state.empty:
                continue
            query = state.program.query
            if any(len(rel) == 0 for rel in query.relations):
                state.empty = True
                continue
            state.gparts = []
            for rel in query.relations:
                blocks, cnts = blockify(
                    rel.data, self.p, self._block_cap(len(rel)), to_device=False
                )
                state.gparts.append((list(rel.scheme), blocks, cnts, len(rel)))

    @staticmethod
    def _general_key_cols(tgt_scheme, tgt_rows, src_scheme, src_rows, shared):
        """One int64 join-key column per side over the ``shared`` attributes.

        Mixed-radix packs (``key = key·radix_j + v_j``) when every value is
        non-negative and the radix product fits int32; otherwise both sides'
        key tuples are densely ranked together (the key only needs to *agree*
        across sides, not be order-preserving).  An empty ``shared`` — the
        cartesian stitch edge between disconnected components — keys every
        row 0, degenerating the semijoin to a non-emptiness filter."""
        if not shared:
            return (
                np.zeros(len(tgt_rows), np.int64),
                np.zeros(len(src_rows), np.int64),
            )
        t = tgt_rows[:, [tgt_scheme.index(a) for a in shared]]
        s = src_rows[:, [src_scheme.index(a) for a in shared]]
        both = np.concatenate([t, s], axis=0)
        if both.size and both.min() >= 0:
            radices = both.max(axis=0).astype(np.int64) + 1
            if np.prod(radices) <= np.iinfo(np.int32).max:
                tk = np.zeros(len(t), np.int64)
                sk = np.zeros(len(s), np.int64)
                for j in range(len(shared)):
                    tk = tk * radices[j] + t[:, j]
                    sk = sk * radices[j] + s[:, j]
                return tk, sk
        _, inv = np.unique(both, axis=0, return_inverse=True)
        inv = inv.astype(np.int64)
        return inv[: len(t)], inv[len(t):]

    def _lower_tree_semijoin(self, program, states, op) -> None:
        """One Yannakakis sweep over the GYO join tree.

        For each tree edge — removal order for the up sweep, reversed for the
        down sweep — the filtering side's distinct key values are hash-
        partitioned and deduped on-device (`batched_sharded_intersect`, one
        piece), then the filtered side's rows, with the packed key appended
        as a trailing column, are exchanged under the same salt and semijoined
        (`batched_sharded_semijoin` on that column).  Edges run sequentially
        (edge i+1 filters against edge i's output) but every live stage
        batches per edge.  Retry groups carry the query index: every general
        stage shares the query-unqualified skey, and one query's re-salt must
        not reorder another's rows."""
        from ..dataplane.exchange import blockify, salt_offset, unblockify
        from ..dataplane.join import (
            batched_sharded_intersect,
            batched_sharded_semijoin,
        )

        self._ensure_general_staged(states)
        n_edges = max(
            (len(state.program.general.tree_edges)
             for state in states if not state.empty),
            default=0,
        )
        for ei in range(n_edges):
            prep: List[_WorkItem] = []
            for state in states:
                if state.empty:
                    continue
                edges = state.program.general.tree_edges
                if ei >= len(edges):
                    continue
                child, par, shared = (
                    edges[ei] if op.phase == "up" else edges[len(edges) - 1 - ei]
                )
                tgt, src = (par, child) if op.phase == "up" else (child, par)
                tgt_scheme, tgt_blocks, tgt_cnts, n_tgt = state.gparts[tgt]
                src_scheme, src_blocks, src_cnts, _ = state.gparts[src]
                tgt_rows = unblockify(tgt_blocks, tgt_cnts)
                src_rows = unblockify(src_blocks, src_cnts)
                tk, sk = self._general_key_cols(
                    tgt_scheme, tgt_rows, src_scheme, src_rows, shared
                )
                piece = np.unique(sk)
                pv, pc = blockify(
                    piece, self.p, self._block_cap(piece.size), to_device=False
                )
                keyed = np.concatenate([tgt_rows, tk[:, None]], axis=1)
                kb, kc = blockify(
                    keyed, self.p, self._block_cap(len(keyed)), to_device=False
                )
                prep.append(_WorkItem(
                    state=state,
                    key=("gsj-intersect", tuple(pv[:, :, 0].shape)),
                    caps={"slot": self._slot_cap(piece.size),
                          "out": self._cap(piece.size)},
                    payload={"pv": pv[:, :, 0], "pc": pc, "rows": kb,
                             "cnts": kc, "n": n_tgt, "tgt": tgt,
                             "col": len(tgt_scheme)},
                    group=("gsj-intersect", state.qi, ei),
                ))

            if not prep:
                continue

            def i_dispatch(bucket):
                s, s_pad = len(bucket), self._pow2_stages(len(bucket))
                pieces = [(
                    self._stack([it.payload["pv"] for it in bucket], s_pad),
                    self._stack([it.payload["pc"] for it in bucket], s_pad),
                )]
                salts = [
                    _salt(it.state.skey, "gsj", op.phase, ei, attempt=it.attempt)
                    for it in bucket
                ]
                offs = np.asarray(
                    [salt_offset(v) for v in salts] + [0] * (s_pad - s), np.int32
                )
                caps = bucket[0].caps
                fn, args = batched_sharded_intersect(
                    self.mesh, self.axis_name, pieces, offs,
                    cap_slot=caps["slot"], cap_out=caps["out"], invoke=False,
                )

                def post(outs, salts=salts, s=s):
                    vals, cnts, ovf = outs

                    def finalize(vals=vals, cnts=cnts):
                        vals, cnts = np.asarray(vals), np.asarray(cnts)
                        return [(vals[i], cnts[i], salts[i]) for i in range(s)]

                    return finalize, ovf[:s]

                return fn, args, post

            sj_items: List[_WorkItem] = []
            for it in self._run_buckets(op.round, prep, i_dispatch):
                vals, cnts, salt = it.result
                pl = dict(it.payload)
                pl["piece"], pl["salt"] = (vals, cnts), salt
                sj_items.append(_WorkItem(
                    state=it.state,
                    key=("gsj-filter", pl["col"], tuple(pl["rows"].shape),
                         tuple(vals.shape)),
                    caps={"slot": self._slot_cap(pl["n"]),
                          "out": self._cap(pl["n"])},
                    payload=pl,
                    group=("gsj-filter", it.state.qi, ei),
                ))

            def f_dispatch(bucket):
                s, s_pad = len(bucket), self._pow2_stages(len(bucket))
                rows = self._stack([it.payload["rows"] for it in bucket], s_pad)
                cnts = self._stack([it.payload["cnts"] for it in bucket], s_pad)
                pv = self._stack([it.payload["piece"][0] for it in bucket], s_pad)
                pc = self._stack([it.payload["piece"][1] for it in bucket], s_pad)
                col = bucket[0].payload["col"]
                # pinned to the intersect pass's distribution salt: rows must
                # land where the piece landed, so retries only grow caps.
                offs = np.asarray(
                    [salt_offset(it.payload["salt"]) for it in bucket]
                    + [0] * (s_pad - s),
                    np.int32,
                )
                caps = bucket[0].caps
                fn, args = batched_sharded_semijoin(
                    self.mesh, self.axis_name, rows, cnts, col, offs, pv, pc,
                    cap_slot=caps["slot"], cap_out=caps["out"], invoke=False,
                )
                return fn, args, partial(self._rows_counts_post, s=s)

            for it in self._run_buckets(op.round, sj_items, f_dispatch):
                blocks, cnts = it.result
                n2 = int(cnts.sum())
                tgt = it.payload["tgt"]
                scheme = it.state.gparts[tgt][0]
                # strip the appended key column
                it.state.gparts[tgt] = (scheme, blocks[:, :, :-1], cnts, n2)
                if n2 == 0:
                    it.state.empty = True

    def _lower_share_route(self, program, states, op) -> None:
        """Generalized HyperCube route: every output attribute is a grid
        dimension (shares from the fractional edge cover LP, Π ≤ p), every
        relation's rows are replicated to the cells agreeing with their
        hashed coordinates — share-1 attributes pin coordinate 0, attributes
        absent from a relation fan out across that dimension.  Lowered
        through the same ``batched_sharded_grid_route`` primitive as the
        binary HC side, with per-attribute salts shared across relations
        (same attribute ⇒ same hash) and one qi-scoped retry group per stage
        so a re-salt re-routes every relation of the query together."""
        from ..dataplane.grid import (
            HCBatchSig,
            _pad_table,
            batched_sharded_grid_route,
            batched_sharded_grid_route_count,
            hc_batch_params,
        )

        self._ensure_general_staged(states)
        raw = []
        for state in states:
            if state.empty:
                continue
            gen = state.program.general
            grid = HyperCubeGrid(
                list(state.program.out_cols), gen.shares_dict
            )
            if grid.size >= 1 << 31:
                raise RuntimeError(f"stage {state.skey}: share grid exceeds int32")
            state.routed = [None] * len(state.gparts)
            for pos, ri in enumerate(gen.join_order):
                scheme, blocks, cnts, n = state.gparts[ri]
                cols, shares, strides, table = hc_batch_params(grid, scheme, 1)
                raw.append((state, pos, {
                    "scheme": scheme, "blocks": blocks, "cnts": cnts,
                    "cols": cols, "shares": shares, "strides": strides,
                    "table": table, "n": n,
                }))

        group_fanout: Dict[Tuple, int] = {}
        for state, pos, pl in raw:
            gk = (state.qi, pl["cols"])
            group_fanout[gk] = max(group_fanout.get(gk, 1), len(pl["table"]))

        items: List[_WorkItem] = []
        for state, pos, pl in raw:
            f_max = _pow2(group_fanout[(state.qi, pl["cols"])])
            own = _pow2(len(pl["table"]))
            fanout = f_max if own * self.fanout_merge_ratio >= f_max else own
            n = pl["n"]
            caps = {
                "slot": 2 * self._slot_cap(n * len(pl["table"])),
                "out": self._cap(n * len(pl["table"])),
            }
            sig = HCBatchSig(cols=pl["cols"], fanout=fanout)
            items.append(_WorkItem(
                state=state,
                key=("ghc", sig, tuple(pl["blocks"].shape)),
                caps=caps,
                payload={"pos": pos, "sig": sig, **pl},
                group=("ghc", state.qi),
            ))

        def make_dispatch(count: bool):
            def dispatch(bucket):
                s, s_pad = len(bucket), self._pow2_stages(len(bucket))
                sig = bucket[0].payload["sig"]
                caps = bucket[0].caps
                pad = s_pad - s
                rows = self._stack([it.payload["blocks"] for it in bucket], s_pad)
                cnts = self._stack([it.payload["cnts"] for it in bucket], s_pad)
                table = np.stack(
                    [_pad_table(it.payload["table"], sig.fanout) for it in bucket]
                    + [np.full((sig.fanout,), -1, np.int32)] * pad
                )
                nf = len(sig.cols)
                salts = np.ones((s_pad, nf), dtype=np.uint32)
                shares = np.ones((s_pad, nf), dtype=np.uint32)
                strides = np.zeros((s_pad, nf), dtype=np.int32)
                for i, it in enumerate(bucket):
                    scheme = it.payload["scheme"]
                    salts[i] = [
                        _salt(it.state.skey, "ghc", scheme[c], attempt=it.attempt)
                        for c in sig.cols
                    ]
                    shares[i] = it.payload["shares"]
                    strides[i] = it.payload["strides"]
                route = (
                    batched_sharded_grid_route_count
                    if count else batched_sharded_grid_route
                )
                kw = {} if count else {
                    "cap_slot": caps["slot"], "cap_out": caps["out"],
                }
                fn, args = route(
                    self.mesh, self.axis_name, rows, cnts, sig,
                    salts=salts, shares=shares, strides=strides, table=table,
                    invoke=False, **kw,
                )
                if count:
                    return fn, args, partial(self._hist_post, s=s)
                return fn, args, partial(self._rows_counts_post, s=s)
            return dispatch

        if self.exact_caps:
            self._apply_exact_caps(
                op.round, items, make_dispatch(count=True),
                caps_from_count=lambda h: {
                    "slot": _quant(max(1, int(h.max()))),
                    "out": _quant(max(1, int(h.sum(axis=0).max()))),
                },
                floor={"slot": 16, "out": 16},
            )

        for it in self._run_buckets(op.round, items, make_dispatch(count=False)):
            rows, cnts = it.result
            n = int(cnts.sum())
            scheme = ["#cell"] + list(it.payload["scheme"])
            it.state.routed[it.payload["pos"]] = (scheme, rows, cnts, n)

    def _lower_cell_join(self, program, states, op) -> None:
        """Output round of the general route: a chain of communication-free
        colocated joins on the cell column, in the compiler's fixed join
        order (tree pre-order for acyclic, greedy connected for cyclic) —
        no reordering, so the chain shape is a pure function of the plan.
        Attributes shared beyond the cell fold into the join key via
        dup_pairs, exactly as in the binary LocalJoin chain."""
        from ..dataplane.exchange import unblockify

        for state in states:
            if state.routed is None:
                raise DataplaneUnsupported("CellJoin before ShareRoute")
            state.parts = list(state.routed)

        while True:
            active = [state for state in states if len(state.parts) >= 2]
            if not active:
                break
            items: List[_WorkItem] = []
            for state in active:
                a_scheme, a_blocks, a_cnts, n_a = state.parts[0]
                b_scheme, b_blocks, b_cnts, n_b = state.parts[1]
                common = [a for a in a_scheme[1:] if a in b_scheme]
                dup_pairs = tuple(
                    (a_scheme.index(a), b_scheme.index(a)) for a in common
                )
                out_scheme = a_scheme + [
                    a for i, a in enumerate(b_scheme) if i != 0 and a not in common
                ]
                mults = _pack_radices(a_blocks, b_blocks, dup_pairs)
                items.append(_WorkItem(
                    state=state,
                    key=("gjoin", tuple(a_blocks.shape), tuple(b_blocks.shape),
                         dup_pairs, mults is not None),
                    caps={"out": self._cap(4 * (n_a + n_b))},
                    payload={"a": (a_blocks, a_cnts), "b": (b_blocks, b_cnts),
                             "dup_pairs": dup_pairs, "scheme": out_scheme,
                             "mults": mults},
                    group=("gjoin", state.qi),
                ))

            if self.exact_caps:
                self._apply_exact_caps(
                    op.round, items, self._make_colocated_dispatch(count=True),
                    caps_from_count=lambda c: {
                        "out": _quant(max(1, int(c.max()))),
                    },
                    floor={"out": 16},
                )

            for it in self._run_buckets(
                op.round, items, self._make_colocated_dispatch(count=False)
            ):
                blocks, cnts = it.result
                n = int(cnts.sum())
                it.state.parts[0:2] = [(it.payload["scheme"], blocks, cnts, n)]

        for state in states:
            scheme, blocks, cnts, n = state.parts[0]
            state.n_out = n
            if not self._materialize or n == 0:
                continue
            rows = unblockify(blocks, cnts)[:, 1:]     # drop the cell column
            out_scheme = scheme[1:]
            perm = [out_scheme.index(a) for a in state.program.out_cols]
            state.rows = rows[:, perm]
