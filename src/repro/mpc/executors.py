"""Execution backends for the round-program IR (repro.mpc.program).

One verified plan, many backends: ``compile_plan`` fixes *which rounds with
which routes*; an :class:`Executor` decides *who executes them*.

* :class:`SimulatorExecutor` interprets every op on the exact-cost
  :class:`~repro.mpc.simulator.MPCSimulator` — the load oracle.  It reproduces
  the pre-IR monolithic engine bit for bit: identical hash keys, identical
  per-machine RNG streams, identical loop order, hence byte-identical
  ``per_h_counts`` and ``parallel_total_load`` (locked by
  tests/test_program_ir.py golden values).

* :class:`DataplaneExecutor` lowers the HashPartition / SemiJoin / LocalJoin
  ops of light-subquery stages onto the JAX data plane: capacity-padded
  ``hash_exchange`` collectives + the merge_join_counts Pallas probe under
  ``shard_map``.  Stages with isolated attributes (the Lemma 3.1 cartesian
  grid) are not lowered yet — the executor rejects such programs loudly; the
  simulator remains the complete reference (docs/DESIGN.md §7).
"""

from __future__ import annotations

import hashlib
import math
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.query import Attr, JoinQuery, Relation, reference_join
from ..core.taxonomy import residual_relations
from .hypercube import route_hypercube
from .program import (
    BroadcastSizes,
    GridRoute,
    HashPartition,
    LocalJoin,
    ProgramStage,
    RoundOp,
    RoundProgram,
    RouteResidual,
    Scatter,
    SemiJoin,
    StageGeometry,
    stage_geometry,
)
from .simulator import MPCSimulator, scatter_input


@dataclass
class MPCJoinResult:
    p: int
    lam: int
    rho: float
    m: int
    count: int
    rows: Optional[np.ndarray]          # over sorted(attset), if materialized
    sim: MPCSimulator
    per_h_counts: Dict[Tuple[Attr, ...], int]

    @property
    def bound(self) -> float:
        """The claimed load bound m / p^{1/ρ} (polylog factors not included)."""
        return self.m / (self.p ** (1.0 / self.rho))

    @property
    def load(self) -> int:
        return self.sim.parallel_total_load

    @property
    def load_ratio(self) -> float:
        return self.load / max(1.0, self.bound)


def _send_grouped(sim: MPCSimulator, phys: np.ndarray, tag, rows: np.ndarray) -> None:
    """Group rows by destination and send one message per destination."""
    if rows.ndim == 1:
        rows = rows.reshape(-1, 1)
    if rows.shape[0] == 0:
        return
    order = np.argsort(phys, kind="stable")
    ps, rs = phys[order], rows[order]
    uniq = np.unique(ps)
    bounds = np.append(np.searchsorted(ps, uniq), ps.shape[0])
    for i, dst in enumerate(uniq.tolist()):
        sim.send(int(dst), tag, rs[bounds[i] : bounds[i + 1]])


# ---------------------------------------------------------------------------
# Simulator backend
# ---------------------------------------------------------------------------


class SimulatorExecutor:
    """Runs a compiled :class:`RoundProgram` on the exact-cost simulator.

    May be handed an existing simulator (so the statistics preprocessing and
    the program execution meter into the same round ledger — the ``mpc_join``
    path), or a bare ``p`` to own a fresh one."""

    def __init__(
        self, sim: Optional[MPCSimulator] = None, p: Optional[int] = None, seed: int = 0
    ):
        if sim is None:
            if p is None:
                raise ValueError("need either a simulator or p")
            sim = MPCSimulator(p, seed=seed)
        self.sim = sim
        self.seed = seed

    # -- input placement (Scatter semantics; idempotent) ---------------------

    def place_inputs(self, query: JoinQuery, seed_offset: int = 17) -> None:
        for rel in query.relations:
            if not self.sim.machines_with(("in", rel.edge)):
                scatter_input(
                    self.sim, ("in", rel.edge), rel.data, seed=self.seed + seed_offset
                )

    # -- program interpretation ----------------------------------------------

    def run(self, program: RoundProgram, materialize: bool = True) -> MPCJoinResult:
        if self.sim.p != program.p:
            raise ValueError(f"simulator has p={self.sim.p}, program wants {program.p}")
        self._program = program
        self._materialize = materialize
        self._geo: Dict[int, StageGeometry] = {}
        self._outputs: Dict[int, List[np.ndarray]] = defaultdict(list)
        self._counts: Dict[Tuple[Attr, ...], int] = defaultdict(int)

        # H = attset(Q) emits: host-side placement, zero communication.
        for mid, row in program.emit:
            self._outputs[mid].append(row)
        for hkey, c in program.emit_counts.items():
            self._counts[hkey] += c

        for op in program.ops:
            self._dispatch(op)

        rows_out = None
        if materialize:
            chunks = [r for parts in self._outputs.values() for r in parts]
            rows_out = (
                np.concatenate(chunks, axis=0)
                if chunks
                else np.zeros((0, len(program.out_cols)), dtype=np.int64)
            )
        return MPCJoinResult(
            p=program.p,
            lam=program.lam,
            rho=program.rho_val,
            m=program.stats.m,
            count=sum(self._counts.values()),
            rows=rows_out,
            sim=self.sim,
            per_h_counts=dict(self._counts),
        )

    def _dispatch(self, op: RoundOp) -> None:
        if isinstance(op, Scatter):
            self.place_inputs(self._program.query, op.seed_offset)
        elif isinstance(op, RouteResidual):
            self._op_route_residual()
        elif isinstance(op, HashPartition):
            self._op_hash_partition()
        elif isinstance(op, SemiJoin):
            self._op_semijoin(op)
        elif isinstance(op, BroadcastSizes):
            self._op_broadcast_sizes()
        elif isinstance(op, GridRoute):
            self._op_grid_route()
        elif isinstance(op, LocalJoin):
            self._op_local_join()
        else:
            raise NotImplementedError(f"unknown op {op!r}")

    # -- step 1: route residual tuples ---------------------------------------

    def _op_route_residual(self) -> None:
        sim, program = self.sim, self._program
        query, stats, p = program.query, program.stats, program.p
        sim.begin_round("step1")
        for mid in range(sim.p):
            mrng = np.random.default_rng(self.seed * 1_000_003 + mid)
            local_cache: Dict = {}
            for rel in query.relations:
                local = sim.local(mid, ("in", rel.edge))
                if local.shape[0] == 0:
                    continue
                x_attr, y_attr = rel.scheme
                hx = stats.is_heavy(x_attr, local[:, 0])
                hy = stats.is_heavy(y_attr, local[:, 1])
                local_cache[rel.edge] = (local, hx, hy)
            for st in program.stages:
                plan, cfg = st.plan, st.cfg
                h = set(plan.h_set)
                grp = cfg.step1_group
                for rel in query.relations:
                    if rel.edge not in local_cache:
                        continue
                    local, hx, hy = local_cache[rel.edge]
                    x_attr, y_attr = rel.scheme
                    inter = rel.edge & h
                    if len(inter) == 2:
                        continue
                    if len(inter) == 0:
                        sel = ~hx & ~hy
                        rows = local[sel]
                    else:
                        (heavy_attr,) = inter
                        if heavy_attr == x_attr:
                            sel = (local[:, 0] == cfg.eta.value(x_attr)) & ~hy
                            rows = local[sel][:, 1:2]   # project to light attr
                        else:
                            sel = (local[:, 1] == cfg.eta.value(y_attr)) & ~hx
                            rows = local[sel][:, 0:1]
                    if rows.shape[0] == 0:
                        continue
                    virt = mrng.integers(0, grp.size, size=rows.shape[0])
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("r1", st.hkey, st.ekey, rel.edge), rows)
        sim.end_round()

    # -- step 2a: unary partition + intersection -----------------------------

    def _op_hash_partition(self) -> None:
        sim, program = self.sim, self._program
        query, p = program.query, program.p
        sim.begin_round("step2-unary")
        for st in program.stages:
            plan, cfg = st.plan, st.cfg
            grp = cfg.step1_group
            for e in plan.cross_edges:
                light_attr = next(iter(e - set(plan.h_set)))
                tag_in = ("r1", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=1)
                    virt = sim.hashes.hash(
                        (st.hkey, st.ekey, "sj", light_attr), rows[:, 0], grp.size
                    )
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("u", st.hkey, st.ekey, light_attr, e), rows)
        sim.end_round()

        # local intersection → R''_X pieces (no communication)
        for st in program.stages:
            plan = st.plan
            for x in plan.border:
                es = [e for e in plan.cross_edges if x in e]
                for mid in range(sim.p):
                    pieces = []
                    ok = True
                    for e in es:
                        vals = sim.local(mid, ("u", st.hkey, st.ekey, x, e), arity=1)
                        if vals.shape[0] == 0:
                            ok = False
                            break
                        pieces.append(np.unique(vals[:, 0]))
                    if not ok:
                        continue
                    inter = pieces[0]
                    for arr in pieces[1:]:
                        inter = np.intersect1d(inter, arr, assume_unique=True)
                    if inter.size:
                        sim.stores[mid][("ux", st.hkey, st.ekey, x)] = [inter.reshape(-1, 1)]

    # -- step 2b/2c: semi-join light edges -----------------------------------

    def _filter_by_membership(self, mid, rows, col, attr, st):
        """Keep rows whose rows[:, col] is in the machine-local R''_attr piece."""
        piece = self.sim.local(mid, ("ux", st.hkey, st.ekey, attr), arity=1)[:, 0]
        if piece.size == 0:
            return rows[:0]
        return rows[np.isin(rows[:, col], piece)]

    def _op_semijoin(self, op: SemiJoin) -> None:
        if op.phase == "x":
            self._semijoin_x()
        elif op.phase == "y":
            self._semijoin_y(fused=False)
            self._semijoin_local_y_filter()
        elif op.phase == "fused-route":
            self._semijoin_fused_route()
        elif op.phase == "fused-filter":
            self._semijoin_y(fused=True)
            self._semijoin_local_y_filter()
        else:
            raise NotImplementedError(f"SemiJoin phase {op.phase!r}")

    def _semijoin_x(self) -> None:
        sim, program = self.sim, self._program
        query, p = program.query, program.p
        sim.begin_round("step2-bx")
        for st in program.stages:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr = rel.scheme[0]
                tag_in = ("r1", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    virt = sim.hashes.hash(
                        (st.hkey, st.ekey, "sj", x_attr), rows[:, 0], grp.size
                    )
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("bx", st.hkey, st.ekey, e), rows)
        sim.end_round()

    def _semijoin_fused_route(self) -> None:
        # Beyond-paper fusion: route directly to the Y partition; X-filtering
        # happens at the Y-side against a replicated X piece fetched in the same
        # round — saves one full data round when X is not a border attribute,
        # else falls back to the two-hop detour.  See EXPERIMENTS §Perf.
        sim, program = self.sim, self._program
        query, p = program.query, program.p
        sim.begin_round("step2-fused")
        for st in program.stages:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr, y_attr = rel.scheme
                tag_in = ("r1", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    if x_attr not in st.plan.border:
                        virt = sim.hashes.hash(
                            (st.hkey, st.ekey, "sj", y_attr), rows[:, 1], grp.size
                        )
                        phys = (grp.base + virt) % p
                        _send_grouped(sim, phys, ("rr", st.hkey, st.ekey, e), rows)
                    else:
                        virt = sim.hashes.hash(
                            (st.hkey, st.ekey, "sj", x_attr), rows[:, 0], grp.size
                        )
                        phys = (grp.base + virt) % p
                        _send_grouped(sim, phys, ("bx", st.hkey, st.ekey, e), rows)
        sim.end_round()

    def _semijoin_y(self, fused: bool) -> None:
        sim, program = self.sim, self._program
        query, p = program.query, program.p
        sim.begin_round("step2-by")
        for st in program.stages:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr, y_attr = rel.scheme
                if fused and x_attr not in st.plan.border:
                    continue
                tag_in = ("bx", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    if x_attr in st.plan.border:
                        rows = self._filter_by_membership(mid, rows, 0, x_attr, st)
                    if rows.shape[0] == 0:
                        continue
                    virt = sim.hashes.hash(
                        (st.hkey, st.ekey, "sj", y_attr), rows[:, 1], grp.size
                    )
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("rr", st.hkey, st.ekey, e), rows)
        sim.end_round()

    def _semijoin_local_y_filter(self) -> None:
        # Y-side filtering is local (the piece lives where the hash sent the row).
        sim, program = self.sim, self._program
        query = program.query
        for st in program.stages:
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                y_attr = rel.scheme[1]
                if y_attr not in st.plan.border:
                    continue
                tag = ("rr", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag):
                    rows = sim.local(mid, tag, arity=2)
                    rows = self._filter_by_membership(mid, rows, 1, y_attr, st)
                    sim.stores[mid][tag] = [rows]

    # -- step 3 sizes: broadcast |R''_X| pieces ------------------------------

    def _op_broadcast_sizes(self) -> None:
        sim, program = self.sim, self._program
        attset = program.query.attset
        stages = program.stages
        sim.begin_round("step3-sizes")
        cfg_index = {(st.hkey, st.ekey): i for i, st in enumerate(stages)}
        attr_index = {a: i for i, a in enumerate(attset)}
        for st in stages:
            for x in st.plan.isolated:
                tag = ("ux", st.hkey, st.ekey, x)
                for mid in sim.machines_with(tag):
                    cnt = sim.local(mid, tag, arity=1).shape[0]
                    msg = np.array(
                        [[cfg_index[(st.hkey, st.ekey)], attr_index[x], mid, cnt]],
                        dtype=np.int64,
                    )
                    sim.broadcast(("sz",), msg)
        sim.end_round()

        size_rows = (
            sim.local(0, ("sz",), arity=4)
            if sim.machines_with(("sz",))
            else np.zeros((0, 4), np.int64)
        )
        piece_sizes: Dict[Tuple[int, int], List[Tuple[int, int]]] = defaultdict(list)
        for ci, ai, mid, cnt in size_rows.tolist():
            piece_sizes[(ci, ai)].append((mid, cnt))

        for i, st in enumerate(stages):
            entries = {
                x: piece_sizes.get((i, attr_index[x]), []) for x in st.plan.isolated
            }
            self._geo[i] = stage_geometry(program, st, entries)

    # -- step 3 route: Lemma 3.1 grid × Lemma 3.3 HyperCube ------------------

    def _op_grid_route(self) -> None:
        sim, program = self.sim, self._program
        query = program.query
        sim.begin_round("step3-route")
        for i, st in enumerate(program.stages):
            geo = self._geo[i]
            if geo.skip:
                continue
            grp = geo.step3_group
            hc_size = geo.hc_grid.size if geo.hc_grid else 1
            cp_size = geo.grid.size if geo.grid else 1

            # CP side: every grid cell is instantiated in every HC column.
            if geo.grid:
                for li, x in enumerate(geo.iso_order):
                    tag = ("ux", st.hkey, st.ekey, x)
                    for mid in sim.machines_with(tag):
                        vals = sim.local(mid, tag, arity=1)
                        ids = geo.offsets[(x, mid)] + np.arange(
                            vals.shape[0], dtype=np.int64
                        )
                        if li < geo.grid.t_prime:
                            cells = geo.grid.cells_for_ids(li, ids)
                            for combo in range(cells.shape[1]):
                                flat = cells[:, combo]
                                for cell in np.unique(flat).tolist():
                                    rows = vals[flat == cell]
                                    for h_cell in range(hc_size):
                                        v = cell * hc_size + h_cell
                                        sim.send(
                                            grp.phys(v),
                                            ("cp", st.hkey, st.ekey, v, x),
                                            rows,
                                        )
                        else:
                            for cell in range(cp_size):
                                for h_cell in range(hc_size):
                                    v = cell * hc_size + h_cell
                                    sim.send(
                                        grp.phys(v), ("cp", st.hkey, st.ekey, v, x), vals
                                    )

            # HC side: every HC cell instantiated in every CP row.
            if geo.hc_grid:
                for e in st.plan.light_edges:
                    rel = query.relation_for(e)
                    tag = ("rr", st.hkey, st.ekey, e)
                    for mid in sim.machines_with(tag):
                        rows = sim.local(mid, tag, arity=2)

                        def deliver(
                            h_cell, out_tag, rs, _grp=grp, _hc=hc_size, _cp=cp_size, _st=st
                        ):
                            for c in range(_cp):
                                v = c * _hc + h_cell
                                sim.send(
                                    _grp.phys(v), ("hc", _st.hkey, _st.ekey, v, out_tag), rs
                                )

                        route_hypercube(
                            sim,
                            geo.hc_grid,
                            [(rel.scheme, e, rows)],
                            salt=(st.hkey, st.ekey, "hc"),
                            deliver=deliver,
                        )
        sim.end_round()

    # -- output: local joins, exactly-once -----------------------------------

    def _op_local_join(self) -> None:
        sim, program = self.sim, self._program
        query = program.query
        out_cols = list(program.out_cols)
        materialize = self._materialize
        for i, st in enumerate(program.stages):
            geo = self._geo[i]
            if geo.skip:
                continue
            plan = st.plan
            grp = geo.step3_group
            hc_size = geo.hc_grid.size if geo.hc_grid else 1
            l_minus_i = [a for a in plan.light if a not in plan.isolated]
            h_count = 0
            for v in range(grp.size):
                mid = grp.phys(v)
                # light side
                if plan.light_edges:
                    frags = []
                    ok = True
                    for e in plan.light_edges:
                        rel = query.relation_for(e)
                        rows = sim.local(mid, ("hc", st.hkey, st.ekey, v, e), arity=2)
                        if rows.shape[0] == 0:
                            ok = False
                            break
                        frags.append(Relation.make(rel.scheme, rows))
                    if not ok:
                        continue
                    light_join = reference_join(JoinQuery.make(frags))
                    light_rows = light_join.data  # over sorted(l_minus_i)
                    if light_rows.shape[0] == 0:
                        continue
                else:
                    light_rows = np.zeros((1, 0), dtype=np.int64)

                # CP side
                cp_lists = []
                ok = True
                for x in geo.iso_order:
                    vals = sim.local(mid, ("cp", st.hkey, st.ekey, v, x), arity=1)
                    vals = np.unique(vals[:, 0])
                    if vals.size == 0:
                        ok = False
                        break
                    cp_lists.append(vals)
                if not ok:
                    continue

                n_cp = math.prod(arr.size for arr in cp_lists) if cp_lists else 1
                n_here = light_rows.shape[0] * n_cp
                h_count += n_here
                if materialize and n_here:
                    rows = light_rows
                    cols = sorted(l_minus_i)
                    for x, vals in zip(geo.iso_order, cp_lists):
                        nn = rows.shape[0]
                        rows = np.repeat(rows, vals.size, axis=0)
                        rows = np.concatenate(
                            [rows, np.tile(vals, nn).reshape(-1, 1)], axis=1
                        )
                        cols.append(x)
                    for a in plan.h_set:
                        rows = np.concatenate(
                            [
                                rows,
                                np.full((rows.shape[0], 1), st.cfg.eta.value(a), np.int64),
                            ],
                            axis=1,
                        )
                        cols.append(a)
                    perm = [cols.index(a) for a in out_cols]
                    self._outputs[mid].append(rows[:, perm])
            self._counts[st.hkey] += h_count


# ---------------------------------------------------------------------------
# JAX dataplane backend
# ---------------------------------------------------------------------------


@dataclass
class DataplaneJoinResult:
    """Result of running a program on the device mesh.  ``rows`` is the full
    exactly-once result multiset (over sorted(attset)); there is no simulator,
    so no metered load — wall-clock is the backend's figure of merit."""

    p: int
    count: int
    rows: Optional[np.ndarray]
    per_h_counts: Dict[Tuple[Attr, ...], int]
    retries: int = 0    # capacity-doubling retries triggered by overflow


class DataplaneUnsupported(NotImplementedError):
    """The program contains a stage the dataplane cannot lower yet."""


def _salt(*key) -> int:
    """Stable small salt for hash_exchange (shared randomness: every host
    derives the same salt from the stage key alone)."""
    h = hashlib.blake2b(repr(key).encode(), digest_size=4).digest()
    return int.from_bytes(h, "little") % (1 << 20)


class DataplaneExecutor:
    """Runs light-subquery programs on a JAX device mesh under shard_map.

    Lowering (per stage):
      Scatter/RouteResidual → host carves Q'(η) from the shared histogram and
        stages padded blocks onto the devices (the histogram is host metadata
        in the paper's model — every machine already holds it);
      HashPartition → `sharded_intersect`: unary residuals exchanged by
        hash(value) and intersected on-device into R''_X(η);
      SemiJoin → `sharded_semijoin`: light edges exchanged by hash(X) / hash(Y)
        with the same salts, filtered against the co-located pieces;
      LocalJoin → a left-deep chain of `sharded_join_step`s (exchange both
        sides on the shared attribute + merge_join_counts local join, with
        duplicate-attribute filtering for cyclic subqueries).

    Overflowed capacities are detected (never dropped) and the stage retries
    with doubled buffers — replacing the paper's 1/p^c failure probability.
    Stages with isolated attributes (CP grid) raise :class:`DataplaneUnsupported`.
    """

    def __init__(
        self,
        mesh=None,
        axis_name: str = "join",
        slack: int = 4,
        max_retries: int = 4,
    ):
        import jax

        if mesh is None:
            n = len(jax.devices())
            mesh = jax.make_mesh((n,), (axis_name,))
        else:
            axis_name = mesh.axis_names[0]
        self.mesh = mesh
        self.axis_name = axis_name
        self.p = mesh.shape[axis_name]
        self.slack = slack
        self.max_retries = max_retries

    # -- public entry ---------------------------------------------------------

    def run(self, program: RoundProgram, materialize: bool = True) -> DataplaneJoinResult:
        self._check_ops(program)
        for st in program.stages:
            if st.plan.isolated:
                raise DataplaneUnsupported(
                    f"stage H={st.hkey} η={st.ekey} needs the Lemma 3.1 CP grid "
                    "(isolated attributes) — not lowered yet; use SimulatorExecutor"
                )
        counts: Dict[Tuple[Attr, ...], int] = defaultdict(int)
        chunks: List[np.ndarray] = []
        retries = 0

        for mid, row in program.emit:
            chunks.append(row)
        for hkey, c in program.emit_counts.items():
            counts[hkey] += c

        for st in program.stages:
            rows, n_retry = self._run_stage(program, st)
            retries += n_retry
            if rows.shape[0]:
                chunks.append(rows)
                counts[st.hkey] += rows.shape[0]

        rows_out = None
        total = sum(int(c.shape[0]) for c in chunks)
        if materialize:
            rows_out = (
                np.concatenate(chunks, axis=0)
                if chunks
                else np.zeros((0, len(program.out_cols)), dtype=np.int64)
            )
        return DataplaneJoinResult(
            p=self.p,
            count=total,
            rows=rows_out,
            per_h_counts=dict(counts),
            retries=retries,
        )

    @staticmethod
    def _check_ops(program: RoundProgram) -> None:
        """The dataplane lowers the op *vocabulary*, not arbitrary op lists:
        its per-stage pipeline covers exactly the known ops (both semi-join
        phasings fold into the same per-attribute filters, so fused and
        unfused programs lower identically).  Anything else — a new op type,
        or a pass that dropped a required op — must fail loudly here instead
        of silently diverging from the simulator backend."""
        known = (Scatter, RouteResidual, HashPartition, SemiJoin, BroadcastSizes,
                 GridRoute, LocalJoin)
        for op in program.ops:
            if not isinstance(op, known):
                raise DataplaneUnsupported(f"op {op!r} has no dataplane lowering")
        required = (Scatter, RouteResidual, HashPartition, SemiJoin, LocalJoin)
        missing = [t.__name__ for t in required
                   if not any(isinstance(op, t) for op in program.ops)]
        if missing and program.stages:
            raise DataplaneUnsupported(
                f"program is missing ops {missing}; the dataplane pipeline "
                "cannot represent a partial round structure"
            )

    # -- one (H, η) stage -----------------------------------------------------

    def _run_stage(self, program: RoundProgram, st: ProgramStage):
        query, stats = program.query, program.stats
        plan = st.plan
        out_cols = list(program.out_cols)
        empty = np.zeros((0, len(out_cols)), dtype=np.int64)

        residuals = residual_relations(query, stats, plan, st.cfg.eta)
        if residuals is None:
            return empty, 0

        from ..dataplane.exchange import blockify

        light_staged = []   # (scheme, blocks, counts, n_rows) — host staging, once
        for e in plan.light_edges:
            rel = residuals[(e, query.relation_for(e).scheme)]
            if len(rel) == 0:
                return empty, 0
            blocks, cnts = blockify(rel.data, self.p, None)
            light_staged.append(
                (list(query.relation_for(e).scheme), blocks, cnts, len(rel))
            )
        piece_staged: Dict[Attr, List[Tuple]] = {}
        for x in plan.border:
            pieces = [residuals[(e, (x,))] for e in plan.cross_edges if x in e]
            if any(len(p) == 0 for p in pieces):
                return empty, 0
            staged = []
            for r in pieces:
                bv, bc = blockify(r.data[:, 0], self.p, None)
                staged.append((bv[:, :, 0], bc, len(r)))
            piece_staged[x] = staged
        if not light_staged:
            # isolated == ∅ and no light edges ⇒ light == ∅ ⇒ H = attset,
            # which compile_plan turned into emits; nothing to do here.
            return empty, 0

        caps_scale = 1
        for attempt in range(self.max_retries + 1):
            rows, overflowed = self._try_stage(
                program, st, light_staged, piece_staged, caps_scale
            )
            if not overflowed:
                return rows, attempt
            caps_scale *= 2
        raise RuntimeError(
            f"stage H={st.hkey} η={st.ekey} still overflows after "
            f"{self.max_retries} capacity doublings"
        )

    def _try_stage(self, program, st, light_staged, piece_staged, caps_scale):
        from ..dataplane.exchange import unblockify
        from ..dataplane.join import sharded_intersect, sharded_join_step, sharded_semijoin

        mesh, axis, p = self.mesh, self.axis_name, self.p
        plan = st.plan
        skey = (st.hkey, st.ekey)

        def cap_for(n_total: int) -> int:
            return max(16, self.slack * (-(-max(1, n_total) // p))) * caps_scale

        overflow = 0

        # HashPartition lowering: intersect unary pieces per border attribute.
        piece_blocks: Dict[Attr, Tuple] = {}
        for x, staged in piece_staged.items():
            cap = cap_for(max(n for _, _, n in staged))
            vals, cnts, ovf = sharded_intersect(
                mesh, axis,
                [(bv, bc) for bv, bc, _ in staged],
                salt=_salt(skey, x),
                cap_slot=cap, cap_out=cap,
            )
            overflow += int(np.asarray(ovf).sum())
            if int(np.asarray(cnts).sum()) == 0:
                return np.zeros((0, len(program.out_cols)), np.int64), overflow > 0
            piece_blocks[x] = (vals, cnts)

        # SemiJoin lowering: filter each light edge against the co-located pieces.
        staged_edges = []   # (scheme, blocks, counts)
        for scheme, blocks, cnts, n_rows in light_staged:
            filters = []
            for col, attr in enumerate(scheme):
                if attr in piece_blocks:
                    pv, pc = piece_blocks[attr]
                    filters.append((col, _salt(skey, attr), pv, pc))
            if filters:
                cap = cap_for(n_rows)
                blocks, cnts, ovf = sharded_semijoin(
                    mesh, axis, blocks, cnts, filters, cap_slot=cap, cap_out=cap
                )
                overflow += int(np.asarray(ovf).sum())
                if int(np.asarray(cnts).sum()) == 0:
                    return np.zeros((0, len(program.out_cols)), np.int64), overflow > 0
            staged_edges.append((list(scheme), blocks, cnts))

        # LocalJoin lowering: left-deep chain of distributed join steps.
        remaining = list(staged_edges)
        scheme, blocks, cnts = remaining.pop(0)
        while remaining:
            j = next(
                (i for i, (s, _, _) in enumerate(remaining) if set(s) & set(scheme)),
                None,
            )
            if j is None:
                raise DataplaneUnsupported(
                    f"stage H={st.hkey}: disconnected light subquery needs the "
                    "CP grid — use SimulatorExecutor"
                )
            b_scheme, b_blocks, b_cnts = remaining.pop(j)
            common = [a for a in scheme if a in b_scheme]
            key = common[0]
            ka, kb = scheme.index(key), b_scheme.index(key)
            dup_pairs = tuple(
                (scheme.index(a), b_scheme.index(a)) for a in common[1:]
            )
            n_a = int(np.asarray(cnts).sum())
            n_b = int(np.asarray(b_cnts).sum())
            cap = cap_for(max(n_a, n_b))
            cap_out = cap_for(4 * (n_a + n_b))
            blocks, cnts, ovf = sharded_join_step(
                mesh, axis, blocks, cnts, b_blocks, b_cnts, ka, kb,
                cap_slot=cap, cap_mid=2 * cap, cap_out=cap_out,
                dup_pairs=dup_pairs, salt=_salt(skey, "join", key),
            )
            overflow += int(np.asarray(ovf).sum())
            b_keep = [a for i, a in enumerate(b_scheme) if i != kb]
            for _, bc in dup_pairs:
                b_keep.remove(b_scheme[bc])
            scheme = scheme + b_keep

        if overflow:
            return np.zeros((0, len(program.out_cols)), np.int64), True

        rows = unblockify(blocks, cnts)
        # append the η constants and permute to the program's output order
        for a in plan.h_set:
            rows = np.concatenate(
                [rows, np.full((rows.shape[0], 1), st.cfg.eta.value(a), np.int64)],
                axis=1,
            )
            scheme = scheme + [a]
        perm = [scheme.index(a) for a in program.out_cols]
        return rows[:, perm], False
