"""Execution backends for the round-program IR (repro.mpc.program).

One verified plan, many backends: ``compile_plan`` fixes *which rounds with
which routes*; an :class:`Executor` decides *who executes them*.

* :class:`SimulatorExecutor` interprets every op on the exact-cost
  :class:`~repro.mpc.simulator.MPCSimulator` — the load oracle.  It reproduces
  the pre-IR monolithic engine bit for bit: identical hash keys, identical
  per-machine RNG streams, identical loop order, hence byte-identical
  ``per_h_counts`` and ``parallel_total_load`` (locked by
  tests/test_program_ir.py golden values).

* :class:`DataplaneExecutor` lowers every op of every compiled program onto
  the JAX data plane — one lowering rule per :class:`RoundOp`, dispatched over
  ``program.ops``: capacity-padded ``hash_exchange`` / ``sharded_grid_route``
  collectives + the merge_join_counts Pallas probe under ``shard_map``.
  Stages with isolated attributes run the Lemma 3.1 cartesian grid composed
  with the Lemma 3.3 HyperCube (the Lemma 3.2 cell mapping lives in
  :class:`~repro.mpc.program.StageGeometry`, shared with the simulator), so
  the device backend covers the whole of Theorem 6.2 (docs/DESIGN.md §7).
"""

from __future__ import annotations

import hashlib
import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.query import Attr, JoinQuery, Relation, reference_join
from ..core.taxonomy import residual_relations
from .hypercube import route_hypercube
from .program import (
    BroadcastSizes,
    GridRoute,
    HashPartition,
    LocalJoin,
    ProgramStage,
    RoundOp,
    RoundProgram,
    RouteResidual,
    Scatter,
    SemiJoin,
    StageGeometry,
    stage_geometry,
)
from .simulator import MPCSimulator, scatter_input


@dataclass
class MPCJoinResult:
    p: int
    lam: int
    rho: float
    m: int
    count: int
    rows: Optional[np.ndarray]          # over sorted(attset), if materialized
    sim: MPCSimulator
    per_h_counts: Dict[Tuple[Attr, ...], int]

    @property
    def bound(self) -> float:
        """The claimed load bound m / p^{1/ρ} (polylog factors not included)."""
        return self.m / (self.p ** (1.0 / self.rho))

    @property
    def load(self) -> int:
        return self.sim.parallel_total_load

    @property
    def load_ratio(self) -> float:
        return self.load / max(1.0, self.bound)


def _send_grouped(sim: MPCSimulator, phys: np.ndarray, tag, rows: np.ndarray) -> None:
    """Group rows by destination and send one message per destination."""
    if rows.ndim == 1:
        rows = rows.reshape(-1, 1)
    if rows.shape[0] == 0:
        return
    order = np.argsort(phys, kind="stable")
    ps, rs = phys[order], rows[order]
    uniq = np.unique(ps)
    bounds = np.append(np.searchsorted(ps, uniq), ps.shape[0])
    for i, dst in enumerate(uniq.tolist()):
        sim.send(int(dst), tag, rs[bounds[i] : bounds[i + 1]])


# ---------------------------------------------------------------------------
# Simulator backend
# ---------------------------------------------------------------------------


class SimulatorExecutor:
    """Runs a compiled :class:`RoundProgram` on the exact-cost simulator.

    May be handed an existing simulator (so the statistics preprocessing and
    the program execution meter into the same round ledger — the ``mpc_join``
    path), or a bare ``p`` to own a fresh one."""

    def __init__(
        self, sim: Optional[MPCSimulator] = None, p: Optional[int] = None, seed: int = 0
    ):
        if sim is None:
            if p is None:
                raise ValueError("need either a simulator or p")
            sim = MPCSimulator(p, seed=seed)
        self.sim = sim
        self.seed = seed

    # -- input placement (Scatter semantics; idempotent) ---------------------

    def place_inputs(self, query: JoinQuery, seed_offset: int = 17) -> None:
        for rel in query.relations:
            if not self.sim.machines_with(("in", rel.edge)):
                scatter_input(
                    self.sim, ("in", rel.edge), rel.data, seed=self.seed + seed_offset
                )

    # -- program interpretation ----------------------------------------------

    def run(self, program: RoundProgram, materialize: bool = True) -> MPCJoinResult:
        if self.sim.p != program.p:
            raise ValueError(f"simulator has p={self.sim.p}, program wants {program.p}")
        self._program = program
        self._materialize = materialize
        self._geo: Dict[int, StageGeometry] = {}
        self._outputs: Dict[int, List[np.ndarray]] = defaultdict(list)
        self._counts: Dict[Tuple[Attr, ...], int] = defaultdict(int)

        # H = attset(Q) emits: host-side placement, zero communication.
        for mid, row in program.emit:
            self._outputs[mid].append(row)
        for hkey, c in program.emit_counts.items():
            self._counts[hkey] += c

        for op in program.ops:
            self._dispatch(op)

        rows_out = None
        if materialize:
            chunks = [r for parts in self._outputs.values() for r in parts]
            rows_out = (
                np.concatenate(chunks, axis=0)
                if chunks
                else np.zeros((0, len(program.out_cols)), dtype=np.int64)
            )
        return MPCJoinResult(
            p=program.p,
            lam=program.lam,
            rho=program.rho_val,
            m=program.stats.m,
            count=sum(self._counts.values()),
            rows=rows_out,
            sim=self.sim,
            per_h_counts=dict(self._counts),
        )

    def _dispatch(self, op: RoundOp) -> None:
        if isinstance(op, Scatter):
            self.place_inputs(self._program.query, op.seed_offset)
        elif isinstance(op, RouteResidual):
            self._op_route_residual()
        elif isinstance(op, HashPartition):
            self._op_hash_partition()
        elif isinstance(op, SemiJoin):
            self._op_semijoin(op)
        elif isinstance(op, BroadcastSizes):
            self._op_broadcast_sizes()
        elif isinstance(op, GridRoute):
            self._op_grid_route()
        elif isinstance(op, LocalJoin):
            self._op_local_join()
        else:
            raise NotImplementedError(f"unknown op {op!r}")

    # -- step 1: route residual tuples ---------------------------------------

    def _op_route_residual(self) -> None:
        sim, program = self.sim, self._program
        query, stats, p = program.query, program.stats, program.p
        sim.begin_round("step1")
        for mid in range(sim.p):
            mrng = np.random.default_rng(self.seed * 1_000_003 + mid)
            local_cache: Dict = {}
            for rel in query.relations:
                local = sim.local(mid, ("in", rel.edge))
                if local.shape[0] == 0:
                    continue
                x_attr, y_attr = rel.scheme
                hx = stats.is_heavy(x_attr, local[:, 0])
                hy = stats.is_heavy(y_attr, local[:, 1])
                local_cache[rel.edge] = (local, hx, hy)
            for st in program.stages:
                plan, cfg = st.plan, st.cfg
                h = set(plan.h_set)
                grp = cfg.step1_group
                for rel in query.relations:
                    if rel.edge not in local_cache:
                        continue
                    local, hx, hy = local_cache[rel.edge]
                    x_attr, y_attr = rel.scheme
                    inter = rel.edge & h
                    if len(inter) == 2:
                        continue
                    if len(inter) == 0:
                        sel = ~hx & ~hy
                        rows = local[sel]
                    else:
                        (heavy_attr,) = inter
                        if heavy_attr == x_attr:
                            sel = (local[:, 0] == cfg.eta.value(x_attr)) & ~hy
                            rows = local[sel][:, 1:2]   # project to light attr
                        else:
                            sel = (local[:, 1] == cfg.eta.value(y_attr)) & ~hx
                            rows = local[sel][:, 0:1]
                    if rows.shape[0] == 0:
                        continue
                    virt = mrng.integers(0, grp.size, size=rows.shape[0])
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("r1", st.hkey, st.ekey, rel.edge), rows)
        sim.end_round()

    # -- step 2a: unary partition + intersection -----------------------------

    def _op_hash_partition(self) -> None:
        sim, program = self.sim, self._program
        query, p = program.query, program.p
        sim.begin_round("step2-unary")
        for st in program.stages:
            plan, cfg = st.plan, st.cfg
            grp = cfg.step1_group
            for e in plan.cross_edges:
                light_attr = next(iter(e - set(plan.h_set)))
                tag_in = ("r1", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=1)
                    virt = sim.hashes.hash(
                        (st.hkey, st.ekey, "sj", light_attr), rows[:, 0], grp.size
                    )
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("u", st.hkey, st.ekey, light_attr, e), rows)
        sim.end_round()

        # local intersection → R''_X pieces (no communication)
        for st in program.stages:
            plan = st.plan
            for x in plan.border:
                es = [e for e in plan.cross_edges if x in e]
                for mid in range(sim.p):
                    pieces = []
                    ok = True
                    for e in es:
                        vals = sim.local(mid, ("u", st.hkey, st.ekey, x, e), arity=1)
                        if vals.shape[0] == 0:
                            ok = False
                            break
                        pieces.append(np.unique(vals[:, 0]))
                    if not ok:
                        continue
                    inter = pieces[0]
                    for arr in pieces[1:]:
                        inter = np.intersect1d(inter, arr, assume_unique=True)
                    if inter.size:
                        sim.stores[mid][("ux", st.hkey, st.ekey, x)] = [inter.reshape(-1, 1)]

    # -- step 2b/2c: semi-join light edges -----------------------------------

    def _filter_by_membership(self, mid, rows, col, attr, st):
        """Keep rows whose rows[:, col] is in the machine-local R''_attr piece."""
        piece = self.sim.local(mid, ("ux", st.hkey, st.ekey, attr), arity=1)[:, 0]
        if piece.size == 0:
            return rows[:0]
        return rows[np.isin(rows[:, col], piece)]

    def _op_semijoin(self, op: SemiJoin) -> None:
        if op.phase == "x":
            self._semijoin_x()
        elif op.phase == "y":
            self._semijoin_y(fused=False)
            self._semijoin_local_y_filter()
        elif op.phase == "fused-route":
            self._semijoin_fused_route()
        elif op.phase == "fused-filter":
            self._semijoin_y(fused=True)
            self._semijoin_local_y_filter()
        else:
            raise NotImplementedError(f"SemiJoin phase {op.phase!r}")

    def _semijoin_x(self) -> None:
        sim, program = self.sim, self._program
        query, p = program.query, program.p
        sim.begin_round("step2-bx")
        for st in program.stages:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr = rel.scheme[0]
                tag_in = ("r1", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    virt = sim.hashes.hash(
                        (st.hkey, st.ekey, "sj", x_attr), rows[:, 0], grp.size
                    )
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("bx", st.hkey, st.ekey, e), rows)
        sim.end_round()

    def _semijoin_fused_route(self) -> None:
        # Beyond-paper fusion: route directly to the Y partition; X-filtering
        # happens at the Y-side against a replicated X piece fetched in the same
        # round — saves one full data round when X is not a border attribute,
        # else falls back to the two-hop detour.  See EXPERIMENTS §Perf.
        sim, program = self.sim, self._program
        query, p = program.query, program.p
        sim.begin_round("step2-fused")
        for st in program.stages:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr, y_attr = rel.scheme
                tag_in = ("r1", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    if x_attr not in st.plan.border:
                        virt = sim.hashes.hash(
                            (st.hkey, st.ekey, "sj", y_attr), rows[:, 1], grp.size
                        )
                        phys = (grp.base + virt) % p
                        _send_grouped(sim, phys, ("rr", st.hkey, st.ekey, e), rows)
                    else:
                        virt = sim.hashes.hash(
                            (st.hkey, st.ekey, "sj", x_attr), rows[:, 0], grp.size
                        )
                        phys = (grp.base + virt) % p
                        _send_grouped(sim, phys, ("bx", st.hkey, st.ekey, e), rows)
        sim.end_round()

    def _semijoin_y(self, fused: bool) -> None:
        sim, program = self.sim, self._program
        query, p = program.query, program.p
        sim.begin_round("step2-by")
        for st in program.stages:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr, y_attr = rel.scheme
                if fused and x_attr not in st.plan.border:
                    continue
                tag_in = ("bx", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    if x_attr in st.plan.border:
                        rows = self._filter_by_membership(mid, rows, 0, x_attr, st)
                    if rows.shape[0] == 0:
                        continue
                    virt = sim.hashes.hash(
                        (st.hkey, st.ekey, "sj", y_attr), rows[:, 1], grp.size
                    )
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("rr", st.hkey, st.ekey, e), rows)
        sim.end_round()

    def _semijoin_local_y_filter(self) -> None:
        # Y-side filtering is local (the piece lives where the hash sent the row).
        sim, program = self.sim, self._program
        query = program.query
        for st in program.stages:
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                y_attr = rel.scheme[1]
                if y_attr not in st.plan.border:
                    continue
                tag = ("rr", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag):
                    rows = sim.local(mid, tag, arity=2)
                    rows = self._filter_by_membership(mid, rows, 1, y_attr, st)
                    sim.stores[mid][tag] = [rows]

    # -- step 3 sizes: broadcast |R''_X| pieces ------------------------------

    def _op_broadcast_sizes(self) -> None:
        sim, program = self.sim, self._program
        attset = program.query.attset
        stages = program.stages
        sim.begin_round("step3-sizes")
        cfg_index = {(st.hkey, st.ekey): i for i, st in enumerate(stages)}
        attr_index = {a: i for i, a in enumerate(attset)}
        for st in stages:
            for x in st.plan.isolated:
                tag = ("ux", st.hkey, st.ekey, x)
                for mid in sim.machines_with(tag):
                    cnt = sim.local(mid, tag, arity=1).shape[0]
                    msg = np.array(
                        [[cfg_index[(st.hkey, st.ekey)], attr_index[x], mid, cnt]],
                        dtype=np.int64,
                    )
                    sim.broadcast(("sz",), msg)
        sim.end_round()

        size_rows = (
            sim.local(0, ("sz",), arity=4)
            if sim.machines_with(("sz",))
            else np.zeros((0, 4), np.int64)
        )
        piece_sizes: Dict[Tuple[int, int], List[Tuple[int, int]]] = defaultdict(list)
        for ci, ai, mid, cnt in size_rows.tolist():
            piece_sizes[(ci, ai)].append((mid, cnt))

        for i, st in enumerate(stages):
            entries = {
                x: piece_sizes.get((i, attr_index[x]), []) for x in st.plan.isolated
            }
            self._geo[i] = stage_geometry(program, st, entries)

    # -- step 3 route: Lemma 3.1 grid × Lemma 3.3 HyperCube ------------------

    def _op_grid_route(self) -> None:
        sim, program = self.sim, self._program
        query = program.query
        sim.begin_round("step3-route")
        for i, st in enumerate(program.stages):
            geo = self._geo[i]
            if geo.skip:
                continue
            grp = geo.step3_group
            hc_size, cp_size = geo.hc_size, geo.cp_size

            # CP side: every grid cell is instantiated in every HC column.
            if geo.grid:
                for li, x in enumerate(geo.iso_order):
                    tag = ("ux", st.hkey, st.ekey, x)
                    for mid in sim.machines_with(tag):
                        vals = sim.local(mid, tag, arity=1)
                        ids = geo.offsets[(x, mid)] + np.arange(
                            vals.shape[0], dtype=np.int64
                        )
                        if li < geo.grid.t_prime:
                            cells = geo.grid.cells_for_ids(li, ids)
                            for combo in range(cells.shape[1]):
                                flat = cells[:, combo]
                                for cell in np.unique(flat).tolist():
                                    rows = vals[flat == cell]
                                    for h_cell in range(hc_size):
                                        v = geo.cell(cell, h_cell)
                                        sim.send(
                                            grp.phys(v),
                                            ("cp", st.hkey, st.ekey, v, x),
                                            rows,
                                        )
                        else:
                            for cell in range(cp_size):
                                for h_cell in range(hc_size):
                                    v = geo.cell(cell, h_cell)
                                    sim.send(
                                        grp.phys(v), ("cp", st.hkey, st.ekey, v, x), vals
                                    )

            # HC side: every HC cell instantiated in every CP row.
            if geo.hc_grid:
                for e in st.plan.light_edges:
                    rel = query.relation_for(e)
                    tag = ("rr", st.hkey, st.ekey, e)
                    for mid in sim.machines_with(tag):
                        rows = sim.local(mid, tag, arity=2)

                        def deliver(
                            h_cell, out_tag, rs, _grp=grp, _geo=geo, _cp=cp_size, _st=st
                        ):
                            for c in range(_cp):
                                v = _geo.cell(c, h_cell)
                                sim.send(
                                    _grp.phys(v), ("hc", _st.hkey, _st.ekey, v, out_tag), rs
                                )

                        route_hypercube(
                            sim,
                            geo.hc_grid,
                            [(rel.scheme, e, rows)],
                            salt=(st.hkey, st.ekey, "hc"),
                            deliver=deliver,
                        )
        sim.end_round()

    # -- output: local joins, exactly-once -----------------------------------

    def _op_local_join(self) -> None:
        sim, program = self.sim, self._program
        query = program.query
        out_cols = list(program.out_cols)
        materialize = self._materialize
        for i, st in enumerate(program.stages):
            geo = self._geo[i]
            if geo.skip:
                continue
            plan = st.plan
            grp = geo.step3_group
            l_minus_i = [a for a in plan.light if a not in plan.isolated]
            h_count = 0
            for v in range(grp.size):
                mid = grp.phys(v)
                # light side
                if plan.light_edges:
                    frags = []
                    ok = True
                    for e in plan.light_edges:
                        rel = query.relation_for(e)
                        rows = sim.local(mid, ("hc", st.hkey, st.ekey, v, e), arity=2)
                        if rows.shape[0] == 0:
                            ok = False
                            break
                        frags.append(Relation.make(rel.scheme, rows))
                    if not ok:
                        continue
                    light_join = reference_join(JoinQuery.make(frags))
                    light_rows = light_join.data  # over sorted(l_minus_i)
                    if light_rows.shape[0] == 0:
                        continue
                else:
                    light_rows = np.zeros((1, 0), dtype=np.int64)

                # CP side
                cp_lists = []
                ok = True
                for x in geo.iso_order:
                    vals = sim.local(mid, ("cp", st.hkey, st.ekey, v, x), arity=1)
                    vals = np.unique(vals[:, 0])
                    if vals.size == 0:
                        ok = False
                        break
                    cp_lists.append(vals)
                if not ok:
                    continue

                n_cp = math.prod(arr.size for arr in cp_lists) if cp_lists else 1
                n_here = light_rows.shape[0] * n_cp
                h_count += n_here
                if materialize and n_here:
                    rows = light_rows
                    cols = sorted(l_minus_i)
                    for x, vals in zip(geo.iso_order, cp_lists):
                        nn = rows.shape[0]
                        rows = np.repeat(rows, vals.size, axis=0)
                        rows = np.concatenate(
                            [rows, np.tile(vals, nn).reshape(-1, 1)], axis=1
                        )
                        cols.append(x)
                    for a in plan.h_set:
                        rows = np.concatenate(
                            [
                                rows,
                                np.full((rows.shape[0], 1), st.cfg.eta.value(a), np.int64),
                            ],
                            axis=1,
                        )
                        cols.append(a)
                    perm = [cols.index(a) for a in out_cols]
                    self._outputs[mid].append(rows[:, perm])
            self._counts[st.hkey] += h_count


# ---------------------------------------------------------------------------
# JAX dataplane backend
# ---------------------------------------------------------------------------


@dataclass
class DataplaneJoinResult:
    """Result of running a program on the device mesh.  ``rows`` is the full
    exactly-once result multiset (over sorted(attset)); there is no simulator,
    so no metered load — wall-clock is the backend's figure of merit."""

    p: int
    count: int
    rows: Optional[np.ndarray]
    per_h_counts: Dict[Tuple[Attr, ...], int]
    retries: int = 0    # capacity-doubling retries triggered by overflow
    # one entry per retry: ((H, η), op round name, "slot" | "out" | "slot+out")
    retry_log: List[Tuple[Tuple, str, str]] = field(default_factory=list)


class DataplaneUnsupported(NotImplementedError):
    """The program contains an op type with no dataplane lowering rule.

    Every op `compile_plan` emits has one (the acceptance bar of the per-op
    lowering layer); this fires only for op types introduced by a rewrite pass
    the dataplane has not been taught about — loudly, never silently."""


def _salt(*key, attempt: int = 0) -> int:
    """Stable 31-bit salt for the routing hashes (shared randomness: every
    host derives the same salt from the stage key alone).  ``attempt`` threads
    the overflow-retry count into the salt so a capacity-doubling retry also
    re-randomizes the routing — the paper draws fresh randomness per attempt,
    which is what makes the 1/p^c failure probability per-attempt independent."""
    h = hashlib.blake2b(repr((key, attempt)).encode(), digest_size=8).digest()
    return int.from_bytes(h, "little") % (1 << 31)


def _pow2(n: int) -> int:
    """Round a capacity up to a power of two (≥ 16): retries double caps, so
    pow2 buckets make repeated executor calls hit the jit cache."""
    return 1 << max(4, int(n - 1).bit_length() if n > 1 else 0)


@dataclass
class _StageState:
    """Device-resident state of one (H, η) stage as it flows through the ops.

    ``skip_count`` mirrors the simulator's geo.skip rule exactly: a stage whose
    isolated R''_X is empty never reaches LocalJoin, so it contributes *no*
    per-H count entry; every other stage contributes one (possibly 0)."""

    stage: ProgramStage
    skey: Tuple
    light: Optional[List] = None          # [(scheme, blocks, counts, n_rows)]
    unary: Optional[Dict[Attr, List]] = None   # x -> [(vals, counts, n)] staged
    host_piece_n: Optional[Dict[Attr, int]] = None  # |R''_X| (host cross-check)
    pieces: Dict[Attr, Tuple] = field(default_factory=dict)   # x -> (vals, counts)
    piece_salt: Dict[Attr, int] = field(default_factory=dict)
    piece_n: Dict[Attr, int] = field(default_factory=dict)
    geo: Optional[StageGeometry] = None
    routed: Optional[List] = None         # [(scheme incl. cell col, blocks, counts)]
    n_out: int = 0
    rows: Optional[np.ndarray] = None
    empty: bool = False
    skip_count: bool = False


class DataplaneExecutor:
    """Runs every compiled :class:`RoundProgram` on a JAX device mesh.

    The backend is a *per-op lowering layer* that mirrors the IR vocabulary:
    one lowering rule per :class:`RoundOp`, dispatched over ``program.ops``
    exactly like the simulator's interpreter — a program rewrite (e.g.
    ``fuse_semijoin_pass``) changes device execution without executor edits.

      Scatter          host no-op (inputs are host-resident; the histogram is
                       shared metadata in the paper's model)
      RouteResidual    host carves Q'(η) per stage and blockifies the padded
                       residual blocks evenly onto the devices
      HashPartition    `sharded_intersect`: unary residuals exchanged by
                       hash(value) and intersected on-device into R''_X(η)
      SemiJoin         `sharded_semijoin`: phase x/fused-route filters the
                       light edges' X column, phase y/fused-filter the Y
                       column, against the co-located pieces
      BroadcastSizes   device piece counts pulled to host (the O(p²) size
                       round); `stage_geometry` — shared verbatim with the
                       simulator — turns them into the CP × HyperCube shape
      GridRoute        `sharded_grid_route`: isolated pieces get global ids
                       from the broadcast counts and go to their
                       `CartesianGrid.cells_for_ids` cells, light residents to
                       their `HyperCubeGrid` shares, every copy tagged with
                       its Lemma 3.2 virtual cell and exchanged by cell % p
      LocalJoin        a chain of communication-free `sharded_colocated_join`
                       steps keyed on the cell column (shared attributes
                       equality-filtered, CP lists appended as per-cell
                       cartesian factors)

    Overflow is detected (never dropped) per op and channel: a *slot*
    overflow doubles the routing buffers and re-randomizes the routing salts
    (fresh randomness per attempt, as in the paper); an *output* overflow
    doubles only the output buffer — replacing the paper's 1/p^c failure
    probability with deterministic retry.
    """

    _LOWERING = {
        Scatter: "_lower_scatter",
        RouteResidual: "_lower_route_residual",
        HashPartition: "_lower_hash_partition",
        SemiJoin: "_lower_semijoin",
        BroadcastSizes: "_lower_broadcast_sizes",
        GridRoute: "_lower_grid_route",
        LocalJoin: "_lower_local_join",
    }

    def __init__(
        self,
        mesh=None,
        axis_name: str = "join",
        slack: int = 4,
        max_retries: int = 6,
    ):
        import jax

        if mesh is None:
            n = len(jax.devices())
            mesh = jax.make_mesh((n,), (axis_name,))
        else:
            axis_name = mesh.axis_names[0]
        self.mesh = mesh
        self.axis_name = axis_name
        self.p = mesh.shape[axis_name]
        self.slack = slack
        self.max_retries = max_retries

    # -- capacity guesses (pow2-bucketed so retries and repeat runs hit the
    # -- jit cache; all of them are starting points for the doubling retry) ---

    def _cap(self, n_total: int) -> int:
        """Per-device receive/output capacity for n_total rows spread over p."""
        return _pow2(self.slack * (-(-max(1, n_total) // self.p)))

    def _slot_cap(self, n_total: int) -> int:
        """Per-(src, dst) send-slot capacity: a device holds ~n/p rows and
        spreads them over p destinations."""
        return _pow2(self.slack * (-(-max(1, n_total) // (self.p * self.p))))

    # -- public entry ---------------------------------------------------------

    def run(self, program: RoundProgram, materialize: bool = True) -> DataplaneJoinResult:
        self._retries = 0
        self._retry_log: List[Tuple[Tuple, str, str]] = []
        self._materialize = materialize
        states = [
            _StageState(stage=st, skey=(st.hkey, st.ekey)) for st in program.stages
        ]

        for op in program.ops:
            try:
                lower = getattr(self, self._LOWERING[type(op)])
            except KeyError:
                raise DataplaneUnsupported(
                    f"op {op!r} has no dataplane lowering rule"
                ) from None
            for state in states:
                if not state.empty:
                    lower(program, state, op)

        counts: Dict[Tuple[Attr, ...], int] = defaultdict(int)
        chunks: List[np.ndarray] = []
        for mid, row in program.emit:
            chunks.append(row)
        for hkey, c in program.emit_counts.items():
            counts[hkey] += c
        for state in states:
            if state.skip_count:
                continue
            counts[state.stage.hkey] += state.n_out
            if state.rows is not None and state.rows.shape[0]:
                chunks.append(state.rows)

        rows_out = None
        if materialize:
            rows_out = (
                np.concatenate(chunks, axis=0)
                if chunks
                else np.zeros((0, len(program.out_cols)), dtype=np.int64)
            )
        return DataplaneJoinResult(
            p=self.p,
            count=sum(counts.values()),
            rows=rows_out,
            per_h_counts=dict(counts),
            retries=self._retries,
            retry_log=list(self._retry_log),
        )

    # -- overflow-retry harness ----------------------------------------------

    def _retry_rounds(self, skey, round_name: str, attempt_fn):
        """The one retry harness: run ``attempt_fn(attempt) -> (result, kinds)``
        until ``kinds`` (the set of overflowed capacity channels, which the
        callee has already doubled) comes back empty.  All retry accounting —
        attempt budget, counter, log, failure error — lives here so every
        lowering reports retries identically."""
        for attempt in range(self.max_retries + 1):
            result, kinds = attempt_fn(attempt)
            if not kinds:
                return result
            self._retries += 1
            self._retry_log.append((skey, round_name, "+".join(sorted(kinds))))
        raise RuntimeError(
            f"stage {skey} op {round_name} still overflows after "
            f"{self.max_retries} capacity doublings"
        )

    def _with_retry(self, skey, round_name: str, caps: Dict[str, int], run):
        """Run ``run(caps, attempt) -> (result, [ovf arrays])`` until no
        overflow, doubling only the capacity channel that overflowed (slot
        overflow also doubles 'mid' when present; the attempt number feeds the
        routing salts so slot retries draw fresh randomness)."""

        def attempt_fn(attempt):
            result, ovfs = run(caps, attempt)
            tot = np.zeros(2, dtype=np.int64)
            for o in ovfs:
                tot += np.asarray(o).reshape(-1, 2).sum(axis=0)
            kinds = set()
            if int(tot[0]):
                for k in caps:
                    if k != "out":
                        caps[k] *= 2
                kinds.add("slot")
            if int(tot[1]):
                caps["out"] *= 2
                kinds.add("out")
            return result, kinds

        return self._retry_rounds(skey, round_name, attempt_fn)

    # -- per-op lowering rules ------------------------------------------------

    def _lower_scatter(self, program: RoundProgram, state: _StageState, op) -> None:
        """Scatter costs no load in the MPC model; the dataplane holds the
        inputs host-side (the histogram is shared metadata), so placement
        happens when RouteResidual stages the carved residuals."""

    def _lower_route_residual(self, program, state, op) -> None:
        from ..dataplane.exchange import blockify

        query, stats = program.query, program.stats
        plan = state.stage.plan
        residuals = residual_relations(query, stats, plan, state.stage.cfg.eta)
        if residuals is None:
            raise RuntimeError(
                f"stage {state.skey} compiled for an infeasible η — compiler bug"
            )

        # Host view of R''_X = ∩ unary pieces: decides the stage's fate the
        # same way the simulator's geometry does (empty isolated piece ⇒
        # geo.skip ⇒ no per-H count entry; any other empty input ⇒ a normal
        # zero-count stage).
        host_piece: Dict[Attr, np.ndarray] = {}
        for x in plan.border:
            vals = None
            for e in plan.cross_edges:
                if x not in e:
                    continue
                pv = np.unique(residuals[(e, (x,))].data[:, 0])
                vals = pv if vals is None else np.intersect1d(
                    vals, pv, assume_unique=True
                )
            host_piece[x] = vals
        if any(host_piece[x].size == 0 for x in plan.isolated):
            state.empty, state.skip_count = True, True
            return
        if any(v.size == 0 for v in host_piece.values()):
            state.empty = True
            return

        state.light = []
        for e in plan.light_edges:
            rel = residuals[(e, query.relation_for(e).scheme)]
            if len(rel) == 0:
                state.empty = True
                return
            blocks, cnts = blockify(rel.data, self.p, None)
            state.light.append(
                (list(query.relation_for(e).scheme), blocks, cnts, len(rel))
            )
        state.unary = {}
        for x in plan.border:
            staged = []
            for e in plan.cross_edges:
                if x not in e:
                    continue
                r = residuals[(e, (x,))]
                bv, bc = blockify(r.data[:, 0], self.p, None)
                staged.append((bv[:, :, 0], bc, len(r)))
            state.unary[x] = staged
        state.host_piece_n = {x: int(v.size) for x, v in host_piece.items()}

    def _lower_hash_partition(self, program, state, op) -> None:
        from ..dataplane.join import sharded_intersect

        for x, staged in state.unary.items():
            n_max = max(n for _, _, n in staged)
            caps = {"slot": self._slot_cap(n_max), "out": self._cap(n_max)}

            def run(caps, attempt, _staged=staged, _x=x):
                salt = _salt(state.skey, _x, attempt=attempt)
                vals, cnts, ovf = sharded_intersect(
                    self.mesh, self.axis_name,
                    [(bv, bc) for bv, bc, _ in _staged],
                    salt=salt, cap_slot=caps["slot"], cap_out=caps["out"],
                )
                return (vals, cnts, salt), [ovf]

            vals, cnts, salt = self._with_retry(state.skey, op.round, caps, run)
            total = int(np.asarray(cnts).sum())
            if total != state.host_piece_n[x]:
                raise RuntimeError(
                    f"stage {state.skey}: device |R''_{x}| = {total} != host "
                    f"{state.host_piece_n[x]} — routing bug"
                )
            state.pieces[x] = (vals, cnts)
            state.piece_salt[x] = salt
            state.piece_n[x] = total

    def _lower_semijoin(self, program, state, op) -> None:
        """Phase x (and its fused-route twin) filters column 0, phase y (and
        fused-filter) column 1 — the fused rewrite reorders the detour but the
        per-attribute filters are the same, so both program shapes lower
        through this one rule."""
        from ..dataplane.join import sharded_semijoin

        if op.phase in ("x", "fused-route"):
            col = 0
        elif op.phase in ("y", "fused-filter"):
            col = 1
        else:
            raise DataplaneUnsupported(f"SemiJoin phase {op.phase!r}")

        for idx, (scheme, blocks, cnts, n) in enumerate(state.light):
            attr = scheme[col]
            if attr not in state.pieces:
                continue
            pv, pc = state.pieces[attr]
            caps = {"slot": self._slot_cap(n), "out": self._cap(n)}

            def run(caps, attempt, _b=blocks, _c=cnts, _pv=pv, _pc=pc, _a=attr):
                # the exchange salt is pinned to the piece's distribution salt
                # (rows must land where HashPartition put the piece), so only
                # capacities scale on retry here.
                rows, c, ovf = sharded_semijoin(
                    self.mesh, self.axis_name, _b, _c,
                    [(col, state.piece_salt[_a], _pv, _pc)],
                    cap_slot=caps["slot"], cap_out=caps["out"],
                )
                return (rows, c), [ovf]

            blocks, cnts = self._with_retry(state.skey, op.round, caps, run)
            n2 = int(np.asarray(cnts).sum())
            state.light[idx] = (scheme, blocks, cnts, n2)
            if n2 == 0:
                state.empty = True
                return

    def _lower_broadcast_sizes(self, program, state, op) -> None:
        """The O(p²) size round: per-device piece counts cross to the host;
        `stage_geometry` (shared verbatim with the simulator) turns them into
        the stage's CP grid × HyperCube shape and the global-id offsets."""
        entries: Dict[Attr, List[Tuple[int, int]]] = {}
        for x in state.stage.plan.isolated:
            cnts = np.asarray(state.pieces[x][1])
            entries[x] = list(enumerate(int(c) for c in cnts.tolist()))
        state.geo = stage_geometry(program, state.stage, entries)
        if state.geo.skip:
            state.empty, state.skip_count = True, True

    def _lower_grid_route(self, program, state, op) -> None:
        from ..dataplane.grid import cp_route_spec, hc_route_spec, sharded_grid_route

        geo = state.geo
        if geo is None:
            raise DataplaneUnsupported("GridRoute before BroadcastSizes")
        if geo.cp_size * geo.hc_size >= 1 << 31:
            raise RuntimeError(f"stage {state.skey}: virtual grid exceeds int32")
        routed: List = []

        # HC side first (join order: light join, then CP cartesian factors).
        # One retry loop spans all light fragments: the per-attribute
        # coordinate salts must stay consistent across edges, so a fresh
        # attempt re-routes every fragment under new salts.
        if state.light:
            specs = [
                hc_route_spec(geo.hc_grid, scheme, geo.cp_size)
                for scheme, _, _, _ in state.light
            ]
            caps = [
                {"slot": self._slot_cap(n * s.fanout), "out": self._cap(n * s.fanout)}
                for (_, _, _, n), s in zip(state.light, specs)
            ]
            def route_all(attempt):
                salt_for = {
                    a: _salt(state.skey, "hc", a, attempt=attempt)
                    for a in geo.hc_grid.attrs
                }
                results = []
                kinds: set = set()
                for (scheme, blocks, cnts, n), spec, cap in zip(
                    state.light, specs, caps
                ):
                    salts = [salt_for[scheme[col]] for col, _, _ in spec.fixed]
                    rows, c, ovf = sharded_grid_route(
                        self.mesh, self.axis_name, blocks, cnts, spec,
                        salts=salts, cap_slot=cap["slot"], cap_out=cap["out"],
                    )
                    ovf = np.asarray(ovf).sum(axis=0)
                    if int(ovf[0]):
                        cap["slot"] *= 2
                        kinds.add("slot")
                    if int(ovf[1]):
                        cap["out"] *= 2
                        kinds.add("out")
                    results.append((["#cell"] + list(scheme), rows, c))
                return results, kinds

            routed.extend(self._retry_rounds(state.skey, op.round, route_all))

        # CP side: id-deterministic routing (no salts), per-piece retry.
        for li, x in enumerate(geo.iso_order):
            vals, cnts = state.pieces[x]
            spec = cp_route_spec(geo.grid, li, geo.hc_size)
            offsets = np.asarray(
                [geo.offsets[(x, dev)] for dev in range(self.p)], dtype=np.int64
            )
            n = state.piece_n[x]
            caps = {
                "slot": self._slot_cap(n * spec.fanout),
                "out": self._cap(n * spec.fanout),
            }

            def run(caps, attempt, _v=vals, _c=cnts, _s=spec, _o=offsets):
                rows, c, ovf = sharded_grid_route(
                    self.mesh, self.axis_name, _v[:, :, None], _c, _s,
                    offsets=_o, cap_slot=caps["slot"], cap_out=caps["out"],
                )
                return (rows, c), [ovf]

            rows, c = self._with_retry(state.skey, op.round, caps, run)
            routed.append((["#cell", x], rows, c))

        state.routed = routed

    def _lower_local_join(self, program, state, op) -> None:
        """Communication-free output: all fragments of a virtual cell live on
        device cell % p, so the per-cell join is a chain of colocated joins on
        the cell column — shared attributes equality-filtered via dup_pairs,
        disconnected components and CP lists combined as in-cell cartesian
        factors.  Each result tuple materializes on exactly one device."""
        from ..dataplane.exchange import unblockify
        from ..dataplane.join import sharded_colocated_join

        if state.routed is None:
            raise DataplaneUnsupported("LocalJoin before GridRoute")
        parts = list(state.routed)
        scheme, blocks, cnts = parts.pop(0)
        while parts:
            b_scheme, b_blocks, b_cnts = parts.pop(0)
            common = [a for a in scheme[1:] if a in b_scheme]
            dup_pairs = tuple(
                (scheme.index(a), b_scheme.index(a)) for a in common
            )
            n_a = int(np.asarray(cnts).sum())
            n_b = int(np.asarray(b_cnts).sum())
            caps = {"out": self._cap(4 * (n_a + n_b))}

            def run(caps, attempt, _a=blocks, _ac=cnts, _b=b_blocks, _bc=b_cnts,
                    _dp=dup_pairs):
                out, c, ovf = sharded_colocated_join(
                    self.mesh, self.axis_name, _a, _ac, _b, _bc, 0, 0,
                    cap_out=caps["out"], dup_pairs=_dp,
                )
                return (out, c), [ovf]

            blocks, cnts = self._with_retry(state.skey, op.round, caps, run)
            scheme = scheme + [
                a for i, a in enumerate(b_scheme) if i != 0 and a not in common
            ]

        state.n_out = int(np.asarray(cnts).sum())
        if not self._materialize or state.n_out == 0:
            return
        rows = unblockify(blocks, cnts)[:, 1:]     # drop the cell column
        out_scheme = scheme[1:]
        for a in state.stage.plan.h_set:
            rows = np.concatenate(
                [
                    rows,
                    np.full(
                        (rows.shape[0], 1), state.stage.cfg.eta.value(a), np.int64
                    ),
                ],
                axis=1,
            )
            out_scheme = out_scheme + [a]
        perm = [out_scheme.index(a) for a in program.out_cols]
        state.rows = rows[:, perm]
