"""Fault taxonomy + deterministic fault injection for the join service.

The paper's load guarantee is probabilistic — each attempt succeeds w.h.p.
and the executor draws fresh salts per retry (``_salt(attempt=)``), the same
per-attempt independence the HyperCube analysis relies on — but the *service*
built on top of it also has to survive non-probabilistic failures: a wedged
dispatch, a poisoned query inside a coalesced batch, a dead drainer thread.
This module provides both halves of that story (docs/design/10-robustness.md):

  * a **structured error taxonomy** rooted at :class:`JoinServiceError`, so
    every failure a :class:`~repro.mpc.service.JoinSession` surfaces is typed,
    names the query it belongs to, and chains the original traceback
    (``__cause__`` is always the root failure);
  * a **deterministic, seeded fault-injection layer** — :class:`FaultPlan` —
    threaded through :class:`~repro.mpc.executors.DataplaneExecutor` and
    :class:`~repro.mpc.service.JoinSession`, so every failure path (overflow
    exhaustion, dispatch exceptions, compile failures, stragglers, drainer
    crashes) is reachable from a unit test with a fixed seed instead of being
    discovered in production.

Injection decisions are *counter-based*: each site keeps an event counter and
each (seed, site, event index, rule index) hashes to an independent uniform
draw, so a decision never depends on which other rules matched — replaying
the same workload under the same plan seed injects the same faults at the
same events.
"""

from __future__ import annotations

import hashlib
import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------


def describe_query(query) -> str:
    """A short, stable human-readable name for a join query: its relation
    schemes in order (``Q[(A,B) (B,C)]``).  Used by every typed service error
    so a failure inside a coalesced batch still names *which* query died."""
    try:
        schemes = " ".join(
            "(" + ",".join(str(a) for a in rel.scheme) + ")"
            for rel in query.relations
        )
        return f"Q[{schemes}]"
    except Exception:
        return repr(query)


class JoinServiceError(RuntimeError):
    """Base of every typed join-service failure.

    Subclasses ``RuntimeError`` so pre-taxonomy callers catching the old bare
    ``RuntimeError`` keep working; new callers should catch this (or a
    specific subclass) instead."""


class RetryExhaustedError(JoinServiceError):
    """A stage still overflowed after ``max_retries`` capacity doublings.

    The deterministic-retry replacement of the paper's 1/p^c failure
    probability ran out of attempts — either the capacity model is badly
    wrong for this data or a fault plan is injecting persistent overflow.
    ``attempt_log`` carries the (stage, round, channel) retry entries of the
    failed run, so the exhaustion is attributable per channel."""

    def __init__(self, message: str, stage=None, op_round: Optional[str] = None,
                 attempts: int = 0, attempt_log: Tuple = ()):
        super().__init__(message)
        self.stage = stage
        self.op_round = op_round
        self.attempts = attempts
        self.attempt_log = tuple(attempt_log)


class DeadlineExceededError(JoinServiceError):
    """A request's monotonic-clock budget expired.

    Raised by the executor *between* dispatches (a collective already in
    flight is never abandoned mid-rendezvous) or by the session before a
    request that is already past its deadline executes at all.  ``query`` is
    filled in by the service layer when the deadline belonged to one request
    of a batch."""

    def __init__(self, message: str, query=None, op_round: Optional[str] = None,
                 deadline_s: Optional[float] = None):
        super().__init__(message)
        self.query = query
        self.op_round = op_round
        self.deadline_s = deadline_s


class QueryFailedError(JoinServiceError):
    """One query of a session failed; ``cause`` is the root exception.

    The generic per-query wrapper of the taxonomy: whatever died inside the
    executor (an injected fault, a routing-invariant violation, an XLA
    error), the service resolves *this* — naming the query — with the
    original exception chained on ``__cause__`` so the executor frames stay
    in the traceback."""

    def __init__(self, query, cause: BaseException, attempt_log: Tuple = ()):
        super().__init__(f"query {describe_query(query)} failed: {cause!r}")
        self.query = query
        self.cause = cause
        self.attempt_log = tuple(attempt_log)
        # the raise-from chain, attached at construction so the error carries
        # its provenance through Future.set_exception / cross-thread hops
        self.__cause__ = cause


class DegradedSessionError(JoinServiceError):
    """The session's drainer thread crashed.

    Every future pending at crash time resolves with this (nothing hangs),
    and subsequent :meth:`~repro.mpc.service.JoinSession.submit_async` calls
    raise it immediately until :meth:`~repro.mpc.service.JoinSession.restart`
    clears the degraded state."""

    def __init__(self, message: str, cause: Optional[BaseException] = None):
        super().__init__(message)
        self.cause = cause
        if cause is not None:
            self.__cause__ = cause


class ProgramVerificationError(JoinServiceError):
    """A compiled :class:`~repro.mpc.program.RoundProgram` failed static
    verification (docs/design/11-verification.md).

    Raised by :mod:`repro.mpc.verify` *before* any device executes a
    collective: the program's structure (op stream, machine allocations,
    grid geometry, capacity grid, packed-key eligibility) or its measured
    load violated an invariant the planner is supposed to guarantee.

    Attributes:
        op_round: the logical round the violation belongs to (``"step1"``,
            ``"step3-route"``, …) or None for program-wide rules.
        rule: the verifier rule name (one of
            :data:`repro.mpc.verify.RULES`) — what the mutation suite keys
            its assertions on.
        detail: human-readable specifics (offending stage, measured vs
            predicted numbers, …).
    """

    def __init__(self, message: str, op_round: Optional[str] = None,
                 rule: Optional[str] = None, detail: str = ""):
        super().__init__(message)
        self.op_round = op_round
        self.rule = rule
        self.detail = detail


# -- injected-fault exceptions (what a FaultPlan raises) ---------------------


class InjectedFault(RuntimeError):
    """Base of every exception a :class:`FaultPlan` raises on purpose.

    Deliberately NOT a :class:`JoinServiceError`: injected faults model
    *arbitrary* infrastructure failures, and the service must translate them
    into typed errors exactly like it would a real one — tests asserting
    "every failure surfaces as a JoinServiceError" would be vacuous if the
    injection were already typed."""


class InjectedDispatchError(InjectedFault):
    """A fused dispatch launch was failed by the fault plan."""


class InjectedCompileError(InjectedFault):
    """An AOT trace+compile was failed by the fault plan."""


class InjectedDrainerError(InjectedFault):
    """The session drainer thread was crashed by the fault plan."""


# ---------------------------------------------------------------------------
# Fault plan
# ---------------------------------------------------------------------------

#: sites a FaultRule can attach to.
SITES = ("dispatch", "compile", "overflow", "latency", "drainer")


@dataclass(frozen=True)
class FaultRule:
    """One injection rule of a :class:`FaultPlan`.

    Args:
        site: where the rule fires — ``"dispatch"`` (raise
            :class:`InjectedDispatchError` at a bucket launch), ``"compile"``
            (raise :class:`InjectedCompileError` in the AOT compile),
            ``"overflow"`` (force the listed ``channels`` to read as
            overflowed at an item's readback — drives the real retry
            machinery, fresh salts and all), ``"latency"`` (sleep
            ``delay_s`` before a bucket launch — an artificial straggler),
            or ``"drainer"`` (raise :class:`InjectedDrainerError` inside the
            session drain loop, between dequeue and demux).
        rate: per-event probability in [0, 1] (1.0 = every matching event).
        count: cap on total injections from this rule (None = unlimited);
            a drained rule never fires again — how tests model *transient*
            faults.
        after: skip the first ``after`` matching events (lets a test warm a
            session cleanly, then fault it).
        rounds: restrict to these op-round names (e.g. ``("output",)``;
            count passes are separate rounds named ``"<round>/count"``).
            None matches every round.  Ignored by the ``drainer`` site.
        channels: which overflow channels to force (``overflow`` site only);
            channels the work item does not carry are ignored.
        delay_s: sleep duration (``latency`` site only).
    """

    site: str
    rate: float = 1.0
    count: Optional[int] = None
    after: int = 0
    rounds: Optional[Tuple[str, ...]] = None
    channels: Tuple[str, ...] = ("slot",)
    delay_s: float = 0.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} (want one of {SITES})")
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")


class FaultPlan:
    """A deterministic, seeded schedule of injected faults.

    Thread through the stack as ``DataplaneExecutor(fault_plan=...)`` /
    ``JoinSession(fault_plan=...)`` (or per run via
    :class:`~repro.mpc.program.RunConfig`).  The plan is consulted at fixed
    sites; each consultation advances that site's event counter, and each
    (seed, site, event, rule) tuple hashes to an independent uniform draw —
    so two runs of the same workload under the same plan inject identically,
    and removing one rule never shifts another rule's decisions.

    Observability: ``injected`` counts injections per site, ``log`` records
    every injection as ``(site, round, detail, event_index)`` — what the
    chaos suite reconciles the service's failure counters against.

    All methods are thread-safe (the drainer and compile pool consult the
    plan concurrently with the submitting thread)."""

    def __init__(self, rules, seed: int = 0):
        self.rules: Tuple[FaultRule, ...] = tuple(rules)
        self.seed = seed
        self._events: Dict[str, int] = defaultdict(int)
        self._matched: Dict[int, int] = defaultdict(int)   # per-rule match count
        self._fired: Dict[int, int] = defaultdict(int)     # per-rule injections
        self.injected: Dict[str, int] = defaultdict(int)
        self.log: List[Tuple[str, Optional[str], str, int]] = []
        self._lock = threading.Lock()

    # -- convenience constructors --------------------------------------------

    @classmethod
    def none(cls) -> "FaultPlan":
        """An empty plan (injects nothing) — the explicit no-faults value."""
        return cls((), seed=0)

    @classmethod
    def dispatch_failures(cls, rate: float, seed: int = 0,
                          count: Optional[int] = None,
                          after: int = 0) -> "FaultPlan":
        """Fail a ``rate`` fraction of fused dispatch launches."""
        return cls(
            [FaultRule(site="dispatch", rate=rate, count=count, after=after)],
            seed=seed,
        )

    @classmethod
    def persistent_overflow(cls, rounds: Optional[Tuple[str, ...]] = None,
                            channels: Tuple[str, ...] = ("slot",),
                            seed: int = 0) -> "FaultPlan":
        """Force the given channels to overflow on every matching readback —
        drives the capacity-doubling retry to :class:`RetryExhaustedError`."""
        return cls(
            [FaultRule(site="overflow", rate=1.0, rounds=rounds, channels=channels)],
            seed=seed,
        )

    # -- decision core --------------------------------------------------------

    def _uniform(self, site: str, event: int, rule_idx: int) -> float:
        h = hashlib.blake2b(
            repr((self.seed, site, event, rule_idx)).encode(), digest_size=8
        ).digest()
        return int.from_bytes(h, "little") / float(1 << 64)

    def _firing_rules(self, site: str, rnd: Optional[str]) -> List[FaultRule]:
        """Advance ``site``'s event counter and return the rules that fire."""
        with self._lock:
            event = self._events[site]
            self._events[site] = event + 1
            fired: List[FaultRule] = []
            for ri, rule in enumerate(self.rules):
                if rule.site != site:
                    continue
                if rule.rounds is not None and site != "drainer" and rnd not in rule.rounds:
                    continue
                matched = self._matched[ri]
                self._matched[ri] = matched + 1
                if matched < rule.after:
                    continue
                if rule.count is not None and self._fired[ri] >= rule.count:
                    continue
                if self._uniform(site, event, ri) >= rule.rate:
                    continue
                self._fired[ri] += 1
                self.injected[site] += 1
                detail = (
                    "+".join(rule.channels) if site == "overflow"
                    else f"{rule.delay_s}s" if site == "latency"
                    else "fail"
                )
                self.log.append((site, rnd, detail, event))
                fired.append(rule)
            return fired

    # -- sites ----------------------------------------------------------------

    def at_dispatch(self, rnd: str) -> None:
        """Consulted once per fused bucket launch: latency rules sleep (the
        artificial straggler), dispatch rules raise."""
        for rule in self._firing_rules("latency", rnd):
            time.sleep(rule.delay_s)
        if self._firing_rules("dispatch", rnd):
            raise InjectedDispatchError(
                f"injected dispatch failure in op round {rnd!r}"
            )

    def at_compile(self, rnd: str) -> None:
        """Consulted once per AOT trace+compile of a fresh signature."""
        if self._firing_rules("compile", rnd):
            raise InjectedCompileError(
                f"injected compile failure in op round {rnd!r}"
            )

    def at_drainer(self) -> None:
        """Consulted once per drain batch, between dequeue and demux —
        exactly the window the shutdown-race satellite tests."""
        if self._firing_rules("drainer", None):
            raise InjectedDrainerError("injected drainer crash")

    def overflow(self, rnd: str) -> Tuple[str, ...]:
        """Consulted once per work-item readback: the union of channels the
        firing overflow rules force.  The executor treats a forced channel
        exactly like a real overflow (doubled caps, fresh salts for slot) —
        and quarantines the item's learned caps, so the injected doubling
        never poisons the fault-free steady state."""
        channels: set = set()
        for rule in self._firing_rules("overflow", rnd):
            channels.update(rule.channels)
        return tuple(sorted(channels))

    # -- observability --------------------------------------------------------

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    def drained(self) -> bool:
        """True when every rule has a ``count`` and has exhausted it — the
        plan can no longer inject anything (the recovery phase of a chaos
        test starts here)."""
        if not self.rules:
            return True
        with self._lock:
            return all(
                r.count is not None and self._fired[i] >= r.count
                for i, r in enumerate(self.rules)
            )
