"""HyperCube routing (Lemma 3.3 / BKS one-round algorithm).

Machines form a grid with one dimension per attribute; a tuple of relation with scheme
{X, Y} is sent to every cell whose X/Y coordinates equal h_X(u(X)), h_Y(u(Y)); a result
tuple is assembled at exactly one cell (the one matching all its hashed coordinates).

Used three ways:
  * skew-free subqueries Q''_light(η) inside Theorem 6.2 (share λ per attribute);
  * the standalone one-round baseline of [13]/[6] with LP-optimal uniform shares
    (``benchmarks/bench_oneround_baseline.py``) — correct on any input, load degrades
    under skew, which is precisely the paper's motivation;
  * the JAX data plane mirrors this routing with all_to_all (repro.dataplane).
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np
from scipy.optimize import linprog

from ..core.hypergraph import Hypergraph
from ..core.query import Attr, JoinQuery, Relation, reference_join
from .simulator import MPCSimulator


def uniform_lp_shares(g: Hypergraph, p: int) -> Dict[Attr, int]:
    """One-round share optimizer for *uniform* data: choose exponents y_X ≥ 0 with
    Σ y_X ≤ 1 maximizing min_e Σ_{X∈e} y_X; share_X = round(p^{y_X}).
    (For a clique/cycle this recovers the classic p^{2/|V|}-style shares.)"""
    attrs = list(g.vertices)
    na = len(attrs)
    aidx = {a: i for i, a in enumerate(attrs)}
    # vars: y_0..y_{na-1}, t ; maximize t  s.t. t - Σ_{X∈e} y_X ≤ 0 ; Σ y ≤ 1 ; y ≥ 0
    nvar = na + 1
    c = np.zeros(nvar)
    c[-1] = -1.0
    a_ub = []
    b_ub = []
    for e in g.edges:
        row = np.zeros(nvar)
        row[-1] = 1.0
        for v in e:
            row[aidx[v]] = -1.0
        a_ub.append(row)
        b_ub.append(0.0)
    row = np.zeros(nvar)
    row[:na] = 1.0
    a_ub.append(row)
    b_ub.append(1.0)
    res = linprog(c, A_ub=np.array(a_ub), b_ub=np.array(b_ub), bounds=(0, None), method="highs")
    if not res.success:
        raise RuntimeError(res.message)
    shares = {}
    for a in attrs:
        shares[a] = max(1, int(round(p ** float(res.x[aidx[a]]))))
    # keep the grid within p cells
    while math.prod(shares.values()) > p:
        amax = max(shares, key=lambda a: shares[a])
        shares[amax] = max(1, shares[amax] - 1)
    return shares


def hc_cell_contribs(
    attrs: Sequence[Attr], dims: Sequence[int], fixed_attrs: Sequence[Attr]
) -> Tuple[Dict[Attr, int], Tuple[int, ...]]:
    """Static (host-side) half of `cells_for`: the flat-cell stride of every
    fixed attribute plus the flat contribution of every combination of the
    free dimensions.  Shared by the numpy and the jnp routing paths so both
    enumerate the exact same cells."""
    attrs = tuple(attrs)
    dims = tuple(dims)
    fixed = set(fixed_attrs)
    strides: Dict[Attr, int] = {}
    for ai, a in enumerate(attrs):
        if a in fixed:
            strides[a] = math.prod(dims[ai + 1:]) if ai + 1 < len(dims) else 1
    free_dims = [d for a, d in zip(attrs, dims) if a not in fixed]
    n_free = math.prod(free_dims) if free_dims else 1
    contribs = np.zeros((n_free,), dtype=np.int64)
    if free_dims:
        grid = np.indices(free_dims).reshape(len(free_dims), -1).T
        j = 0
        for ai, a in enumerate(attrs):
            if a in fixed:
                continue
            s = math.prod(dims[ai + 1:]) if ai + 1 < len(dims) else 1
            contribs += grid[:, j] * s
            j += 1
    return strides, tuple(int(c) for c in contribs)


def hc_cells_dev(fixed_coords, free_contribs: Sequence[int], n: int):
    """jnp cell enumeration from already-fixed coordinates: ``fixed_coords``
    is a sequence of (traced (n,) coordinate array, static flat stride) pairs,
    ``free_contribs`` the flat ids of the free-dimension combos.  Returns
    (n, n_free) flat cells.  The single device-side implementation — both
    `HyperCubeGrid.cells_for_dev` and the dataplane GridRoute lowering call
    it, so route math cannot diverge from the grid geometry."""
    import jax.numpy as jnp

    flat = jnp.zeros((n,), dtype=jnp.int32)
    for coord, stride in fixed_coords:
        flat = flat + coord.astype(jnp.int32) * stride
    return flat[:, None] + jnp.asarray(free_contribs, dtype=jnp.int32)[None, :]


class HyperCubeGrid:
    """Mixed-radix cell indexing over an ordered attribute list."""

    def __init__(self, attrs: Sequence[Attr], shares: Dict[Attr, int]):
        self.attrs = tuple(attrs)
        self.dims = tuple(int(shares[a]) for a in self.attrs)
        self.size = math.prod(self.dims) if self.dims else 1

    def share(self, attr: Attr) -> int:
        return self.dims[self.attrs.index(attr)]

    def cells_for(self, fixed: Dict[Attr, np.ndarray]) -> np.ndarray:
        """Vectorized: given per-attribute fixed coordinates (arrays of equal length n)
        for a subset of attrs, return (n, n_free_combos) flat cell ids covering all
        combinations of the free dims."""
        n = len(next(iter(fixed.values()))) if fixed else 1
        free_dims = [d for a, d in zip(self.attrs, self.dims) if a not in fixed]
        n_free = math.prod(free_dims) if free_dims else 1
        # enumerate free combos
        combos = np.zeros((n_free, len(self.attrs)), dtype=np.int64)
        if free_dims:
            grid = np.indices(free_dims).reshape(len(free_dims), -1).T
            j = 0
            for ai, a in enumerate(self.attrs):
                if a not in fixed:
                    combos[:, ai] = grid[:, j]
                    j += 1
        flat = np.zeros((n, n_free), dtype=np.int64)
        for ai, a in enumerate(self.attrs):
            stride = math.prod(self.dims[ai + 1 :]) if ai + 1 < len(self.dims) else 1
            if a in fixed:
                flat += (fixed[a].reshape(-1, 1)) * stride
            else:
                flat += combos[:, ai].reshape(1, -1) * stride
        return flat

    def cells_for_dev(self, fixed: Dict[Attr, "jax.Array"]) -> "jax.Array":  # noqa: F821
        """jnp twin of `cells_for` for device-side routing: the per-attribute
        coordinates in ``fixed`` are traced (n,) int arrays, the grid structure
        is static.  Returns (n, n_free_combos) flat cell ids identical to the
        numpy version — delegates to `hc_cells_dev`, the same function the
        dataplane GridRoute lowering traces."""
        strides, contribs = hc_cell_contribs(self.attrs, self.dims, tuple(fixed))
        n = next(iter(fixed.values())).shape[0] if fixed else 1
        return hc_cells_dev(
            [(coord, strides[a]) for a, coord in fixed.items()], contribs, n
        )


def route_hypercube(
    sim: MPCSimulator,
    grid: HyperCubeGrid,
    fragments: Iterable[Tuple[Tuple[Attr, ...], object, np.ndarray]],
    salt,
    deliver: Callable[[int, object, np.ndarray], None],
) -> None:
    """Route rows to HyperCube cells. ``fragments`` yields (scheme, out_tag, rows);
    ``deliver(cell, out_tag, rows)`` performs the sends (caller controls the physical
    mapping, enabling the Lemma 3.2 matrix composition). Must be called inside a round."""
    for scheme, out_tag, rows in fragments:
        if rows.shape[0] == 0:
            continue
        fixed = {}
        for col, attr in enumerate(scheme):
            if attr in grid.attrs:
                share = grid.dims[grid.attrs.index(attr)]
                fixed[attr] = sim.hashes.hash((salt, attr), rows[:, col], share)
        cells = grid.cells_for(fixed)  # (n, n_free)
        for combo in range(cells.shape[1]):
            flat = cells[:, combo]
            order = np.argsort(flat, kind="stable")
            flat_sorted = flat[order]
            rows_sorted = rows[order]
            bounds = np.searchsorted(flat_sorted, np.unique(flat_sorted))
            uniq = np.unique(flat_sorted)
            bounds = np.append(bounds, flat.shape[0])
            for i, cell in enumerate(uniq.tolist()):
                deliver(int(cell), out_tag, rows_sorted[bounds[i] : bounds[i + 1]])


def skewfree_hypercube_join(
    query: JoinQuery,
    shares: Dict[Attr, int],
    p: int,
    seed: int = 0,
    materialize: bool = True,
) -> Tuple[MPCSimulator, int, Optional[Relation]]:
    """Standalone one-round HyperCube join (Lemma 3.3 / the one-round baseline).

    Returns (sim with metered loads, result_count, result or None). Input placement is
    even; the single communication round routes every tuple to its hash cells; each cell
    joins its fragments locally. Correct on any input; optimal only when skew-free.
    """
    sim = MPCSimulator(p, seed=seed)
    from .simulator import scatter_input

    for rel in query.relations:
        scatter_input(sim, ("in", rel.edge), rel.data, seed=seed + 1)

    attrs = query.attset
    grid = HyperCubeGrid(attrs, shares)
    assert grid.size <= p, (grid.size, p)

    sim.begin_round("hypercube")
    for mid in range(sim.p):
        frags = []
        for rel in query.relations:
            local = sim.local(mid, ("in", rel.edge))
            frags.append((rel.scheme, ("hc", rel.edge), local))
        route_hypercube(
            sim,
            grid,
            frags,
            salt="hc",
            deliver=lambda cell, tag, rows: sim.send(cell, tag, rows),
        )
    sim.end_round()

    total = 0
    out_rows = []
    for cell in range(grid.size):
        rels = []
        empty = False
        for rel in query.relations:
            rows = sim.local(cell, ("hc", rel.edge))
            if rows.shape[0] == 0:
                empty = True
                break
            rels.append(Relation.make(rel.scheme, rows))
        if empty:
            continue
        local_join = reference_join(JoinQuery.make(rels))
        total += len(local_join)
        if materialize and len(local_join):
            out_rows.append(local_join.data)
    result = None
    if materialize:
        data = (
            np.concatenate(out_rows, axis=0)
            if out_rows
            else np.zeros((0, len(attrs)), dtype=np.int64)
        )
        result = Relation.make(attrs, data)
    return sim, total, result
