"""Round-program IR for the Theorem 6.2 join (paper Sec. 6).

``compile_plan`` turns (query, histogram, p) into a :class:`RoundProgram`: the
complete host-side plan of the constant-round algorithm — which (H, η) stages
exist, how many machines each gets, and the fixed sequence of :class:`RoundOp`s
that any execution backend must perform.  Compilation is pure metadata work
(every machine could derive it identically from the shared histogram, so it
costs zero communication); all data movement happens in an
:class:`~repro.mpc.executors.Executor` that interprets the ops.

Op vocabulary (one op per logical engine phase; the simulator meters each as
one named round, see docs/DESIGN.md §7):

  ``Scatter``          even initial placement of the input relations
  ``RouteResidual``    step 1 — residual tuples of every Q'(η) to its group
  ``HashPartition``    step 2a — unary residuals hashed per border attribute,
                       then the local intersection → R''_X(η)
  ``SemiJoin``         step 2b/2c — light edges semi-joined on X then Y
  ``BroadcastSizes``   step 3 — |R''_X(η)| pieces broadcast (the O(p²) round)
  ``GridRoute``        step 3 — Lemma 3.1 CP grid × Lemma 3.3 HyperCube,
                       composed via the Lemma 3.2 matrix; one round
  ``LocalJoin``        output — local joins; each result tuple materializes on
                       exactly one machine

Program rewrites are passes over the op list: ``fuse_semijoin_pass`` replaces
the two-round semi-join with the beyond-paper fused variant (one data round
saved when a light edge's X attribute is not a border attribute).

Arbitrary-arity queries (any relation with arity ≠ 2, or ``force_general``)
compile through :func:`compile_general_plan` instead: acyclic queries get a
Yannakakis-style program — two semijoin sweeps along a GYO join tree
(``TreeSemiJoin``) followed by a HyperCube route + tree-ordered local join
chain — and cyclic queries the generalized one-round HyperCube (per-attribute
shares from the fractional edge cover LP, Beame–Koutris–Suciu) with the same
route + chain-join tail (``ShareRoute`` + ``CellJoin``).  General programs
carry a :class:`GeneralPlan` and a single :class:`GeneralStage`, flow through
the same executors/caches/verifier as binary programs, and are checked by the
``join-tree`` / ``share-exponent`` rules of ``repro.mpc.verify``.
"""

from __future__ import annotations

import itertools
import os
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.hypergraph import rho
from ..core.jointree import build_join_tree
from ..core.planner import (
    ConfigPlan,
    HPlanWithAlloc,
    MachineGroup,
    QueryPlan,
    _stable_base,
    step1_allocation,
    step3_allocation,
)
from ..core.query import Attr, JoinQuery
from ..core.taxonomy import (
    Configuration,
    HPlan,
    HeavyStats,
    config_feasible,
    configurations,
    plan_for_h,
    residual_size,
)
from .cartesian import CartesianGrid
from .hypercube import HyperCubeGrid, uniform_lp_shares


# ---------------------------------------------------------------------------
# Ops
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RoundOp:
    """One logical phase of the constant-round algorithm."""

    @property
    def round(self) -> str:
        raise NotImplementedError


@dataclass(frozen=True)
class Scatter(RoundOp):
    """Even initial placement of every input relation (Θ(m/p) per machine).
    Costs no load in the MPC model; backends that already hold the inputs
    (e.g. because the statistics preprocessing placed them) treat it as a
    no-op.  Relations sharing a physical ``Relation.table`` (self-join-shaped
    queries, e.g. the subgraph-enumeration reduction) are placed once and
    aliased per edge — the shared-input Scatter path."""

    seed_offset: int = 17

    @property
    def round(self) -> str:
        return "scatter"


@dataclass(frozen=True)
class RouteResidual(RoundOp):
    """Step 1: every machine routes, per stage, the residual tuples of Q'(η)
    to a uniformly random virtual machine of the stage's p'_η group."""

    @property
    def round(self) -> str:
        return "step1"


@dataclass(frozen=True)
class HashPartition(RoundOp):
    """Step 2a: unary residuals (from cross edges) are hash-partitioned per
    border attribute; machines then intersect the co-located pieces into
    R''_X(η) locally."""

    @property
    def round(self) -> str:
        return "step2-unary"


@dataclass(frozen=True)
class SemiJoin(RoundOp):
    """Step 2b/2c: semi-join of the light edges against the R''_X pieces.

    ``phase`` selects the sub-round:
      * ``"x"``            route by hash(X)                    (round step2-bx)
      * ``"y"``            filter on X, route by hash(Y),
                           then filter on Y locally            (round step2-by)
      * ``"fused-route"``  fused variant: non-border-X edges go straight to
                           their Y partition                   (round step2-fused)
      * ``"fused-filter"`` border-X edges complete the detour  (round step2-by)
    """

    phase: str = "x"

    @property
    def round(self) -> str:
        return {
            "x": "step2-bx",
            "y": "step2-by",
            "fused-route": "step2-fused",
            "fused-filter": "step2-by",
        }[self.phase]


@dataclass(frozen=True)
class BroadcastSizes(RoundOp):
    """Step 3 statistics: every machine broadcasts the sizes of its R''_X
    pieces (the paper's O(p²) round); afterwards all machines agree on the
    step-3 geometry (grid dims, HyperCube shares) of every stage."""

    @property
    def round(self) -> str:
        return "step3-sizes"


@dataclass(frozen=True)
class GridRoute(RoundOp):
    """Step 3 routing: the Lemma 3.1 cartesian grid over the isolated
    R''_X lists composed with the Lemma 3.3 HyperCube over L \\ I, glued by
    the Lemma 3.2 matrix — a single communication round."""

    @property
    def round(self) -> str:
        return "step3-route"


@dataclass(frozen=True)
class LocalJoin(RoundOp):
    """Output: each machine joins its fragments locally; every result tuple
    of every stage materializes on exactly one machine (no communication)."""

    @property
    def round(self) -> str:
        return "output"


@dataclass(frozen=True)
class TreeSemiJoin(RoundOp):
    """Yannakakis semijoin sweep along the GYO join tree (general route).

    ``phase`` = ``"up"`` (leaves → root, GYO removal order: each parent is
    filtered by every child) or ``"down"`` (root → leaves, reversed order:
    each child filtered by its already-reduced parent).  After both sweeps the
    query is fully reduced — every surviving tuple contributes to the output
    (Yannakakis; Hu/Yi 1903.09717 give the MPC instance-optimal form).  Each
    tree edge is one hash-partitioned semijoin on the edge's shared attributes
    (an empty label degenerates to a non-emptiness filter — the cartesian
    stitch edge between components)."""

    phase: str = "up"

    @property
    def round(self) -> str:
        return {"up": "yan-up", "down": "yan-down"}[self.phase]


@dataclass(frozen=True)
class ShareRoute(RoundOp):
    """Generalized HyperCube route (BKS 1604.01848): every relation's tuples
    are replicated to the grid cells agreeing with their hashed coordinates,
    with per-attribute shares from the fractional edge cover LP (Π shares ≤ p,
    load m/p^{1/ρ} on skew-free data).  One communication round; each result
    tuple is assembled at exactly one cell."""

    @property
    def round(self) -> str:
        return "hc-route"


@dataclass(frozen=True)
class CellJoin(RoundOp):
    """Output round of the general route: each cell joins its co-located
    fragments through a chain of local joins — ordered by the join tree for
    acyclic queries, by shared-attribute greedy order for cyclic ones — with
    every attribute a grid dimension, so each result tuple materializes on
    exactly one machine (no communication)."""

    @property
    def round(self) -> str:
        return "output"


DEFAULT_OPS: Tuple[RoundOp, ...] = (
    Scatter(),
    RouteResidual(),
    HashPartition(),
    SemiJoin(phase="x"),
    SemiJoin(phase="y"),
    BroadcastSizes(),
    GridRoute(),
    LocalJoin(),
)

GENERAL_ACYCLIC_OPS: Tuple[RoundOp, ...] = (
    Scatter(),
    TreeSemiJoin(phase="up"),
    TreeSemiJoin(phase="down"),
    ShareRoute(),
    CellJoin(),
)

GENERAL_CYCLIC_OPS: Tuple[RoundOp, ...] = (
    Scatter(),
    ShareRoute(),
    CellJoin(),
)


# ---------------------------------------------------------------------------
# Stages + program
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StageSignature:
    """Compile-time batching signature of a stage (bucket-signature metadata).

    Two stages with equal signatures perform structurally identical work in
    every round — same light/cross edge shapes, same border and isolated
    attribute counts — differing only in η values and data sizes.  The
    stage-batched :class:`~repro.mpc.executors.DataplaneExecutor` groups work
    finer than this (adding run-time geometry and pow2 capacities), but the
    signature is the IR-level upper bound on how many compiled variants a
    program can need: O(#signatures), never O(#stages)."""

    h_set: Tuple[Attr, ...]
    light_edges: Tuple[Tuple[Attr, ...], ...]
    cross_edges: Tuple[Tuple[Attr, ...], ...]
    border: Tuple[Attr, ...]
    isolated: Tuple[Attr, ...]


@dataclass
class ProgramStage:
    """One (H, η) configuration with its machine allocation.

    ``cfg`` carries the step-1 group at compile time; the step-3 geometry is
    filled in at run time by :func:`stage_geometry` once the R''_X sizes are
    known (they depend on the data, not the histogram)."""

    plan: HPlan
    cfg: ConfigPlan

    @property
    def hkey(self) -> Tuple[Attr, ...]:
        return self.plan.h_set

    @property
    def ekey(self) -> Tuple[int, ...]:
        return self.cfg.eta.values

    @property
    def signature(self) -> StageSignature:
        """The stage's compile-time batching signature (see
        :class:`StageSignature`)."""
        return StageSignature(
            h_set=tuple(self.plan.h_set),
            light_edges=tuple(
                tuple(sorted(e)) for e in self.plan.light_edges
            ),
            cross_edges=tuple(
                tuple(sorted(e)) for e in self.plan.cross_edges
            ),
            border=tuple(sorted(self.plan.border)),
            isolated=tuple(sorted(self.plan.isolated)),
        )


@dataclass(frozen=True)
class GeneralPlan:
    """Structure of a general (arbitrary-arity) program.

    ``kind`` is ``"yannakakis"`` (acyclic: semijoin sweeps + routed join) or
    ``"hypercube"`` (cyclic: one-round generalized shares).  ``tree_edges``
    lists the join tree's (child, parent, shared attrs) in GYO removal order
    (the valid up-sweep order; the down sweep is its exact reverse — the
    ``join-tree`` verify rule re-checks both).  ``join_order`` is the relation
    order of the CellJoin chain (a pre-order of the tree for acyclic queries,
    so each joined relation is adjacent to the already-joined set).
    ``shares`` are the per-attribute HyperCube shares from the fractional edge
    cover LP, with Π shares ≤ p (the ``share-exponent`` verify rule)."""

    kind: str
    tree_root: int
    tree_edges: Tuple[Tuple[int, int, Tuple[Attr, ...]], ...]
    join_order: Tuple[int, ...]
    shares: Tuple[Tuple[Attr, int], ...]

    @property
    def shares_dict(self) -> Dict[Attr, int]:
        return dict(self.shares)


@dataclass
class GeneralStage:
    """The single pseudo-stage a general program carries.

    Duck-typed to the :class:`ProgramStage` surface the stage-batched executor
    reads (``hkey``/``ekey``/``signature``; ``plan`` is None — there is no
    binary (H, η) taxonomy behind it).  ``struct`` pins the query structure so
    salts and retry groups derived from the stage key are deterministic."""

    kind: str
    struct: Tuple

    plan = None

    @property
    def hkey(self) -> Tuple[Attr, ...]:
        return ("*",)

    @property
    def ekey(self) -> Tuple[int, ...]:
        return ()

    @property
    def signature(self) -> Tuple:
        return ("general", self.kind, self.struct)


@dataclass
class RoundProgram:
    """A compiled Theorem 6.2 instance: stages + op sequence + emit tuples.

    Attributes:
        query: the query this program is currently bound to (swap the data
            with :meth:`rebind` — compilation never read it).
        p / lam / rho_val: machine count, heavy parameter, edge-cover number.
        stats: the histogram the plan was compiled against.
        stages: one :class:`ProgramStage` per surviving (H, η) configuration.
        emit: the H = attset(Q) results (η itself is the result tuple; zero
            communication) as (machine, row over ``out_cols``) pairs;
            ``emit_counts`` their per-H totals.
        ops: the fixed :class:`RoundOp` sequence every backend interprets;
            ``fused`` records whether ``fuse_semijoin_pass`` rewrote it.

    Programs are immutable execution artifacts: compile once, execute on any
    backend any number of times (executors copy per-run state out of the
    stages), cache across queries under :func:`plan_cache_key`.
    """

    query: JoinQuery
    p: int
    lam: int
    rho_val: float
    stats: HeavyStats
    stages: List[ProgramStage]
    emit: List[Tuple[int, np.ndarray]]
    emit_counts: Dict[Tuple[Attr, ...], int]
    ops: Tuple[RoundOp, ...] = DEFAULT_OPS
    fused: bool = False
    general: Optional[GeneralPlan] = None

    @property
    def out_cols(self) -> Tuple[Attr, ...]:
        return tuple(self.query.attset)

    @property
    def round_names(self) -> List[str]:
        return [op.round for op in self.ops]

    def op_sequence(self) -> List[str]:
        """Compact human/test-readable op listing, e.g. ['Scatter', ...]."""
        out = []
        for op in self.ops:
            name = type(op).__name__
            if isinstance(op, (SemiJoin, TreeSemiJoin)):
                name += f"[{op.phase}]"
            out.append(name)
        return out

    def bucket_histogram(self) -> Dict["StageSignature", int]:
        """Stage count per compile-time batching signature — the IR-level
        view of how a stage-batched executor will bucket this program (the
        bench and the scheduler-observability tests read it)."""
        out: Dict[StageSignature, int] = {}
        for st in self.stages:
            sig = st.signature
            out[sig] = out.get(sig, 0) + 1
        return out

    def rebind(self, query: JoinQuery) -> "RoundProgram":
        """Return a copy of this compiled program bound to ``query``'s data.

        Sound exactly when ``plan_cache_key(query, self.stats, self.p, ...)``
        equals the key this program was compiled under: compilation is a pure
        function of (query structure, histogram, p) — see
        :func:`plan_cache_key` — so the stages, emits, and op list can be
        shared verbatim and only the relation data behind the plan changes.
        The cross-query plan cache of :class:`repro.mpc.service.JoinSession`
        is built on this."""
        return replace(self, query=query)

    def query_plan(self) -> QueryPlan:
        """Group the stages back into the planner's per-H view."""
        h_plans: Dict[Tuple[Attr, ...], HPlanWithAlloc] = {}
        for st in self.stages:
            h_plans.setdefault(st.hkey, HPlanWithAlloc(plan=st.plan)).configs.append(
                st.cfg
            )
        return QueryPlan(
            p=self.p, lam=self.lam, rho_val=self.rho_val, h_plans=h_plans
        )


# ---------------------------------------------------------------------------
# Run configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RunConfig:
    """Per-run execution knobs threaded through ``Executor.run_many``.

    Separates *what* runs (the :class:`RoundProgram`, cached and reused
    across queries) from *how this particular run* behaves — so deadlines
    and fault plans never leak into plan cache keys or coalesce signatures.

    Attributes:
        materialize: gather output rows to host (False = sizes only).
        deadline: absolute ``time.monotonic()`` instant after which the
            executor raises ``DeadlineExceededError``.  Checked *between*
            dispatches only — a collective in flight is never abandoned
            mid-rendezvous — so overshoot is bounded by one bucket dispatch.
            None = no budget.
        fault_plan: a ``repro.mpc.faults.FaultPlan`` consulted at the
            executor's injection sites for this run, overriding any plan the
            executor itself was constructed with.  None = use the
            executor's own (which defaults to no injection).
        verify: re-run the static verifier (``repro.mpc.verify``) over every
            program of this run — including the executor's learned-caps
            store — before any collective is dispatched.  Off by default;
            compile-time verification is governed separately by
            ``compile_plan(verify=...)`` / the ``REPRO_VERIFY`` env var.
    """

    materialize: bool = True
    deadline: Optional[float] = None
    fault_plan: Optional[object] = None
    verify: bool = False


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------


def _verify_default() -> bool:
    """Resolve compile-time verification from the ``REPRO_VERIFY`` env var
    (tests/conftest.py turns it on for the whole suite)."""
    return os.environ.get("REPRO_VERIFY", "0").strip().lower() not in (
        "", "0", "false", "off",
    )


def _general_join_order(
    schemes: Sequence[Tuple[Attr, ...]],
    tree_edges: Sequence[Tuple[int, int, Tuple[Attr, ...]]],
    root: int,
) -> Tuple[int, ...]:
    """Relation order of the CellJoin chain.

    Acyclic (tree present): pre-order of the join tree, lowest child index
    first — every joined relation is tree-adjacent to the already-joined set,
    so each chain step is a real join on the tree edge's shared attributes.
    Cyclic: greedy connected order — start at relation 0, repeatedly take the
    lowest-index remaining relation sharing an attribute with the covered set
    (falling back to the lowest index for a disconnected component)."""
    n = len(schemes)
    if n == 1:
        return (0,)
    if tree_edges:
        children: Dict[int, List[int]] = {}
        for c, parent, _ in tree_edges:
            children.setdefault(parent, []).append(c)
        order: List[int] = []
        stack = [root]
        while stack:
            node = stack.pop()
            order.append(node)
            stack.extend(sorted(children.get(node, []), reverse=True))
        return tuple(order)
    order = [0]
    covered = set(schemes[0])
    remaining = [i for i in range(1, n)]
    while remaining:
        nxt = next(
            (i for i in remaining if covered & set(schemes[i])), remaining[0]
        )
        remaining.remove(nxt)
        order.append(nxt)
        covered |= set(schemes[nxt])
    return tuple(order)


def compile_general_plan(
    query: JoinQuery,
    stats: HeavyStats,
    p: int,
    verify: Optional[bool] = None,
) -> RoundProgram:
    """Compile an arbitrary-arity query into a general :class:`RoundProgram`.

    Acyclic queries (GYO-reducible) get the Yannakakis program: an up + down
    :class:`TreeSemiJoin` sweep along the join tree (a full reducer — every
    surviving tuple contributes), then a :class:`ShareRoute` over *all*
    attributes and a tree-ordered :class:`CellJoin` chain.  Cyclic queries
    skip the sweeps: the generalized HyperCube shares (fractional edge cover
    LP exponents, Π shares ≤ p) bound the per-cell load by m/p^{1/ρ} on
    skew-free data, and the same route + chain tail assembles the output.
    Every attribute is a grid dimension (share-1 attributes collapse to
    coordinate 0), so each result tuple materializes at exactly one cell —
    the exactly-once emission the differential harness locks."""
    rho_val = float(rho(query))
    schemes = [r.scheme for r in query.relations]
    tree = build_join_tree([frozenset(s) for s in schemes])
    shares = uniform_lp_shares(query.hypergraph, p)
    shares_t = tuple(sorted((a, int(s)) for a, s in shares.items()))
    if tree is not None:
        kind = "yannakakis"
        root = tree.root
        tree_edges = tuple(
            (c, par, tuple(sorted(shared))) for c, par, shared in tree.edges
        )
        ops = GENERAL_ACYCLIC_OPS
    else:
        kind = "hypercube"
        root = 0
        tree_edges = ()
        ops = GENERAL_CYCLIC_OPS
    join_order = _general_join_order(schemes, tree_edges, root)
    plan = GeneralPlan(
        kind=kind,
        tree_root=root,
        tree_edges=tree_edges,
        join_order=join_order,
        shares=shares_t,
    )
    stage = GeneralStage(
        kind=kind,
        struct=(tuple(schemes), tree_edges, root, join_order, shares_t),
    )
    program = RoundProgram(
        query=query,
        p=p,
        lam=stats.lam,
        rho_val=rho_val,
        stats=stats,
        stages=[stage],
        emit=[],
        emit_counts={},
        ops=ops,
        general=plan,
    )
    if _verify_default() if verify is None else verify:
        from .verify import verify_program  # local: verify imports this module

        verify_program(program)
    return program


def compile_plan(
    query: JoinQuery,
    stats: HeavyStats,
    p: int,
    h_subsets: Optional[Sequence[Sequence[Attr]]] = None,
    fuse_semijoin: bool = False,
    verify: Optional[bool] = None,
) -> RoundProgram:
    """Compile the full H-taxonomy of ``query`` into a :class:`RoundProgram`.

    Absorbs all host-side planning of the engine: H enumeration, per-η
    inactive-edge feasibility (from the extended histogram — ruled-out η cost
    no communication), residual sizing, step-1 machine allocation, and the
    H = attset(Q) emit set.  ``h_subsets`` restricts the taxonomy (testing).

    ``verify`` runs the static verifier (``repro.mpc.verify``) over the
    compiled program before returning it; None defers to the ``REPRO_VERIFY``
    env var (default on in tests, off in production hot paths — the service
    layer times its own verification pass explicitly).
    """
    if query.is_general:
        # arbitrary-arity route: h_subsets/fuse_semijoin are binary-taxonomy
        # knobs with no general counterpart — the general compiler ignores
        # them (plan_cache_key keeps the keyspaces apart via is_general).
        return compile_general_plan(query, stats, p, verify=verify)

    attset = query.attset
    k = len(attset)
    rho_val = float(rho(query))

    if h_subsets is None:
        h_subsets = [
            h for r in range(k + 1) for h in itertools.combinations(attset, r)
        ]

    stages: List[ProgramStage] = []
    emit: List[Tuple[int, np.ndarray]] = []
    emit_counts: Dict[Tuple[Attr, ...], int] = {}
    out_cols = list(attset)

    for h in h_subsets:
        plan = plan_for_h(query, h)
        cfg_sizes: List[Tuple[Configuration, int]] = []
        for eta in configurations(stats, plan.h_set):
            if not config_feasible(query, stats, plan, eta):
                continue
            if len(plan.h_set) == k:
                # every edge inactive; η itself is the result tuple (no comm).
                mid = _stable_base(p, "emit", plan.h_set, eta.values)
                row = np.array([[eta.value(a) for a in out_cols]], dtype=np.int64)
                emit.append((mid, row))
                emit_counts[plan.h_set] = emit_counts.get(plan.h_set, 0) + 1
                continue
            m_eta = residual_size(query, stats, plan, eta)
            if m_eta == 0 and (plan.light_edges or plan.cross_edges):
                # some active edge has empty residual input ⇒ empty join.
                continue
            cfg_sizes.append((eta, m_eta))
        for cfg in step1_allocation(query, stats, plan, cfg_sizes, p):
            stages.append(ProgramStage(plan=plan, cfg=cfg))

    program = RoundProgram(
        query=query,
        p=p,
        lam=stats.lam,
        rho_val=rho_val,
        stats=stats,
        stages=stages,
        emit=emit,
        emit_counts=emit_counts,
        ops=DEFAULT_OPS,
    )
    if fuse_semijoin:
        program = fuse_semijoin_pass(program)
    if _verify_default() if verify is None else verify:
        from .verify import verify_program  # local: verify imports this module

        verify_program(program)
    return program


# ---------------------------------------------------------------------------
# Canonical plan keys (cross-query plan/compile reuse)
# ---------------------------------------------------------------------------


def histogram_signature(stats: HeavyStats) -> Tuple:
    """Hashable canonical form of a histogram — the data-side half of a plan
    cache key.

    Two instances with equal signatures have *identical* extended histograms
    (λ, m, heavy-value sets, and every cond/pair/light_cnt record), which is
    everything :func:`compile_plan` reads from the data.  Equal signature +
    equal query structure therefore implies an identical compiled program —
    the invariant the service-layer plan cache relies on (docs/design/
    09-service.md)."""
    return (
        stats.lam,
        stats.m,
        tuple(sorted((a, tuple(v.tolist())) for a, v in stats.heavy.items())),
        tuple(
            sorted(
                (tuple(sorted(e)), a, x, c) for (e, a, x), c in stats.cond.items()
            )
        ),
        tuple(
            sorted(
                (tuple(sorted(e)), x, y, c) for (e, x, y), c in stats.pair.items()
            )
        ),
        tuple(sorted((tuple(sorted(e)), c) for e, c in stats.light_cnt.items())),
    )


def plan_cache_key(
    query: JoinQuery,
    stats: HeavyStats,
    p: int,
    h_subsets: Optional[Sequence[Sequence[Attr]]] = None,
    fuse_semijoin: bool = False,
) -> Tuple:
    """Canonical cache key under which :func:`compile_plan` is a pure function.

    The key captures every compile-time input: the query *structure* (relation
    schemes in relation order, plus which relations alias one physical
    ``Relation.table`` — the shared-input Scatter classes), the machine count,
    the taxonomy restriction, the fusion flag, and the full
    :func:`histogram_signature`.  Concrete tuples are deliberately absent:
    two instances with equal keys compile to the same program, so a cached
    program may be :meth:`RoundProgram.rebind`-ed onto fresh data.  A shifted
    histogram (new heavy values, changed counts) changes the signature and
    therefore simply *misses* — stale plans age out of the service LRU rather
    than being invalidated in place."""
    alias: Dict[str, int] = {}
    struct = []
    for rel in query.relations:
        tid = None
        if rel.table is not None:
            tid = alias.setdefault(rel.table, len(alias))
        struct.append((rel.scheme, tid))
    hs = (
        None
        if h_subsets is None
        else tuple(tuple(sorted(h)) for h in h_subsets)
    )
    return (
        tuple(struct),
        bool(query.force_general),
        p,
        hs,
        bool(fuse_semijoin),
        histogram_signature(stats),
    )


def coalesce_signature(program: RoundProgram) -> Tuple:
    """Bucket-layer compatibility key for cross-query coalescing.

    Two compiled programs with equal signatures run the *same op sequence*
    over the *same machine count*, which is exactly what
    :meth:`StageBatchedDataplaneExecutor.run_many` requires to drive several
    programs through one scheduling pass: each op lowers every program's
    stages into one shared work-item round, and stages whose geometry buckets
    coincide fuse into one stacked dispatch.  The bucket histogram rides
    along so schedulers (and the service drainer) can see *how much* fusion
    to expect: equal histograms mean the stacked round has the same bucket
    population as replaying one program ``k`` times — the perfect-fusion
    case — while differing histograms still coalesce, just with partially
    shared buckets.

    Deliberately coarser than :func:`plan_cache_key`: data identity, heavy
    value sets, and λ are absent, because the stage axis is data-blind —
    only op order and block geometry decide whether dispatches merge."""
    return (
        program.p,
        tuple(program.op_sequence()),
        tuple(sorted(
            ((sig, n) for sig, n in program.bucket_histogram().items()),
            key=repr,
        )),
    )


def programs_coalescible(a: RoundProgram, b: RoundProgram) -> bool:
    """True when ``a`` and ``b`` may share one batched scheduling pass.

    The hard requirement (checked again by ``run_many``) is identical op
    sequences on identical ``p``; the histogram component of
    :func:`coalesce_signature` additionally demands matching bucket shapes,
    which is the profitable case — so this predicate is the service
    drainer's grouping rule, not merely the executor's legality rule."""
    return coalesce_signature(a) == coalesce_signature(b)


def fuse_semijoin_pass(program: RoundProgram) -> RoundProgram:
    """Program rewrite: replace SemiJoin[x] + SemiJoin[y] with the fused pair.

    The fused route sends each light tuple whose X attribute is *not* a border
    attribute straight to its Y partition (no X-membership to resolve), saving
    one full data round for those edges; border-X edges keep the two-hop
    detour.  Correctness is unchanged — the rewrite only reorders routing (see
    EXPERIMENTS §Perf and tests/test_engine_fusion.py)."""
    ops: List[RoundOp] = []
    i = 0
    seq = list(program.ops)
    while i < len(seq):
        op = seq[i]
        if (
            isinstance(op, SemiJoin)
            and op.phase == "x"
            and i + 1 < len(seq)
            and isinstance(seq[i + 1], SemiJoin)
            and seq[i + 1].phase == "y"
        ):
            ops.append(SemiJoin(phase="fused-route"))
            ops.append(SemiJoin(phase="fused-filter"))
            i += 2
            continue
        ops.append(op)
        i += 1
    return replace(program, ops=tuple(ops), fused=True)


# ---------------------------------------------------------------------------
# Run-time geometry (shared by all executors)
# ---------------------------------------------------------------------------


@dataclass
class StageGeometry:
    """Step-3 geometry of one stage, derived from the broadcast |R''_X| sizes.

    Identical on every machine (a pure function of broadcast data), so any
    backend may compute it host-side without extra communication.  It is
    per-*run* state: the compiled program (and its ``ConfigPlan``s) is never
    mutated, so one program can be executed concurrently by many executors."""

    iso_order: List[Attr] = field(default_factory=list)  # isolated attrs, size desc
    iso_sizes: Dict[Attr, int] = field(default_factory=dict)
    offsets: Dict[Tuple[Attr, int], int] = field(default_factory=dict)
    grid: Optional[CartesianGrid] = None
    hc_grid: Optional[HyperCubeGrid] = None
    step3_group: Optional[MachineGroup] = None
    skip: bool = False

    # -- Lemma 3.2 composition (shared by every backend) ---------------------

    @property
    def hc_size(self) -> int:
        return self.hc_grid.size if self.hc_grid else 1

    @property
    def cp_size(self) -> int:
        return self.grid.size if self.grid else 1

    def cell(self, cp_cell: int, hc_cell: int) -> int:
        """Virtual machine id of (CP row, HyperCube column): the Lemma 3.2
        matrix flattened row-major.  Both executors route through this one
        composition rule."""
        return cp_cell * self.hc_size + hc_cell


def stage_geometry(
    program: RoundProgram,
    stage: ProgramStage,
    piece_entries: Dict[Attr, List[Tuple[int, int]]],
) -> StageGeometry:
    """Finalize a stage's step-3 allocation from the broadcast piece sizes.

    ``piece_entries[x]`` lists (machine, count) for attribute x's R''_X
    pieces; ids are offset in sorted-machine order so every backend assigns
    the same global ids.  Runs :func:`~repro.core.planner.step3_allocation`
    on a *copy* of the stage's ``ConfigPlan`` (the shared program stays
    immutable) and builds the CP / HyperCube grids of Lemma 6.1."""
    geo = StageGeometry()
    plan = stage.plan
    for x in plan.isolated:
        entries = sorted(piece_entries.get(x, []))
        total = sum(c for _, c in entries)
        geo.iso_sizes[x] = total
        off = 0
        for mid, c in entries:
            geo.offsets[(x, mid)] = off
            off += c
    if any(v == 0 for v in geo.iso_sizes.values()):
        geo.skip = True
        return geo
    cfg = replace(stage.cfg)
    step3_allocation(
        program.query,
        program.stats,
        plan,
        cfg,
        geo.iso_sizes,
        program.p,
        program.rho_val,
    )
    geo.step3_group = cfg.step3_group
    geo.iso_order = sorted(plan.isolated, key=lambda a: -geo.iso_sizes[a])
    if geo.iso_order:
        geo.grid = CartesianGrid(
            [geo.iso_sizes[a] for a in geo.iso_order], cfg.cp_machines
        )
    l_minus_i = [a for a in plan.light if a not in plan.isolated]
    if l_minus_i:
        geo.hc_grid = HyperCubeGrid(
            l_minus_i, {a: program.stats.lam for a in l_minus_i}
        )
    return geo
