"""Exact-cost MPC runtime: machines, rounds, load metering, and the paper's algorithms.

The simulator is the *paper-faithful* execution substrate: the MPC model's cost metric is
"max words received by any machine in a round" (paper Sec. 1.1) — a communication metric
that must be metered exactly to validate the Õ(m/p^{1/ρ}) claim. The JAX data plane
(repro.dataplane) mirrors the communication-heavy phases on a device mesh.
"""

from .simulator import MPCSimulator, HashFamily
