"""Exact-cost MPC runtime: machines, rounds, load metering, and the paper's algorithms.

The simulator is the *paper-faithful* execution substrate: the MPC model's cost metric is
"max words received by any machine in a round" (paper Sec. 1.1) — a communication metric
that must be metered exactly to validate the Õ(m/p^{1/ρ}) claim. The JAX data plane
(repro.dataplane) mirrors the communication-heavy phases on a device mesh.

Layering (docs/DESIGN.md §7): ``program`` compiles (query, histogram, p) into a
round-program IR; ``executors`` provides the pluggable backends
(SimulatorExecutor = exact load oracle, DataplaneExecutor = JAX device mesh);
``engine.mpc_join`` is the historical compile-and-simulate entry point.
"""

from .simulator import MPCSimulator, HashFamily
from .faults import (
    DeadlineExceededError,
    DegradedSessionError,
    FaultPlan,
    FaultRule,
    InjectedCompileError,
    InjectedDispatchError,
    InjectedDrainerError,
    InjectedFault,
    JoinServiceError,
    QueryFailedError,
    RetryExhaustedError,
)
from .program import (
    BroadcastSizes,
    GridRoute,
    HashPartition,
    LocalJoin,
    RoundOp,
    RoundProgram,
    RouteResidual,
    RunConfig,
    Scatter,
    SemiJoin,
    coalesce_signature,
    compile_plan,
    fuse_semijoin_pass,
    histogram_signature,
    plan_cache_key,
    programs_coalescible,
)
from .executors import (
    BatchRunStats,
    DataplaneExecutor,
    DataplaneJoinResult,
    DataplaneUnsupported,
    ExecutableCache,
    MPCJoinResult,
    SimulatorExecutor,
)
from .service import (
    AdmissionError,
    JoinSession,
    ServiceStats,
    SessionResult,
)
from .engine import mpc_join
