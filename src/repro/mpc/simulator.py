"""MPC simulator with exact load accounting (paper Sec. 1.1 model).

Machines hold numpy arrays in a tag-indexed store. An algorithm runs in rounds; within a
round every machine *prepares messages from its local storage only* (enforced by the
orchestration structure: message construction reads the store, delivery mutates it after
the round closes). The per-round load is max over machines of received words
(1 word = one int64 value; a (n, a) array = n·a words). Total load of a constant-round
algorithm = sum of per-round loads (asymptotically the max round, paper Sec. 1.1).

Shared randomness (paper footnote 2) is modeled by HashFamily seeded from a single seed
that all machines are assumed to have pre-agreed on; this costs no load, as in the paper.
"""

from __future__ import annotations

import hashlib
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, Hashable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

Tag = Hashable

_PRIME = (1 << 61) - 1  # Mersenne prime for 2-universal hashing


def _mod_mersenne61(y: np.ndarray) -> np.ndarray:
    """y mod (2^61 - 1) for uint64 y. Since 2^61 ≡ 1, fold the high bits down;
    one fold leaves a value < 2^61 + 7, so a single conditional subtract finishes."""
    r = (y >> np.uint64(61)) + (y & np.uint64(_PRIME))
    return np.where(r >= np.uint64(_PRIME), r - np.uint64(_PRIME), r)


def _mulmod_mersenne61(a: int, x: np.ndarray) -> np.ndarray:
    """(a · x) mod (2^61 - 1), exact, vectorized. a < 2^61; x uint64 < 2^61.

    Split both factors at 32 bits: a·x = ah·xh·2^64 + (ah·xl + al·xh)·2^32 + al·xl.
    Every partial product fits uint64 (ah, xh < 2^29; al, xl < 2^32), and
    2^64 ≡ 8, 2^32 shifts are folded via 2^61 ≡ 1."""
    mask32 = np.uint64(0xFFFFFFFF)
    ah, al = np.uint64(a >> 32), np.uint64(a & 0xFFFFFFFF)
    xh, xl = x >> np.uint64(32), x & mask32
    hi = _mod_mersenne61(ah * xh) * np.uint64(8)            # ·2^64 ≡ ·8  (< 2^64)
    mid = _mod_mersenne61(ah * xl + al * xh)                 # < 2^61
    # mid·2^32: split at bit 29 so the shifted halves stay below 2^61
    mid = (mid >> np.uint64(29)) + ((mid & np.uint64((1 << 29) - 1)) << np.uint64(32))
    lo = _mod_mersenne61(al * xl)
    return _mod_mersenne61(_mod_mersenne61(hi) + _mod_mersenne61(mid) + lo)


class HashFamily:
    """Shared 2-universal hash functions h_key(v) ∈ [0, range). Deterministic in
    (seed, key): every machine evaluates identical functions without communication.

    Evaluation is exact modular arithmetic under the Mersenne prime 2^61 - 1,
    vectorized in uint64 (no Python-int loop); tests/test_program_ir.py
    cross-checks it against the scalar big-int reference."""

    def __init__(self, seed: int):
        self.seed = seed

    def _coeffs(self, key: Hashable) -> Tuple[int, int]:
        h = hashlib.blake2b(repr((self.seed, key)).encode(), digest_size=16).digest()
        a = int.from_bytes(h[:8], "little") % (_PRIME - 1) + 1
        b = int.from_bytes(h[8:], "little") % _PRIME
        return a, b

    def hash(self, key: Hashable, values: np.ndarray, mod: int) -> np.ndarray:
        a, b = self._coeffs(key)
        values = np.asarray(values, dtype=np.int64)
        uniq, inv = np.unique(values, return_inverse=True)
        x = np.mod(uniq, _PRIME).astype(np.uint64)           # Python-mod semantics on negatives
        hashed = _mod_mersenne61(_mulmod_mersenne61(a, x) + np.uint64(b))
        hashed = (hashed % np.uint64(mod)).astype(np.int64)
        return hashed[inv].reshape(values.shape)


@dataclass
class RoundLoad:
    name: str
    received_words: np.ndarray  # (p,) words received per machine this round

    @property
    def load(self) -> int:
        return int(self.received_words.max()) if self.received_words.size else 0


class MPCSimulator:
    """p machines, tag-indexed stores, exact received-word metering."""

    def __init__(self, p: int, seed: int = 0):
        self.p = p
        self.hashes = HashFamily(seed)
        self.stores: List[Dict[Tag, List[np.ndarray]]] = [defaultdict(list) for _ in range(p)]
        self.rounds: List[RoundLoad] = []
        self._outbox: Optional[List[Tuple[int, Tag, np.ndarray]]] = None

    # -- round protocol ------------------------------------------------------

    def begin_round(self, name: str) -> None:
        if self._outbox is not None:
            raise RuntimeError("previous round not closed")
        self._round_name = name
        self._outbox = []

    def send(self, dst: int, tag: Tag, rows: np.ndarray) -> None:
        """Queue a message (delivered at end_round). rows: (n,) or (n, a) int64."""
        if self._outbox is None:
            raise RuntimeError("send outside a round")
        rows = np.asarray(rows, dtype=np.int64)
        if rows.size == 0:
            return
        if rows.ndim == 1:
            rows = rows.reshape(-1, 1)
        self._outbox.append((int(dst) % self.p, tag, rows))

    def broadcast(self, tag: Tag, rows: np.ndarray) -> None:
        for dst in range(self.p):
            self.send(dst, tag, rows)

    def end_round(self) -> RoundLoad:
        assert self._outbox is not None
        words = np.zeros(self.p, dtype=np.int64)
        for dst, tag, rows in self._outbox:
            words[dst] += rows.size
            self.stores[dst][tag].append(rows)
        rl = RoundLoad(name=self._round_name, received_words=words)
        self.rounds.append(rl)
        self._outbox = None
        return rl

    # -- store access --------------------------------------------------------

    def local(self, mid: int, tag: Tag, arity: int = 2) -> np.ndarray:
        parts = self.stores[mid].get(tag)
        if not parts:
            return np.zeros((0, arity), dtype=np.int64)
        return np.concatenate(parts, axis=0)

    def machines_with(self, tag: Tag) -> List[int]:
        return [i for i in range(self.p) if self.stores[i].get(tag)]

    def clear_tag(self, tag: Tag) -> None:
        for s in self.stores:
            s.pop(tag, None)

    # -- metrics ---------------------------------------------------------------

    @property
    def total_load(self) -> int:
        """Paper Sec 1.1: total load = Σ per-round loads (constant #rounds ⇒ same as max
        up to constants; we report the sum, the stricter number)."""
        return sum(r.load for r in self.rounds)

    @property
    def max_round_load(self) -> int:
        return max((r.load for r in self.rounds), default=0)

    def load_report(self) -> List[Tuple[str, int]]:
        return [(r.name, r.load) for r in self.rounds]

    def merged_round_loads(self) -> Dict[str, int]:
        """Rounds that share a name are 'the same logical round' executed for different
        H-subsets/configurations in parallel (paper Sec. 6: processing all H in parallel
        costs a constant factor). Their receive-words add per machine."""
        acc: Dict[str, np.ndarray] = {}
        for r in self.rounds:
            if r.name in acc:
                acc[r.name] = acc[r.name] + r.received_words
            else:
                acc[r.name] = r.received_words.copy()
        return {k: int(v.max()) for k, v in acc.items()}

    @property
    def parallel_total_load(self) -> int:
        """Total load when same-named rounds run in parallel (the paper's execution)."""
        return sum(self.merged_round_loads().values())


def scatter_input(
    sim: MPCSimulator, tag: Tag, data: np.ndarray, seed: int = 1
) -> None:
    """Distribute input tuples evenly across machines (paper: input starts evenly
    spread, Θ(m/p) per machine). Deterministic round-robin after a seeded shuffle;
    costs no load (initial placement)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(data.shape[0])
    data = data[perm]
    for mid in range(sim.p):
        part = data[mid :: sim.p]
        if part.size:
            sim.stores[mid][tag].append(part.astype(np.int64))
