"""The MPC join algorithm of Theorem 6.2, end to end, with exact load metering.

Round structure (constant, independent of the query — paper Sec. 6; all H ⊆ attset(Q)
and all configurations η are processed inside the *same* physical rounds):

  stats-candidates / stats-counts / stats-extended   (preprocessing histogram)
  step1          route residual tuples of every Q'(η) to its p'_η-machine group
  step2-unary    hash-partition unary residuals; intersect → R''_X(η)
  step2-bx       semi-join light edges on X
  step2-by       semi-join light edges on Y            → R''_e(η)
  step3-sizes    broadcast |R''_X(η)| pieces (the paper's O(p²) statistics round)
  step3-route    Lemma 3.1 grid (isolated CP) + Lemma 3.3 HyperCube (light subquery),
                 composed via the Lemma 3.2 matrix; one round
  (output)       local joins; every result tuple materializes on exactly one machine

Engine-level choices the paper leaves open (documented in DESIGN.md §6):
  * virtual machine groups are hashed onto physical machines;
  * configurations whose residual input is empty on an *active* edge are skipped early
    (their join is empty);
  * inactive-edge (heavy-heavy) feasibility is checked against the extended histogram
    that every machine holds, so ruled-out η cost no communication.
"""

from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.hypergraph import fractional_edge_cover
from ..core.planner import (
    ConfigPlan,
    MachineGroup,
    _stable_base,
    grid_dims,
    heavy_parameter,
    step1_allocation,
    step3_allocation,
)
from ..core.query import Attr, JoinQuery, Relation, reference_join
from ..core.taxonomy import (
    Configuration,
    HPlan,
    HeavyStats,
    configurations,
    plan_for_h,
    residual_size,
)
from .cartesian import CartesianGrid, route_cartesian
from .hypercube import HyperCubeGrid, route_hypercube
from .simulator import MPCSimulator, scatter_input
from .statistics import distributed_stats


@dataclass
class MPCJoinResult:
    p: int
    lam: int
    rho: float
    m: int
    count: int
    rows: Optional[np.ndarray]          # over sorted(attset), if materialized
    sim: MPCSimulator
    per_h_counts: Dict[Tuple[Attr, ...], int]

    @property
    def bound(self) -> float:
        """The claimed load bound m / p^{1/ρ} (polylog factors not included)."""
        return self.m / (self.p ** (1.0 / self.rho))

    @property
    def load(self) -> int:
        return self.sim.parallel_total_load

    @property
    def load_ratio(self) -> float:
        return self.load / max(1.0, self.bound)


def _send_grouped(sim: MPCSimulator, phys: np.ndarray, tag, rows: np.ndarray) -> None:
    """Group rows by destination and send one message per destination."""
    if rows.ndim == 1:
        rows = rows.reshape(-1, 1)
    if rows.shape[0] == 0:
        return
    order = np.argsort(phys, kind="stable")
    ps, rs = phys[order], rows[order]
    uniq = np.unique(ps)
    bounds = np.append(np.searchsorted(ps, uniq), ps.shape[0])
    for i, dst in enumerate(uniq.tolist()):
        sim.send(int(dst), tag, rs[bounds[i] : bounds[i + 1]])


@dataclass
class _CfgState:
    plan: HPlan
    cfg: ConfigPlan
    hkey: Tuple[Attr, ...]
    ekey: Tuple[int, ...]
    iso_order: List[Attr] = field(default_factory=list)   # isolated attrs by size desc
    iso_sizes: Dict[Attr, int] = field(default_factory=dict)
    offsets: Dict[Tuple[Attr, int], int] = field(default_factory=dict)  # (X, mid) -> id offset
    grid: Optional[CartesianGrid] = None
    hc_grid: Optional[HyperCubeGrid] = None
    skip: bool = False


def mpc_join(
    query: JoinQuery,
    p: int,
    seed: int = 0,
    lam: Optional[int] = None,
    materialize: bool = True,
    h_subsets: Optional[Sequence[Sequence[Attr]]] = None,
    fuse_semijoin: bool = False,
) -> MPCJoinResult:
    """Run the full Theorem 6.2 algorithm on p simulated machines.

    ``h_subsets`` restricts the taxonomy to specific H sets (testing); default = all.
    ``fuse_semijoin`` enables the beyond-paper round fusion (see EXPERIMENTS §Perf):
    step2-bx/step2-by are fused into one round by routing each light tuple to its
    Y-partition with an X-membership *bitmap request* piggybacked — implemented as
    routing by Y while filtering on X at the sender using the sender-local R''_X
    replica obtained in step2-unary (valid because the X-partition of the sender in
    step2-bx is exactly where the tuple sits after step2-unary routing).
    """
    g = query.hypergraph
    rho_val = float(fractional_edge_cover(g)[0])
    attset = query.attset
    k = len(attset)
    if lam is None:
        lam = heavy_parameter(p, rho_val)

    sim = MPCSimulator(p, seed=seed)
    for rel in query.relations:
        scatter_input(sim, ("in", rel.edge), rel.data, seed=seed + 17)

    stats = distributed_stats(sim, query, lam)

    if h_subsets is None:
        import itertools as _it

        h_subsets = [
            h for r in range(k + 1) for h in _it.combinations(attset, r)
        ]

    # ---- planning (host-side metadata; every machine could derive it identically
    # from the shared histogram — zero communication, paper Sec. 6) ------------------
    plans: List[Tuple[HPlan, List[ConfigPlan]]] = []
    emit_only: List[Tuple[HPlan, Configuration]] = []
    for h in h_subsets:
        plan = plan_for_h(query, h)
        cfg_sizes = []
        for eta in configurations(stats, plan.h_set):
            # inactive-edge feasibility from the shared histogram
            feasible = True
            for e in plan.heavy_edges:
                rel = query.relation_for(e)
                x_attr, y_attr = rel.scheme
                if stats.pair.get((e, eta.value(x_attr), eta.value(y_attr)), 0) == 0:
                    feasible = False
                    break
            if not feasible:
                continue
            if len(plan.h_set) == k:
                emit_only.append((plan, eta))
                continue
            m_eta = residual_size(query, stats, plan, eta)
            if m_eta == 0 and (plan.light_edges or plan.cross_edges):
                # some active edge exists; zero residual input ⇒ empty join.
                # (unless ALL active edges are... m_eta==0 means all residuals empty)
                continue
            cfg_sizes.append((eta, m_eta))
        cfgs = step1_allocation(query, stats, plan, cfg_sizes, p)
        if cfgs:
            plans.append((plan, cfgs))

    # H = attset(Q): every edge inactive; η itself is the result tuple (no comm).
    out_cols = list(attset)
    outputs: Dict[int, List[np.ndarray]] = defaultdict(list)
    counts_per_h: Dict[Tuple[Attr, ...], int] = defaultdict(int)
    for plan, eta in emit_only:
        mid = _stable_base(p, "emit", plan.h_set, eta.values)
        row = np.array(
            [[eta.value(a) for a in out_cols]], dtype=np.int64
        )
        outputs[mid].append(row)
        counts_per_h[plan.h_set] += 1

    states: List[_CfgState] = [
        _CfgState(
            plan=plan,
            cfg=cfg,
            hkey=plan.h_set,
            ekey=cfg.eta.values,
        )
        for plan, cfgs in plans
        for cfg in cfgs
    ]

    # ---- step 1: route residual tuples --------------------------------------------
    sim.begin_round("step1")
    for mid in range(sim.p):
        mrng = np.random.default_rng(seed * 1_000_003 + mid)
        local_cache: Dict = {}
        for rel in query.relations:
            local = sim.local(mid, ("in", rel.edge))
            if local.shape[0] == 0:
                continue
            x_attr, y_attr = rel.scheme
            hx = stats.is_heavy(x_attr, local[:, 0])
            hy = stats.is_heavy(y_attr, local[:, 1])
            local_cache[rel.edge] = (local, hx, hy)
        for st in states:
            plan, cfg = st.plan, st.cfg
            h = set(plan.h_set)
            grp = cfg.step1_group
            for rel in query.relations:
                if rel.edge not in local_cache:
                    continue
                local, hx, hy = local_cache[rel.edge]
                x_attr, y_attr = rel.scheme
                inter = rel.edge & h
                if len(inter) == 2:
                    continue
                if len(inter) == 0:
                    sel = ~hx & ~hy
                    rows = local[sel]
                else:
                    (heavy_attr,) = inter
                    if heavy_attr == x_attr:
                        sel = (local[:, 0] == cfg.eta.value(x_attr)) & ~hy
                        rows = local[sel][:, 1:2]   # project to light attr
                    else:
                        sel = (local[:, 1] == cfg.eta.value(y_attr)) & ~hx
                        rows = local[sel][:, 0:1]
                if rows.shape[0] == 0:
                    continue
                virt = mrng.integers(0, grp.size, size=rows.shape[0])
                phys = (grp.base + virt) % p
                _send_grouped(sim, phys, ("r1", st.hkey, st.ekey, rel.edge), rows)
    sim.end_round()

    # ---- step 2a: unary partition + intersection -----------------------------------
    sim.begin_round("step2-unary")
    for st in states:
        plan, cfg = st.plan, st.cfg
        grp = cfg.step1_group
        for e in plan.cross_edges:
            rel = query.relation_for(e)
            light_attr = next(iter(e - set(plan.h_set)))
            tag_in = ("r1", st.hkey, st.ekey, e)
            for mid in sim.machines_with(tag_in):
                rows = sim.local(mid, tag_in, arity=1)
                virt = sim.hashes.hash((st.hkey, st.ekey, "sj", light_attr), rows[:, 0], grp.size)
                phys = (grp.base + virt) % p
                _send_grouped(sim, phys, ("u", st.hkey, st.ekey, light_attr, e), rows)
    sim.end_round()

    # local intersection → R''_X pieces (no communication)
    cross_by_attr: Dict[Tuple[Tuple[Attr, ...], Attr], List] = defaultdict(list)
    for st in states:
        for e in st.plan.cross_edges:
            light_attr = next(iter(e - set(st.plan.h_set)))
            cross_by_attr[(st.hkey, light_attr)].append(e)
    for st in states:
        plan = st.plan
        for x in plan.border:
            es = [e for e in plan.cross_edges if x in e]
            for mid in range(sim.p):
                pieces = []
                ok = True
                for e in es:
                    vals = sim.local(mid, ("u", st.hkey, st.ekey, x, e), arity=1)
                    if vals.shape[0] == 0:
                        ok = False
                        break
                    pieces.append(np.unique(vals[:, 0]))
                if not ok:
                    continue
                inter = pieces[0]
                for arr in pieces[1:]:
                    inter = np.intersect1d(inter, arr, assume_unique=True)
                if inter.size:
                    sim.stores[mid][("ux", st.hkey, st.ekey, x)] = [inter.reshape(-1, 1)]

    # ---- step 2b/2c: semi-join light edges ------------------------------------------
    def _filter_by_membership(mid, rows, col, attr, st):
        """Keep rows whose rows[:, col] is in the machine-local R''_attr piece."""
        piece = sim.local(mid, ("ux", st.hkey, st.ekey, attr), arity=1)[:, 0]
        if piece.size == 0:
            return rows[:0]
        return rows[np.isin(rows[:, col], piece)]

    if not fuse_semijoin:
        sim.begin_round("step2-bx")
        for st in states:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr = rel.scheme[0]
                tag_in = ("r1", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    virt = sim.hashes.hash((st.hkey, st.ekey, "sj", x_attr), rows[:, 0], grp.size)
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("bx", st.hkey, st.ekey, e), rows)
        sim.end_round()

        sim.begin_round("step2-by")
        for st in states:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr, y_attr = rel.scheme
                tag_in = ("bx", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    if x_attr in st.plan.border:
                        rows = _filter_by_membership(mid, rows, 0, x_attr, st)
                    if rows.shape[0] == 0:
                        continue
                    virt = sim.hashes.hash((st.hkey, st.ekey, "sj", y_attr), rows[:, 1], grp.size)
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("rr", st.hkey, st.ekey, e), rows)
        sim.end_round()
    else:
        # Beyond-paper fusion: route directly to the Y partition; X-filtering happens
        # at the Y-side against a replicated X piece fetched in the same round (the
        # bitmap exchange below), saving one full data round. See EXPERIMENTS §Perf.
        sim.begin_round("step2-fused")
        for st in states:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr, y_attr = rel.scheme
                tag_in = ("r1", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    # membership of X values must be resolved; ask the X-partition by
                    # sending (x, y) keyed by X — identical cost to step2-bx, but the
                    # Y-routing is *piggybacked*: the X-partition machine forwards in
                    # the same round using its local piece (allowed: the forward is a
                    # function of data it already has + the arriving message only in
                    # the NEXT round; hence this fusion trades one round for routing
                    # via hash(X) then local re-route — net: 1 round saved when X is
                    # not a border attribute, else falls back).
                    if x_attr not in st.plan.border:
                        virt = sim.hashes.hash((st.hkey, st.ekey, "sj", y_attr), rows[:, 1], grp.size)
                        phys = (grp.base + virt) % p
                        _send_grouped(sim, phys, ("rr", st.hkey, st.ekey, e), rows)
                    else:
                        virt = sim.hashes.hash((st.hkey, st.ekey, "sj", x_attr), rows[:, 0], grp.size)
                        phys = (grp.base + virt) % p
                        _send_grouped(sim, phys, ("bx", st.hkey, st.ekey, e), rows)
        sim.end_round()
        sim.begin_round("step2-by")
        for st in states:
            grp = st.cfg.step1_group
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                x_attr, y_attr = rel.scheme
                if x_attr not in st.plan.border:
                    continue
                tag_in = ("bx", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag_in):
                    rows = sim.local(mid, tag_in, arity=2)
                    rows = _filter_by_membership(mid, rows, 0, x_attr, st)
                    if rows.shape[0] == 0:
                        continue
                    virt = sim.hashes.hash((st.hkey, st.ekey, "sj", y_attr), rows[:, 1], grp.size)
                    phys = (grp.base + virt) % p
                    _send_grouped(sim, phys, ("rr", st.hkey, st.ekey, e), rows)
        sim.end_round()

    # Y-side filtering is local (the piece lives where the hash sent the row).
    for st in states:
        for e in st.plan.light_edges:
            rel = query.relation_for(e)
            y_attr = rel.scheme[1]
            if y_attr not in st.plan.border:
                continue
            tag = ("rr", st.hkey, st.ekey, e)
            for mid in sim.machines_with(tag):
                rows = sim.local(mid, tag, arity=2)
                rows = _filter_by_membership(mid, rows, 1, y_attr, st)
                sim.stores[mid][tag] = [rows]

    # ---- step 3 sizes: broadcast |R''_X| pieces (paper's O(p²) stats round) ---------
    sim.begin_round("step3-sizes")
    cfg_index = {(st.hkey, st.ekey): i for i, st in enumerate(states)}
    attr_index = {a: i for i, a in enumerate(attset)}
    for st in states:
        for x in st.plan.isolated:
            tag = ("ux", st.hkey, st.ekey, x)
            for mid in sim.machines_with(tag):
                cnt = sim.local(mid, tag, arity=1).shape[0]
                msg = np.array(
                    [[cfg_index[(st.hkey, st.ekey)], attr_index[x], mid, cnt]],
                    dtype=np.int64,
                )
                sim.broadcast(("sz",), msg)
    sim.end_round()

    size_rows = sim.local(0, ("sz",), arity=4) if sim.machines_with(("sz",)) else np.zeros((0, 4), np.int64)
    piece_sizes: Dict[Tuple[int, int], List[Tuple[int, int]]] = defaultdict(list)
    for ci, ai, mid, cnt in size_rows.tolist():
        piece_sizes[(ci, ai)].append((mid, cnt))

    for i, st in enumerate(states):
        iso_sizes = {}
        for x in st.plan.isolated:
            entries = sorted(piece_sizes.get((i, attr_index[x]), []))
            total = sum(c for _, c in entries)
            iso_sizes[x] = total
            off = 0
            for mid, c in entries:
                st.offsets[(x, mid)] = off
                off += c
        st.iso_sizes = iso_sizes
        if any(v == 0 for v in iso_sizes.values()):
            st.skip = True
            continue
        step3_allocation(query, stats, st.plan, st.cfg, iso_sizes, p, rho_val)
        st.iso_order = sorted(st.plan.isolated, key=lambda a: -iso_sizes[a])
        if st.iso_order:
            st.grid = CartesianGrid([iso_sizes[a] for a in st.iso_order], st.cfg.cp_machines)
        l_minus_i = [a for a in st.plan.light if a not in st.plan.isolated]
        if l_minus_i:
            st.hc_grid = HyperCubeGrid(l_minus_i, {a: stats.lam for a in l_minus_i})

    # ---- step 3 route: Lemma 3.1 grid × Lemma 3.3 HyperCube (Lemma 3.2 matrix) ------
    sim.begin_round("step3-route")
    for st in states:
        if st.skip:
            continue
        grp = st.cfg.step3_group
        hc_size = st.hc_grid.size if st.hc_grid else 1
        cp_size = st.grid.size if st.grid else 1

        # CP side: every grid cell is instantiated in every HC column.
        if st.grid:
            for li, x in enumerate(st.iso_order):
                tag = ("ux", st.hkey, st.ekey, x)
                for mid in sim.machines_with(tag):
                    vals = sim.local(mid, tag, arity=1)
                    ids = st.offsets[(x, mid)] + np.arange(vals.shape[0], dtype=np.int64)
                    if li < st.grid.t_prime:
                        cells = st.grid.cells_for_ids(li, ids)
                        for combo in range(cells.shape[1]):
                            flat = cells[:, combo]
                            for cell in np.unique(flat).tolist():
                                rows = vals[flat == cell]
                                for h_cell in range(hc_size):
                                    v = cell * hc_size + h_cell
                                    sim.send(grp.phys(v), ("cp", st.hkey, st.ekey, v, x), rows)
                    else:
                        for cell in range(cp_size):
                            for h_cell in range(hc_size):
                                v = cell * hc_size + h_cell
                                sim.send(grp.phys(v), ("cp", st.hkey, st.ekey, v, x), vals)

        # HC side: every HC cell instantiated in every CP row.
        if st.hc_grid:
            for e in st.plan.light_edges:
                rel = query.relation_for(e)
                tag = ("rr", st.hkey, st.ekey, e)
                for mid in sim.machines_with(tag):
                    rows = sim.local(mid, tag, arity=2)

                    def deliver(h_cell, out_tag, rs, _grp=grp, _hc=hc_size, _cp=cp_size, _st=st):
                        for c in range(_cp):
                            v = c * _hc + h_cell
                            sim.send(_grp.phys(v), ("hc", _st.hkey, _st.ekey, v, out_tag), rs)

                    route_hypercube(
                        sim,
                        st.hc_grid,
                        [(rel.scheme, e, rows)],
                        salt=(st.hkey, st.ekey, "hc"),
                        deliver=deliver,
                    )
    sim.end_round()

    # ---- output: local joins, exactly-once ------------------------------------------
    total_count = 0
    for st in states:
        if st.skip:
            continue
        plan = st.plan
        grp = st.cfg.step3_group
        hc_size = st.hc_grid.size if st.hc_grid else 1
        cp_size = st.grid.size if st.grid else 1
        l_minus_i = [a for a in plan.light if a not in plan.isolated]
        h_count = 0
        for v in range(grp.size):
            mid = grp.phys(v)
            # light side
            if plan.light_edges:
                frags = []
                ok = True
                for e in plan.light_edges:
                    rel = query.relation_for(e)
                    rows = sim.local(mid, ("hc", st.hkey, st.ekey, v, e), arity=2)
                    if rows.shape[0] == 0:
                        ok = False
                        break
                    frags.append(Relation.make(rel.scheme, rows))
                if not ok:
                    continue
                light_join = reference_join(JoinQuery.make(frags))
                light_rows = light_join.data  # over sorted(l_minus_i)
                if light_rows.shape[0] == 0:
                    continue
            else:
                light_rows = np.zeros((1, 0), dtype=np.int64)

            # CP side
            cp_lists = []
            ok = True
            for x in st.iso_order:
                vals = sim.local(mid, ("cp", st.hkey, st.ekey, v, x), arity=1)
                vals = np.unique(vals[:, 0])
                if vals.size == 0:
                    ok = False
                    break
                cp_lists.append(vals)
            if not ok:
                continue

            n_cp = math.prod(arr.size for arr in cp_lists) if cp_lists else 1
            n_here = light_rows.shape[0] * n_cp
            h_count += n_here
            if materialize and n_here:
                rows = light_rows
                cols = sorted(l_minus_i)
                for x, vals in zip(st.iso_order, cp_lists):
                    nn = rows.shape[0]
                    rows = np.repeat(rows, vals.size, axis=0)
                    rows = np.concatenate(
                        [rows, np.tile(vals, nn).reshape(-1, 1)], axis=1
                    )
                    cols.append(x)
                for a in plan.h_set:
                    rows = np.concatenate(
                        [rows, np.full((rows.shape[0], 1), st.cfg.eta.value(a), np.int64)],
                        axis=1,
                    )
                    cols.append(a)
                perm = [cols.index(a) for a in out_cols]
                outputs[mid].append(rows[:, perm])
        counts_per_h[st.hkey] += h_count

    rows_out = None
    if materialize:
        chunks = [r for parts in outputs.values() for r in parts]
        rows_out = (
            np.concatenate(chunks, axis=0)
            if chunks
            else np.zeros((0, len(out_cols)), dtype=np.int64)
        )

    total_count = sum(counts_per_h.values())

    return MPCJoinResult(
        p=p,
        lam=stats.lam,
        rho=rho_val,
        m=stats.m,
        count=total_count,
        rows=rows_out,
        sim=sim,
        per_h_counts=dict(counts_per_h),
    )
