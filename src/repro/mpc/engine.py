"""The MPC join algorithm of Theorem 6.2: compile to the round-program IR,
then execute on the exact-cost simulator.

The round structure (constant, independent of the query — paper Sec. 6; all
H ⊆ attset(Q) and all configurations η are processed inside the *same*
physical rounds) now lives in two places:

  * ``repro.mpc.program``   — what the rounds are and who routes what
                              (``compile_plan`` → :class:`RoundProgram`);
  * ``repro.mpc.executors`` — who executes them (:class:`SimulatorExecutor`
                              for exact load metering, :class:`DataplaneExecutor`
                              for the JAX device mesh).

``mpc_join`` is the historical entry point and is now a thin wrapper:
scatter inputs, run the 3-round statistics protocol, compile, execute.
Engine-level choices the paper leaves open are documented in docs/DESIGN.md §6.
"""

from __future__ import annotations

from typing import Optional, Sequence

from ..core.hypergraph import fractional_edge_cover
from ..core.planner import heavy_parameter
from ..core.query import Attr, JoinQuery
from ..core.taxonomy import HeavyStats
from .executors import MPCJoinResult, SimulatorExecutor
from .program import compile_plan
from .simulator import MPCSimulator
from .statistics import distributed_stats


def mpc_join(
    query: JoinQuery,
    p: int,
    seed: int = 0,
    lam: Optional[int] = None,
    materialize: bool = True,
    h_subsets: Optional[Sequence[Sequence[Attr]]] = None,
    fuse_semijoin: bool = False,
    stats: Optional[HeavyStats] = None,
) -> MPCJoinResult:
    """Run the full Theorem 6.2 algorithm on p simulated machines.

    ``h_subsets`` restricts the taxonomy to specific H sets (testing); default = all.
    ``fuse_semijoin`` enables the beyond-paper round fusion (a program-rewrite
    pass; see :func:`repro.mpc.program.fuse_semijoin_pass` and EXPERIMENTS §Perf).
    ``stats`` optionally injects a precomputed histogram (e.g. the centralized
    ``compute_stats`` oracle, or one shared across repeated runs); by default
    the 3 metered rounds of the distributed protocol produce it.  Relations
    sharing a physical ``Relation.table`` are placed once by the shared-input
    Scatter path (self-join-shaped queries such as the subgraph reduction).
    """
    rho_val = float(fractional_edge_cover(query.hypergraph)[0])
    if lam is None:
        lam = heavy_parameter(p, rho_val) if stats is None else stats.lam

    sim = MPCSimulator(p, seed=seed)
    executor = SimulatorExecutor(sim, seed=seed)
    executor.place_inputs(query)                      # Scatter semantics
    if stats is None:
        stats = distributed_stats(sim, query, lam)    # 3 metered histogram rounds
    program = compile_plan(
        query, stats, p, h_subsets=h_subsets, fuse_semijoin=fuse_semijoin
    )
    return executor.run(program, materialize=materialize)
