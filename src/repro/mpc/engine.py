"""The MPC join algorithm of Theorem 6.2: compile to the round-program IR,
then execute on the exact-cost simulator.

The round structure (constant, independent of the query — paper Sec. 6; all
H ⊆ attset(Q) and all configurations η are processed inside the *same*
physical rounds) now lives in two places:

  * ``repro.mpc.program``   — what the rounds are and who routes what
                              (``compile_plan`` → :class:`RoundProgram`);
  * ``repro.mpc.executors`` — who executes them (:class:`SimulatorExecutor`
                              for exact load metering, :class:`DataplaneExecutor`
                              for the JAX device mesh).

``mpc_join`` is the historical entry point and is now a one-shot
:class:`~repro.mpc.service.JoinSession`: scatter inputs, run the 3-round
statistics protocol, compile, execute, discard the session.  Long-lived
callers should hold a ``JoinSession`` instead — it caches compiled plans and
executor state across queries (docs/design/09-service.md).  Engine-level
choices the paper leaves open are documented in docs/design/06-engine-choices.md.
"""

from __future__ import annotations

from typing import Optional, Sequence

from ..core.query import Attr, JoinQuery
from ..core.taxonomy import HeavyStats
from .executors import MPCJoinResult
from .service import JoinSession


def mpc_join(
    query: JoinQuery,
    p: int,
    seed: int = 0,
    lam: Optional[int] = None,
    materialize: bool = True,
    h_subsets: Optional[Sequence[Sequence[Attr]]] = None,
    fuse_semijoin: bool = False,
    stats: Optional[HeavyStats] = None,
) -> MPCJoinResult:
    """Run the full Theorem 6.2 algorithm once on p simulated machines.

    Args:
        query: the join query (concrete relations attached).
        p: number of simulated MPC machines.
        seed: shared-randomness seed (scatter + routing hash family).
        lam: heavy parameter λ; default Θ(p^{1/(2ρ)}) per the paper.
        materialize: materialize result rows (False: counts/load only).
        h_subsets: restrict the taxonomy to specific H sets (testing);
            default = all subsets of attset(Q).
        fuse_semijoin: enable the beyond-paper round fusion (a program-rewrite
            pass; see :func:`repro.mpc.program.fuse_semijoin_pass`).
        stats: inject a precomputed histogram (e.g. the centralized
            ``compute_stats`` oracle, or one shared across repeated runs); by
            default the 3 metered rounds of the distributed protocol produce
            it.  Relations sharing a physical ``Relation.table`` are placed
            once by the shared-input Scatter path.

    Returns:
        An :class:`~repro.mpc.executors.MPCJoinResult` with the exact join
        count, per-H counts, materialized rows, and the metered simulator
        (``result.load`` vs ``result.bound`` is the paper's claim).

    This is the *one-shot* path: every artifact (plan, simulator ledger) is
    per-call.  Repeated workloads should use
    :class:`~repro.mpc.service.JoinSession`, which produces row-identical
    results while caching plans across calls.
    """
    session = JoinSession(p=p, backend="simulator", seed=seed)
    return session.submit(
        query,
        lam=lam,
        stats=stats,
        materialize=materialize,
        h_subsets=h_subsets,
        fuse_semijoin=fuse_semijoin,
    ).result
