"""Distributed heavy-value statistics (the paper's 'histogram', Sec. 6 preprocessing).

Three metered rounds (see DESIGN.md §6 for the deviation note):

  1. ``stats-candidates``: machine i broadcasts, per (relation R, attribute X), every
     value with local count ≥ L_{i,R}/λ (weighted pigeonhole: any globally heavy value
     is a candidate on ≥1 machine), plus its local |R| counts. ≤ λ candidates per
     (machine, R, X) ⇒ round load O(p·λ).
  2. ``stats-counts``: every machine broadcasts its local count for every candidate;
     all machines now agree on exact global counts ⇒ exact heavy sets. Load O(p·λ).
  3. ``stats-extended``: heavy-conditioned counts needed to compute m_η exactly:
     cond(e, X, x)=|{u∈R_e : u(X)=x heavy, other light}|, pair(e, x, y) for heavy-heavy
     pairs, light_cnt(e). Load O(p·λ²).

All ≤ O(p·λ²+p) received words per machine — dominated by m/p^{1/ρ} when m ≥ p³
(the paper's own O(p²) Step-3 statistic round is bigger). The output HeavyStats is
identical on every machine by construction; we return one copy and tests assert it
matches the centralized ``compute_stats`` oracle.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Tuple

import numpy as np

from ..core.query import JoinQuery
from ..core.taxonomy import HeavyStats
from .simulator import MPCSimulator


def distributed_stats(sim: MPCSimulator, query: JoinQuery, lam: int) -> HeavyStats:
    edges = [rel.edge for rel in query.relations]
    eidx = {e: i for i, e in enumerate(edges)}
    schemes = {rel.edge: rel.scheme for rel in query.relations}

    # ---- round 1: candidates + local sizes ---------------------------------
    sim.begin_round("stats-candidates")
    for mid in range(sim.p):
        size_rows = []
        cand_rows = []
        for rel in query.relations:
            local = sim.local(mid, ("in", rel.edge), arity=rel.arity)
            size_rows.append([eidx[rel.edge], local.shape[0]])
            n_local = local.shape[0]
            if n_local == 0:
                continue
            thr = max(1, int(np.ceil(n_local / lam)))
            for col, attr in enumerate(rel.scheme):
                vals, cnts = np.unique(local[:, col], return_counts=True)
                cands = vals[cnts >= thr]
                for v in cands.tolist():
                    cand_rows.append([eidx[rel.edge], col, v])
        sim.broadcast(("st-size", mid), np.array(size_rows, dtype=np.int64))
        if cand_rows:
            sim.broadcast(("st-cand", mid), np.array(cand_rows, dtype=np.int64))
    sim.end_round()

    # every machine reconstructs the identical candidate set + global m
    cand_set = set()
    m_global = 0
    for mid in range(sim.p):
        sizes = sim.local(0, ("st-size", mid), arity=2)
        m_global += int(sizes[:, 1].sum())
        rows = sim.local(0, ("st-cand", mid), arity=3)
        for e_i, col, v in rows.tolist():
            cand_set.add((e_i, col, v))
    cand_list = sorted(cand_set)
    cand_pos = {c: i for i, c in enumerate(cand_list)}

    # ---- round 2: exact counts for candidates ------------------------------
    sim.begin_round("stats-counts")
    for mid in range(sim.p):
        rows = []
        for rel in query.relations:
            local = sim.local(mid, ("in", rel.edge), arity=rel.arity)
            if local.shape[0] == 0:
                continue
            for col in range(rel.arity):
                vals, cnts = np.unique(local[:, col], return_counts=True)
                for v, c in zip(vals.tolist(), cnts.tolist()):
                    key = (eidx[rel.edge], col, v)
                    if key in cand_pos:
                        rows.append([cand_pos[key], c])
        if rows:
            sim.broadcast(("st-cnt", mid), np.array(rows, dtype=np.int64))
    sim.end_round()

    global_cnt = np.zeros(len(cand_list), dtype=np.int64)
    for mid in range(sim.p):
        rows = sim.local(0, ("st-cnt", mid), arity=2)
        for pos, c in rows.tolist():
            global_cnt[pos] += c

    threshold = max(1, -(-m_global // lam))  # ceil(m/λ)
    heavy_sets: Dict[str, set] = defaultdict(set)
    for (e_i, col, v), cnt in zip(cand_list, global_cnt.tolist()):
        if cnt >= threshold:
            attr = schemes[edges[e_i]][col]
            heavy_sets[attr].add(v)
    heavy = {a: np.array(sorted(s), dtype=np.int64) for a, s in heavy_sets.items()}

    stats = HeavyStats(
        lam=lam, m=m_global, heavy=heavy, cond={}, pair={}, light_cnt={}
    )

    # ---- round 3: extended (heavy-conditioned) records ---------------------
    sim.begin_round("stats-extended")
    for mid in range(sim.p):
        cond_rows, pair_rows, light_rows = [], [], []
        for rel in query.relations:
            local = sim.local(mid, ("in", rel.edge), arity=rel.arity)
            if local.shape[0] == 0:
                continue
            if rel.arity != 2:
                # k-ary edges carry no binary cond/pair records (the general
                # route never reads them) — only the all-light count, exactly
                # mirroring the centralized compute_stats guard.
                heavy_any = np.zeros(local.shape[0], dtype=bool)
                for col, attr in enumerate(rel.scheme):
                    heavy_any |= stats.is_heavy(attr, local[:, col])
                light_rows.append([eidx[rel.edge], int((~heavy_any).sum())])
                continue
            x_attr, y_attr = rel.scheme
            hx = stats.is_heavy(x_attr, local[:, 0])
            hy = stats.is_heavy(y_attr, local[:, 1])
            light_rows.append([eidx[rel.edge], int((~hx & ~hy).sum())])
            for col, (mask_h, mask_other) in enumerate([(hx, hy), (hy, hx)]):
                sel = mask_h & ~mask_other
                vals, cnts = np.unique(local[sel, col], return_counts=True)
                for v, c in zip(vals.tolist(), cnts.tolist()):
                    cond_rows.append([eidx[rel.edge], col, v, c])
            sel = hx & hy
            if sel.any():
                uniq, cnts = np.unique(local[sel], axis=0, return_counts=True)
                for (vx, vy), c in zip(uniq.tolist(), cnts.tolist()):
                    pair_rows.append([eidx[rel.edge], vx, vy, c])
        if cond_rows:
            sim.broadcast(("st-cond", mid), np.array(cond_rows, dtype=np.int64))
        if pair_rows:
            sim.broadcast(("st-pair", mid), np.array(pair_rows, dtype=np.int64))
        sim.broadcast(("st-light", mid), np.array(light_rows, dtype=np.int64))
    sim.end_round()

    light_acc: Dict[int, int] = defaultdict(int)
    for mid in range(sim.p):
        for e_i, col, v, c in sim.local(0, ("st-cond", mid), arity=4).tolist():
            attr = schemes[edges[e_i]][col]
            key = (edges[e_i], attr, v)
            stats.cond[key] = stats.cond.get(key, 0) + c
        for e_i, vx, vy, c in sim.local(0, ("st-pair", mid), arity=4).tolist():
            key = (edges[e_i], vx, vy)
            stats.pair[key] = stats.pair.get(key, 0) + c
        for e_i, c in sim.local(0, ("st-light", mid), arity=2).tolist():
            light_acc[e_i] += c
    for e_i, c in light_acc.items():
        stats.light_cnt[edges[e_i]] = c
    for rel in query.relations:  # edges never seen (all-empty locals)
        stats.light_cnt.setdefault(rel.edge, 0)

    # drop the broadcast working tags from stores (they are metadata, not relation data)
    for mid in range(sim.p):
        for tag in list(sim.stores[mid].keys()):
            if isinstance(tag, tuple) and str(tag[0]).startswith("st-"):
                del sim.stores[mid][tag]
    return stats
