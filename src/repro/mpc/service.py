"""Persistent join service: one long-lived session, many queries, cross-query reuse.

``mpc_join`` answers one query and throws everything away: the planner LPs,
the compiled :class:`~repro.mpc.program.RoundProgram`, the executor's learned
overflow capacities, and every AOT-compiled XLA executable die with the call.
A serving deployment answers the *same shapes* over and over — repeated
pattern queries over a graph, dashboards re-running a join as data refreshes —
and the paper's structure makes that reuse sound: the Theorem 6.2 plan is a
pure function of the query's hypergraph and the histogram, never of the
concrete tuples (``compile_plan`` reads only structure + ``HeavyStats``).

:class:`JoinSession` is the layer that exploits it (docs/design/09-service.md):

  * **Plan cache.**  Compiled programs are kept in an LRU keyed by
    :func:`~repro.mpc.program.plan_cache_key` — query structure (schemes +
    shared-table alias classes) plus the full histogram signature.  A hit
    skips the planner LPs and the taxonomy sweep entirely; the cached program
    is :meth:`~repro.mpc.program.RoundProgram.rebind`-ed onto the submitted
    data.  A shifted histogram changes the key, so stale plans are never
    reused — they age out of the LRU.
  * **Executor persistence.**  One :class:`DataplaneExecutor` lives as long
    as the session: its learned overflow capacities and the process-wide
    :class:`~repro.mpc.executors.ExecutableCache` survive across submits, so
    a warm repeat of any query runs with zero recompiles and zero retries —
    steady-state latency is the pure dispatch cost of the stage-batched
    scheduler.
  * **Batch submission.**  :meth:`JoinSession.submit_batch` shares per-table
    work across queries binding the same physical ``Relation.table``: one
    scatter placement on the simulator, one unique-count pass for the
    histogram on the dataplane (the cross-query extension of the
    shared-input Scatter path).
  * **Observability.**  Every submit returns a :class:`SessionResult` with
    per-phase latency and cache provenance; :attr:`JoinSession.stats`
    accumulates the session-wide :class:`ServiceStats` (hit/miss counts,
    cold-vs-warm latency).

``mpc_join`` remains the one-shot path and is implemented as a throwaway
session (see :mod:`repro.mpc.engine`); session and one-shot results are
row-multiset identical on both backends (``tests/test_service.py``).
"""

from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field, replace
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

from ..core.hypergraph import fractional_edge_cover
from ..core.planner import heavy_parameter
from ..core.query import Attr, JoinQuery
from ..core.taxonomy import HeavyStats, compute_stats
from .executors import (
    DataplaneExecutor,
    DataplaneJoinResult,
    MPCJoinResult,
    SimulatorExecutor,
)
from .program import RoundProgram, compile_plan, plan_cache_key
from .simulator import MPCSimulator
from .statistics import distributed_stats


#: sliding-window size of the ServiceStats latency samples.
LATENCY_WINDOW = 512


@dataclass
class ServiceStats:
    """Session-wide service counters (live object on :attr:`JoinSession.stats`).

    ``plan_hits``/``plan_misses`` meter the plan LRU; ``jit_hits``/
    ``jit_misses``/``retries`` aggregate the dataplane scheduler's per-run
    counters; ``cold_us``/``warm_us`` collect end-to-end submit latencies
    split by plan-cache outcome (cold = the submit compiled a new plan) over
    a sliding window of the last :data:`LATENCY_WINDOW` submits each — a
    bounded store, like every other cache in this layer."""

    submits: int = 0
    plan_hits: int = 0
    plan_misses: int = 0
    plan_evictions: int = 0
    cached_plans: int = 0
    jit_hits: int = 0
    jit_misses: int = 0
    retries: int = 0
    cold_us: Deque[float] = field(default_factory=lambda: deque(maxlen=LATENCY_WINDOW))
    warm_us: Deque[float] = field(default_factory=lambda: deque(maxlen=LATENCY_WINDOW))

    @property
    def mean_cold_us(self) -> float:
        return sum(self.cold_us) / len(self.cold_us) if self.cold_us else 0.0

    @property
    def mean_warm_us(self) -> float:
        return sum(self.warm_us) / len(self.warm_us) if self.warm_us else 0.0


@dataclass
class SessionResult:
    """One submit's answer plus its service provenance.

    ``result`` is the backend result (:class:`MPCJoinResult` on the
    simulator, :class:`DataplaneJoinResult` on the dataplane); the convenience
    properties forward the common fields.  ``plan_cache_hit`` says whether the
    plan LRU served the compiled program; the ``*_us`` fields break the
    submit's wall-clock into statistics / compile / execute phases."""

    result: Union[MPCJoinResult, DataplaneJoinResult]
    plan_key: Tuple
    plan_cache_hit: bool
    stats_us: float
    compile_us: float
    execute_us: float
    total_us: float

    @property
    def count(self) -> int:
        return self.result.count

    @property
    def rows(self):
        return self.result.rows

    @property
    def per_h_counts(self):
        return self.result.per_h_counts

    @property
    def retries(self) -> int:
        return getattr(self.result, "retries", 0)

    @property
    def retry_log(self) -> list:
        return getattr(self.result, "retry_log", [])

    @property
    def jit_cache_misses(self) -> int:
        return getattr(self.result, "jit_cache_misses", 0)


class JoinSession:
    """A persistent join service over one executor: repeated ``submit`` calls
    with cross-query plan/compile reuse.

    Args:
        p: machine count every submitted plan is compiled for (the dataplane
            maps it onto however many devices its mesh has).
        backend: ``"dataplane"`` (default — the long-lived
            :class:`DataplaneExecutor`) or ``"simulator"`` (a fresh metered
            :class:`~repro.mpc.simulator.MPCSimulator` per submit, so each
            query gets its own load ledger; plans are still cached across
            submits).
        executor: optionally inject a configured :class:`DataplaneExecutor`
            (e.g. ``batch_stages=False``); ignored on the simulator backend.
        plan_cache_size: LRU bound on cached compiled programs.
        seed: shared-randomness seed (scatter + routing hashes).
        fuse_semijoin: default fusion flag for submits that don't pass one.

    A repeat submit of a cached query shape is the *warm path*: the plan LRU
    skips ``compile_plan``, and on the dataplane the executor's learned caps
    and executable cache make the run retry-free and recompile-free —
    ``tests/test_service.py`` locks ``jit_cache_misses == 0`` and an empty
    ``retry_log`` on the second submit, including after an LRU
    eviction/readmission cycle (learned caps are executor-lifetime state,
    keyed independently of the plan LRU)."""

    def __init__(
        self,
        p: int,
        backend: str = "dataplane",
        executor: Optional[DataplaneExecutor] = None,
        plan_cache_size: int = 64,
        seed: int = 0,
        fuse_semijoin: bool = False,
    ):
        if backend not in ("dataplane", "simulator"):
            raise ValueError(f"unknown backend {backend!r}")
        self.p = p
        self.backend = backend
        self.seed = seed
        self.fuse_semijoin = fuse_semijoin
        self.plan_cache_size = plan_cache_size
        self.executor: Optional[DataplaneExecutor] = None
        if backend == "dataplane":
            self.executor = executor if executor is not None else DataplaneExecutor()
        self._plans: "OrderedDict[Tuple, RoundProgram]" = OrderedDict()
        self.stats = ServiceStats()

    # -- single-query entry ---------------------------------------------------

    def submit(
        self,
        query: JoinQuery,
        lam: Optional[int] = None,
        stats: Optional[HeavyStats] = None,
        materialize: bool = True,
        h_subsets: Optional[Sequence[Sequence[Attr]]] = None,
        fuse_semijoin: Optional[bool] = None,
        _batch: Optional[Dict] = None,
    ) -> SessionResult:
        """Answer one join query, reusing every cached artifact that applies.

        Args:
            query: the join query (concrete relations attached).
            lam: heavy parameter λ; default Θ(p^{1/(2ρ)}) per the paper.
            stats: inject a precomputed histogram; by default the simulator
                backend runs the 3 metered rounds of the distributed protocol
                and the dataplane backend computes the centralized oracle.
            materialize: return result rows (False: counts only).
            h_subsets: restrict the H-taxonomy (testing).
            fuse_semijoin: override the session's default fusion flag.

        Returns:
            A :class:`SessionResult` wrapping the backend result with cache
            provenance and per-phase latency.
        """
        t_start = time.perf_counter()
        fuse = self.fuse_semijoin if fuse_semijoin is None else fuse_semijoin
        if lam is None:
            # only the λ default needs ρ — keep the LP solve off the
            # explicit-λ hot path (steady-state submits must be dispatch-only)
            if stats is not None:
                lam = stats.lam
            else:
                rho_val = float(fractional_edge_cover(query.hypergraph)[0])
                lam = heavy_parameter(self.p, rho_val)
        batch = _batch or {}

        t0 = time.perf_counter()
        if self.backend == "simulator":
            sim = MPCSimulator(self.p, seed=self.seed)
            executor: object = SimulatorExecutor(sim, seed=self.seed)
            executor.place_inputs(query, scatter_cache=batch.get("scatter"))
            if stats is None:
                stats = distributed_stats(sim, query, lam)
        else:
            executor = self.executor
            if stats is None:
                stats = compute_stats(query, lam, unique_memo=batch.get("unique"))
        stats_us = (time.perf_counter() - t0) * 1e6

        key = plan_cache_key(query, stats, self.p, h_subsets, fuse)
        cached = self._plans.get(key)
        compile_us = 0.0
        if cached is not None:
            self._plans.move_to_end(key)
            program = cached.rebind(query)
            self.stats.plan_hits += 1
        else:
            t0 = time.perf_counter()
            program = compile_plan(
                query, stats, self.p, h_subsets=h_subsets, fuse_semijoin=fuse
            )
            compile_us = (time.perf_counter() - t0) * 1e6
            # cache plan metadata only: the concrete relations are rebound on
            # every hit, so pinning the first submitter's tuple data in the
            # LRU would retain up to plan_cache_size tables for no reader
            self._plans[key] = replace(program, query=None)
            self.stats.plan_misses += 1
            while len(self._plans) > self.plan_cache_size:
                self._plans.popitem(last=False)
                self.stats.plan_evictions += 1

        t0 = time.perf_counter()
        res = executor.run(program, materialize=materialize)
        execute_us = (time.perf_counter() - t0) * 1e6
        total_us = (time.perf_counter() - t_start) * 1e6

        self.stats.submits += 1
        self.stats.cached_plans = len(self._plans)
        self.stats.jit_hits += getattr(res, "jit_cache_hits", 0)
        self.stats.jit_misses += getattr(res, "jit_cache_misses", 0)
        self.stats.retries += getattr(res, "retries", 0)
        (self.stats.warm_us if cached is not None else self.stats.cold_us).append(
            total_us
        )
        return SessionResult(
            result=res,
            plan_key=key,
            plan_cache_hit=cached is not None,
            stats_us=stats_us,
            compile_us=compile_us,
            execute_us=execute_us,
            total_us=total_us,
        )

    # -- batch entry ----------------------------------------------------------

    def submit_batch(
        self,
        queries: Sequence[JoinQuery],
        lam: Optional[int] = None,
        materialize: bool = True,
        fuse_semijoin: Optional[bool] = None,
    ) -> List[SessionResult]:
        """Answer a batch of queries, sharing per-table work across the batch.

        Queries binding the same physical ``Relation.table`` share one device
        placement: on the simulator backend the first query's seeded scatter
        shuffle is installed verbatim into every later query's simulator
        (bit-identical to re-scattering — ``scatter_input`` is deterministic);
        on the dataplane backend the histogram's per-(table, column)
        unique-count pass — the sort-dominated part of ``compute_stats`` — is
        computed once per table.  Results are identical to one
        :meth:`submit` per query, in order.

        Returns: one :class:`SessionResult` per query, in submission order.
        """
        batch: Dict = {"scatter": {}, "unique": {}}
        return [
            self.submit(
                q,
                lam=lam,
                materialize=materialize,
                fuse_semijoin=fuse_semijoin,
                _batch=batch,
            )
            for q in queries
        ]

    # -- pattern entry (subgraph enumeration) ---------------------------------

    def submit_pattern(
        self,
        pattern,
        graph,
        lam: Optional[int] = None,
        orientation: str = "degree",
        fuse_semijoin: Optional[bool] = None,
    ):
        """Enumerate ``pattern`` in ``graph`` through this session.

        The session-backed twin of
        :func:`repro.graph.enumerate.enumerate_subgraphs`: the pattern is
        compiled to a shared-table :class:`JoinQuery`, submitted (hitting the
        plan cache when the graph's histogram signature is unchanged — e.g.
        the same pattern re-run, or re-run after an edge batch that didn't
        shift any heavy value), and post-processed into exactly-once
        occurrences.

        Returns: an :class:`repro.graph.enumerate.EnumerationResult`.
        """
        from ..graph.enumerate import enumerate_subgraphs

        return enumerate_subgraphs(
            graph,
            pattern,
            p=self.p,
            lam=lam,
            orientation=orientation,
            fuse_semijoin=(
                self.fuse_semijoin if fuse_semijoin is None else fuse_semijoin
            ),
            session=self,
        )

    # -- cache control --------------------------------------------------------

    def clear_plans(self) -> None:
        """Drop every cached compiled program (executor state is kept)."""
        self._plans.clear()
        self.stats.cached_plans = 0

    @property
    def cached_plan_keys(self) -> List[Tuple]:
        """Plan-LRU keys, oldest first (testing/observability)."""
        return list(self._plans.keys())
