"""Persistent join service: one long-lived session, many queries, cross-query reuse.

``mpc_join`` answers one query and throws everything away: the planner LPs,
the compiled :class:`~repro.mpc.program.RoundProgram`, the executor's learned
overflow capacities, and every AOT-compiled XLA executable die with the call.
A serving deployment answers the *same shapes* over and over — repeated
pattern queries over a graph, dashboards re-running a join as data refreshes —
and the paper's structure makes that reuse sound: the Theorem 6.2 plan is a
pure function of the query's hypergraph and the histogram, never of the
concrete tuples (``compile_plan`` reads only structure + ``HeavyStats``).

:class:`JoinSession` is the layer that exploits it (docs/design/09-service.md):

  * **Plan cache.**  Compiled programs are kept in an LRU keyed by
    :func:`~repro.mpc.program.plan_cache_key` — query structure (schemes +
    shared-table alias classes) plus the full histogram signature.  A hit
    skips the planner LPs and the taxonomy sweep entirely; the cached program
    is :meth:`~repro.mpc.program.RoundProgram.rebind`-ed onto the submitted
    data.  A shifted histogram changes the key, so stale plans are never
    reused — they age out of the LRU.
  * **Executor persistence.**  One :class:`DataplaneExecutor` lives as long
    as the session: its learned overflow capacities and the process-wide
    :class:`~repro.mpc.executors.ExecutableCache` survive across submits, so
    a warm repeat of any query runs with zero recompiles and zero retries —
    steady-state latency is the pure dispatch cost of the stage-batched
    scheduler.
  * **Batch submission.**  :meth:`JoinSession.submit_batch` shares per-table
    work across queries binding the same physical ``Relation.table``: one
    scatter placement on the simulator, one unique-count pass for the
    histogram on the dataplane (the cross-query extension of the
    shared-input Scatter path).
  * **Cross-query coalescing.**  :meth:`JoinSession.submit_async` enqueues
    requests into a bounded submission queue; a drainer thread groups queued
    queries whose compiled programs share a
    :func:`~repro.mpc.program.coalesce_signature` and runs each group through
    ONE pass of the stage-batched scheduler
    (:meth:`DataplaneExecutor.run_many`) — stages from different queries
    landing in the same geometry bucket ride one fused ``shard_map``
    dispatch, so the strictly serial collective stream (concurrent
    collective executions deadlock) serves many queries per dispatch.
    Identical submissions (same plan key, same bound tables) collapse
    further: one member executes and the rest share its result.  Results
    demultiplex per query with correct counts/stats and are byte-identical
    to serial :meth:`submit` (tests/test_service_async.py).
    :meth:`JoinSession.submit_coalesced` is the same machinery as a
    synchronous call.  Admission control is a bounded queue: a full queue
    rejects with :class:`AdmissionError` (backpressure) instead of queueing
    unboundedly.
  * **Observability.**  Every submit returns a :class:`SessionResult` with
    per-phase latency and cache provenance; :attr:`JoinSession.stats`
    accumulates the session-wide :class:`ServiceStats` (hit/miss counts per
    cache — plan LRU, learned caps, and executables metered separately —
    cold/warm/e2e latency windows with percentiles, and SLO counters).

``mpc_join`` remains the one-shot path and is implemented as a throwaway
session (see :mod:`repro.mpc.engine`); session and one-shot results are
row-multiset identical on both backends (``tests/test_service.py``).
"""

from __future__ import annotations

import math
import queue as queue_mod
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from dataclasses import dataclass, field, replace
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

from ..core.hypergraph import rho
from ..core.planner import heavy_parameter
from ..core.query import Attr, JoinQuery
from ..core.taxonomy import HeavyStats, compute_stats
from ..train.fault import Heartbeat, StragglerMonitor
from .executors import (
    DataplaneExecutor,
    DataplaneJoinResult,
    MPCJoinResult,
    SimulatorExecutor,
)
from .faults import (
    DeadlineExceededError,
    DegradedSessionError,
    JoinServiceError,
    ProgramVerificationError,
    QueryFailedError,
    describe_query,
)
from .program import (
    RoundProgram,
    RunConfig,
    _verify_default,
    coalesce_signature,
    compile_plan,
    plan_cache_key,
)
from .verify import verify_bindings, verify_program
from .simulator import MPCSimulator
from .statistics import distributed_stats


#: sliding-window size of the ServiceStats latency samples.
LATENCY_WINDOW = 512


class AdmissionError(RuntimeError):
    """The submission queue is full — the request was rejected, not queued.

    Backpressure signal of the bounded async queue: callers should retry
    later or shed load; ``ServiceStats.rejected`` counts these."""


@dataclass
class ServiceStats:
    """Session-wide service counters (live object on :attr:`JoinSession.stats`).

    Each cache layer meters separately so provenance is unambiguous:
    ``plan_hits``/``plan_misses``/``plan_evictions`` are the plan LRU;
    ``caps_hits``/``caps_misses``/``caps_evictions`` are the executor's
    learned-overflow-caps store (a *capacity* cache — its eviction cannot
    change results, only cause one rediscovery retry); ``jit_hits``/
    ``jit_misses`` are the process-wide executable cache.  ``retries``
    aggregates the dataplane scheduler's overflow retries.

    ``cold_us``/``warm_us`` collect per-submit service latencies split by
    plan-cache outcome (cold = the submit compiled a new plan) and
    ``e2e_us`` collects queue-inclusive latencies of async submits, each
    over a sliding window of the last :data:`LATENCY_WINDOW` samples — a
    bounded store, like every other cache in this layer.  ``percentile``
    reads any window; ``slo_ok``/``slo_violations`` count submits against
    the session's ``slo_target_us`` (e2e when queued, service time
    otherwise).

    The coalescing layer adds: ``async_submits`` (requests entering the
    queue), ``rejected`` (admission-control bounces), ``coalesced_batches``/
    ``coalesced_queries``/``max_coalesced_batch`` (multi-query drains), and
    ``deduped`` (requests served by sharing an identical member's
    execution).

    The robustness layer (docs/design/10-robustness.md) adds: ``failed``
    (requests resolved with a typed :class:`~repro.mpc.faults.JoinServiceError`),
    ``deadline_exceeded`` (the subset that hit their monotonic budget),
    ``degraded_fallbacks`` (coalesced groups whose fused dispatch failed and
    fell back to per-member serial execution), ``drainer_crashes`` (drainer
    supervision trips → degraded sessions), ``slow_batches`` (drain batches
    the :class:`~repro.train.fault.StragglerMonitor` flagged), and
    ``quarantined_caps``/``quarantined_plans`` (cache entries invalidated
    because a failed attempt touched them — ``quarantined_caps`` mirrors the
    executor's lifetime counter).

    The verification layer (docs/design/11-verification.md) adds:
    ``verified`` (submits whose compiled program passed the *full* static
    verifier — plan-cache misses only; hits re-verify bindings, which is
    deliberately not counted here) and ``verify_us`` (total wall time spent
    in any verification, full or bindings-only, so the warm-path cost is
    observable and provably near zero)."""

    submits: int = 0
    plan_hits: int = 0
    plan_misses: int = 0
    plan_evictions: int = 0
    cached_plans: int = 0
    jit_hits: int = 0
    jit_misses: int = 0
    retries: int = 0
    caps_hits: int = 0
    caps_misses: int = 0
    caps_evictions: int = 0
    async_submits: int = 0
    rejected: int = 0
    coalesced_batches: int = 0
    coalesced_queries: int = 0
    max_coalesced_batch: int = 0
    deduped: int = 0
    failed: int = 0
    deadline_exceeded: int = 0
    degraded_fallbacks: int = 0
    drainer_crashes: int = 0
    slow_batches: int = 0
    quarantined_caps: int = 0
    quarantined_plans: int = 0
    slo_ok: int = 0
    slo_violations: int = 0
    verified: int = 0
    verify_us: float = 0.0
    cold_us: Deque[float] = field(default_factory=lambda: deque(maxlen=LATENCY_WINDOW))
    warm_us: Deque[float] = field(default_factory=lambda: deque(maxlen=LATENCY_WINDOW))
    e2e_us: Deque[float] = field(default_factory=lambda: deque(maxlen=LATENCY_WINDOW))

    @property
    def mean_cold_us(self) -> float:
        return sum(self.cold_us) / len(self.cold_us) if self.cold_us else 0.0

    @property
    def mean_warm_us(self) -> float:
        return sum(self.warm_us) / len(self.warm_us) if self.warm_us else 0.0

    def percentile(self, q: float, window: str = "warm") -> float:
        """Latency percentile over one sliding window (``warm``/``cold``/
        ``e2e``), linearly interpolated; 0.0 on an empty window."""
        if window not in ("warm", "cold", "e2e"):
            raise ValueError(f"unknown latency window {window!r}")
        samples = sorted(getattr(self, f"{window}_us"))
        if not samples:
            return 0.0
        rank = (q / 100.0) * (len(samples) - 1)
        lo = int(math.floor(rank))
        hi = min(lo + 1, len(samples) - 1)
        frac = rank - lo
        return samples[lo] * (1.0 - frac) + samples[hi] * frac


@dataclass
class SessionResult:
    """One submit's answer plus its service provenance.

    ``result`` is the backend result (:class:`MPCJoinResult` on the
    simulator, :class:`DataplaneJoinResult` on the dataplane); the convenience
    properties forward the common fields.  ``plan_cache_hit`` says whether the
    plan LRU served the compiled program; the ``*_us`` fields break the
    submit's wall-clock into statistics / compile / execute phases.

    Coalescing provenance: ``coalesced`` is True when the request ran inside
    a multi-query scheduler pass (its ``execute_us`` is then the *shared*
    batch execute wall — the whole point is that k queries split it);
    ``batch_size`` is that drain batch's size; ``deduplicated`` is True when
    an identical concurrent submission executed and this request shares its
    result object.  ``queue_us``/``e2e_us`` are nonzero only for
    :meth:`JoinSession.submit_async` requests (time spent queued, and
    enqueue-to-resolution wall).  ``caps_hits``/``caps_misses``/
    ``caps_evictions`` forward the learned-caps counters of the run so cache
    provenance (plan LRU vs learned caps vs executables) is unambiguous
    per-result, not just session-wide."""

    result: Union[MPCJoinResult, DataplaneJoinResult]
    plan_key: Tuple
    plan_cache_hit: bool
    stats_us: float
    compile_us: float
    execute_us: float
    total_us: float
    coalesced: bool = False
    batch_size: int = 1
    deduplicated: bool = False
    queue_us: float = 0.0
    e2e_us: float = 0.0
    #: True when the *full* static verifier ran over this submit's compiled
    #: program (plan-cache miss); cache hits re-verify bindings only and
    #: report False — the observable proof that verification stays off the
    #: warm hot path.  ``verify_us`` is the time spent either way (part of
    #: ``total_us``).
    verified: bool = False
    verify_us: float = 0.0

    @property
    def count(self) -> int:
        return self.result.count

    @property
    def rows(self):
        return self.result.rows

    @property
    def per_h_counts(self):
        return self.result.per_h_counts

    @property
    def retries(self) -> int:
        return getattr(self.result, "retries", 0)

    @property
    def retry_log(self) -> list:
        return getattr(self.result, "retry_log", [])

    @property
    def jit_cache_misses(self) -> int:
        return getattr(self.result, "jit_cache_misses", 0)

    @property
    def caps_hits(self) -> int:
        return getattr(self.result, "caps_hits", 0)

    @property
    def caps_misses(self) -> int:
        return getattr(self.result, "caps_misses", 0)

    @property
    def caps_evictions(self) -> int:
        return getattr(self.result, "caps_evictions", 0)


@dataclass
class _Request:
    """One queued (or inline) submission flowing through ``_execute_batch``."""

    query: JoinQuery
    lam: Optional[int] = None
    stats: Optional[HeavyStats] = None
    materialize: bool = True
    h_subsets: Optional[Sequence[Sequence[Attr]]] = None
    fuse_semijoin: Optional[bool] = None
    batch: Optional[Dict] = None          # submit_batch's shared-table memos
    future: Optional[Future] = None       # async submits resolve through this
    t_enqueue: Optional[float] = None     # perf_counter at queue admission
    deadline: Optional[float] = None      # absolute monotonic budget (or None)
    # filled by _prepare:
    executor: object = None
    program: Optional[RoundProgram] = None
    plan_key: Optional[Tuple] = None
    plan_cache_hit: bool = False
    stats_us: float = 0.0
    compile_us: float = 0.0
    verified: bool = False
    verify_us: float = 0.0
    error: Optional[BaseException] = None


#: drainer shutdown sentinel (enqueued by :meth:`JoinSession.close`).
_SHUTDOWN = object()


class JoinSession:
    """A persistent join service over one executor: repeated ``submit`` calls
    with cross-query plan/compile reuse.

    Args:
        p: machine count every submitted plan is compiled for (the dataplane
            maps it onto however many devices its mesh has).
        backend: ``"dataplane"`` (default — the long-lived
            :class:`DataplaneExecutor`) or ``"simulator"`` (a fresh metered
            :class:`~repro.mpc.simulator.MPCSimulator` per submit, so each
            query gets its own load ledger; plans are still cached across
            submits).
        executor: optionally inject a configured :class:`DataplaneExecutor`
            (e.g. ``batch_stages=False``); ignored on the simulator backend.
        plan_cache_size: LRU bound on cached compiled programs.
        seed: shared-randomness seed (scatter + routing hashes).
        fuse_semijoin: default fusion flag for submits that don't pass one.
        max_queue: admission bound of the async submission queue — a full
            queue rejects :meth:`submit_async` with :class:`AdmissionError`.
        max_coalesce: most requests one drain batch may coalesce.
        slo_target_us: per-query latency SLO; when set, every submit counts
            into ``stats.slo_ok``/``stats.slo_violations`` (async submits
            judged on queue-inclusive e2e latency).
        async_autostart: start the drainer thread lazily on the first
            :meth:`submit_async` (disable to unit-test admission control or
            to drive the queue deterministically via :meth:`close`).
        fault_plan: a :class:`~repro.mpc.faults.FaultPlan` consulted at every
            injection site — executor dispatch/compile/overflow plus the
            drainer — for chaos testing (None = no injection).
        heartbeat_path: when set, the drainer writes a
            :class:`~repro.train.fault.Heartbeat` file before every drain
            batch, so an external supervisor can detect a wedged session.
        straggler_factor: drain batches slower than ``factor ×`` the running
            EMA are counted into ``stats.slow_batches`` (the
            :class:`~repro.train.fault.StragglerMonitor` contract).

    Failure semantics (docs/design/10-robustness.md): every failed request
    resolves exactly once with a typed
    :class:`~repro.mpc.faults.JoinServiceError` naming its query; a fused
    coalesced dispatch that fails falls back to per-member serial execution
    so batchmates of a poisoned query still get byte-identical results; a
    crashed drainer resolves everything pending with
    :class:`~repro.mpc.faults.DegradedSessionError` and flips the session
    degraded until :meth:`restart`; caches touched by a failed attempt are
    quarantined so transient faults never poison the warm steady state.

    A repeat submit of a cached query shape is the *warm path*: the plan LRU
    skips ``compile_plan``, and on the dataplane the executor's learned caps
    and executable cache make the run retry-free and recompile-free —
    ``tests/test_service.py`` locks ``jit_cache_misses == 0`` and an empty
    ``retry_log`` on the second submit, including after an LRU
    eviction/readmission cycle (learned caps are executor-lifetime state,
    keyed independently of the plan LRU).

    Thread-safety: all executor access is serialized under one re-entrant
    lock — concurrent collective executions deadlock, so multiplexing happens
    at the bucket layer (coalesced dispatches), never with parallel runs."""

    def __init__(
        self,
        p: int,
        backend: str = "dataplane",
        executor: Optional[DataplaneExecutor] = None,
        plan_cache_size: int = 64,
        seed: int = 0,
        fuse_semijoin: bool = False,
        max_queue: int = 256,
        max_coalesce: int = 32,
        slo_target_us: Optional[float] = None,
        async_autostart: bool = True,
        fault_plan=None,
        heartbeat_path=None,
        straggler_factor: float = 2.5,
        verify: Optional[bool] = None,
    ):
        if backend not in ("dataplane", "simulator"):
            raise ValueError(f"unknown backend {backend!r}")
        if max_coalesce < 1:
            raise ValueError("max_coalesce must be >= 1")
        self.p = p
        self.backend = backend
        self.seed = seed
        self.fuse_semijoin = fuse_semijoin
        # static verification: full pass on every plan-cache miss, bindings
        # re-check on every hit (None defers to the REPRO_VERIFY env var, so
        # the test suite runs verified by default without touching prod).
        self.verify = _verify_default() if verify is None else bool(verify)
        self.plan_cache_size = plan_cache_size
        self.max_coalesce = max_coalesce
        self.slo_target_us = slo_target_us
        self.async_autostart = async_autostart
        self.executor: Optional[DataplaneExecutor] = None
        if backend == "dataplane":
            self.executor = executor if executor is not None else DataplaneExecutor()
        self._plans: "OrderedDict[Tuple, RoundProgram]" = OrderedDict()
        self.stats = ServiceStats()
        self._lock = threading.RLock()
        self._queue: "queue_mod.Queue" = queue_mod.Queue(maxsize=max_queue)
        self._drainer: Optional[threading.Thread] = None
        self._closed = False
        self.fault_plan = fault_plan
        self._degraded_cause: Optional[BaseException] = None
        self._monitor = StragglerMonitor(factor=straggler_factor, warmup=1)
        self._heartbeat = (
            Heartbeat(heartbeat_path) if heartbeat_path is not None else None
        )
        self._batch_seq = 0

    # -- single-query entry ---------------------------------------------------

    def submit(
        self,
        query: JoinQuery,
        lam: Optional[int] = None,
        stats: Optional[HeavyStats] = None,
        materialize: bool = True,
        h_subsets: Optional[Sequence[Sequence[Attr]]] = None,
        fuse_semijoin: Optional[bool] = None,
        deadline_s: Optional[float] = None,
        _batch: Optional[Dict] = None,
    ) -> SessionResult:
        """Answer one join query, reusing every cached artifact that applies.

        Args:
            query: the join query (concrete relations attached).
            lam: heavy parameter λ; default Θ(p^{1/(2ρ)}) per the paper.
            stats: inject a precomputed histogram; by default the simulator
                backend runs the 3 metered rounds of the distributed protocol
                and the dataplane backend computes the centralized oracle.
            materialize: return result rows (False: counts only).
            h_subsets: restrict the H-taxonomy (testing).
            fuse_semijoin: override the session's default fusion flag.
            deadline_s: monotonic-clock budget in seconds; past it the query
                fails with :class:`~repro.mpc.faults.DeadlineExceededError`
                (checked between dispatches, never mid-collective).

        Returns:
            A :class:`SessionResult` wrapping the backend result with cache
            provenance and per-phase latency.

        Raises:
            A typed :class:`~repro.mpc.faults.JoinServiceError` naming the
            query on any failure, with the root cause (executor frames
            included) chained on ``__cause__``.
        """
        req = _Request(
            query=query, lam=lam, stats=stats, materialize=materialize,
            h_subsets=h_subsets, fuse_semijoin=fuse_semijoin, batch=_batch,
            deadline=self._abs_deadline(deadline_s),
        )
        out = self._execute_batch([req])[0]
        if isinstance(out, BaseException):
            # re-raise with the stored traceback intact (the original frames
            # would otherwise be replaced by this raise site)
            raise out.with_traceback(out.__traceback__)
        return out

    # -- async / coalescing entry ---------------------------------------------

    def submit_async(
        self,
        query: JoinQuery,
        lam: Optional[int] = None,
        stats: Optional[HeavyStats] = None,
        materialize: bool = True,
        h_subsets: Optional[Sequence[Sequence[Attr]]] = None,
        fuse_semijoin: Optional[bool] = None,
        block: bool = True,
        timeout: Optional[float] = None,
        deadline_s: Optional[float] = None,
    ) -> "Future[SessionResult]":
        """Enqueue one query; a drainer coalesces concurrent requests.

        Returns a :class:`concurrent.futures.Future` resolving to the same
        :class:`SessionResult` a serial :meth:`submit` would produce (byte-
        identical rows — coalescing changes scheduling, never results), with
        ``queue_us``/``e2e_us`` filled in.

        Admission control: the queue is bounded at ``max_queue``.  With
        ``block=False`` (or when ``timeout`` elapses) a full queue raises
        :class:`AdmissionError` immediately — the backpressure signal — and
        increments ``stats.rejected``.

        The drainer thread starts lazily on the first call (disable with
        ``async_autostart=False``; :meth:`close` then drains inline).

        ``deadline_s`` starts the request's monotonic budget at admission —
        time spent queued counts against it, so a request stuck behind a slow
        batch times out instead of blocking its caller forever.

        A degraded session (drainer crashed — see :meth:`restart`) raises
        :class:`~repro.mpc.faults.DegradedSessionError` immediately."""
        if self._closed:
            raise RuntimeError("session is closed")
        if self._degraded_cause is not None:
            raise DegradedSessionError(
                "session is degraded (drainer crashed); call restart()",
                cause=self._degraded_cause,
            )
        req = _Request(
            query=query, lam=lam, stats=stats, materialize=materialize,
            h_subsets=h_subsets, fuse_semijoin=fuse_semijoin,
            future=Future(), t_enqueue=time.perf_counter(),
            deadline=self._abs_deadline(deadline_s),
        )
        try:
            self._queue.put(req, block=block, timeout=timeout)
        except queue_mod.Full:
            self.stats.rejected += 1
            raise AdmissionError(
                f"submission queue full ({self._queue.maxsize} pending)"
            ) from None
        self.stats.async_submits += 1
        if self.async_autostart:
            self.start()
        return req.future

    def submit_coalesced(
        self,
        queries: Sequence[JoinQuery],
        lam: Optional[int] = None,
        materialize: bool = True,
        fuse_semijoin: Optional[bool] = None,
        deadline_s: Optional[float] = None,
    ) -> List[SessionResult]:
        """Answer several queries through ONE coalesced scheduler pass.

        The synchronous twin of draining ``len(queries)`` concurrent
        :meth:`submit_async` requests in one batch (and the deterministic
        seam the tests use): same grouping by
        :func:`~repro.mpc.program.coalesce_signature`, same identical-
        submission dedup, same demux.  Results are in submission order and
        byte-identical to one :meth:`submit` per query.  The first member's
        failure raises (traceback preserved); per-member outcomes are
        available through :meth:`submit_async` instead."""
        share: Dict = {"scatter": {}, "unique": {}}
        reqs = [
            _Request(
                query=q, lam=lam, materialize=materialize,
                fuse_semijoin=fuse_semijoin, batch=share,
                deadline=self._abs_deadline(deadline_s),
            )
            for q in queries
        ]
        outs = self._execute_batch(reqs)
        for out in outs:
            if isinstance(out, BaseException):
                raise out.with_traceback(out.__traceback__)
        return outs

    @staticmethod
    def _abs_deadline(deadline_s: Optional[float]) -> Optional[float]:
        """Relative budget (seconds) → absolute ``time.monotonic`` instant."""
        return None if deadline_s is None else time.monotonic() + deadline_s

    def start(self) -> None:
        """Start the drainer thread (idempotent; ``submit_async`` autostarts
        unless the session was built with ``async_autostart=False``).  A
        degraded session refuses — :meth:`restart` is the supervised path
        back."""
        if self._degraded_cause is not None:
            raise DegradedSessionError(
                "session is degraded (drainer crashed); call restart()",
                cause=self._degraded_cause,
            )
        if self._drainer is None or not self._drainer.is_alive():
            self._drainer = threading.Thread(
                target=self._drain_loop, name="join-session-drainer", daemon=True
            )
            self._drainer.start()

    @property
    def degraded(self) -> bool:
        """True after a drainer crash, until :meth:`restart`."""
        return self._degraded_cause is not None

    def restart(self) -> None:
        """Supervised recovery from a drainer crash: clear the degraded
        state, reset the straggler monitor's latency model (post-fault
        batches shouldn't be judged against a pre-fault EMA), and start a
        fresh drainer.  Executor caches are untouched — anything a failed
        attempt poisoned was already quarantined when it failed."""
        if self._closed:
            raise JoinServiceError("cannot restart a closed session")
        self._degraded_cause = None
        self._monitor.reset()
        self.start()

    def close(self, wait: bool = True) -> None:
        """Stop accepting async submits and drain what's already queued.

        With a live drainer the shutdown sentinel is enqueued and (when
        ``wait``) joined; afterwards — and for drainer-less
        (``async_autostart=False``) or degraded sessions — any request still
        queued is swept so **every admitted request resolves exactly once**:
        executed inline on a healthy session, failed with
        :class:`~repro.mpc.faults.DegradedSessionError` on a degraded one."""
        if self._closed:
            return
        self._closed = True
        if self._drainer is not None and self._drainer.is_alive():
            self._queue.put(_SHUTDOWN)
            if not wait:
                return
            self._drainer.join()
        # sweep whatever is still queued (race leftovers, degraded-session
        # backlog, drainer-less sessions) in queue order
        pending: List[_Request] = []
        while True:
            try:
                item = self._queue.get_nowait()
            except queue_mod.Empty:
                break
            if item is not _SHUTDOWN:
                pending.append(item)
        if self._degraded_cause is not None:
            err = DegradedSessionError(
                "session closed while degraded (drainer crashed)",
                cause=self._degraded_cause,
            )
            for req in pending:
                if self._resolve(req, err):
                    self.stats.failed += 1
            return
        while pending:
            batch, pending = pending[: self.max_coalesce], pending[self.max_coalesce:]
            self._process(batch)

    def __enter__(self) -> "JoinSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _drain_loop(self) -> None:
        """Drainer: block on the queue, then coalesce everything already
        waiting (up to ``max_coalesce``) into one batch.  Natural batching —
        under light load batches are singletons and latency is a serial
        submit's; under burst load the batch grows and the per-dispatch cost
        amortizes across it.

        Supervision: the loop body is guarded — any exception escaping it
        (``_process`` itself never raises; this is the heartbeat/injection
        window between dequeue and demux) degrades the session via
        :meth:`_enter_degraded` instead of leaking a dead thread with hung
        futures.  Each batch beats the optional heartbeat file and feeds the
        straggler monitor."""
        while True:
            item = self._queue.get()
            if item is _SHUTDOWN:
                return
            batch = [item]
            stop = False
            while len(batch) < self.max_coalesce:
                try:
                    nxt = self._queue.get_nowait()
                except queue_mod.Empty:
                    break
                if nxt is _SHUTDOWN:
                    stop = True
                    break
                batch.append(nxt)
            try:
                seq = self._batch_seq
                self._batch_seq = seq + 1
                if self._heartbeat is not None:
                    self._heartbeat.beat(seq)
                if self.fault_plan is not None:
                    self.fault_plan.at_drainer()
                t0 = time.perf_counter()
                self._process(batch)
                if self._monitor.record(seq, time.perf_counter() - t0):
                    self.stats.slow_batches += 1
            except BaseException as e:
                self._enter_degraded(e, batch)
                return
            if stop:
                return

    def _enter_degraded(self, cause: BaseException, inflight: List[_Request]) -> None:
        """Drainer-crash path: resolve the in-flight batch AND everything
        still queued with :class:`~repro.mpc.faults.DegradedSessionError`
        (zero hung futures), then flip the session degraded so new
        :meth:`submit_async` calls fail fast until :meth:`restart`."""
        self._degraded_cause = cause
        self.stats.drainer_crashes += 1
        err = DegradedSessionError(
            f"session drainer crashed: {cause!r}", cause=cause
        )
        pending = list(inflight)
        while True:
            try:
                item = self._queue.get_nowait()
            except queue_mod.Empty:
                break
            if item is not _SHUTDOWN:
                pending.append(item)
        for req in pending:
            if self._resolve(req, err):
                self.stats.failed += 1

    @staticmethod
    def _resolve(req: _Request, out) -> bool:
        """Resolve a request's future exactly once; True if this call did it.

        The done() guard (plus the InvalidStateError backstop for the racing
        case) is what makes crash paths safe to run concurrently with the
        normal demux — a future can only ever carry one outcome."""
        fut = req.future
        if fut is None or fut.done():
            return False
        try:
            if isinstance(out, BaseException):
                fut.set_exception(out)
            else:
                fut.set_result(out)
        except Exception:       # InvalidStateError: someone else won the race
            return False
        return True

    def _process(self, batch: List[_Request]) -> None:
        """Execute one drain batch and resolve its futures (never raises —
        a drainer must survive any single request's failure)."""
        try:
            outs = self._execute_batch(batch)
        except BaseException as e:  # defensive: _execute_batch reports per-request
            outs = [e] * len(batch)
        for req, out in zip(batch, outs):
            self._resolve(req, out)

    # -- the shared execution path --------------------------------------------

    def _prepare(self, req: _Request, share: Dict) -> None:
        """Phase 1 of a submit: histogram, plan-cache lookup, compile on miss.

        Fills the request in place; any failure lands in ``req.error`` so one
        bad query never poisons the rest of a coalesced batch."""
        try:
            fuse = (
                self.fuse_semijoin
                if req.fuse_semijoin is None
                else req.fuse_semijoin
            )
            lam, stats = req.lam, req.stats
            if lam is None:
                # only the λ default needs ρ — keep the LP solve off the
                # explicit-λ hot path (steady-state submits must be
                # dispatch-only)
                if stats is not None:
                    lam = stats.lam
                else:
                    lam = heavy_parameter(self.p, float(rho(req.query)))

            t0 = time.perf_counter()
            if self.backend == "simulator":
                sim = MPCSimulator(self.p, seed=self.seed)
                executor: object = SimulatorExecutor(sim, seed=self.seed)
                executor.place_inputs(req.query, scatter_cache=share.get("scatter"))
                if stats is None:
                    stats = distributed_stats(sim, req.query, lam)
            else:
                executor = self.executor
                if stats is None:
                    stats = compute_stats(
                        req.query, lam, unique_memo=share.get("unique")
                    )
            req.stats_us = (time.perf_counter() - t0) * 1e6

            key = plan_cache_key(req.query, stats, self.p, req.h_subsets, fuse)
            cached = self._plans.get(key)
            if cached is not None:
                self._plans.move_to_end(key)
                req.program = cached.rebind(req.query)
                self.stats.plan_hits += 1
                if self.verify:
                    # warm path: the cached plan was fully verified when it
                    # was compiled; only the fresh bindings need re-checking.
                    t0 = time.perf_counter()
                    verify_bindings(req.program)
                    req.verify_us = (time.perf_counter() - t0) * 1e6
            else:
                t0 = time.perf_counter()
                req.program = compile_plan(
                    req.query, stats, self.p,
                    h_subsets=req.h_subsets, fuse_semijoin=fuse,
                    verify=False,  # timed separately below
                )
                req.compile_us = (time.perf_counter() - t0) * 1e6
                if self.verify:
                    t0 = time.perf_counter()
                    verify_program(
                        req.program,
                        caps=getattr(executor, "_learned_caps", None),
                    )
                    req.verify_us = (time.perf_counter() - t0) * 1e6
                    req.verified = True
                # cache plan metadata only: the concrete relations are rebound
                # on every hit, so pinning the first submitter's tuple data in
                # the LRU would retain up to plan_cache_size tables for no
                # reader
                self._plans[key] = replace(req.program, query=None)
                self.stats.plan_misses += 1
                while len(self._plans) > self.plan_cache_size:
                    self._plans.popitem(last=False)
                    self.stats.plan_evictions += 1
            req.executor = executor
            req.plan_key = key
            req.plan_cache_hit = cached is not None
        except BaseException as e:
            req.error = e

    def _execute_batch(
        self, reqs: List[_Request]
    ) -> List[Union[SessionResult, BaseException]]:
        """Prepare, group, run, and demux one batch of requests.

        Grouping (dataplane only; the simulator backend runs serially — each
        query owns a metered simulator):

          1. requests are grouped by ``(coalesce_signature(program),
             materialize)`` — the bucket-compatibility rule: equal signatures
             mean identical op sequences and matching stage-geometry
             histograms, so the group shares one ``run_many`` scheduler pass;
          2. within a group, requests with identical *executions* — equal
             plan key AND the same bound table objects — deduplicate: one
             representative runs, the duplicates share its result (the
             ``deduped`` counter; results are read-only).

        Scheduler counters (dispatches, jit, caps, retries) aggregate into
        :attr:`stats` once per ``run_many`` call — they are batch-level, so
        summing them per member would multi-count."""
        with self._lock:
            t_batch = time.perf_counter()
            share = (
                reqs[0].batch
                if len(reqs) == 1 and reqs[0].batch is not None
                else (reqs[0].batch or {"scatter": {}, "unique": {}})
            )
            for req in reqs:
                self._prepare(req, req.batch if req.batch is not None else share)

            # deadline admission: a request already past its budget (e.g. it
            # queued behind a slow batch) fails cheaply before any dispatch
            now = time.monotonic()
            for req in reqs:
                if (
                    req.error is None
                    and req.deadline is not None
                    and now > req.deadline
                ):
                    req.error = DeadlineExceededError(
                        f"query {describe_query(req.query)} exceeded its "
                        "deadline before execution",
                        query=req.query, deadline_s=req.deadline,
                    )

            live = [r for r in reqs if r.error is None]
            outs: Dict[int, Union[SessionResult, BaseException]] = {}

            if self.backend == "simulator" or self.executor is None:
                for req in live:
                    t0 = time.perf_counter()
                    try:
                        res = req.executor.run(
                            req.program, materialize=req.materialize
                        )
                    except BaseException as e:
                        req.error = e
                        continue
                    execute_us = (time.perf_counter() - t0) * 1e6
                    self.stats.jit_hits += getattr(res, "jit_cache_hits", 0)
                    self.stats.jit_misses += getattr(res, "jit_cache_misses", 0)
                    self.stats.retries += getattr(res, "retries", 0)
                    outs[id(req)] = self._wrap(
                        req, res, execute_us, len(reqs), coalesced=False,
                        deduplicated=False,
                    )
            else:
                # group by bucket compatibility, preserving submission order
                groups: "OrderedDict[Tuple, List[_Request]]" = OrderedDict()
                for req in live:
                    gkey = (coalesce_signature(req.program), req.materialize)
                    groups.setdefault(gkey, []).append(req)
                for members in groups.values():
                    # identical-submission dedup: same plan key + same bound
                    # table objects ⇒ same bytes out, so run once and share
                    reps: List[_Request] = []
                    assign: List[int] = []
                    seen: Dict[Tuple, int] = {}
                    for req in members:
                        dk = (
                            req.plan_key,
                            tuple(id(r.data) for r in req.query.relations),
                        )
                        if dk in seen:
                            assign.append(seen[dk])
                            self.stats.deduped += 1
                        else:
                            seen[dk] = len(reps)
                            assign.append(len(reps))
                            reps.append(req)
                    deadlines = [r.deadline for r in reps if r.deadline is not None]
                    t0 = time.perf_counter()
                    try:
                        results, bstats = self.executor.run_many(
                            [r.program for r in reps],
                            config=RunConfig(
                                materialize=members[0].materialize,
                                deadline=min(deadlines) if deadlines else None,
                                fault_plan=self.fault_plan,
                            ),
                        )
                    except BaseException as e:
                        if len(reps) == 1:
                            for req in members:
                                req.error = e
                        else:
                            # coalesced-group failure isolation: the fused
                            # dispatch is all-or-nothing, so fall back to
                            # per-member serial runs — the poisoned member
                            # fails alone and its batchmates still produce
                            # the exact bytes a serial submit would have
                            # (salts never depend on coalescing)
                            self.stats.degraded_fallbacks += 1
                            self._run_serial_fallback(members, reps, assign, outs, len(reqs))
                        continue
                    execute_us = (time.perf_counter() - t0) * 1e6
                    self._absorb(bstats)
                    coalesced = len(members) > 1
                    for req, ri in zip(members, assign):
                        outs[id(req)] = self._wrap(
                            req, results[ri], execute_us, len(reqs),
                            coalesced=coalesced,
                            deduplicated=(req is not reps[ri]),
                        )

            if len(reqs) > 1:
                self.stats.coalesced_batches += 1
                self.stats.coalesced_queries += len(reqs)
                self.stats.max_coalesced_batch = max(
                    self.stats.max_coalesced_batch, len(reqs)
                )
            self.stats.cached_plans = len(self._plans)
            if self.executor is not None:
                # mirror of the executor's lifetime quarantine counter (the
                # per-run count is unavailable when the run itself raised)
                self.stats.quarantined_caps = self.executor.caps_quarantined

            t_done = time.perf_counter()
            final: List[Union[SessionResult, BaseException]] = []
            for req in reqs:
                if req.error is not None:
                    err = self._typed_error(req)
                    req.error = err
                    self.stats.failed += 1
                    if isinstance(err, DeadlineExceededError):
                        self.stats.deadline_exceeded += 1
                    # plan quarantine: the compiled program a failed attempt
                    # used is dropped from the LRU — if the failure was the
                    # plan's fault (stale histogram, planner bug), the next
                    # submit recompiles instead of re-failing forever
                    if (
                        req.plan_key is not None
                        and self._plans.pop(req.plan_key, None) is not None
                    ):
                        self.stats.quarantined_plans += 1
                        self.stats.cached_plans = len(self._plans)
                    final.append(err)
                    continue
                out = outs[id(req)]
                if req.t_enqueue is not None:
                    out.queue_us = max(0.0, (t_batch - req.t_enqueue) * 1e6)
                    out.e2e_us = (t_done - req.t_enqueue) * 1e6
                    self.stats.e2e_us.append(out.e2e_us)
                if self.slo_target_us is not None:
                    lat = out.e2e_us if req.t_enqueue is not None else out.total_us
                    if lat <= self.slo_target_us:
                        self.stats.slo_ok += 1
                    else:
                        self.stats.slo_violations += 1
                final.append(out)
            return final

    def _absorb(self, bstats) -> None:
        """Aggregate one ``run_many`` call's batch-level counters into
        :attr:`stats` (exactly once per scheduler pass)."""
        self.stats.jit_hits += bstats.jit_cache_hits
        self.stats.jit_misses += bstats.jit_cache_misses
        self.stats.retries += bstats.retries
        self.stats.caps_hits += bstats.caps_hits
        self.stats.caps_misses += bstats.caps_misses
        self.stats.caps_evictions += bstats.caps_evictions

    def _run_serial_fallback(
        self,
        members: List[_Request],
        reps: List[_Request],
        assign: List[int],
        outs: Dict,
        batch_size: int,
    ) -> None:
        """The group-isolation fallback ladder, rung 2: after a fused
        coalesced dispatch failed, run each deduplicated representative as
        its own serial scheduler pass (own deadline, fault plan still
        active).  Only the members whose representative fails get an error;
        everyone else's rows are byte-identical to a fault-free serial
        submit because routing salts derive from the query-unqualified stage
        key, never from the batch shape."""
        rep_out: List = []
        for rep in reps:
            t1 = time.perf_counter()
            try:
                res_list, bstats = self.executor.run_many(
                    [rep.program],
                    config=RunConfig(
                        materialize=rep.materialize,
                        deadline=rep.deadline,
                        fault_plan=self.fault_plan,
                    ),
                )
            except BaseException as e:
                rep_out.append(e)
                continue
            self._absorb(bstats)
            rep_out.append((res_list[0], (time.perf_counter() - t1) * 1e6))
        for req, ri in zip(members, assign):
            o = rep_out[ri]
            if isinstance(o, BaseException):
                req.error = o
            else:
                res, ex_us = o
                outs[id(req)] = self._wrap(
                    req, res, ex_us, batch_size,
                    coalesced=False, deduplicated=(req is not reps[ri]),
                )

    def _typed_error(self, req: _Request) -> JoinServiceError:
        """Map a request's raw failure onto the taxonomy, always naming the
        query and always chaining the root cause's traceback."""
        e = req.error
        if isinstance(e, DeadlineExceededError):
            if e.query is None:
                out = DeadlineExceededError(
                    f"query {describe_query(req.query)}: {e}",
                    query=req.query, op_round=e.op_round,
                    deadline_s=e.deadline_s,
                )
                out.__cause__ = e
                return out
            return e
        if isinstance(
            e,
            (
                QueryFailedError,
                DegradedSessionError,
                AdmissionError,
                ProgramVerificationError,
            ),
        ):
            return e
        return QueryFailedError(
            req.query, e, attempt_log=getattr(e, "attempt_log", ())
        )

    def _wrap(
        self,
        req: _Request,
        res: Union[MPCJoinResult, DataplaneJoinResult],
        execute_us: float,
        batch_size: int,
        coalesced: bool,
        deduplicated: bool,
    ) -> SessionResult:
        total_us = req.stats_us + req.compile_us + req.verify_us + execute_us
        self.stats.submits += 1
        if req.verified:
            self.stats.verified += 1
        self.stats.verify_us += req.verify_us
        (self.stats.warm_us if req.plan_cache_hit else self.stats.cold_us).append(
            total_us
        )
        return SessionResult(
            result=res,
            plan_key=req.plan_key,
            plan_cache_hit=req.plan_cache_hit,
            stats_us=req.stats_us,
            compile_us=req.compile_us,
            execute_us=execute_us,
            total_us=total_us,
            coalesced=coalesced,
            batch_size=batch_size,
            deduplicated=deduplicated,
            verified=req.verified,
            verify_us=req.verify_us,
        )

    # -- batch entry ----------------------------------------------------------

    def submit_batch(
        self,
        queries: Sequence[JoinQuery],
        lam: Optional[int] = None,
        materialize: bool = True,
        fuse_semijoin: Optional[bool] = None,
    ) -> List[SessionResult]:
        """Answer a batch of queries serially, sharing per-table work.

        Queries binding the same physical ``Relation.table`` share one device
        placement: on the simulator backend the first query's seeded scatter
        shuffle is installed verbatim into every later query's simulator
        (bit-identical to re-scattering — ``scatter_input`` is deterministic);
        on the dataplane backend the histogram's per-(table, column)
        unique-count pass — the sort-dominated part of ``compute_stats`` — is
        computed once per table.  Results are identical to one
        :meth:`submit` per query, in order.  (For a *coalesced* batch — one
        scheduler pass for the whole set — see :meth:`submit_coalesced`.)

        Returns: one :class:`SessionResult` per query, in submission order.
        """
        batch: Dict = {"scatter": {}, "unique": {}}
        return [
            self.submit(
                q,
                lam=lam,
                materialize=materialize,
                fuse_semijoin=fuse_semijoin,
                _batch=batch,
            )
            for q in queries
        ]

    # -- pattern entry (subgraph enumeration) ---------------------------------

    def submit_pattern(
        self,
        pattern,
        graph,
        lam: Optional[int] = None,
        orientation: str = "degree",
        fuse_semijoin: Optional[bool] = None,
    ):
        """Enumerate ``pattern`` in ``graph`` through this session.

        The session-backed twin of
        :func:`repro.graph.enumerate.enumerate_subgraphs`: the pattern is
        compiled to a shared-table :class:`JoinQuery`, submitted (hitting the
        plan cache when the graph's histogram signature is unchanged — e.g.
        the same pattern re-run, or re-run after an edge batch that didn't
        shift any heavy value), and post-processed into exactly-once
        occurrences.

        Returns: an :class:`repro.graph.enumerate.EnumerationResult`.
        """
        from ..graph.enumerate import enumerate_subgraphs

        return enumerate_subgraphs(
            graph,
            pattern,
            p=self.p,
            lam=lam,
            orientation=orientation,
            fuse_semijoin=(
                self.fuse_semijoin if fuse_semijoin is None else fuse_semijoin
            ),
            session=self,
        )

    # -- cache control --------------------------------------------------------

    def clear_plans(self) -> None:
        """Drop every cached compiled program (executor state is kept)."""
        self._plans.clear()
        self.stats.cached_plans = 0

    @property
    def cached_plan_keys(self) -> List[Tuple]:
        """Plan-LRU keys, oldest first (testing/observability)."""
        return list(self._plans.keys())
