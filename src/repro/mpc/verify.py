"""Static verification of compiled RoundPrograms (docs/design/11-verification.md).

A verification pass runs entirely host-side — no device, no collective, no
relation data movement — and either returns a :class:`VerificationReport` or
raises a typed :class:`~repro.mpc.faults.ProgramVerificationError` carrying
``(op_round, rule, detail)``.  The rules:

  ``scatter-binding``    every relation's data matches its scheme arity; all
                         relations declaring one physical ``Relation.table``
                         bind the same rows (the shared-input alias classes
                         Scatter places once); emit tuples target machines
                         in [0, p) with the right width.
  ``semijoin-fusion``    the SemiJoin phases are exactly ("x", "y") or, when
                         ``program.fused``, ("fused-route", "fused-filter")
                         *and* the fused op list is the exact image of
                         :func:`~repro.mpc.program.fuse_semijoin_pass`.
  ``grid-invariants``    machine groups live on [0, p) with stable-hash
                         bases; step-1 group sizes match the allocation
                         formula; recorded m_η equals the recomputed residual
                         size; CP grids respect the Lemma 3.1 budget
                         Π(grid_dims) ≤ p; the Lemma 3.2 composition matrix
                         has ≤ |step-3 group| cells and flattens row-major.
  ``cap-grid``           every learned capacity sits on the {2^k, 3·2^(k-1)}
                         quantization grid (≥ 16) that keeps the executable
                         signature count bounded.
  ``packed-key``         packed int32 composite keys only when the
                         mixed-radix space (max_cell+1)·Π(max_dup+1) fits
                         INT32_MAX; grid-route cell spaces stay < 2^31.
  ``collective-stream``  the op sequence admits exactly one strictly-serial
                         collective order — each collective op appears
                         exactly once, in canonical phase order (two
                         collectives in flight deadlock; a missing one
                         starves every downstream round).
  ``load-bound``         (``check_load``, needs a metered run) every measured
                         round load is ≤ the symbolic model bound of
                         :mod:`repro.analysis.loadmodel` — the Theorem 6.2
                         Õ(m/p^{1/ρ}) promise as an executable assertion.
  ``join-tree``          (general programs) the compiled join tree is real:
                         full-intersection edge labels, running intersection,
                         leaves-first sweep order, pre-order CellJoin chain,
                         and no acyclic query demoted to the cyclic route.
  ``share-exponent``     (general programs) HyperCube shares are positive
                         ints over exactly the output attributes, Π ≤ p, and
                         equal the fractional-edge-cover LP solution.

General programs (``program.general`` set) swap the binary-taxonomy rules
(semijoin-fusion, grid-invariants) for ``join-tree`` + ``share-exponent`` and
a general ``collective-stream`` check; scatter-binding and cap-grid apply to
both routes unchanged.

``verify_program`` runs every static rule (everything but ``load-bound``).
``verify_bindings`` is the cheap warm-path subset: a plan-cache hit rebinds a
verified plan onto fresh data, so only the binding-dependent checks need to
re-run (the service's cache-hit path calls exactly this).
"""

from __future__ import annotations

import math
from dataclasses import replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..analysis.loadmodel import MODEL_CONSTANT, round_bounds_by_name
from ..core.jointree import JoinTree, build_join_tree, running_intersection_ok
from ..core.planner import _stable_base
from ..core.taxonomy import residual_size
from .faults import ProgramVerificationError
from .hypercube import uniform_lp_shares
from .program import (
    GENERAL_ACYCLIC_OPS,
    GENERAL_CYCLIC_OPS,
    BroadcastSizes,
    GridRoute,
    HashPartition,
    LocalJoin,
    RoundProgram,
    RouteResidual,
    Scatter,
    SemiJoin,
    StageGeometry,
    fuse_semijoin_pass,
    stage_geometry,
)

#: Every rule a verification pass can fail with (ProgramVerificationError.rule).
RULES = (
    "scatter-binding",
    "semijoin-fusion",
    "grid-invariants",
    "cap-grid",
    "packed-key",
    "collective-stream",
    "load-bound",
    "join-tree",
    "share-exponent",
)

#: Cell-id space limit of the packed grid-route path (mirrors the
#: ``_lower_grid_route`` guard in executors.py).
INT32_CELLS = 1 << 31

_INT32_MAX = int(np.iinfo(np.int32).max)


def _fail(rule: str, op_round: Optional[str], detail: str) -> None:
    raise ProgramVerificationError(
        f"[{rule}] {op_round or 'program'}: {detail}",
        op_round=op_round,
        rule=rule,
        detail=detail,
    )


class VerificationReport:
    """What a successful pass covered (``repr`` shows up in CI logs)."""

    def __init__(self, p: int, stages: int, checks: int, geometry_probes: int):
        self.p = p
        self.stages = stages
        self.checks = checks
        self.geometry_probes = geometry_probes
        self.rules = RULES

    def __repr__(self) -> str:
        return (
            f"VerificationReport(p={self.p}, stages={self.stages}, "
            f"checks={self.checks}, geometry_probes={self.geometry_probes})"
        )


# ---------------------------------------------------------------------------
# collective-stream + semijoin-fusion: the op sequence
# ---------------------------------------------------------------------------

_OP_ORDER = {
    Scatter: 0,
    RouteResidual: 1,
    HashPartition: 2,
    SemiJoin: 3,
    BroadcastSizes: 4,
    GridRoute: 5,
    LocalJoin: 6,
}

#: Ops that must appear exactly once for a serial collective order to exist.
_SINGLETONS = (Scatter, RouteResidual, HashPartition, BroadcastSizes, GridRoute, LocalJoin)


def _check_op_stream(program: RoundProgram) -> int:
    """``collective-stream``: exactly-once collectives in canonical order."""
    last = -1
    counts: Dict[type, int] = {}
    for op in program.ops:
        rank = _OP_ORDER.get(type(op))
        if rank is None:
            _fail("collective-stream", getattr(op, "round", None),
                  f"unknown op {type(op).__name__} has no place in the serial collective order")
        if rank < last:
            _fail("collective-stream", op.round,
                  f"{type(op).__name__} is scheduled after a later phase — two collectives "
                  f"could be in flight at once (the PR 3 deadlock mode)")
        last = rank
        counts[type(op)] = counts.get(type(op), 0) + 1
    for cls in _SINGLETONS:
        n = counts.get(cls, 0)
        if n == 0:
            _fail("collective-stream", cls().round,
                  f"{cls.__name__} is missing: downstream rounds would consume data that "
                  f"was never routed")
        if n > 1:
            _fail("collective-stream", cls().round,
                  f"{cls.__name__} appears {n} times: the op list admits no strictly-serial "
                  f"collective order")
    return len(program.ops) + len(_SINGLETONS)


def _check_semijoin_fusion(program: RoundProgram) -> int:
    """``semijoin-fusion``: phase pair legality + fuse-pass re-derivability."""
    phases = [op.phase for op in program.ops if isinstance(op, SemiJoin)]
    want = ["fused-route", "fused-filter"] if program.fused else ["x", "y"]
    if phases != want:
        _fail("semijoin-fusion", "step2-bx",
              f"SemiJoin phases {phases} do not form the legal pair {want} "
              f"(fused={program.fused})")
    if program.fused:
        unfused = tuple(
            SemiJoin(phase="x") if isinstance(op, SemiJoin) and op.phase == "fused-route"
            else SemiJoin(phase="y") if isinstance(op, SemiJoin) and op.phase == "fused-filter"
            else op
            for op in program.ops
        )
        refused = fuse_semijoin_pass(replace(program, ops=unfused, fused=False))
        if tuple(refused.ops) != tuple(program.ops):
            _fail("semijoin-fusion", "step2-fused",
                  "fused op list is not the image of fuse_semijoin_pass over its unfused "
                  "form — the rewrite cannot be re-verified")
    return 2


# ---------------------------------------------------------------------------
# scatter-binding: the warm-path (rebind) subset
# ---------------------------------------------------------------------------


def verify_bindings(program: RoundProgram) -> int:
    """The binding-dependent checks (rule ``scatter-binding``) — everything a
    plan-cache hit must re-establish after :meth:`RoundProgram.rebind`.

    O(#relations + #emits) plus one row comparison per shared-table alias
    pair; deliberately cheap enough for the service's warm path.  Returns the
    number of checks performed."""
    q = program.query
    if q is None:
        _fail("scatter-binding", "scatter",
              "program is not bound to a query (cache entries strip the data; "
              "rebind before verifying bindings)")
    if program.p < 1:
        _fail("scatter-binding", "scatter", f"p={program.p} < 1")
    checks = 2
    first_for_table: Dict[str, Tuple[int, object]] = {}
    for i, rel in enumerate(q.relations):
        d = rel.data
        if d.ndim != 2 or d.shape[1] != len(rel.scheme):
            _fail("scatter-binding", "scatter",
                  f"relation {i} {rel.scheme}: data shape {d.shape} does not match "
                  f"scheme arity {len(rel.scheme)}")
        checks += 1
        if rel.table is None:
            continue
        prev = first_for_table.setdefault(rel.table, (i, rel))
        if prev[1] is rel:
            continue
        pd = prev[1].data
        # Scatter places each physical table once and aliases it per edge, so
        # every relation of an alias class must bind identical rows.  Arrays
        # need not be the same object (Relation.make dedups into fresh
        # arrays) — compare contents.
        if pd is not d and (
            pd.shape != d.shape or pd.dtype != d.dtype or not np.array_equal(pd, d)
        ):
            _fail("scatter-binding", "scatter",
                  f"relations {prev[0]} and {i} both declare table {rel.table!r} "
                  f"but bind different data — the shared-input Scatter would place "
                  f"one and silently drop the other")
        checks += 1
    width = len(program.out_cols)
    for mid, row in program.emit:
        if not (0 <= mid < program.p):
            _fail("scatter-binding", "output",
                  f"emit targets machine {mid} outside [0, {program.p})")
        if row.ndim != 2 or row.shape[1] != width:
            _fail("scatter-binding", "output",
                  f"emit row block has shape {row.shape}, want (*, {width})")
        checks += 1
    return checks


# ---------------------------------------------------------------------------
# grid-invariants + packed-key: allocations and geometry
# ---------------------------------------------------------------------------


def check_stage_geometry(geo: StageGeometry, p: int, op_round: str = "step3-route") -> int:
    """Lemma 3.1 / 3.2 invariants of one finalized stage geometry."""
    if geo.skip:
        return 1
    checks = 0
    grp = geo.step3_group
    if grp is not None:
        if grp.p != p or not (0 <= grp.base < p) or grp.size < 1:
            _fail("grid-invariants", op_round,
                  f"step-3 group (base={grp.base}, size={grp.size}, p={grp.p}) is not "
                  f"a valid virtual group over {p} machines")
        checks += 1
    if geo.grid is not None:
        g = geo.grid
        prod = 1
        for d in g.dims:
            if d < 1:
                _fail("grid-invariants", op_round, f"CP grid dimension {d} < 1")
            prod *= int(d)
        if prod > g.p:
            _fail("grid-invariants", op_round,
                  f"Π(grid_dims)={prod} exceeds the Lemma 3.1 machine budget {g.p}")
        if prod != g.size:
            _fail("grid-invariants", op_round,
                  f"CartesianGrid.size={g.size} disagrees with Π(grid_dims)={prod}")
        checks += 3
    cells = geo.cp_size * geo.hc_size
    if grp is not None and cells > grp.size:
        _fail("grid-invariants", op_round,
              f"the Lemma 3.2 composition matrix has {cells} cells but the step-3 "
              f"group only has {grp.size} machines")
    if cells >= INT32_CELLS:
        _fail("packed-key", op_round,
              f"cell space {cells} ≥ 2^31: packed int32 cell ids would overflow "
              f"(the _lower_grid_route guard would reject this at run time)")
    for cp in {0, geo.cp_size - 1}:
        for hc in {0, geo.hc_size - 1}:
            if geo.cell(cp, hc) != cp * geo.hc_size + hc:
                _fail("grid-invariants", op_round,
                      f"cell({cp}, {hc}) = {geo.cell(cp, hc)} is not the row-major "
                      f"Lemma 3.2 flattening {cp * geo.hc_size + hc}")
            checks += 1
    return checks + 2


def _check_stages(program: RoundProgram) -> Tuple[int, int]:
    """Per-stage allocation checks + synthetic geometry probes.

    Geometry depends only on (stage signature, m_η) for a fixed program, so
    probes are deduplicated on that key — stage counts can be large (one per
    surviving η) while distinct geometries stay O(#signatures)."""
    p = program.p
    stats = program.stats
    if stats.lam != program.lam:
        _fail("grid-invariants", "step1",
              f"program.lam={program.lam} disagrees with stats.lam={stats.lam}")
    k = len(program.query.attset)
    denom = max(1.0, float(stats.m) * float(stats.lam) ** max(0, k - 2))
    checks, probes = 1, 0
    probed = set()
    for st in program.stages:
        cfg = st.cfg
        grp = cfg.step1_group
        if grp.p != p or not (0 <= grp.base < p) or not (1 <= grp.size <= p):
            _fail("grid-invariants", "step1",
                  f"stage (H={st.plan.h_set}, η={cfg.eta.values}): step-1 group "
                  f"(base={grp.base}, size={grp.size}, p={grp.p}) is not a valid "
                  f"virtual group over {p} machines")
        if grp.base != _stable_base(p, "s1", st.plan.h_set, cfg.eta.values):
            _fail("grid-invariants", "step1",
                  f"stage (H={st.plan.h_set}, η={cfg.eta.values}): step-1 group base "
                  f"{grp.base} disagrees with the stable hash — senders and receivers "
                  f"would disagree on the group")
        m_eta = residual_size(program.query, stats, st.plan, cfg.eta)
        if m_eta != cfg.m_eta:
            _fail("grid-invariants", "step1",
                  f"stage (H={st.plan.h_set}, η={cfg.eta.values}): recorded "
                  f"m_η={cfg.m_eta} but the residual size recomputes to {m_eta}")
        want = min(p, max(1, math.ceil(p * cfg.m_eta / denom)))
        if grp.size != want:
            _fail("grid-invariants", "step1",
                  f"stage (H={st.plan.h_set}, η={cfg.eta.values}): step-1 group size "
                  f"{grp.size} != allocation formula ⌈p·m_η/(m·λ^(k-2))⌉ = {want}")
        checks += 4
        pkey = (st.signature, cfg.m_eta)
        if pkey in probed:
            continue
        probed.add(pkey)
        for s in sorted({1, max(1, cfg.m_eta)}):
            entries = {x: [(0, s)] for x in st.plan.isolated}
            geo = stage_geometry(program, st, entries)
            checks += check_stage_geometry(geo, p)
            probes += 1
    return checks, probes


# ---------------------------------------------------------------------------
# join-tree + share-exponent + collective-stream: the general route
# ---------------------------------------------------------------------------


def _check_general_stream(program: RoundProgram) -> int:
    """``collective-stream`` for general programs: the op list must be the
    exact compiler image — Scatter, both TreeSemiJoin sweeps (up before
    down), ShareRoute, CellJoin for acyclic plans; Scatter, ShareRoute,
    CellJoin for cyclic ones.  Anything else breaks either the strictly
    serial collective order or the Yannakakis reduction (a down sweep before
    the up sweep is not a full reducer)."""
    want = (
        GENERAL_ACYCLIC_OPS if program.general.kind == "yannakakis"
        else GENERAL_CYCLIC_OPS
    )
    if tuple(program.ops) != want:
        _fail("collective-stream", None,
              f"general op sequence {program.op_sequence()} is not the "
              f"canonical {[op.round for op in want]} stream for a "
              f"{program.general.kind!r} plan — the semijoin sweeps must run "
              f"up-then-down before the route, each collective exactly once")
    return 1


def _check_join_tree(program: RoundProgram) -> int:
    """``join-tree``: the compiled plan's tree is a real join tree of the
    query — every non-root relation hangs off exactly one parent, every edge
    label is the full scheme intersection, the running intersection property
    holds, the recorded order is leaves-first (a valid up sweep), and the
    CellJoin order is a tree pre-order.  Cyclic plans must carry no tree and
    acyclic queries must not have been demoted to the cyclic route."""
    gen = program.general
    schemes = [frozenset(r.scheme) for r in program.query.relations]
    n = len(schemes)
    real_tree = build_join_tree(schemes)
    if gen.kind == "hypercube":
        if gen.tree_edges:
            _fail("join-tree", "hc-route",
                  "cyclic (hypercube) plan carries join-tree edges")
        if real_tree is not None:
            _fail("join-tree", "hc-route",
                  "query is GYO-acyclic but the plan routes it through the "
                  "cyclic HyperCube program — the Yannakakis reduction was "
                  "dropped")
        if sorted(gen.join_order) != list(range(n)):
            _fail("join-tree", "output",
                  f"join order {gen.join_order} is not a permutation of the "
                  f"{n} relations")
        return 3
    if real_tree is None:
        _fail("join-tree", "yan-up",
              "query is cyclic but the plan claims a Yannakakis join tree")
    tree = JoinTree(
        n_nodes=n,
        root=gen.tree_root,
        edges=tuple(
            (c, par, frozenset(sh)) for c, par, sh in gen.tree_edges
        ),
    )
    if not running_intersection_ok(schemes, tree):
        _fail("join-tree", "yan-up",
              f"tree edges {gen.tree_edges} violate the running intersection "
              f"property (or are structurally broken) — the two semijoin "
              f"sweeps would not be a full reducer")
    checks = 2
    for c, par, sh in gen.tree_edges:
        if frozenset(sh) != schemes[c] & schemes[par]:
            _fail("join-tree", "yan-up",
                  f"edge ({c}, {par}) label {sh} is not the full scheme "
                  f"intersection {sorted(schemes[c] & schemes[par])}")
        checks += 1
    removed: set = set()
    for c, par, _ in gen.tree_edges:
        if c in removed or par in removed:
            _fail("join-tree", "yan-up",
                  f"edge ({c}, {par}) fires after one endpoint was already "
                  f"removed — the recorded order is not a leaves-first up "
                  f"sweep (the down sweep, its reverse, breaks too)")
        removed.add(c)
        checks += 1
    order = gen.join_order
    if sorted(order) != list(range(n)):
        _fail("join-tree", "output",
              f"join order {order} is not a permutation of the {n} relations")
    if order and order[0] != gen.tree_root:
        _fail("join-tree", "output",
              f"join order starts at {order[0]}, not the tree root "
              f"{gen.tree_root}")
    parent = tree.parent
    placed = {gen.tree_root}
    for node in order[1:]:
        if parent.get(node) not in placed:
            _fail("join-tree", "output",
                  f"join order {order} joins relation {node} before its tree "
                  f"parent — the chain step would be a cartesian blowup, not "
                  f"a tree-edge join")
        placed.add(node)
        checks += 1
    return checks + 2


def _check_share_exponent(program: RoundProgram) -> int:
    """``share-exponent``: the HyperCube shares are positive integers over
    exactly the output attributes, their product respects the machine budget
    Π ≤ p, and they equal the fractional-edge-cover LP solution the compiler
    derives (`uniform_lp_shares`) — a tampered share vector either breaks
    exactly-once cell assembly or the m/p^{1/ρ} load shape."""
    gen = program.general
    shares = dict(gen.shares)
    attrs = set(program.query.attset)
    if set(shares) != attrs:
        _fail("share-exponent", "hc-route",
              f"share attributes {sorted(shares)} do not cover the query "
              f"attributes {sorted(attrs)} — unshared attributes break "
              f"exactly-once cell assembly")
    prod = 1
    for a, s in sorted(shares.items()):
        if not isinstance(s, int) or s < 1:
            _fail("share-exponent", "hc-route",
                  f"share({a}) = {s!r} is not a positive integer")
        prod *= s
    if prod > program.p:
        _fail("share-exponent", "hc-route",
              f"Π shares = {prod} exceeds the machine budget p = {program.p}")
    want = uniform_lp_shares(program.query.hypergraph, program.p)
    if shares != {a: int(s) for a, s in want.items()}:
        _fail("share-exponent", "hc-route",
              f"shares {sorted(shares.items())} disagree with the "
              f"fractional-edge-cover LP solution "
              f"{sorted((a, int(s)) for a, s in want.items())}")
    return len(shares) + 3


# ---------------------------------------------------------------------------
# cap-grid + packed-key: executor-facing helpers
# ---------------------------------------------------------------------------


def on_cap_grid(n: int) -> bool:
    """True iff ``n`` is a legal quantized capacity: ≥ 16 and of the form
    2^k or 3·2^(k-1) (the ``_quant`` grid in executors.py)."""
    if n != int(n) or n < 16:
        return False
    n = int(n)
    if n & (n - 1) == 0:
        return True
    return n % 3 == 0 and (n // 3) >= 8 and ((n // 3) & (n // 3 - 1)) == 0


def verify_caps(caps: Mapping, op_round: Optional[str] = None) -> int:
    """``cap-grid``: every learned capacity is a positive int on the quant
    grid and every signature maps channel names to capacities."""
    checks = 0
    for key, chans in caps.items():
        if not isinstance(chans, Mapping):
            _fail("cap-grid", op_round,
                  f"cap signature {key!r} maps to {type(chans).__name__}, "
                  f"want a channel→capacity mapping")
        for chan, cap in chans.items():
            if not isinstance(chan, str):
                _fail("cap-grid", op_round,
                      f"cap signature {key!r} has non-string channel {chan!r}")
            if not on_cap_grid(cap):
                _fail("cap-grid", op_round,
                      f"cap {chan}={cap!r} for {key!r} is off the {{2^k, 3·2^(k-1)}} "
                      f"quantization grid (≥ 16) — unbounded executable signatures")
            checks += 1
    return checks


def check_packed_key(
    max_cell: int, dup_maxes: Sequence[int], packed: bool, op_round: str = "output"
) -> None:
    """``packed-key``: the packed flag is only legal when the mixed-radix key
    space (max_cell+1)·Π(max_dup_i+1) fits int32 with non-negative parts."""
    if not packed:
        return
    if max_cell < 0 or any(d < 0 for d in dup_maxes):
        _fail("packed-key", op_round,
              "packed flag set with a negative key component — packing is not "
              "collision-free over negatives")
    space = int(max_cell) + 1
    for d in dup_maxes:
        space *= int(d) + 1
    if space > _INT32_MAX:
        _fail("packed-key", op_round,
              f"packed flag set but the mixed-radix key space {space} exceeds "
              f"INT32_MAX={_INT32_MAX} — keys would collide")


# ---------------------------------------------------------------------------
# load-bound: the symbolic model vs a metered run
# ---------------------------------------------------------------------------


def check_load(program: RoundProgram, result, constant: float = 1.0) -> Dict[str, float]:
    """``load-bound``: assert every measured round load of a metered run is
    ≤ ``constant`` × the symbolic model bound of
    :func:`repro.analysis.loadmodel.round_bounds`.

    ``result`` is an ``MPCJoinResult`` (anything with ``.sim``) or a plain
    ``{round: load}`` mapping (e.g. ``sim.merged_round_loads()``).  Returns
    the per-round measured/bound fractions on success."""
    measured = result if isinstance(result, Mapping) else result.sim.merged_round_loads()
    bounds = round_bounds_by_name(program, constant=MODEL_CONSTANT)
    fractions: Dict[str, float] = {}
    for name, load in measured.items():
        b = bounds.get(name)
        if b is None:  # scatter/output: load-free rounds
            continue
        limit = constant * b.words
        if load > limit:
            _fail("load-bound", name,
                  f"measured load {load:.0f} exceeds the Theorem 6.2 model bound "
                  f"{limit:.0f} = {constant:g} × {b.formula}")
        fractions[name] = load / max(limit, 1e-30)
    return fractions


# ---------------------------------------------------------------------------
# the full static pass
# ---------------------------------------------------------------------------


def verify_program(
    program: RoundProgram, caps: Optional[Mapping] = None
) -> VerificationReport:
    """Run every static rule over a *bound* compiled program.

    ``caps`` optionally adds the executor's learned-capacity store to the
    pass (rule ``cap-grid``).  Raises :class:`ProgramVerificationError` on
    the first violation; returns a :class:`VerificationReport` otherwise."""
    checks = verify_bindings(program)
    if getattr(program, "general", None) is not None:
        # General (arbitrary-arity) programs: the binary taxonomy rules have
        # no meaning here — the structural invariants are the join tree, the
        # share exponents, and the general collective stream.
        checks += _check_general_stream(program)
        checks += _check_join_tree(program)
        checks += _check_share_exponent(program)
        if caps is not None:
            checks += verify_caps(caps)
        return VerificationReport(
            p=program.p, stages=len(program.stages), checks=checks,
            geometry_probes=0,
        )
    checks += _check_op_stream(program)
    checks += _check_semijoin_fusion(program)
    stage_checks, probes = _check_stages(program)
    checks += stage_checks
    if caps is not None:
        checks += verify_caps(caps)
    return VerificationReport(
        p=program.p, stages=len(program.stages), checks=checks, geometry_probes=probes
    )
