"""train_step / serve_step / prefill_step builders — the functions the launcher jits.

train_step supports microbatch gradient accumulation (lax.scan over microbatches) and
optional int8 gradient compression with error feedback. Under a mesh, the DP gradient
mean is implicit in GSPMD (batch sharded over dp ⇒ the loss mean inserts the
all-reduce); compression runs on the accumulated local gradient before the optimizer.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.model import decode_step, loss_fn, prefill
from .optimizer import (
    AdamWConfig,
    adamw_update,
    compressed_grads_with_ef,
    init_ef_state,
)


@dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    compress_grads: bool = False


def make_train_step(cfg, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) → (params, opt_state, metrics).

    opt_state = {"adamw": …, "ef": … (if compression)}.
    batch leaves have leading dim = global_batch (microbatches folded internally).
    """

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True
        )(params)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            def split(x):
                b = x.shape[0]
                mb = tcfg.microbatches
                return x.reshape(mb, b // mb, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb_batch):
                acc = carry
                g, metrics = compute_grads(params, mb_batch)
                acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
                return acc, metrics

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, metrics_all = jax.lax.scan(acc_body, zero, micro)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics_all)
        else:
            grads, metrics = compute_grads(params, batch)

        if tcfg.compress_grads:
            grads, new_ef = compressed_grads_with_ef(grads, opt_state["ef"])
        else:
            new_ef = opt_state.get("ef")

        new_params, new_adamw, opt_metrics = adamw_update(
            tcfg.adamw, params, grads, opt_state["adamw"]
        )
        new_opt = {"adamw": new_adamw}
        if new_ef is not None:
            new_opt["ef"] = new_ef
        metrics = {**metrics, **opt_metrics}
        return new_params, new_opt, metrics

    return train_step


def init_train_state(cfg, tcfg: TrainConfig, params):
    from .optimizer import init_opt_state

    state = {"adamw": init_opt_state(params)}
    if tcfg.compress_grads:
        state["ef"] = init_ef_state(params)
    return state


def make_serve_step(cfg):
    """serve_step(params, cache, tokens_last) → (next_tokens, logits, cache)."""

    def serve_step(params, cache, tokens_last):
        logits, cache = decode_step(cfg, params, cache, tokens_last)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, cache

    return serve_step


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch)

    return prefill_step
