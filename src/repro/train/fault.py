"""Fault-tolerance utilities: straggler detection, heartbeats, bounded retries.

On a real 1000-node fleet, per-step timing skew is the first failure signal: a host
whose step time drifts k× above the fleet EMA is a straggler (failing HBM, thermal
throttle, a noisy neighbor). The monitor keeps an EMA + deviation score and fires a
callback (log / re-shard / evict) — the same hook a pod-level supervisor consumes.
Heartbeat files let an external watchdog detect a hung process (no Python-level signal
can be trusted when XLA wedges) and restart it; auto-resume then picks up the latest
checkpoint (see checkpoint.py).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, List, Optional


@dataclass
class StragglerMonitor:
    factor: float = 2.5          # slow-step threshold vs EMA
    alpha: float = 0.1           # EMA weight
    warmup: int = 3              # ignore the first steps (compile, cache warm)
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    _ema: Optional[float] = field(default=None, init=False)
    _n: int = field(default=0, init=False)
    events: List[dict] = field(default_factory=list, init=False)

    def record(self, step: int, duration_s: float) -> bool:
        """Returns True if this step is flagged as a straggler event."""
        self._n += 1
        if self._n <= self.warmup:
            return False
        if self._ema is None:
            self._ema = duration_s
            return False
        slow = duration_s > self.factor * self._ema
        if slow:
            self.events.append({"step": step, "duration_s": duration_s, "ema_s": self._ema})
            if self.on_straggler:
                self.on_straggler(step, duration_s, self._ema)
        # clamp the update so one straggler doesn't poison the EMA
        upd = min(duration_s, self.factor * self._ema)
        self._ema = (1 - self.alpha) * self._ema + self.alpha * upd
        return slow

    def reset(self) -> None:
        """Forget the latency model (EMA + warmup), keep the event log.

        Supervised-restart hook: after a crash/recovery cycle the first
        post-restart steps recompile and re-warm caches, so judging them
        against the pre-crash EMA would flag every one of them."""
        self._ema = None
        self._n = 0

    @property
    def ema_s(self) -> Optional[float]:
        return self._ema


class Heartbeat:
    """Touch a file every step; an external watchdog restarts the process when the
    mtime goes stale (the launcher's auto-resume makes the restart cheap)."""

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def beat(self, step: int) -> None:
        self.path.write_text(f"{step} {time.time()}\n")

    def age_s(self) -> Optional[float]:
        if not self.path.exists():
            return None
        return time.time() - self.path.stat().st_mtime


def retry(fn: Callable, attempts: int = 3, backoff_s: float = 1.0,
          retriable=(OSError, IOError)):
    """Bounded retry for transient host-side failures (checkpoint I/O, RPC)."""
    last = None
    for i in range(attempts):
        try:
            return fn()
        except retriable as e:  # noqa: PERF203
            last = e
            time.sleep(backoff_s * (2 ** i))
    raise last
