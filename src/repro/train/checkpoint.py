"""Checkpoint/restart with elastic resharding.

Format: one .npz per checkpoint step (leaves keyed by tree key-path) + a manifest
JSON (step, arch, mesh geometry, wall time). Writes are atomic (tmp + rename) and a
``latest`` marker is updated last, so a crash mid-write can never corrupt the resume
point — the launcher's auto-resume picks the newest complete step.

Elastic: leaves are saved as *global* (unsharded) arrays; restore re-places them under
whatever mesh/shardings the new run uses (the geometry can change between runs —
device_put reshards). An async writer thread overlaps serialization with training.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":  # npz can't store ml_dtypes: upcast lossless
            arr = arr.astype(np.float32)
        flat[jax.tree_util.keystr(path)] = arr
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    leaves = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(template)[0]:
        key = jax.tree_util.keystr(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------------

    def _paths(self, step: int) -> Tuple[Path, Path]:
        return self.dir / f"ckpt_{step:08d}.npz", self.dir / f"ckpt_{step:08d}.json"

    def save(self, step: int, state: Dict[str, Any], meta: Optional[dict] = None) -> None:
        npz, man = self._paths(step)
        flat = _flatten(state)
        tmp = npz.with_suffix(".npz.tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        tmp.rename(npz)
        manifest = {"step": step, "time": time.time(), **(meta or {})}
        tmp2 = man.with_suffix(".json.tmp")
        tmp2.write_text(json.dumps(manifest, indent=2))
        tmp2.rename(man)
        (self.dir / "latest.tmp").write_text(str(step))
        (self.dir / "latest.tmp").rename(self.dir / "latest")
        self._gc()

    def save_async(self, step: int, state: Dict[str, Any], meta: Optional[dict] = None) -> None:
        """Snapshot to host memory synchronously (cheap), write on a thread."""
        self.wait()
        flat = _flatten(state)  # device_get happens here, before training resumes

        def _write():
            npz, man = self._paths(step)
            tmp = npz.with_suffix(".npz.tmp")
            with open(tmp, "wb") as f:
                np.savez(f, **flat)
            tmp.rename(npz)
            manifest = {"step": step, "time": time.time(), **(meta or {})}
            tmp2 = man.with_suffix(".json.tmp")
            tmp2.write_text(json.dumps(manifest, indent=2))
            tmp2.rename(man)
            (self.dir / "latest.tmp").write_text(str(step))
            (self.dir / "latest.tmp").rename(self.dir / "latest")
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            npz, man = self._paths(s)
            npz.unlink(missing_ok=True)
            man.unlink(missing_ok=True)

    # -- restore ----------------------------------------------------------------

    def all_steps(self):
        return [
            int(p.stem.split("_")[1]) for p in self.dir.glob("ckpt_*.npz")
        ]

    def latest_step(self) -> Optional[int]:
        marker = self.dir / "latest"
        if marker.exists():
            s = int(marker.read_text().strip())
            if self._paths(s)[0].exists():
                return s
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, step: int, template, shardings=None):
        """Rebuild `template`-shaped state; device_put under `shardings` (elastic)."""
        npz, man = self._paths(step)
        with np.load(npz) as data:
            flat = {k: data[k] for k in data.files}
        state = _unflatten(template, flat)
        if shardings is not None:
            state = jax.tree.map(lambda a, s: jax.device_put(a, s), state, shardings)
        else:
            state = jax.tree.map(jax.device_put, state)
        meta = json.loads(man.read_text()) if man.exists() else {"step": step}
        return state, meta
