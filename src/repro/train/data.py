"""Deterministic, stateless-resumable synthetic data pipeline.

Every batch is a pure function of (seed, step, rank geometry): restart-safe by
construction — resuming at step k regenerates exactly the same stream with no iterator
state to checkpoint (the fault-tolerance story's data leg). Shardable: each DP rank
materializes only its slice.

The token stream is a hash-mixed Zipf-ish LM surrogate with enough structure for loss
to fall (next token depends on current token + position parity)."""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _mix(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> 30)) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> 27)) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> 31)


def synth_batch(
    cfg,
    step: int,
    global_batch: int,
    seq: int,
    seed: int = 0,
    rank: int = 0,
    n_ranks: int = 1,
) -> Dict[str, np.ndarray]:
    """Batch slice for `rank` of `n_ranks`. tokens/labels (B_loc, seq)."""
    assert global_batch % n_ranks == 0
    b_loc = global_batch // n_ranks
    rows = np.arange(rank * b_loc, (rank + 1) * b_loc, dtype=np.uint64)
    base = _mix(
        rows[:, None] * np.uint64(1_000_003)
        + np.uint64(step) * np.uint64(7_919)
        + np.uint64(seed)
    )
    pos = np.arange(seq, dtype=np.uint64)[None, :]
    raw = _mix(base + pos * np.uint64(2_654_435_761))
    vocab = cfg.vocab
    # structured stream: half the positions repeat a rank-specific motif (learnable)
    motif = (base % np.uint64(max(1, vocab // 8))).astype(np.int64)
    noise = (raw % np.uint64(vocab)).astype(np.int64)
    parity = (np.arange(seq) % 2 == 0)[None, :]
    tokens = np.where(parity, motif, noise).astype(np.int32)
    out = {"tokens": tokens, "labels": tokens.copy()}
    if cfg.frontend == "prefix_embeds":
        emb = _mix(base[:, :1] + np.uint64(17)).astype(np.float64)
        rng = np.random.default_rng(int(emb[0, 0]) % (2**32))
        out["vision_embeds"] = rng.standard_normal(
            (b_loc, cfg.n_frontend, cfg.d_model), dtype=np.float32
        )
        out["tokens"] = tokens[:, : seq - cfg.n_frontend]
        out["labels"] = out["tokens"].copy()
    elif cfg.frontend == "encoder_frames":
        rng = np.random.default_rng((seed * 977 + step * 31 + rank) % (2**32))
        out["frames"] = rng.standard_normal(
            (b_loc, cfg.n_frontend, cfg.d_model), dtype=np.float32
        )
    return out
