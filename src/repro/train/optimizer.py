"""AdamW with fp32 master weights + optional int8 gradient compression.

State layout (per parameter leaf): {"master": fp32 copy, "m": fp32, "v": fp32} plus
{"step": scalar}. Model params stay in cfg.dtype (bf16) for compute; the update runs in
fp32 against the master copy and re-casts. Under the production mesh the state inherits
the parameter sharding *plus* DP sharding on the first divisible dim (ZeRO-1) — see
repro/distributed/specs.py.

Gradient compression (cfg-flag): symmetric per-leaf int8 quantization with error
feedback [Seide et al.; 1-bit Adam lineage]. The quantize→dequantize round-trip runs
*before* the DP mean so the all-reduce payload is int8 (the dry-run lowers the
quantized collective; on CPU tests we verify convergence parity and EF correctness).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(1, cfg.warmup_steps)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
    )
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    # jnp.array(copy) — astype is a no-op for already-fp32 leaves and the resulting
    # buffer aliasing breaks donation (same buffer donated twice).
    f32 = lambda p: jnp.array(p, dtype=jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), n


def adamw_update(
    cfg: AdamWConfig, params, grads, state
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params (model dtype), new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(master, m, v, g):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master)
        return new_master, m, v

    flat_master, treedef = jax.tree.flatten(state["master"])
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_g = jax.tree.leaves(grads)
    new_master, new_m, new_v = [], [], []
    for ma, m, v, g in zip(flat_master, flat_m, flat_v, flat_g):
        a, b, c = upd(ma, m, v, g)
        new_master.append(a)
        new_m.append(b)
        new_v.append(c)
    new_state = {
        "master": jax.tree.unflatten(treedef, new_master),
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "step": step,
    }
    model_dtype = jax.tree.leaves(params)[0].dtype
    new_params = jax.tree.map(lambda ma: ma.astype(model_dtype), new_state["master"])
    # CSE barrier: fp32 leaves would otherwise share output buffers with the master
    # copy, and the next step's double-donation fails at Execute().
    new_params = jax.lax.optimization_barrier(new_params)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback
# ---------------------------------------------------------------------------


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q int8, scale fp32)."""
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_grads_with_ef(grads, ef_state):
    """Quantize (grad + ef) per leaf; new ef = residual. Returns (deq grads, new ef)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = compress_int8(g)
        deq = decompress_int8(q, s)
        return deq, g - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = jax.tree.unflatten(treedef, [o[0] for o in outs])
    ef = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return deq, ef


def init_ef_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
