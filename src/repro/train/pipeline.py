"""Pipeline parallelism (design + working reference implementation).

The assigned production meshes fix the axes to (pod, data, model), so PP is not part
of the graded dry-run (DESIGN.md §7) — but the feature exists: a GPipe-style schedule
over a "stage" mesh axis using shard_map + collective_permute. Layers are split into
S stages; M microbatches flow through; each tick every stage computes its resident
microbatch and ppermutes activations to the next stage. Bubble fraction is the usual
(S-1)/(M+S-1).

`pipelined_forward` is validated against the serial reference in
tests/test_dataplane_subprocess.py (4 fake host devices, 2 stages × 2 dp)."""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipelined_forward(
    mesh,
    stage_axis: str,
    n_stages: int,
    n_micro: int,
    stage_fn: Callable[[jax.Array, int], jax.Array],
    x: jax.Array,              # (n_micro, B_micro, ...) microbatched input
    stage_params,              # pytree with leading dim = n_stages
):
    """GPipe forward: returns (n_micro, B_micro, ...) outputs from the last stage.

    stage_fn(x_micro, params_slice) applies one stage's layers.
    """
    from jax.experimental.shard_map import shard_map

    def body(xm, sp):
        # xm: (n_micro, B, ...) replicated per stage; sp: this stage's params (1, ...)
        sp = jax.tree.map(lambda a: a[0], sp)
        sid = jax.lax.axis_index(stage_axis)
        ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(xm[0])
        outs = jnp.zeros_like(xm)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (when valid)
            mb = jnp.clip(t, 0, n_micro - 1)
            inject = xm[mb]
            cur = jnp.where(sid == 0, inject, buf)
            valid = (t - sid >= 0) & (t - sid < n_micro)
            y = stage_fn(cur, sp)
            y = jnp.where(valid, y, jnp.zeros_like(y))
            # pass activations down the pipe
            nxt = jax.lax.ppermute(
                y, stage_axis,
                perm=[(i, i + 1) for i in range(n_stages - 1)],
            )
            # last stage records its finished microbatch
            out_mb = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            is_out = (sid == n_stages - 1) & valid
            outs = jax.lax.cond(
                is_out,
                lambda o: o.at[out_mb].set(y),
                lambda o: o,
                outs,
            )
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage's outs are real; broadcast via masked psum
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), stage_axis
        )
        return outs

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(stage_axis)),
        out_specs=P(),
        check_rep=False,
    )
    return fn(x, stage_params)
