"""Hierarchical gradient synchronization for multi-pod meshes.

On a (pod, data, model) mesh the naive DP gradient all-reduce spans pod × data —
crossing the (slower, oversubscribed) inter-pod links with full payload. The
hierarchical schedule:

    1. reduce-scatter within the pod over "data"   (fast intra-pod ICI)
    2. all-reduce the 1/16 shards across "pod"     (inter-pod traffic ÷ 16)
    3. all-gather within the pod over "data"

moves 2/16 of the payload across pods instead of 2×. Implemented as a shard_map so
the schedule is explicit in the HLO (the dry-run's collective table shows the swap);
`sync_grads(grads, mesh, axes)` is a drop-in used by the train driver when the mesh
has a "pod" axis. Composes with int8 compression (optimizer.py): quantize before
step 1, dequantize after step 3.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _hier_one(g: jax.Array, data_size: int) -> jax.Array:
    """Inside shard_map: g is the device-local gradient block (already summed over
    model-parallel partial terms by GSPMD before entry). ``data_size`` is the
    static "data" axis extent (shapes below depend on it, so it must be a
    Python int, not a collective result)."""
    # flatten so the scatter axis always divides
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % data_size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    # 1. reduce-scatter over data (psum_scatter)
    shard = jax.lax.psum_scatter(
        flat.reshape(data_size, -1), "data", scatter_dimension=0, tiled=False
    )
    # 2. all-reduce across pods
    shard = jax.lax.psum(shard, "pod")
    # 3. all-gather back over data
    full = jax.lax.all_gather(shard, "data", axis=0, tiled=False).reshape(-1)
    if pad:
        full = full[:n]
    return full.reshape(g.shape)


def hierarchical_mean(grads: Any, mesh, replicated_specs) -> Any:
    """All leaves are replicated inputs per (pod, data) and already divided by the
    global batch; returns the cross-replica mean with the hierarchical schedule."""
    from jax.experimental.shard_map import shard_map

    n_rep = mesh.shape["pod"] * mesh.shape["data"]
    data_size = mesh.shape["data"]

    def body(g):
        return jax.tree.map(lambda x: _hier_one(x, data_size) / n_rep, g)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(replicated_specs,), out_specs=replicated_specs,
        check_rep=False,
    )
    return fn(grads)
