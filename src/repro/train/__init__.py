"""Training substrate: AdamW (built here — no optax in the container), ZeRO-1 via
sharding specs, gradient compression with error feedback, deterministic resumable data
pipeline, checkpoint/restart, straggler monitoring."""
