"""mistral-large-123b [dense] — 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407]. Pure full attention →
long_500k skipped (DESIGN.md §5)."""

from .base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=32768,
    pattern=(BlockSpec(mixer="attn"),),
    rope_theta=1e6,
    sequence_parallel=True,
)
