"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.
24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818; hf].
SWA window 4096 (the danube v1 training window)."""

from .base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32000,
    pattern=(BlockSpec(mixer="attn", window=4096),),
)
