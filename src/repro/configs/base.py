"""Architecture config schema + shape cells (assigned architectures × input shapes).

Every assigned arch is expressed as a repeating ``pattern`` of BlockSpecs (period P),
optionally preceded by ``prefix`` blocks (e.g. DeepSeek's first dense layer). The model
executes ``prefix`` unrolled, then ``jax.lax.scan`` over ``n_layers_in_pattern_repeats``
— keeping HLO size O(P), which is what makes the 88-layer/123B dry-run compile fast.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BlockSpec:
    """One transformer/SSM block position inside the repeating pattern."""

    mixer: str = "attn"          # "attn" | "mla" | "mamba"
    window: int = 0              # 0 = full causal attention; >0 = sliding window
    rope_theta: float = 1e4
    moe: bool = False            # MoE FFN instead of dense FFN
    ffn: bool = True             # Mamba2 backbone has no FFN


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    pattern: Tuple[BlockSpec, ...] = (BlockSpec(),)
    prefix: Tuple[BlockSpec, ...] = ()

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_dispatch: str = "a2a"        # "a2a" (shard_map EP) | "dense" (naive baseline) | "loop"
    capacity_factor: float = 1.25

    # MLA (DeepSeek-V2)
    kv_lora: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # SSM (Mamba2 / SSD)
    d_state: int = 0
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_expand: int = 2
    conv_k: int = 4
    ssd_chunk: int = 256

    # encoder-decoder / frontend stubs
    is_encdec: bool = False
    n_enc_layers: int = 0
    n_frontend: int = 0              # stub length: ViT patches / audio frames
    frontend: str = "none"           # "none" | "prefix_embeds" | "encoder_frames"

    norm: str = "rms"                # "rms" | "ln"
    act: str = "swiglu"              # "swiglu" | "geglu" (gated) | "gelu" (2-matrix)
    rope_theta: float = 1e4
    tie_embeddings: bool = True

    # distribution / memory knobs (hillclimb levers; see EXPERIMENTS §Perf)
    sequence_parallel: bool = False
    sp_boundary: str = "subblock"    # "subblock" (Megatron SP) | "layer" (1 AG+RS/layer)
    remat: str = "nothing"           # "none" | "dots" | "nothing"
    shard_attn_heads: bool = True    # False: replicate attention (tiny models, 12H<16)
    dtype: str = "bfloat16"

    # ---------------------------------------------------------------

    @property
    def vocab_padded(self) -> int:
        return int(math.ceil(self.vocab / 256) * 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def n_repeats(self) -> int:
        n = self.n_layers - len(self.prefix)
        assert n % len(self.pattern) == 0, (self.name, n, len(self.pattern))
        return n // len(self.pattern)

    @property
    def n_dec_layers(self) -> int:
        return self.n_layers

    def block_at(self, layer: int) -> BlockSpec:
        if layer < len(self.prefix):
            return self.prefix[layer]
        return self.pattern[(layer - len(self.prefix)) % len(self.pattern)]

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k: every block is SSM or windowed attention, except
        for a bounded fraction of global layers (hybrid / local:global patterns)."""
        blocks = list(self.prefix) + list(self.pattern)
        full_attn = sum(1 for b in blocks if b.mixer in ("attn", "mla") and b.window == 0)
        return full_attn < len(blocks) / 2

    def param_count(self) -> int:
        """Total parameters (embedding + blocks); used for 6·N·D model-FLOPs."""
        d = self.d_model
        total = self.vocab_padded * d
        if not self.tie_embeddings:
            total += self.vocab_padded * d
        for layer in range(self.n_layers):
            total += self._block_params(self.block_at(layer))
        if self.is_encdec:
            for _ in range(self.n_enc_layers):
                total += self._block_params(BlockSpec()) + self._cross_attn_params()
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared experts only)."""
        d = self.d_model
        total = self.vocab_padded * d
        for layer in range(self.n_layers):
            b = self.block_at(layer)
            total += self._block_params(b, active_only=True)
        if self.is_encdec:
            for _ in range(self.n_enc_layers):
                total += self._block_params(BlockSpec()) + self._cross_attn_params()
        return total

    def _attn_params(self) -> int:
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        return d * h * hd + 2 * d * kv * hd + h * hd * d

    def _mla_params(self) -> int:
        d, h = self.d_model, self.n_heads
        qd = self.qk_nope_dim + self.qk_rope_dim
        out = d * h * qd                        # q proj
        out += d * (self.kv_lora + self.qk_rope_dim)   # kv down + shared k_rope
        out += self.kv_lora * h * (self.qk_nope_dim + self.v_head_dim)  # up-proj
        out += h * self.v_head_dim * d          # o proj
        return out

    def _mamba_params(self) -> int:
        d, di = self.d_model, self.d_inner
        g, s, nh = self.ssm_ngroups, self.d_state, self.ssm_nheads
        out = d * (2 * di + 2 * g * s + nh)     # z, x, B, C, dt projections
        out += self.conv_k * (di + 2 * g * s)   # depthwise conv
        out += nh * 2                           # A_log, D
        out += di * d                           # out proj
        return out

    def _ffn_params(self, d_ff: int) -> int:
        mult = 3 if self.act in ("swiglu", "geglu") else 2
        return mult * self.d_model * d_ff

    def _cross_attn_params(self) -> int:
        return self._attn_params()

    def _block_params(self, b: BlockSpec, active_only: bool = False) -> int:
        if b.mixer == "attn":
            total = self._attn_params()
        elif b.mixer == "mla":
            total = self._mla_params()
        elif b.mixer == "mamba":
            total = self._mamba_params()
        else:
            raise ValueError(b.mixer)
        if self.is_encdec and b.mixer == "attn":
            total += self._cross_attn_params()
        if b.ffn:
            if b.moe:
                n_live = (self.top_k + self.n_shared_experts) if active_only else (
                    self.n_experts + self.n_shared_experts
                )
                total += n_live * self._ffn_params(self.d_ff_expert)
                total += self.d_model * self.n_experts      # router
            else:
                total += self._ffn_params(self.d_ff)
        return total


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Cell applicability per the assignment (skips documented in DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "long_500k requires sub-quadratic attention (pure full-attention arch)"
    return True, ""


def reduced_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests: few layers (≥ one full pattern
    period), small width/vocab/experts — the structure is preserved."""
    small_pattern = tuple(
        replace(b, window=min(b.window, 16) if b.window else 0) for b in cfg.pattern
    )
    small_prefix = tuple(
        replace(b, window=min(b.window, 16) if b.window else 0) for b in cfg.prefix
    )
    n_layers = len(small_prefix) + 2 * len(small_pattern)
    return replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        n_experts=4 if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        vocab=512,
        kv_lora=32 if cfg.kv_lora else 0,
        qk_rope_dim=8 if cfg.kv_lora else cfg.qk_rope_dim,
        qk_nope_dim=16 if cfg.kv_lora else cfg.qk_nope_dim,
        v_head_dim=16 if cfg.kv_lora else cfg.v_head_dim,
        d_state=16 if cfg.d_state else 0,
        ssm_headdim=16 if cfg.d_state else cfg.ssm_headdim,
        ssd_chunk=8,
        n_enc_layers=2 if cfg.is_encdec else 0,
        n_frontend=8 if cfg.n_frontend else 0,
        pattern=small_pattern,
        prefix=small_prefix,
        remat="none",
        shard_attn_heads=True,
    )
