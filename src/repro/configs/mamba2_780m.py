"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.
48L d_model=1536 vocab=50280, d_state=128, headdim=64 → d_inner=3072, 48 SSD heads
[arXiv:2405.21060]. No FFN (the Mamba backbone is norm→mixer→residual only).
SSM → long_500k applies (constant-size recurrent state)."""

from .base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,           # unused (attention-free)
    n_kv_heads=1,
    head_dim=1,
    d_ff=0,
    vocab=50280,
    pattern=(BlockSpec(mixer="mamba", ffn=False),),
    d_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
)
