"""internvl2-26b [vlm] — InternViT frontend (stub) + InternLM2-20B backbone.
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 [arXiv:2404.16821; hf].

The assignment specifies the transformer BACKBONE only; the ViT frontend is a stub:
``input_specs()`` provides 256 precomputed patch embeddings per sample, prepended to the
token sequence (total sequence = shape seq_len; text tokens = seq_len - 256)."""

from .base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    pattern=(BlockSpec(mixer="attn"),),
    n_frontend=256,
    frontend="prefix_embeds",
    rope_theta=1e6,
    sequence_parallel=True,
)
