"""deepseek-moe-16b [moe] — fine-grained experts: 2 shared + 64 routed, top-6.
28L d_model=2048 16H (kv=16, MHA) d_ff(expert)=1408 vocab=102400 [arXiv:2401.06066; hf].
First layer dense (d_ff=10944), remaining 27 MoE."""

from .base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    vocab=102400,
    prefix=(BlockSpec(mixer="attn", moe=False),),
    pattern=(BlockSpec(mixer="attn", moe=True),),
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
)
