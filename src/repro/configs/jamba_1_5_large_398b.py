"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.
72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536 [arXiv:2403.19887; hf].

Pattern period 8: [attn, mamba×7] (1:7 attn:mamba as assigned); MoE FFN on every other
layer (period-2, as in released Jamba), dense FFN otherwise. SSM blocks use the SSD
(Mamba-2) formulation for MXU-friendly chunked matmuls — a TPU adaptation documented in
DESIGN.md (released Jamba uses Mamba-1 selective scan). Hybrid → long_500k applies."""

from .base import ArchConfig, BlockSpec

_P = []
for i in range(8):
    mixer = "attn" if i == 0 else "mamba"
    _P.append(BlockSpec(mixer=mixer, moe=(i % 2 == 1)))

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    pattern=tuple(_P),
    n_experts=16,
    n_shared_experts=0,
    top_k=2,
    d_ff_expert=24576,
    d_state=128,
    ssm_headdim=128,
    ssm_expand=2,
    sequence_parallel=True,
)
