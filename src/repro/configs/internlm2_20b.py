"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544
[arXiv:2403.17297; hf]. Pure full attention → long_500k skipped."""

from .base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92544,
    pattern=(BlockSpec(mixer="attn"),),
    rope_theta=1e6,
    sequence_parallel=True,
)
