"""Registry of assigned architectures (--arch <id>) + shape cells."""

from typing import Dict

from .base import SHAPES, ArchConfig, ShapeSpec, reduced_for_smoke, shape_applicable
from .deepseek_moe_16b import CONFIG as _deepseek_moe_16b
from .deepseek_v2_lite_16b import CONFIG as _deepseek_v2_lite_16b
from .gemma3_12b import CONFIG as _gemma3_12b
from .h2o_danube_1_8b import CONFIG as _h2o_danube_1_8b
from .internlm2_20b import CONFIG as _internlm2_20b
from .internvl2_26b import CONFIG as _internvl2_26b
from .jamba_1_5_large_398b import CONFIG as _jamba_1_5_large_398b
from .mamba2_780m import CONFIG as _mamba2_780m
from .mistral_large_123b import CONFIG as _mistral_large_123b
from .whisper_small import CONFIG as _whisper_small

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _internvl2_26b,
        _whisper_small,
        _gemma3_12b,
        _h2o_danube_1_8b,
        _mistral_large_123b,
        _internlm2_20b,
        _jamba_1_5_large_398b,
        _deepseek_v2_lite_16b,
        _deepseek_moe_16b,
        _mamba2_780m,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
