"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.
48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144 [hf:google/gemma-3 family].

Pattern period 6: five sliding-window (1024) layers then one global layer
(rope_theta 1e4 local / 1e6 global, as in the released configs). The 5:1 local:global
mix makes the arch sub-quadratic-dominated → long_500k applies (DESIGN.md §5)."""

from .base import ArchConfig, BlockSpec

_LOCAL = BlockSpec(mixer="attn", window=1024, rope_theta=1e4)
_GLOBAL = BlockSpec(mixer="attn", window=0, rope_theta=1e6)

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    act="geglu",
    sequence_parallel=True,
)
