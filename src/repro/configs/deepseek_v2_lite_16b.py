"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + fine-grained MoE.
27L d_model=2048 16H d_ff(expert)=1408 vocab=102400, 2 shared + 64 routed top-6
[arXiv:2405.04434; hf]. (The assignment note "160 routed" matches DeepSeek-V2-236B;
V2-*Lite* has 64 routed experts — we follow the hf config, noted in DESIGN.md.)
First layer uses a dense FFN (d_ff=10944), remaining 26 are MoE — hence prefix+pattern.
MLA caches only the 512-d latent + 64-d rope key per token (the paper's point)."""

from .base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    vocab=102400,
    prefix=(BlockSpec(mixer="mla", moe=False),),
    pattern=(BlockSpec(mixer="mla", moe=True),),
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
    kv_lora=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
)
