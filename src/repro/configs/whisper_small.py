"""whisper-small [audio] — encoder-decoder; conv/mel frontend is a STUB.
12L d_model=768 12H (kv=12, i.e. MHA) d_ff=3072 vocab=51865 [arXiv:2212.04356].

Interpreted as 12 encoder + 12 decoder layers (the standard Whisper-small split).
``input_specs()`` provides 1500 precomputed frame embeddings (post-conv stub) for the
encoder; the decoder cross-attends to the encoder output. 12 heads do not divide the
16-way model axis, so attention activations stay replicated over TP (weights and FFN
remain sharded) — see DESIGN.md §5."""

from .base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    pattern=(BlockSpec(mixer="attn"),),
    is_encdec=True,
    n_enc_layers=12,
    n_frontend=1500,
    frontend="encoder_frames",
    norm="ln",
    act="gelu",
    shard_attn_heads=False,
)
