#!/usr/bin/env python
"""Compile + statically verify the parity-suite query battery — the CI
`verify` job's fast gate (no device, no execution, pure host-side planning).

Each battery entry mirrors a tests/test_executor_parity.py case (the query
shapes known to exercise every planner corner: isolated CP grids, ≥2-D grids,
pure-CP hub stars, disconnected light subqueries, fused programs).  For every
entry this script compiles the program — unfused and fused — runs the full
static verifier over it (repro/mpc/verify.py), and prints the per-round
symbolic load bounds of the model (repro/analysis/loadmodel.py).  Any
violation raises a typed ProgramVerificationError and exits non-zero.

    PYTHONPATH=src python tools/verify_battery.py
"""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.analysis.loadmodel import predicted_load
from repro.core.query import disconnected_query, hub_star_query, random_query
from repro.core.taxonomy import compute_stats
from repro.mpc.program import compile_plan
from repro.mpc.verify import verify_program

P = 8

BATTERY = (
    ("triangle-zipf", lambda: random_query(
        np.random.default_rng(2), "clique", 3, tuples_per_rel=200, dom_size=30,
        skew=2.0), 16),
    ("four-cycle-2d-iso", lambda: random_query(
        np.random.default_rng(7), "cycle", 4, tuples_per_rel=120, dom_size=10,
        skew=2.5), 24),
    ("hub-star-pure-cp", lambda: hub_star_query(n=48, hub_n=24, dom_size=25), 10),
    ("disconnected-light", lambda: disconnected_query(90, dom_size=12, skew=1.8), 8),
    ("star4-fusable", lambda: random_query(
        np.random.default_rng(4), "star", 4, tuples_per_rel=150, dom_size=12,
        skew=1.5), 3),
)


def main() -> int:
    failures = 0
    for name, make, lam in BATTERY:
        q = make()
        stats = compute_stats(q, lam)
        for fused in (False, True):
            label = f"{name}{'/fused' if fused else ''}"
            t0 = time.perf_counter()
            try:
                prog = compile_plan(
                    q, stats, P, fuse_semijoin=fused, verify=False
                )
                rep = verify_program(prog)
            except Exception as e:  # noqa: BLE001 - report and keep scanning
                failures += 1
                print(f"FAIL  {label}: {e}")
                continue
            us = (time.perf_counter() - t0) * 1e6
            print(
                f"ok    {label}: stages={rep.stages} checks={rep.checks} "
                f"probes={rep.geometry_probes} "
                f"predicted_load={predicted_load(prog):.0f}w  ({us:.0f}us)"
            )
    if failures:
        print(f"verify_battery: {failures} FAILURES")
        return 1
    print("verify_battery: all programs verified clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
