"""Docs link checker: every intra-repo markdown link must resolve.

Scans all tracked ``*.md`` files (repo root, docs/, docs/design/, …) for
inline markdown links ``[text](target)`` and fails if any relative target —
file or directory — does not exist on disk. External links (http/https/
mailto) and pure in-page anchors (``#…``) are skipped; a relative target's
``#anchor`` suffix is stripped before resolution (we check the file exists,
not the heading). This is the CI ``docs`` job's first step, so a doc page
moved or renamed without updating its references fails the build instead of
rotting silently.

    python tools/check_docs.py [repo_root]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules", ".claude"}


def iter_markdown_files(root: Path):
    for path in sorted(root.rglob("*.md")):
        if any(part in SKIP_DIRS for part in path.parts):
            continue
        yield path


def check_file(path: Path, root: Path):
    """Return a list of (link, reason) for every broken link in ``path``."""
    broken = []
    text = path.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        try:
            resolved.relative_to(root.resolve())
        except ValueError:
            broken.append((target, "escapes the repository"))
            continue
        if not resolved.exists():
            broken.append((target, "does not exist"))
    return broken


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parents[1]
    n_files = n_links_bad = 0
    for path in iter_markdown_files(root):
        n_files += 1
        for target, reason in check_file(path, root):
            n_links_bad += 1
            print(f"BROKEN {path.relative_to(root)}: ({target}) {reason}")
    if n_links_bad:
        print(f"check_docs: {n_links_bad} broken link(s) across {n_files} files")
        return 1
    print(f"check_docs: all intra-repo links resolve ({n_files} markdown files)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
