"""Multi-device data plane checks — run in a subprocess with 8 fake host devices.
Exits nonzero on any failure (the pytest wrapper asserts the return code)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.core.query import (  # noqa: E402
    JoinQuery,
    Relation,
    disconnected_query,
    hub_star_query,
    hub_triangle_query,
    reference_join,
)
from repro.core.taxonomy import compute_stats  # noqa: E402
from repro.dataplane.decode_attn import (  # noqa: E402
    reference_decode_attention,
    split_kv_decode_attention,
)
from repro.dataplane.exchange import blockify  # noqa: E402
from repro.dataplane.join import hypercube_binary_join  # noqa: E402
from repro.mpc.executors import DataplaneExecutor, SimulatorExecutor  # noqa: E402
from repro.mpc.program import compile_plan  # noqa: E402
from repro.train.grad_sync import hierarchical_mean  # noqa: E402
from repro.train.pipeline import pipelined_forward  # noqa: E402


def _mesh(shape, names):
    return jax.make_mesh(shape, names)


def check_join():
    rng = np.random.default_rng(0)
    p, cap = 8, 256
    n_a, n_b = 1200, 1500
    a = rng.integers(0, 60, size=(n_a, 2)).astype(np.int32)
    b = rng.integers(0, 60, size=(n_b, 2)).astype(np.int32)
    # dedup (relations are sets)
    a = np.unique(a, axis=0)
    b = np.unique(b, axis=0)

    a_g, a_c = blockify(a, p, cap)
    b_g, b_c = blockify(b, p, cap)
    mesh = _mesh((p,), ("m",))
    out, cnt, ovf = hypercube_binary_join(
        mesh, "m", a_g, a_c, b_g, b_c, ka=1, kb=0,
        cap_slot=cap, cap_mid=2 * cap, cap_out=4096,
    )
    assert int(jnp.sum(ovf)) == 0, "overflow in padded exchange"
    got = set()
    out_np, cnt_np = np.asarray(out), np.asarray(cnt)
    for i in range(p):
        for r in out_np[i, : cnt_np[i]]:
            got.add((int(r[0]), int(r[1]), int(r[2])))  # (A,B,C)

    q = JoinQuery.make(
        [Relation.make(("A", "B"), a.astype(np.int64)),
         Relation.make(("B", "C"), b.astype(np.int64))]
    )
    oracle = reference_join(q)  # columns sorted: A,B,C
    want = {(int(r[0]), int(r[1]), int(r[2])) for r in oracle.data}
    assert got == want, f"join mismatch: {len(got)} vs {len(want)}"
    print(f"[ok] distributed join: {len(got)} tuples match oracle")


def check_program_binary_join():
    """Acceptance: DataplaneExecutor on the compiled binary-join program matches
    the oracle multiset on 8 fake host devices."""
    rng = np.random.default_rng(0)
    a = np.unique(rng.integers(0, 60, size=(1200, 2)), axis=0)
    b = np.unique(rng.integers(0, 60, size=(1500, 2)), axis=0)
    q = JoinQuery.make(
        [Relation.make(("A", "B"), a), Relation.make(("B", "C"), b)]
    )
    stats = compute_stats(q, lam=2)  # threshold m/2 ⇒ no heavy values ⇒ one H=∅ stage
    program = compile_plan(q, stats, p=8)
    assert [type(op).__name__ for op in program.ops][0] == "Scatter"
    res = DataplaneExecutor().run(program)
    oracle = reference_join(q)
    got = sorted(map(tuple, res.rows.tolist()))
    want = sorted(map(tuple, oracle.data.tolist()))
    assert res.count == len(oracle) and got == want, (res.count, len(oracle))
    print(f"[ok] dataplane executor, binary-join program: {res.count} tuples match oracle")


def check_program_light_subquery():
    """Acceptance: a light-subquery program — triangle with a planted heavy hub.
    The H={X0} stage exercises the HashPartition (unary intersect) and SemiJoin
    lowerings; the H=∅ stage is a cyclic light join (duplicate-attr filter).
    The same program also runs on the simulator backend; both must agree with
    the oracle (and each other) on the result multiset."""
    q = hub_triangle_query(n=150, hub_n=60, dom_size=30)
    stats = compute_stats(q, lam=12)
    assert stats.heavy.get("X0") is not None, "hub must be heavy for this check"
    program = compile_plan(q, stats, p=8)
    assert any(st.hkey == ("X0",) for st in program.stages), "need an H={X0} stage"

    res = DataplaneExecutor().run(program)
    oracle = reference_join(q)
    got = sorted(map(tuple, res.rows.tolist()))
    want = sorted(map(tuple, oracle.data.tolist()))
    assert res.count == len(oracle) and got == want, (res.count, len(oracle))

    sim_res = SimulatorExecutor(p=8).run(program)
    assert sim_res.count == res.count
    assert sorted(map(tuple, sim_res.rows.tolist())) == got
    assert sim_res.per_h_counts == res.per_h_counts
    print(
        f"[ok] dataplane executor, light-subquery program: {res.count} tuples, "
        f"per-H {res.per_h_counts} match oracle + simulator backend"
    )


def check_program_cp_grid():
    """Acceptance: a CP-grid program on 8 real devices — the planted-hub star
    isolates every leaf under H={X0} (no light edges survive), so the stage
    runs entirely through the Lemma 3.1 grid route + per-cell cartesian
    LocalJoin.  The dataplane must match the simulator exactly."""
    q = hub_star_query(n=60, hub_n=30, dom_size=25)
    stats = compute_stats(q, lam=10)
    program = compile_plan(q, stats, p=8)
    cp_stages = [st for st in program.stages if st.plan.isolated]
    assert cp_stages, "hub star must produce CP-grid stages"

    res = DataplaneExecutor().run(program)
    sim_res = SimulatorExecutor(p=8).run(program)
    oracle = reference_join(q)
    assert res.count == sim_res.count == len(oracle), (res.count, sim_res.count)
    assert res.per_h_counts == sim_res.per_h_counts
    assert sorted(map(tuple, res.rows.tolist())) == sorted(
        map(tuple, sim_res.rows.tolist())
    )
    print(
        f"[ok] dataplane executor, CP-grid program: {res.count} tuples, "
        f"{len(cp_stages)} isolated-attribute stage(s) match oracle + simulator"
    )


def check_program_disconnected_light():
    """Acceptance: a disconnected light subquery (A,B) ⋈ (C,D) — formerly the
    second DataplaneUnsupported escape hatch — runs as an in-cell cartesian
    across HyperCube components."""
    q = disconnected_query(80, dom_size=12, seed=5)
    stats = compute_stats(q, lam=4)
    program = compile_plan(q, stats, p=8)
    res = DataplaneExecutor().run(program)
    sim_res = SimulatorExecutor(p=8).run(program)
    oracle = reference_join(q)
    assert res.count == sim_res.count == len(oracle), (res.count, sim_res.count)
    assert res.per_h_counts == sim_res.per_h_counts
    assert sorted(map(tuple, res.rows.tolist())) == sorted(
        map(tuple, sim_res.rows.tolist())
    )
    print(
        f"[ok] dataplane executor, disconnected light subquery: {res.count} "
        "tuples match oracle + simulator"
    )


def check_decode_attn():
    rng = np.random.default_rng(1)
    b, h, kv, hd, s = 2, 8, 4, 16, 64
    q = jnp.asarray(rng.normal(size=(b, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
    mesh = _mesh((8,), ("model",))
    out = jax.jit(lambda q, k, v: split_kv_decode_attention(mesh, "model", q, k, v))(q, k, v)
    ref = reference_decode_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
    print("[ok] split-KV decode attention matches reference")


def check_hierarchical_grad_sync():
    rng = np.random.default_rng(2)
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    g = {"w": jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32)),
         "b": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}
    specs = {"w": P(), "b": P()}
    out = jax.jit(lambda g: hierarchical_mean(g, mesh, specs))(g)
    # replicated input ⇒ mean over 4 identical replicas = identity
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(g["b"]), rtol=1e-6)
    print("[ok] hierarchical grad sync (rs→ar→ag) reduces correctly")


def check_pipeline():
    rng = np.random.default_rng(3)
    n_stages, n_micro, bsz, d = 2, 4, 4, 16
    mesh = _mesh((2, 4), ("stage", "dp"))
    w = jnp.asarray(rng.normal(size=(n_stages, 1, d, d)).astype(np.float32) * 0.3)
    x = jnp.asarray(rng.normal(size=(n_micro, bsz, d)).astype(np.float32))

    def stage_fn(xm, sp):
        return jnp.tanh(xm @ sp[0])

    out = jax.jit(
        lambda x, w: pipelined_forward(mesh, "stage", n_stages, n_micro, stage_fn, x, w)
    )(x, w)
    ref = x
    for sidx in range(n_stages):
        ref = jnp.tanh(ref @ w[sidx, 0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)
    print("[ok] GPipe pipeline matches serial reference")


if __name__ == "__main__":
    check_join()
    check_program_binary_join()
    check_program_light_subquery()
    check_program_cp_grid()
    check_program_disconnected_light()
    check_decode_attn()
    check_hierarchical_grad_sync()
    check_pipeline()
    print("ALL DATAPLANE CHECKS PASSED")
