"""Multi-device data plane checks — run in a subprocess with 8 fake host devices.
Exits nonzero on any failure (the pytest wrapper asserts the return code)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.core.query import JoinQuery, Relation, reference_join  # noqa: E402
from repro.dataplane.decode_attn import (  # noqa: E402
    reference_decode_attention,
    split_kv_decode_attention,
)
from repro.dataplane.join import hypercube_binary_join  # noqa: E402
from repro.train.grad_sync import hierarchical_mean  # noqa: E402
from repro.train.pipeline import pipelined_forward  # noqa: E402


def _mesh(shape, names):
    kinds = (jax.sharding.AxisType.Auto,) * len(names)
    return jax.make_mesh(shape, names, axis_types=kinds)


def check_join():
    rng = np.random.default_rng(0)
    p, cap = 8, 256
    n_a, n_b = 1200, 1500
    a = rng.integers(0, 60, size=(n_a, 2)).astype(np.int32)
    b = rng.integers(0, 60, size=(n_b, 2)).astype(np.int32)
    # dedup (relations are sets)
    a = np.unique(a, axis=0)
    b = np.unique(b, axis=0)

    # pad to per-device blocks
    def blockify(rows):
        per = -(-rows.shape[0] // p)
        out = np.zeros((p, cap, 2), np.int32)
        counts = np.zeros((p,), np.int32)
        for i in range(p):
            part = rows[i * per : (i + 1) * per]
            out[i, : len(part)] = part
            counts[i] = len(part)
        return jnp.asarray(out), jnp.asarray(counts)

    a_g, a_c = blockify(a)
    b_g, b_c = blockify(b)
    mesh = _mesh((p,), ("m",))
    with jax.sharding.set_mesh(mesh):
        out, cnt, ovf = jax.jit(
            lambda ag, ac, bg, bc: hypercube_binary_join(
                mesh, "m", ag, ac, bg, bc, ka=1, kb=0,
                cap_slot=cap, cap_mid=2 * cap, cap_out=4096,
            )
        )(a_g, a_c, b_g, b_c)
    assert int(jnp.sum(ovf)) == 0, "overflow in padded exchange"
    got = set()
    out_np, cnt_np = np.asarray(out), np.asarray(cnt)
    for i in range(p):
        for r in out_np[i, : cnt_np[i]]:
            got.add((int(r[0]), int(r[1]), int(r[2])))  # (A,B,C)

    q = JoinQuery.make(
        [Relation.make(("A", "B"), a.astype(np.int64)),
         Relation.make(("B", "C"), b.astype(np.int64))]
    )
    oracle = reference_join(q)  # columns sorted: A,B,C
    want = {(int(r[0]), int(r[1]), int(r[2])) for r in oracle.data}
    assert got == want, f"join mismatch: {len(got)} vs {len(want)}"
    print(f"[ok] distributed join: {len(got)} tuples match oracle")


def check_decode_attn():
    rng = np.random.default_rng(1)
    b, h, kv, hd, s = 2, 8, 4, 16, 64
    q = jnp.asarray(rng.normal(size=(b, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
    mesh = _mesh((8,), ("model",))
    with jax.sharding.set_mesh(mesh):
        out = jax.jit(lambda q, k, v: split_kv_decode_attention(mesh, "model", q, k, v))(q, k, v)
    ref = reference_decode_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
    print("[ok] split-KV decode attention matches reference")


def check_hierarchical_grad_sync():
    rng = np.random.default_rng(2)
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    g = {"w": jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32)),
         "b": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}
    specs = {"w": P(), "b": P()}
    with jax.sharding.set_mesh(mesh):
        out = jax.jit(lambda g: hierarchical_mean(g, mesh, specs))(g)
    # replicated input ⇒ mean over 4 identical replicas = identity
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(g["b"]), rtol=1e-6)
    print("[ok] hierarchical grad sync (rs→ar→ag) reduces correctly")


def check_pipeline():
    rng = np.random.default_rng(3)
    n_stages, n_micro, bsz, d = 2, 4, 4, 16
    mesh = _mesh((2, 4), ("stage", "dp"))
    w = jnp.asarray(rng.normal(size=(n_stages, 1, d, d)).astype(np.float32) * 0.3)
    x = jnp.asarray(rng.normal(size=(n_micro, bsz, d)).astype(np.float32))

    def stage_fn(xm, sp):
        return jnp.tanh(xm @ sp[0])

    with jax.sharding.set_mesh(mesh):
        out = jax.jit(
            lambda x, w: pipelined_forward(mesh, "stage", n_stages, n_micro, stage_fn, x, w)
        )(x, w)
    ref = x
    for sidx in range(n_stages):
        ref = jnp.tanh(ref @ w[sidx, 0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)
    print("[ok] GPipe pipeline matches serial reference")


if __name__ == "__main__":
    check_join()
    check_decode_attn()
    check_hierarchical_grad_sync()
    check_pipeline()
    print("ALL DATAPLANE CHECKS PASSED")
