"""Service layer: cross-query plan/compile reuse through JoinSession.

The acceptance bar of the persistent-service layer (docs/design/09-service.md):

  * session and one-shot paths are row-multiset identical on both executors
    (byte-identical on the simulator, including the metered load);
  * a warm repeat of a cached query runs with zero jit cache misses and zero
    overflow retries — plan LRU + learned caps + executable cache together;
  * learned caps and executables are *executor-lifetime* state: they survive
    a plan-LRU eviction/readmission cycle;
  * plan reuse is sound across *different data* with an equal plan cache key
    (the key captures everything compile_plan reads).
"""

from collections import OrderedDict

import numpy as np
import pytest

from repro.core.query import (
    JoinQuery,
    Relation,
    disconnected_query,
    random_query,
    reference_join,
)
from repro.core.taxonomy import compute_stats
from repro.mpc import (
    DataplaneExecutor,
    ExecutableCache,
    JoinSession,
    mpc_join,
)
from repro.mpc.program import compile_plan, histogram_signature, plan_cache_key


def rows_key(rows):
    return sorted(map(tuple, rows.tolist()))


def skew_triangle():
    return random_query(
        np.random.default_rng(2), "clique", 3, tuples_per_rel=200, dom_size=30,
        skew=2.0,
    )


def perm_query(seed: int, n: int = 60) -> JoinQuery:
    """(A,B) ⋈ (B,C) where both relations are permutation graphs: every value
    appears exactly once per column, so there are *no* heavy values and the
    histogram signature depends only on (n, λ) — two different seeds produce
    different data behind an identical plan cache key."""
    rng = np.random.default_rng(seed)
    ab = np.stack([np.arange(n), rng.permutation(n)], axis=1)
    bc = np.stack([np.arange(n), rng.permutation(n)], axis=1)
    return JoinQuery.make(
        [Relation.make(("A", "B"), ab), Relation.make(("B", "C"), bc)]
    )


# ---------------------------------------------------------------------------
# Plan cache key
# ---------------------------------------------------------------------------


def test_plan_cache_key_captures_structure_histogram_and_flags():
    q = perm_query(0)
    stats = compute_stats(q, lam=4)
    base = plan_cache_key(q, stats, p=8)
    assert base == plan_cache_key(q, stats, p=8)
    assert base != plan_cache_key(q, stats, p=16)
    assert base != plan_cache_key(q, stats, p=8, fuse_semijoin=True)
    assert base != plan_cache_key(q, compute_stats(q, lam=8), p=8)
    # different data, same structure + histogram ⇒ same key (the reuse case)
    q2 = perm_query(1)
    assert histogram_signature(compute_stats(q2, lam=4)) == histogram_signature(stats)
    assert plan_cache_key(q2, compute_stats(q2, lam=4), p=8) == base


def test_plan_cache_key_sees_shared_table_alias_classes():
    data = np.stack([np.arange(40), np.arange(40) + 1], axis=1)
    shared = JoinQuery.make(
        [
            Relation(scheme=("A", "B"), data=data, table="T"),
            Relation(scheme=("B", "C"), data=data, table="T"),
        ]
    )
    unshared = JoinQuery.make(
        [
            Relation(scheme=("A", "B"), data=data, table="T1"),
            Relation(scheme=("B", "C"), data=data, table="T2"),
        ]
    )
    stats = compute_stats(shared, lam=4)
    assert plan_cache_key(shared, stats, 8) != plan_cache_key(unshared, stats, 8)


# ---------------------------------------------------------------------------
# Session ≡ one-shot parity (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_session_simulator_byte_identical_to_mpc_join():
    q = skew_triangle()
    one_shot = mpc_join(q, p=8, lam=16)
    session = JoinSession(p=8, backend="simulator")
    r = session.submit(q, lam=16)
    assert r.count == one_shot.count == len(reference_join(q))
    assert rows_key(r.rows) == rows_key(one_shot.rows)
    assert r.per_h_counts == one_shot.per_h_counts
    assert r.result.sim.parallel_total_load == one_shot.sim.parallel_total_load
    # repeat submit: plan cache hit, still byte-identical
    r2 = session.submit(q, lam=16)
    assert r2.plan_cache_hit and r2.compile_us == 0.0
    assert rows_key(r2.rows) == rows_key(one_shot.rows)
    assert r2.result.sim.parallel_total_load == one_shot.sim.parallel_total_load


def test_session_dataplane_matches_one_shot_and_oracle():
    q = disconnected_query(90, dom_size=12, skew=1.8)
    stats = compute_stats(q, lam=8)
    one_shot = DataplaneExecutor().run(compile_plan(q, stats, 8))
    session = JoinSession(p=8, backend="dataplane")
    r = session.submit(q, lam=8)
    assert r.count == one_shot.count == len(reference_join(q))
    assert rows_key(r.rows) == rows_key(one_shot.rows)
    assert r.per_h_counts == one_shot.per_h_counts


# ---------------------------------------------------------------------------
# Warm path: zero recompiles, zero retries (learned-caps persistence)
# ---------------------------------------------------------------------------


def test_warm_repeat_zero_jit_misses_zero_retries():
    q = skew_triangle()
    session = JoinSession(p=8, backend="dataplane")
    cold = session.submit(q, lam=16)
    assert not cold.plan_cache_hit
    warm = session.submit(q, lam=16)
    assert warm.plan_cache_hit
    assert warm.jit_cache_misses == 0, "warm repeat must not recompile"
    assert warm.retry_log == [] and warm.retries == 0
    assert rows_key(warm.rows) == rows_key(cold.rows)
    assert session.stats.plan_hits == 1 and session.stats.plan_misses == 1
    assert session.stats.warm_us and session.stats.cold_us


def test_learned_caps_survive_plan_lru_eviction_cycle():
    """Plan eviction must not forget the executor: learned caps and compiled
    executables are keyed independently of the plan LRU, so a readmitted
    query recompiles its *plan* (host metadata) but no executables, and
    rediscovers no overflow."""
    qa = skew_triangle()
    qb = perm_query(3)          # different attrs ⇒ disjoint learned-caps keys
    session = JoinSession(p=8, backend="dataplane", plan_cache_size=1)
    session.submit(qa, lam=16)
    warm = session.submit(qa, lam=16)
    assert warm.jit_cache_misses == 0 and warm.retry_log == []
    session.submit(qb, lam=4)   # evicts qa's plan (capacity 1)
    assert session.stats.plan_evictions >= 1
    readmitted = session.submit(qa, lam=16)
    assert not readmitted.plan_cache_hit, "plan was evicted — must recompile"
    assert readmitted.jit_cache_misses == 0, (
        "executables are executor-lifetime state, not plan-LRU state"
    )
    assert readmitted.retry_log == [] and readmitted.retries == 0
    assert rows_key(readmitted.rows) == rows_key(warm.rows)


# ---------------------------------------------------------------------------
# Plan reuse across different data (rebind soundness)
# ---------------------------------------------------------------------------


def test_plan_reuse_across_different_data_same_key():
    """Two permutation queries share a plan cache key but hold different
    tuples: the second submit must reuse the compiled plan AND produce *its
    own* join result — the rebind ships the plan, never the data."""
    q1, q2 = perm_query(10), perm_query(11)
    session = JoinSession(p=8, backend="dataplane")
    r1 = session.submit(q1, lam=4)
    r2 = session.submit(q2, lam=4)
    assert r2.plan_cache_hit, "equal keys must share one compiled plan"
    assert rows_key(r1.rows) == rows_key(reference_join(q1).data)
    assert rows_key(r2.rows) == rows_key(reference_join(q2).data)
    assert rows_key(r1.rows) != rows_key(r2.rows), "distinct data ⇒ distinct joins"


def test_histogram_shift_changes_key_and_misses():
    """A shifted histogram (here: a planted hub crossing the heavy threshold)
    must not reuse the stale plan — the signature is part of the key."""
    n = 80
    rng = np.random.default_rng(5)
    light = np.stack([np.arange(n), rng.permutation(n)], axis=1)
    hubbed = light.copy()
    hubbed[: n // 2, 0] = 7     # one value now holds n/2 tuples: heavy
    bc = np.stack([np.arange(n), rng.permutation(n)], axis=1)
    q_light = JoinQuery.make(
        [Relation.make(("A", "B"), light), Relation.make(("B", "C"), bc)]
    )
    q_heavy = JoinQuery.make(
        [Relation.make(("A", "B"), hubbed), Relation.make(("B", "C"), bc)]
    )
    session = JoinSession(p=8, backend="simulator")
    session.submit(q_light, lam=4)
    session.submit(q_heavy, lam=4)
    assert session.stats.plan_misses == 2 and session.stats.plan_hits == 0
    assert len(session.cached_plan_keys) == 2


# ---------------------------------------------------------------------------
# Batch submission (shared physical tables across queries)
# ---------------------------------------------------------------------------


def _shared_table_queries():
    rng = np.random.default_rng(9)
    table = np.unique(rng.integers(0, 40, size=(250, 2)), axis=0)
    tri = JoinQuery.make(
        [
            Relation(scheme=("A", "B"), data=table, table="T"),
            Relation(scheme=("B", "C"), data=table, table="T"),
            Relation(scheme=("A", "C"), data=table, table="T"),
        ]
    )
    path = JoinQuery.make(
        [
            Relation(scheme=("A", "B"), data=table, table="T"),
            Relation(scheme=("B", "C"), data=table, table="T"),
        ]
    )
    return tri, path


@pytest.mark.parametrize("backend", ["simulator", "dataplane"])
def test_submit_batch_matches_individual_submits(backend):
    tri, path = _shared_table_queries()
    batch_session = JoinSession(p=8, backend=backend)
    solo_session = JoinSession(p=8, backend=backend)
    batch = batch_session.submit_batch([tri, path], lam=6)
    solos = [solo_session.submit(q, lam=6) for q in (tri, path)]
    for b, s, q in zip(batch, solos, (tri, path)):
        assert b.count == s.count == len(reference_join(q))
        assert rows_key(b.rows) == rows_key(s.rows)
    if backend == "simulator":
        # shared placement is bit-identical: identical metered loads
        for b, s in zip(batch, solos):
            assert (
                b.result.sim.parallel_total_load
                == s.result.sim.parallel_total_load
            )


# ---------------------------------------------------------------------------
# Session-backed subgraph enumeration
# ---------------------------------------------------------------------------


def test_submit_pattern_matches_one_shot_enumeration():
    from repro.graph import enumerate_subgraphs, triangle, zipf_graph

    g = zipf_graph(np.random.default_rng(0), n_vertices=300, n_edges=900, skew=1.0)
    one_shot = enumerate_subgraphs(g, triangle(), p=8, backend="simulator")
    session = JoinSession(p=8, backend="simulator")
    r1 = session.submit_pattern(triangle(), g)
    assert np.array_equal(r1.occurrences, one_shot.occurrences)
    r2 = session.submit_pattern(triangle(), g)
    assert np.array_equal(r2.occurrences, one_shot.occurrences)
    assert session.stats.plan_hits >= 1, "repeat pattern must hit the plan cache"


# ---------------------------------------------------------------------------
# ExecutableCache unit behavior (extraction satellite)
# ---------------------------------------------------------------------------


def test_executable_cache_lru_eviction_and_stats():
    cache = ExecutableCache(capacity=2)
    cache.put("a", 1)
    cache.put("b", 2)
    assert cache.get("a") == 1          # refreshes a's slot
    cache.put("c", 3)                   # evicts b (LRU)
    assert len(cache) == 2 and cache.evictions == 1
    assert cache.get("b") is None
    assert cache.get("a") == 1 and cache.get("c") == 3
    assert cache.hits == 3 and cache.misses == 1
    cache.clear()
    assert len(cache) == 0


def test_learned_caps_store_is_bounded():
    ex = DataplaneExecutor.__new__(DataplaneExecutor)
    ex._learned_caps = OrderedDict()
    cap = DataplaneExecutor._LEARNED_CAPS_CAPACITY
    assert cap >= 1 << 12, "bound must be generous enough for real programs"
