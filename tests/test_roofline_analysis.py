"""Roofline machinery: HLO collective parser, term math, mesh builders, spec rules."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.roofline import HW, collective_bytes, roofline_terms
from repro.configs import ARCHS, SHAPES, shape_applicable


HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[16,4096,512] parameter(0)
  %ag = bf16[16,4096,8192]{2,1,0} all-gather(%p0), dimensions={2}
  %ar = f32[1024,1024] all-reduce(%x), to_apply=%add
  ROOT %t = (f32[2,2]) tuple(%y)
  %rs.1 = bf16[8,128]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = (bf16[4,64]{1,0}, bf16[4,64]{1,0}) all-to-all(%a, %b)
  %cp = u32[16] collective-permute(%c), source_target_pairs={{0,1}}
  %ags = bf16[32,32] all-gather-start(%w)
  %agd = bf16[32,32] all-gather-done(%ags)
}
"""


def test_collective_parser():
    out = collective_bytes(HLO)
    assert out["all-gather_bytes"] == 16 * 4096 * 8192 * 2 + 32 * 32 * 2
    assert out["all-reduce_bytes"] == 1024 * 1024 * 4
    assert out["reduce-scatter_bytes"] == 8 * 128 * 2
    assert out["all-to-all_bytes"] == 2 * 4 * 64 * 2
    assert out["collective-permute_bytes"] == 16 * 4
    assert out["all-gather_count"] == 2  # -start counted once, -done skipped
    assert out["total_bytes"] == sum(
        out[f"{k}_bytes"]
        for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
    )


def test_roofline_terms():
    t = roofline_terms(197e12, 819e9, 100e9)   # exactly 1 s compute & memory, 2 s coll
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["t_memory_s"] == pytest.approx(1.0)
    assert t["t_collective_s"] == pytest.approx(2.0)
    assert t["bottleneck"] == "collective"


def test_shape_applicability_matrix():
    """40 cells: 34 applicable + 6 documented long_500k skips."""
    total = ok = 0
    skipped = []
    for arch, cfg in ARCHS.items():
        for name, shape in SHAPES.items():
            total += 1
            a, why = shape_applicable(cfg, shape)
            if a:
                ok += 1
            else:
                skipped.append((arch, name))
    assert total == 40 and ok == 34
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == {
        "internvl2-26b", "whisper-small", "mistral-large-123b",
        "internlm2-20b", "deepseek-v2-lite-16b", "deepseek-moe-16b",
    }


def test_mesh_builders_shapes():
    from repro.launch.mesh import axes_for, make_production_mesh

    # on 1 device we can't build the real mesh; validate geometry logic instead
    assert make_production_mesh.__defaults__ == (False,) or True
    import repro.launch.mesh as m

    # axes_for on an abstract stand-in
    class FakeMesh:
        axis_names = ("pod", "data", "model")

    ax = axes_for(FakeMesh(), sequence_parallel=True)
    assert ax.data == ("pod", "data") and ax.model == "model" and ax.sequence_parallel


def test_param_spec_rules_divisibility():
    """Non-divisible dims fall back to replication (whisper's 12-head case)."""
    from repro.distributed.specs import _fit

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = _fit(FakeMesh(), (12, 64), ("model", None), stack_dims=0)
    assert spec == P(None, None)          # 12 % 16 != 0 → replicated
    spec = _fit(FakeMesh(), (768, 3072), ("data", "model"), stack_dims=0)
    assert spec == P("data", "model")
    spec = _fit(FakeMesh(), (4, 768, 3072), ("data", "model"), stack_dims=1)
    assert spec == P(None, "data", "model")
