"""GYO reduction vs brute-force acyclicity + running-intersection property.

`gyo_reduction` is greedy (one fixed ear order); acyclicity is order-independent
only because GYO is confluent.  The lock here brute-forces ALL ear-removal
orders (`brute_force_acyclic`) over every ≤5-edge hypergraph shape on 4
vertices (exhaustive: 4943 edge sets) plus canonical 5-vertex families, and
asserts the greedy answer matches; for every acyclic instance the derived join
tree must satisfy the running intersection property directly.
"""

import itertools

import pytest

from repro.core.jointree import (
    JoinTree,
    brute_force_acyclic,
    build_join_tree,
    gyo_reduction,
    is_acyclic,
    running_intersection_ok,
)


def _all_edge_sets(n_vertices: int, max_edges: int):
    verts = [f"X{i}" for i in range(n_vertices)]
    all_edges = []
    for r in range(1, n_vertices + 1):
        all_edges += [frozenset(c) for c in itertools.combinations(verts, r)]
    for k in range(1, max_edges + 1):
        for combo in itertools.combinations(all_edges, k):
            yield list(combo)


def test_gyo_matches_bruteforce_exhaustive_4v():
    """Every ≤5-edge hypergraph on 4 vertices: greedy GYO ≡ any-order brute force."""
    n_acyclic = n_cyclic = 0
    for schemes in _all_edge_sets(4, 5):
        greedy = is_acyclic(schemes)
        brute = brute_force_acyclic(schemes)
        assert greedy == brute, f"GYO confluence violated on {schemes}"
        if greedy:
            n_acyclic += 1
            tree = build_join_tree(schemes)
            assert tree is not None
            assert running_intersection_ok(schemes, tree), schemes
        else:
            n_cyclic += 1
            assert build_join_tree(schemes) is None
    # sanity: the sweep actually saw both classes
    assert n_acyclic > 1000 and n_cyclic > 100


FIVE_VERTEX_CASES = [
    # (schemes, expected acyclic)
    ([("A", "B", "C"), ("A", "A1"), ("B", "B1"), ("C", "C1")], True),  # star3
    ([("A", "B", "C"), ("A", "A1"), ("A1", "A2"), ("B", "B1"), ("C", "C1")], True),
    ([("X0", "X1"), ("X1", "X2", "X3"), ("X3", "X4"), ("X4", "X5", "X6")], True),
    ([("X0", "X1"), ("X1", "X2"), ("X2", "X3"), ("X3", "X4"), ("X4", "X0")], False),
    ([("X0", "X1"), ("X0", "X2"), ("X1", "X2")], False),  # triangle
    ([("X0", "X1", "X2"), ("X0", "X1"), ("X1", "X2"), ("X0", "X2")], True),  # covered triangle
    ([("A", "B"), ("C", "D")], True),  # disconnected forest
    ([("A", "B"), ("B", "C"), ("C", "A"), ("D", "E")], False),  # cycle + island
    ([("A",)], True),  # single unary edge
    ([("A", "B", "C", "D", "E")], True),  # one wide edge
]


@pytest.mark.parametrize("schemes,expected", FIVE_VERTEX_CASES)
def test_known_families(schemes, expected):
    schemes = [frozenset(s) for s in schemes]
    assert is_acyclic(schemes) == expected
    assert brute_force_acyclic(schemes) == expected
    tree = build_join_tree(schemes)
    if expected:
        assert tree is not None
        assert running_intersection_ok(schemes, tree)
    else:
        assert tree is None


def test_gyo_sequence_is_leaves_first():
    """The recorded removal order is a valid up-sweep: when (c, p, _) fires,
    c can no longer be any later edge's witness."""
    schemes = [frozenset(s) for s in
               [("A", "B", "C"), ("A", "A1"), ("A1", "A2"), ("B", "B1"), ("C", "C1")]]
    seq = gyo_reduction(schemes)
    assert seq is not None
    removed = set()
    for c, p, shared in seq:
        assert c not in removed
        assert p not in removed, "witness already removed — not leaves-first"
        assert frozenset(shared) == schemes[c] & schemes[p]
        removed.add(c)


def test_running_intersection_rejects_corrupted_tree():
    """Mutating one tree edge's parent breaks the property (the verify rule's
    detection primitive)."""
    schemes = [frozenset(s) for s in
               [("A", "B", "C"), ("A", "A1"), ("A1", "A2"), ("B", "B1"), ("C", "C1")]]
    tree = build_join_tree(schemes)
    assert tree is not None and running_intersection_ok(schemes, tree)
    # reattach the A1-A2 leaf (index 2) under the B dimension (index 3): the
    # shared attr A1 no longer appears along the new path
    bad_edges = tuple(
        (c, 3 if c == 2 else p, shared) for c, p, shared in tree.edges
    )
    bad = JoinTree(n_nodes=tree.n_nodes, root=tree.root, edges=bad_edges)
    assert not running_intersection_ok(schemes, bad)


def test_path_endpoints_and_meet():
    schemes = [frozenset(s) for s in
               [("A", "B", "C"), ("A", "A1"), ("A1", "A2"), ("B", "B1"), ("C", "C1")]]
    tree = build_join_tree(schemes)
    path = tree.path(2, 4)  # A1A2 leaf to C1 leaf crosses the fact table
    assert path[0] == 2 and path[-1] == 4
    assert 0 in path
