"""End-to-end behaviour tests for the paper's system."""

import numpy as np

from repro.core.hypergraph import fractional_edge_cover, quasi_packing_number
from repro.core.query import JoinQuery, Relation, reference_join
from repro.mpc.engine import mpc_join
from repro.mpc.hypercube import skewfree_hypercube_join, uniform_lp_shares


def test_end_to_end_skewed_triangle():
    """The paper's headline, end to end: plan (ρ from the LP), execute (Theorem 6.2
    on the metered MPC runtime), validate (oracle equality + exactly-once), and
    confirm the one-round baseline agrees on the result."""
    rng = np.random.default_rng(0)
    n, p = 1200, 27
    ab = np.stack([np.zeros(n, np.int64), np.arange(n)], axis=1)
    ac = np.stack([np.zeros(n, np.int64), np.arange(n)], axis=1)
    bc = np.stack([rng.integers(0, n, n), rng.integers(0, n, n)], axis=1)
    q = JoinQuery.make(
        [
            Relation.make(("A", "B"), ab),
            Relation.make(("B", "C"), bc),
            Relation.make(("A", "C"), ac),
        ]
    )
    g = q.hypergraph
    rho, _ = fractional_edge_cover(g)
    psi = quasi_packing_number(g)
    assert float(rho) == 1.5 and float(psi) == 2.0  # triangle: the ψ>ρ gap exists

    res = mpc_join(q, p=p, lam=8, materialize=True)
    oracle = reference_join(q)
    assert res.count == len(oracle)
    assert res.rows.shape[0] == res.count                      # exactly-once
    assert set(map(tuple, res.rows.tolist())) == oracle.rows_as_set()
    assert res.load > 0 and np.isfinite(res.load_ratio)

    # constant number of rounds, independent of the data (Theorem 6.2)
    round_names = {name for name, _ in res.sim.load_report()}
    assert len(round_names) <= 9

    # one-round baseline agrees on the result (correctness) on the same input
    shares = uniform_lp_shares(g, p)
    _, count_hc, _ = skewfree_hypercube_join(q, shares, p=p, materialize=False)
    assert count_hc == res.count


def test_end_to_end_subgraph_counting():
    """Sec. 1.4 application: triangle counting on a small graph via the join engine."""
    rng = np.random.default_rng(1)
    edges = np.unique(rng.integers(0, 40, size=(300, 2)), axis=0)
    edges = edges[edges[:, 0] != edges[:, 1]]
    sym = np.concatenate([edges, edges[:, ::-1]], axis=0)
    q = JoinQuery.make(
        [Relation.make(e, sym) for e in (("A", "B"), ("B", "C"), ("A", "C"))]
    )
    res = mpc_join(q, p=8, lam=8, materialize=True)
    # brute-force triangle count
    adj = set(map(tuple, sym.tolist()))
    nodes = sorted({v for e in adj for v in e})
    brute = sum(
        1
        for i, a in enumerate(nodes)
        for b in nodes[i + 1 :]
        if (a, b) in adj
        for c in nodes
        if c > b and (b, c) in adj and (a, c) in adj
    )
    assert res.count == 6 * brute  # ordered embeddings
