"""Training infrastructure: checkpoint/restart determinism, elastic restore,
straggler monitor, gradient compression, optimizer sanity, data pipeline resume."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_for_smoke
from repro.models.model import init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.data import synth_batch
from repro.train.fault import Heartbeat, StragglerMonitor, retry
from repro.train.optimizer import (
    AdamWConfig,
    compress_int8,
    compressed_grads_with_ef,
    decompress_int8,
    init_ef_state,
    lr_at,
)
from repro.train.step import TrainConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced_for_smoke(ARCHS["h2o-danube-1.8b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _batch(cfg, step):
    return {
        k: jnp.asarray(v)
        for k, v in synth_batch(cfg, step=step, global_batch=2, seq=16).items()
    }


def test_checkpoint_restart_bitexact(tmp_path, tiny):
    """Train 5 steps; checkpoint at 3; restart from 3 → steps 4-5 identical."""
    cfg, params0 = tiny
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20))
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    mgr = CheckpointManager(tmp_path / "ckpt")

    params, state = params0, init_train_state(cfg, tcfg, params0)
    trace = []
    for i in range(5):
        params, state, m = step_fn(params, state, _batch(cfg, i))
        trace.append(float(m["loss"]))
        if i == 2:
            mgr.save(i, {"params": params, "opt": state}, {"arch": cfg.name})

    # restart
    latest = mgr.latest_step()
    assert latest == 2
    template = {"params": params, "opt": state}
    restored, meta = mgr.restore(latest, template)
    params2, state2 = restored["params"], restored["opt"]
    trace2 = []
    for i in range(3, 5):
        params2, state2, m = step_fn(params2, state2, _batch(cfg, i))
        trace2.append(float(m["loss"]))
    np.testing.assert_allclose(trace[3:], trace2, rtol=1e-6)
    # final params bit-identical
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path, tiny):
    cfg, params = tiny
    mgr = CheckpointManager(tmp_path / "c2", keep=2)
    for s in range(4):
        mgr.save_async(s, {"params": params}, {"arch": cfg.name})
    mgr.wait()
    steps = sorted(mgr.all_steps())
    assert steps == [2, 3]
    restored, meta = mgr.restore(3, {"params": params})
    assert meta["step"] == 3


def test_checkpoint_corruption_safe(tmp_path, tiny):
    """A torn write (tmp file) never becomes the resume point."""
    cfg, params = tiny
    mgr = CheckpointManager(tmp_path / "c3")
    mgr.save(1, {"params": params})
    # simulate a crash mid-write of step 2
    (tmp_path / "c3" / "ckpt_00000002.npz.tmp").write_bytes(b"garbage")
    assert mgr.latest_step() == 1


def test_straggler_monitor():
    mon = StragglerMonitor(factor=2.0, warmup=1)
    flagged = []
    mon.on_straggler = lambda s, d, e: flagged.append(s)
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 0.5)       # 5× EMA
    assert flagged == [10]
    assert not mon.record(11, 0.1)   # EMA not poisoned by the outlier


def test_heartbeat(tmp_path):
    hb = Heartbeat(tmp_path / "hb")
    hb.beat(1)
    assert hb.age_s() < 5


def test_retry():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return 42

    assert retry(flaky, attempts=4, backoff_s=0.001) == 42


def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    q, s = compress_int8(x)
    err = jnp.abs(decompress_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates():
    """EF makes the *sum* of compressed grads converge to the sum of true grads."""
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(128,)).astype(np.float32) * 1e-3)}
    ef = init_ef_state(g)
    total_true = np.zeros(128, np.float32)
    total_sent = np.zeros(128, np.float32)
    for _ in range(50):
        deq, ef = compressed_grads_with_ef(g, ef)
        total_true += np.asarray(g["w"])
        total_sent += np.asarray(deq["w"])
    # residual is bounded by one quantization step, not 50 of them
    resid = np.abs(total_true - total_sent).max()
    one_step = float(np.abs(np.asarray(g["w"])).max()) / 127 * 2
    assert resid <= one_step + 1e-5


def test_compressed_training_converges(tiny):
    cfg, params = tiny
    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=3e-3, warmup_steps=1, total_steps=50), compress_grads=True
    )
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    state = init_train_state(cfg, tcfg, params)
    batch = _batch(cfg, 0)
    losses = []
    for _ in range(8):
        params, state, m = step_fn(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_lr_schedule():
    c = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(lr_at(c, jnp.array(5))) == pytest.approx(0.5)
    assert float(lr_at(c, jnp.array(110))) == pytest.approx(0.1, abs=1e-3)


def test_data_pipeline_deterministic_and_sharded():
    cfg = reduced_for_smoke(ARCHS["internlm2-20b"])
    a = synth_batch(cfg, step=7, global_batch=8, seq=16, rank=0, n_ranks=2)
    b = synth_batch(cfg, step=7, global_batch=8, seq=16, rank=0, n_ranks=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # resumable
    full = synth_batch(cfg, step=7, global_batch=8, seq=16)
    r0 = synth_batch(cfg, step=7, global_batch=8, seq=16, rank=0, n_ranks=2)
    r1 = synth_batch(cfg, step=7, global_batch=8, seq=16, rank=1, n_ranks=2)
    np.testing.assert_array_equal(np.concatenate([r0["tokens"], r1["tokens"]]), full["tokens"])


def test_microbatch_accumulation_matches_full_batch(tiny):
    """grad accumulation (2 microbatches) ≈ single-batch step (same data)."""
    cfg, params = tiny
    batch = _batch(cfg, 0)
    t1 = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20))
    t2 = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20), microbatches=2)
    s1 = init_train_state(cfg, t1, params)
    s2 = init_train_state(cfg, t2, params)
    p1, _, m1 = jax.jit(make_train_step(cfg, t1))(params, s1, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, t2))(params, s2, batch)
    # means over microbatches == full-batch mean (CE is a mean; grads average)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-2, rtol=5e-2
        )
