"""Executor parity: DataplaneExecutor ≡ SimulatorExecutor on every compiled program.

The acceptance bar of the per-op dataplane lowering: for any program
`compile_plan` emits — including stages with isolated attributes (Lemma 3.1
CP grid), multi-dimensional isolated sets, and disconnected light subqueries —
the device backend must reproduce the simulator's join count, per-H counts
(including the zero entries of stages that ran but produced nothing), and the
sorted result-row multiset.  Inputs are seeded Zipf-skewed so heavy values
actually exist and the taxonomy fans out into many (H, η) stages.

Also covers the overflow-retry contract: output overflow scales only the
output capacity (routing buffers untouched), and slot retries re-randomize
the routing salts (fresh randomness per attempt).
"""

import numpy as np
import pytest

from repro.core.query import (
    JoinQuery,
    Relation,
    disconnected_query,
    hub_star_query,
    random_query,
    reference_join,
)
from repro.core.taxonomy import compute_stats
from repro.mpc.cartesian import CartesianGrid
from repro.mpc.executors import DataplaneExecutor, SimulatorExecutor, _salt
from repro.mpc.hypercube import HyperCubeGrid
from repro.mpc.program import compile_plan, fuse_semijoin_pass


def assert_parity(q: JoinQuery, lam: int, p: int = 8, fused: bool = False):
    """Compile once, run both backends, compare against each other + oracle."""
    stats = compute_stats(q, lam)
    program = compile_plan(q, stats, p)
    if fused:
        program = fuse_semijoin_pass(program)
    sim = SimulatorExecutor(p=p).run(program)
    dp = DataplaneExecutor().run(program)
    oracle = reference_join(q)
    assert sim.count == len(oracle), "simulator must match the oracle"
    assert dp.count == sim.count, (dp.count, sim.count)
    assert dp.per_h_counts == sim.per_h_counts, (dp.per_h_counts, sim.per_h_counts)
    assert sorted(map(tuple, dp.rows.tolist())) == sorted(
        map(tuple, sim.rows.tolist())
    )
    return program, sim, dp


# ---------------------------------------------------------------------------
# Randomized seeded parity across query families (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_parity_triangle_zipf_isolated_stages():
    """Skewed triangle: H with two heavy attrs leaves the third attribute
    isolated (CP grid with hc_size = 1), alongside cyclic light stages."""
    q = random_query(
        np.random.default_rng(2), "clique", 3, tuples_per_rel=200, dom_size=30,
        skew=2.0,
    )
    program, _, _ = assert_parity(q, lam=16)
    assert any(st.plan.isolated for st in program.stages), (
        "triangle taxonomy must exercise isolated attributes"
    )


def test_parity_four_cycle_2d_isolated_grid():
    """Skewed 4-cycle: H = two opposite attributes isolates the other two —
    a genuinely multi-dimensional Lemma 3.1 grid."""
    q = random_query(
        np.random.default_rng(7), "cycle", 4, tuples_per_rel=120, dom_size=10,
        skew=2.5,
    )
    program, _, _ = assert_parity(q, lam=24)
    assert any(len(st.plan.isolated) >= 2 for st in program.stages), (
        "4-cycle taxonomy must exercise a >=2-dimensional CP grid"
    )


def test_parity_hub_star_isolated_only():
    """Planted heavy hub on a star: under H = {hub} every leaf is isolated and
    no light edges survive — the pure-CP-grid stage the dataplane formerly
    rejected with DataplaneUnsupported."""
    q = hub_star_query(n=48, hub_n=24, dom_size=25)
    program, _, _ = assert_parity(q, lam=10)
    assert any(
        st.plan.isolated and not st.plan.light_edges for st in program.stages
    ), "hub star must produce a light-edge-free CP-grid stage"


def test_parity_disconnected_light_subquery():
    """Two skewed components (A,B) ⋈ (C,D): the H = ∅ light subquery is
    disconnected (the second former DataplaneUnsupported escape hatch), and
    heavy values produce stages mixing an isolated attribute with a light
    component."""
    q = disconnected_query(90, dom_size=12, skew=1.8)
    program, _, _ = assert_parity(q, lam=8)
    h_empty = [st for st in program.stages if st.hkey == ()]
    assert h_empty and len(h_empty[0].plan.light_edges) == 2, (
        "H=∅ stage must carry the disconnected light subquery"
    )


def test_parity_fused_program():
    """The fused semi-join rewrite changes the op list, not the executor: the
    per-op dispatch lowers SemiJoin[fused-*] through the same rule."""
    q = random_query(
        np.random.default_rng(4), "star", 4, tuples_per_rel=150, dom_size=12,
        skew=1.5,
    )
    program, _, _ = assert_parity(q, lam=3, fused=True)
    assert program.fused


# ---------------------------------------------------------------------------
# Overflow-retry contract (satellites: split channels + fresh randomness)
# ---------------------------------------------------------------------------


def test_output_only_overflow_scales_cap_out_not_routing():
    """A high-fanout join forces the LocalJoin output estimate to overflow
    while every routing buffer fits: the retry must scale only cap_out.  Runs
    on a 1-device mesh so routing-slot overflow is impossible by construction
    — any retry the log records is a pure output-capacity retry."""
    import jax

    a = np.stack(
        [np.repeat(np.arange(100), 2), np.tile(np.arange(2), 100)], axis=1
    )
    b = np.stack(
        [np.tile(np.arange(2), 100), 1000 + np.repeat(np.arange(100), 2)], axis=1
    )
    q = JoinQuery.make(
        [Relation.make(("A", "B"), a), Relation.make(("B", "C"), b)]
    )
    stats = compute_stats(q, lam=2)   # threshold m/2: no heavy values
    program = compile_plan(q, stats, p=8)
    mesh = jax.make_mesh((1,), ("join",))
    ex = DataplaneExecutor(mesh=mesh)
    res = ex.run(program)
    oracle = reference_join(q)
    assert res.count == len(oracle) == 20_000
    assert sorted(map(tuple, res.rows.tolist())) == sorted(
        map(tuple, oracle.data.tolist())
    )
    assert res.retries >= 1, "the output estimate must have been exceeded"
    assert all(kind == "out" for _, _, kind in res.retry_log), res.retry_log
    assert any(rnd == "output" for _, rnd, _ in res.retry_log), res.retry_log


def test_retry_harness_scales_only_overflowed_channel():
    """Unit-level: _with_retry doubles 'out' on output overflow and leaves the
    routing capacities untouched (and vice versa)."""
    ex = DataplaneExecutor.__new__(DataplaneExecutor)   # no mesh needed
    ex.max_retries = 4
    ex._retries, ex._retry_log = 0, []

    seen = []

    def run_out_overflow(caps, attempt):
        seen.append(dict(caps))
        ovf = np.array([[0, 1]] if len(seen) == 1 else [[0, 0]])
        return ("ok", attempt), [ovf]

    result = ex._with_retry(("k",), "output", {"slot": 16, "mid": 32, "out": 64}, run_out_overflow)
    assert result == ("ok", 1)
    assert seen == [
        {"slot": 16, "mid": 32, "out": 64},
        {"slot": 16, "mid": 32, "out": 128},   # only 'out' doubled
    ]
    assert ex._retry_log == [(("k",), "output", "out")]

    seen.clear()
    ex._retry_log.clear()

    def run_slot_overflow(caps, attempt):
        seen.append(dict(caps))
        ovf = np.array([[1, 0]] if len(seen) == 1 else [[0, 0]])
        return "ok", [ovf]

    ex._with_retry(("k",), "step1", {"slot": 16, "mid": 32, "out": 64}, run_slot_overflow)
    assert seen[1] == {"slot": 32, "mid": 64, "out": 64}   # 'out' untouched


def test_salt_is_wide_and_attempt_threaded():
    """The routing salt spans the full 31-bit range (beyond the old 2^20) and
    a retry draws a fresh value — the paper's per-attempt randomness."""
    salts = {_salt("stage", i) for i in range(2000)}
    assert max(salts) >= 1 << 20, "salt range must exceed the old 2^20 cap"
    assert len(salts) == 2000
    assert _salt("k", attempt=0) != _salt("k", attempt=1)
    # stability: same key + attempt ⇒ same salt on every host
    assert _salt("k", 3, attempt=2) == _salt("k", 3, attempt=2)


# ---------------------------------------------------------------------------
# Device grid math ≡ host grid math (the geometry the route relies on)
# ---------------------------------------------------------------------------


def test_grid_coordinate_functions_match_numpy():
    import jax.numpy as jnp

    g = CartesianGrid([50, 30, 7], 16)
    ids = np.arange(87, dtype=np.int64)
    for li in range(g.t_prime):
        want = g.cells_for_ids(li, ids)
        got = np.asarray(g.cells_for_ids_dev(li, jnp.asarray(ids, jnp.int32)))
        assert np.array_equal(want, got)

    hc = HyperCubeGrid(("A", "B", "C"), {"A": 3, "B": 2, "C": 4})
    fixed = {"A": np.array([0, 1, 2, 0, 2]), "C": np.array([3, 2, 1, 0, 3])}
    want = hc.cells_for(fixed)
    got = np.asarray(
        hc.cells_for_dev({k: jnp.asarray(v, jnp.int32) for k, v in fixed.items()})
    )
    assert np.array_equal(want, got)
