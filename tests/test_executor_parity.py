"""Executor parity: DataplaneExecutor ≡ SimulatorExecutor on every compiled program.

The acceptance bar of the per-op dataplane lowering: for any program
`compile_plan` emits — including stages with isolated attributes (Lemma 3.1
CP grid), multi-dimensional isolated sets, and disconnected light subqueries —
the device backend must reproduce the simulator's join count, per-H counts
(including the zero entries of stages that ran but produced nothing), and the
sorted result-row multiset.  Inputs are seeded Zipf-skewed so heavy values
actually exist and the taxonomy fans out into many (H, η) stages.

Also covers the overflow-retry contract: output overflow scales only the
output capacity (routing buffers untouched), and slot retries re-randomize
the routing salts (fresh randomness per attempt).
"""

from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.query import (
    JoinQuery,
    Relation,
    disconnected_query,
    hub_star_query,
    random_query,
    reference_join,
)
from repro.core.taxonomy import compute_stats
from repro.mpc.cartesian import CartesianGrid
from repro.mpc.executors import DataplaneExecutor, SimulatorExecutor, _WorkItem, _salt
from repro.mpc.hypercube import HyperCubeGrid
from repro.mpc.program import compile_plan, fuse_semijoin_pass


def rows_key(rows):
    return sorted(map(tuple, rows.tolist()))


def assert_parity(q: JoinQuery, lam: int, p: int = 8, fused: bool = False):
    """Compile once, run every backend and schedule, compare all + oracle.

    The dataplane runs twice — stage-batched and per-stage (``batch_stages``
    off) — and the two schedules must agree on results *and* retry-log
    semantics: capacities are a function of the round's work items, never of
    the bucketing, so overflow behavior is schedule-independent."""
    stats = compute_stats(q, lam)
    program = compile_plan(q, stats, p)
    if fused:
        program = fuse_semijoin_pass(program)
    sim = SimulatorExecutor(p=p).run(program)
    dp = DataplaneExecutor(batch_stages=True).run(program)
    dp_u = DataplaneExecutor(batch_stages=False).run(program)
    oracle = reference_join(q)
    assert sim.count == len(oracle), "simulator must match the oracle"
    assert dp.count == sim.count, (dp.count, sim.count)
    assert dp.per_h_counts == sim.per_h_counts, (dp.per_h_counts, sim.per_h_counts)
    assert rows_key(dp.rows) == rows_key(sim.rows)
    # batched ≡ unbatched: identical results and identical retry semantics
    assert dp_u.count == dp.count
    assert dp_u.per_h_counts == dp.per_h_counts
    assert rows_key(dp_u.rows) == rows_key(dp.rows)
    assert dp_u.retries == dp.retries
    assert dp_u.retry_log == dp.retry_log
    # the batched schedule must actually batch: never more fused dispatches
    # than the per-stage schedule issues
    assert dp.dispatches <= dp_u.dispatches
    return program, sim, dp


# ---------------------------------------------------------------------------
# Randomized seeded parity across query families (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_parity_triangle_zipf_isolated_stages():
    """Skewed triangle: H with two heavy attrs leaves the third attribute
    isolated (CP grid with hc_size = 1), alongside cyclic light stages."""
    q = random_query(
        np.random.default_rng(2), "clique", 3, tuples_per_rel=200, dom_size=30,
        skew=2.0,
    )
    program, _, _ = assert_parity(q, lam=16)
    assert any(st.plan.isolated for st in program.stages), (
        "triangle taxonomy must exercise isolated attributes"
    )


def test_parity_four_cycle_2d_isolated_grid():
    """Skewed 4-cycle: H = two opposite attributes isolates the other two —
    a genuinely multi-dimensional Lemma 3.1 grid."""
    q = random_query(
        np.random.default_rng(7), "cycle", 4, tuples_per_rel=120, dom_size=10,
        skew=2.5,
    )
    program, _, _ = assert_parity(q, lam=24)
    assert any(len(st.plan.isolated) >= 2 for st in program.stages), (
        "4-cycle taxonomy must exercise a >=2-dimensional CP grid"
    )


def test_parity_hub_star_isolated_only():
    """Planted heavy hub on a star: under H = {hub} every leaf is isolated and
    no light edges survive — the pure-CP-grid stage the dataplane formerly
    rejected with DataplaneUnsupported."""
    q = hub_star_query(n=48, hub_n=24, dom_size=25)
    program, _, _ = assert_parity(q, lam=10)
    assert any(
        st.plan.isolated and not st.plan.light_edges for st in program.stages
    ), "hub star must produce a light-edge-free CP-grid stage"


def test_parity_disconnected_light_subquery():
    """Two skewed components (A,B) ⋈ (C,D): the H = ∅ light subquery is
    disconnected (the second former DataplaneUnsupported escape hatch), and
    heavy values produce stages mixing an isolated attribute with a light
    component."""
    q = disconnected_query(90, dom_size=12, skew=1.8)
    program, _, _ = assert_parity(q, lam=8)
    h_empty = [st for st in program.stages if st.hkey == ()]
    assert h_empty and len(h_empty[0].plan.light_edges) == 2, (
        "H=∅ stage must carry the disconnected light subquery"
    )


def test_parity_fused_program():
    """The fused semi-join rewrite changes the op list, not the executor: the
    per-op dispatch lowers SemiJoin[fused-*] through the same rule."""
    q = random_query(
        np.random.default_rng(4), "star", 4, tuples_per_rel=150, dom_size=12,
        skew=1.5,
    )
    program, _, _ = assert_parity(q, lam=3, fused=True)
    assert program.fused


# ---------------------------------------------------------------------------
# Packed int32 composite keys: eligibility + checked fallback
# ---------------------------------------------------------------------------


def _join_packed_flags(ex):
    """Packed-key decisions recorded in the executor's learned-caps keys:
    one flag per composite-key LocalJoin bucket (dup_pairs non-empty)."""
    return [
        key[4]
        for (_, _, key, _) in ex._learned_caps
        if key and key[0] == "join" and len(key) == 5 and key[3]
    ]


def _run_both_schedules(q, lam, p=8):
    stats = compute_stats(q, lam)
    program = compile_plan(q, stats, p)
    ex = DataplaneExecutor(batch_stages=True)
    res = ex.run(program)
    ex_u = DataplaneExecutor(batch_stages=False)
    res_u = ex_u.run(program)
    oracle = reference_join(q)
    assert res.count == len(oracle) == res_u.count
    assert rows_key(res.rows) == rows_key(oracle.data) == rows_key(res_u.rows)
    return ex, ex_u


def test_key_compression_packs_small_domains():
    """Cyclic (triangle) query with small vertex ids: every composite-key
    join bucket passes the int32 eligibility check and takes the packed path."""
    q = random_query(
        np.random.default_rng(2), "clique", 3, tuples_per_rel=200, dom_size=30,
        skew=2.0,
    )
    ex, ex_u = _run_both_schedules(q, lam=16)
    for e in (ex, ex_u):
        flags = _join_packed_flags(e)
        assert flags, "triangle chains must produce composite-key joins"
        assert all(flags), "small domains must take the packed int32 path"


def test_key_compression_int32_overflow_takes_ranked_fallback():
    """Adversarial key space: vertex ids shifted by 5·10^7 keep every value
    int32-safe, but (max_cell+1)·(max_dup+1) exceeds 2^31, so packing would
    collide — the eligibility check must reject it and the ranked
    (lexicographic dense-rank) fallback must produce the identical result on
    both schedules."""
    q = random_query(
        np.random.default_rng(2), "clique", 3, tuples_per_rel=200, dom_size=30,
        skew=2.0,
    )
    shift = 50_000_000
    q_big = JoinQuery.make(
        [Relation.make(r.scheme, r.data + shift) for r in q.relations]
    )
    ex, ex_u = _run_both_schedules(q_big, lam=16)
    for e in (ex, ex_u):
        flags = _join_packed_flags(e)
        assert flags, "triangle chains must produce composite-key joins"
        assert not any(flags), (
            "key space over 2^31 must take the ranked fallback"
        )


# ---------------------------------------------------------------------------
# Overflow-retry contract (satellites: split channels + fresh randomness)
# ---------------------------------------------------------------------------


def test_output_only_overflow_scales_cap_out_not_routing():
    """A high-fanout join forces the LocalJoin output estimate to overflow
    while every routing buffer fits: the retry must scale only cap_out.  Runs
    on a 1-device mesh so routing-slot overflow is impossible by construction
    — any retry the log records is a pure output-capacity retry.  Uses
    ``exact_caps=False``: the legacy estimate+retry path this test exercises
    (the default count-then-emit path sizes caps exactly and never retries)."""
    import jax

    a = np.stack(
        [np.repeat(np.arange(100), 2), np.tile(np.arange(2), 100)], axis=1
    )
    b = np.stack(
        [np.tile(np.arange(2), 100), 1000 + np.repeat(np.arange(100), 2)], axis=1
    )
    q = JoinQuery.make(
        [Relation.make(("A", "B"), a), Relation.make(("B", "C"), b)]
    )
    stats = compute_stats(q, lam=2)   # threshold m/2: no heavy values
    program = compile_plan(q, stats, p=8)
    mesh = jax.make_mesh((1,), ("join",))
    ex = DataplaneExecutor(mesh=mesh, exact_caps=False)
    res = ex.run(program)
    oracle = reference_join(q)
    assert res.count == len(oracle) == 20_000
    assert sorted(map(tuple, res.rows.tolist())) == sorted(
        map(tuple, oracle.data.tolist())
    )
    assert res.retries >= 1, "the output estimate must have been exceeded"
    assert all(kind == "out" for _, _, kind in res.retry_log), res.retry_log
    assert any(rnd == "output" for _, rnd, _ in res.retry_log), res.retry_log


def _bare_scheduler(batch=True):
    """A DataplaneExecutor shell with only the scheduler state — no devices
    (the fake mesh tag just keys the executable-cache signatures)."""
    from collections import OrderedDict, defaultdict

    from repro.mpc.executors import ExecutableCache

    ex = DataplaneExecutor.__new__(DataplaneExecutor)
    ex.max_retries = 4
    ex.batch_stages = batch
    ex.mesh, ex.axis_name = "fake-mesh", "join"
    ex.compiled_cache = ExecutableCache()
    ex._retries, ex._retry_log = 0, []
    ex._qi_retries, ex._qi_retry_log = defaultdict(int), defaultdict(list)
    ex._dispatches, ex._jit_hits, ex._jit_misses = 0, 0, 0
    ex._bucket_log, ex._learned_caps = {}, OrderedDict()
    ex._caps_hits, ex._caps_misses, ex._caps_evictions = 0, 0, 0
    ex.caps_hits, ex.caps_misses, ex.caps_evictions = 0, 0, 0
    ex._phase_us, ex._round_us = {}, {}
    return ex


class _FakeFn:
    """Stands in for a jitted primitive.  Like a real compiled executable its
    output is a pure function of its call args (the scheduler caches by
    signature, so a bucket may execute an executable compiled for an earlier
    same-signature bucket): each arg is (trip, retries) for one stage and the
    overflow tensor trips that stage's channel on the first run only (a real
    retry runs at grown caps / fresh salts, which is what clears the trip)."""

    def lower(self, *args):
        return self

    def compile(self):
        return self._impl

    @staticmethod
    def _impl(*args):
        ovf = np.zeros((len(args), 1, 2), np.int64)
        for j, (trip, retries) in enumerate(args):
            if retries == 0 and trip:
                ovf[j, 0, 0 if trip == "slot" else 1] = 1
        return ovf


def _item(i, caps, trip=None):
    """trip: None | "slot" | "out" — which channel overflows on the first run."""
    return _WorkItem(
        state=SimpleNamespace(skey=("H", i), qi=0),
        key=("k",),
        caps=dict(caps),
        payload={"i": i, "trip": trip},
        group=("g", i),
    )


def _fake_dispatch(log):
    def dispatch(bucket):
        log.append([(it.payload["i"], dict(it.caps), it.attempt) for it in bucket])
        args = tuple((it.payload["trip"] or "", it.retries) for it in bucket)

        def post(outs):
            return (lambda: [it.payload["i"] for it in bucket]), outs

        return _FakeFn(), args, post

    return dispatch


def test_scheduler_doubles_only_the_tripped_channel():
    """Per-channel retry: an output overflow doubles only 'out' and keeps the
    attempt-0 salts (row order must not depend on capacity history); a slot
    overflow doubles only 'slot' and advances to fresh attempt salts."""
    for trip, doubled, attempt in (("out", {"slot": 16, "out": 128}, 0),
                                   ("slot", {"slot": 32, "out": 64}, 1)):
        ex = _bare_scheduler()
        log = []
        items = [_item(0, {"slot": 16, "out": 64}, trip=trip)]
        out = ex._run_buckets("rnd", items, _fake_dispatch(log))
        assert out[0].result == 0
        assert log[0][0] == (0, {"slot": 16, "out": 64}, 0)
        assert log[1][0] == (0, doubled, attempt), (trip, log)
        assert ex._retry_log == [(("H", 0), "rnd", trip)]
        assert ex._retries == 1


def test_scheduler_mixed_channel_overflow_in_one_bucket():
    """Mixed channels inside one fused bucket: each item doubles exactly its
    own tripped channel, untouched items never re-run, and the retry log
    carries one entry per overflowed group."""
    ex = _bare_scheduler()
    log = []
    caps = {"slot": 16, "out": 64}
    items = [
        _item(0, caps, trip="slot"),
        _item(1, caps, trip="out"),
        _item(2, caps, trip=None),
    ]
    ex._run_buckets("rnd", items, _fake_dispatch(log))
    assert log[0] == [
        (0, {"slot": 16, "out": 64}, 0),
        (1, {"slot": 16, "out": 64}, 0),
        (2, {"slot": 16, "out": 64}, 0),
    ]
    # retry round: only the two overflowed items, each with its own channel
    # doubled — and (caps now differing) in separate buckets; the slot item
    # re-salts (attempt 1) while the out item keeps its attempt-0 salts
    retried = sorted((b[0] for b in log[1:]), key=lambda t: t[0])
    assert retried == [
        (0, {"slot": 32, "out": 64}, 1),
        (1, {"slot": 16, "out": 128}, 0),
    ]
    assert ex._retry_log == [
        (("H", 0), "rnd", "slot"),
        (("H", 1), "rnd", "out"),
    ]
    assert items[2].attempt == 0            # clean item never re-ran
    assert ex._retries == 2


def test_scheduler_batched_and_unbatched_retry_identically():
    """The same item set produces the same caps trajectory and retry log
    under both schedules (capacities are item-set functions, not bucket
    functions)."""
    logs = {}
    for batch in (True, False):
        ex = _bare_scheduler(batch=batch)
        log = []
        caps = {"slot": 16, "out": 64}
        items = [_item(0, caps, trip="slot"), _item(1, caps, trip="out")]
        ex._run_buckets("rnd", items, _fake_dispatch(log))
        logs[batch] = (ex._retry_log, [it.caps for it in items], ex._retries)
    assert logs[True] == logs[False]


def test_salt_is_wide_and_attempt_threaded():
    """The routing salt spans the full 31-bit range (beyond the old 2^20) and
    a retry draws a fresh value — the paper's per-attempt randomness."""
    salts = {_salt("stage", i) for i in range(2000)}
    assert max(salts) >= 1 << 20, "salt range must exceed the old 2^20 cap"
    assert len(salts) == 2000
    assert _salt("k", attempt=0) != _salt("k", attempt=1)
    # stability: same key + attempt ⇒ same salt on every host
    assert _salt("k", 3, attempt=2) == _salt("k", 3, attempt=2)


# ---------------------------------------------------------------------------
# Device grid math ≡ host grid math (the geometry the route relies on)
# ---------------------------------------------------------------------------


def test_grid_coordinate_functions_match_numpy():
    import jax.numpy as jnp

    g = CartesianGrid([50, 30, 7], 16)
    ids = np.arange(87, dtype=np.int64)
    for li in range(g.t_prime):
        want = g.cells_for_ids(li, ids)
        got = np.asarray(g.cells_for_ids_dev(li, jnp.asarray(ids, jnp.int32)))
        assert np.array_equal(want, got)

    hc = HyperCubeGrid(("A", "B", "C"), {"A": 3, "B": 2, "C": 4})
    fixed = {"A": np.array([0, 1, 2, 0, 2]), "C": np.array([3, 2, 1, 0, 3])}
    want = hc.cells_for(fixed)
    got = np.asarray(
        hc.cells_for_dev({k: jnp.asarray(v, jnp.int32) for k, v in fixed.items()})
    )
    assert np.array_equal(want, got)


# ---------------------------------------------------------------------------
# Scheduler observability (satellite: compile count is O(#buckets))
# ---------------------------------------------------------------------------


def test_compile_count_scales_with_buckets_not_stages():
    """The stage-batched scheduler compiles one executable per geometry
    bucket: the jit-miss count is bounded by the bucket count (itself far
    below the work-item count), and a repeat run compiles nothing."""
    q = disconnected_query(90, dom_size=12, skew=1.8)
    stats = compute_stats(q, lam=8)
    program = compile_plan(q, stats, 8)
    ex = DataplaneExecutor()
    res = ex.run(program)
    n_buckets = sum(len(v) for v in res.bucket_stage_counts.values())
    n_items = sum(sum(v) for v in res.bucket_stage_counts.values())
    assert res.dispatches == n_buckets
    assert n_buckets < n_items, "batching must actually group stages"
    assert res.jit_cache_misses <= n_buckets
    assert res.jit_cache_hits + res.jit_cache_misses == res.dispatches
    # Steady state: learned caps converge within one repeat run (a run-1
    # partial-bucket retry may force run 2 to compile the merged-caps
    # variant once), after which nothing compiles and nothing retries.
    ex.run(program, materialize=False)
    res3 = ex.run(program, materialize=False)
    assert res3.jit_cache_misses == 0
    assert res3.retries == 0
    assert res3.jit_cache_hits == res3.dispatches
    # the IR-level signature histogram bounds the bucket structure: far
    # fewer distinct signatures than stages
    hist = program.bucket_histogram()
    assert sum(hist.values()) == len(program.stages)
    assert len(hist) < len(program.stages)
