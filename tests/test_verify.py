"""Static verifier + load model: mutation suite, false-positive gate, and the
mis-planned-program CI gate (docs/design/11-verification.md).

Every mutation test compiles a *good* program, corrupts one invariant, and
asserts the verifier rejects it with exactly the right rule name — the
verifier's own regression lock.  The load-bound tests demonstrate the CI
gate: a correctly planned program sits well inside the symbolic model bound,
while a deliberately mis-planned one (λ = 2, so a degree-n hub is never
tagged heavy) blows through it at large p.
"""

import numpy as np
import pytest
from dataclasses import replace
from fractions import Fraction

from repro.analysis.loadmodel import predicted_load, round_bounds, round_bounds_by_name
from repro.core.hypergraph import Hypergraph, rho
from repro.core.planner import MachineGroup, heavy_parameter
from repro.core.query import (
    JoinQuery,
    Relation,
    general_query,
    pattern_edges,
    random_query,
)
from repro.core.taxonomy import compute_stats
from repro.mpc.cartesian import CartesianGrid
from repro.mpc.executors import SimulatorExecutor
from repro.mpc.faults import JoinServiceError, ProgramVerificationError
from repro.mpc.program import (
    GENERAL_CYCLIC_OPS,
    CellJoin,
    GridRoute,
    RouteResidual,
    Scatter,
    SemiJoin,
    ShareRoute,
    StageGeometry,
    TreeSemiJoin,
    compile_plan,
    stage_geometry,
)
from repro.mpc.service import JoinSession
from repro.mpc.verify import (
    RULES,
    check_load,
    check_packed_key,
    check_stage_geometry,
    on_cap_grid,
    verify_bindings,
    verify_caps,
    verify_program,
)


def triangle(seed=2, n=200, dom=30, skew=2.0):
    return random_query(
        np.random.default_rng(seed), "clique", 3, tuples_per_rel=n, dom_size=dom, skew=skew
    )


def compiled(q=None, p=8, lam=16, fuse=False):
    q = q if q is not None else triangle()
    stats = compute_stats(q, lam)
    return compile_plan(q, stats, p, fuse_semijoin=fuse, verify=False)


def hub_triangle(n=1500, seed=3):
    """Triangle with a degree-n hub value on X0 — worst case for a planner
    that fails to tag the hub heavy."""
    rng = np.random.default_rng(seed)
    rels = []
    for e in pattern_edges("clique", 3):
        if e[0] == "X0":
            data = np.stack([np.zeros(n, np.int64), np.arange(n)], axis=1)
        elif e[1] == "X0":
            data = np.stack([np.arange(n), np.zeros(n, np.int64)], axis=1)
        else:
            data = rng.integers(0, n, size=(n, 2))
        rels.append(Relation.make(e, data))
    return JoinQuery.make(rels)


def rule_of(excinfo) -> str:
    assert isinstance(excinfo.value, ProgramVerificationError)
    assert isinstance(excinfo.value, JoinServiceError)  # PR 8 taxonomy member
    assert excinfo.value.rule in RULES
    return excinfo.value.rule


# ---------------------------------------------------------------------------
# zero false positives on good programs
# ---------------------------------------------------------------------------


def test_good_programs_verify_clean():
    for fuse in (False, True):
        prog = compiled(fuse=fuse)
        rep = verify_program(prog)
        assert rep.stages == len(prog.stages)
        assert rep.checks > 0 and rep.geometry_probes > 0
    # shared-table alias classes (the subgraph-reduction shape) verify clean
    base = np.random.default_rng(0).integers(0, 20, size=(60, 2))
    q = JoinQuery.make([
        Relation.make(("X0", "X1"), base, table="edges"),
        Relation.make(("X1", "X2"), base, table="edges"),
        Relation.make(("X0", "X2"), base, table="edges"),
    ])
    verify_program(compiled(q=q, lam=8))


def test_rho_accepts_query_and_hypergraph():
    q = triangle()
    assert rho(q) == rho(q.hypergraph) == Fraction(3, 2)
    assert rho(Hypergraph.from_edges([("A", "B"), ("B", "C"), ("C", "D"), ("D", "A")])) == 2
    with pytest.raises(TypeError):
        rho(42)


# ---------------------------------------------------------------------------
# mutation: op stream (collective-stream / semijoin-fusion)
# ---------------------------------------------------------------------------


def test_dropped_op_caught():
    prog = compiled()
    prog.ops = tuple(op for op in prog.ops if not isinstance(op, RouteResidual))
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "collective-stream"
    assert ei.value.op_round == "step1"


def test_duplicated_collective_caught():
    prog = compiled()
    prog.ops = prog.ops + (GridRoute(),)
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "collective-stream"


def test_reordered_collectives_caught():
    prog = compiled()
    ops = list(prog.ops)
    ops[1], ops[-2] = ops[-2], ops[1]  # RouteResidual <-> GridRoute
    prog.ops = tuple(ops)
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "collective-stream"


def test_broken_semijoin_pair_caught():
    prog = compiled()
    prog.ops = tuple(
        SemiJoin(phase="x") if isinstance(op, SemiJoin) else op for op in prog.ops
    )
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "semijoin-fusion"


def test_fused_flag_without_fused_ops_caught():
    prog = compiled()
    prog.fused = True  # ops still carry the unfused ("x", "y") pair
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "semijoin-fusion"


# ---------------------------------------------------------------------------
# mutation: allocations and geometry (grid-invariants / packed-key)
# ---------------------------------------------------------------------------


def test_oversized_step1_group_caught():
    prog = compiled()
    st = prog.stages[0]
    st.cfg.step1_group = MachineGroup(
        base=st.cfg.step1_group.base, size=prog.p + 5, p=prog.p
    )
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "grid-invariants"
    assert ei.value.op_round == "step1"


def test_corrupted_m_eta_caught():
    prog = compiled()
    prog.stages[0].cfg.m_eta += 7
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "grid-invariants"


def test_unstable_group_base_caught():
    prog = compiled()
    st = prog.stages[0]
    st.cfg.step1_group = MachineGroup(
        base=(st.cfg.step1_group.base + 1) % prog.p,
        size=st.cfg.step1_group.size,
        p=prog.p,
    )
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "grid-invariants"


def test_broken_grid_dims_product_caught():
    prog = compiled()
    st = next(s for s in prog.stages if s.plan.isolated)
    geo = stage_geometry(prog, st, {x: [(0, 50)] for x in st.plan.isolated})
    assert check_stage_geometry(geo, prog.p) > 0  # clean before corruption
    geo.grid.dims[0] = geo.grid.p + 1  # Π(dims) now exceeds the Lemma 3.1 budget
    with pytest.raises(ProgramVerificationError) as ei:
        check_stage_geometry(geo, prog.p)
    assert rule_of(ei) == "grid-invariants"


def test_oversized_cell_space_caught():
    geo = StageGeometry()
    big = 1 << 32
    geo.grid = CartesianGrid([big], big)  # one-list grid: dims = [2^32]
    geo.step3_group = MachineGroup(base=0, size=big, p=big)
    with pytest.raises(ProgramVerificationError) as ei:
        check_stage_geometry(geo, big)
    assert rule_of(ei) == "packed-key"


def test_packed_flag_on_oversized_key_space_caught():
    check_packed_key(2**10, [2**4, 2**3], packed=True)  # fits int32: fine
    check_packed_key(2**40, [2**12], packed=False)  # unpacked: exempt
    with pytest.raises(ProgramVerificationError) as ei:
        check_packed_key(2**20, [2**12, 2**5], packed=True)
    assert rule_of(ei) == "packed-key"
    with pytest.raises(ProgramVerificationError) as ei:
        check_packed_key(2**4, [-1], packed=True)
    assert rule_of(ei) == "packed-key"


# ---------------------------------------------------------------------------
# mutation: bindings (scatter-binding)
# ---------------------------------------------------------------------------


def test_alias_class_mismatch_caught():
    base = np.random.default_rng(0).integers(0, 20, size=(60, 2))
    other = np.random.default_rng(1).integers(0, 20, size=(60, 2))
    q = JoinQuery.make([
        Relation.make(("X0", "X1"), base, table="edges"),
        Relation.make(("X1", "X2"), base, table="edges"),
        Relation.make(("X0", "X2"), base, table="edges"),
    ])
    prog = compiled(q=q, lam=8)
    bad = JoinQuery.make([
        Relation.make(("X0", "X1"), base, table="edges"),
        Relation.make(("X1", "X2"), other, table="edges"),  # same table, new rows
        Relation.make(("X0", "X2"), base, table="edges"),
    ])
    with pytest.raises(ProgramVerificationError) as ei:
        verify_bindings(prog.rebind(bad))
    assert rule_of(ei) == "scatter-binding"


def test_unbound_cache_entry_caught():
    from dataclasses import replace

    prog = compiled()
    with pytest.raises(ProgramVerificationError) as ei:
        verify_bindings(replace(prog, query=None))
    assert rule_of(ei) == "scatter-binding"


def test_emit_machine_out_of_range_caught():
    prog = compiled()
    if not prog.emit:
        prog.emit = [(0, np.zeros((1, len(prog.out_cols)), dtype=np.int64))]
    mid, row = prog.emit[0]
    prog.emit[0] = (prog.p + 3, row)
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "scatter-binding"
    assert ei.value.op_round == "output"


# ---------------------------------------------------------------------------
# caps (cap-grid)
# ---------------------------------------------------------------------------


def test_cap_grid_rule():
    for good in (16, 24, 32, 48, 64, 96, 1 << 20, 3 << 19):
        assert on_cap_grid(good), good
    for bad in (0, 8, 17, 20, 36, 15, 1000):
        assert not on_cap_grid(bad), bad
    verify_caps({("k",): {"slot": 64, "out": 24}})
    with pytest.raises(ProgramVerificationError) as ei:
        verify_caps({("k",): {"slot": 17}})
    assert rule_of(ei) == "cap-grid"


def test_dataplane_learned_caps_stay_on_grid():
    jax = pytest.importorskip("jax")
    if len(jax.devices()) < 2:
        pytest.skip("needs multi-device jax")
    from repro.mpc.executors import DataplaneExecutor
    from repro.mpc.program import RunConfig

    q = triangle(n=120, dom=20)
    ex = DataplaneExecutor()
    prog = compiled(q=q, p=len(jax.devices()), lam=8)
    ex.run(prog, config=RunConfig(materialize=True, verify=True))
    assert ex._learned_caps  # the run learned something
    verify_caps(ex._learned_caps)  # and all of it is on the quant grid
    # a second run re-verifies (program + caps) via RunConfig and still passes
    ex.run(prog, config=RunConfig(materialize=True, verify=True))


# ---------------------------------------------------------------------------
# load-bound: the symbolic model and the mis-planned-program gate
# ---------------------------------------------------------------------------


def test_load_model_shape():
    prog = compiled()
    bounds = round_bounds(prog)
    names = [b.round for b in bounds]
    assert "step1" in names and "step3-route" in names
    assert "scatter" not in names and "output" not in names
    assert all(b.words > 0 and b.formula for b in bounds)
    assert predicted_load(prog) == pytest.approx(sum(b.words for b in bounds))
    # semi-join rounds carry the m/λ* skew term on top of the base bound
    by = round_bounds_by_name(prog)
    assert by["step2-bx"].words > by["step1"].words


def test_well_planned_program_within_load_bound():
    q = hub_triangle()
    p = 256
    lam = heavy_parameter(p, float(rho(q)))
    stats = compute_stats(q, lam)
    prog = compile_plan(q, stats, p, verify=False)
    res = SimulatorExecutor(p=p).run(prog, materialize=False)
    fractions = check_load(prog, res)  # must not raise
    assert fractions and max(fractions.values()) < 1.0


def test_misplanned_program_fails_load_gate():
    """The CI gate: λ = 2 never tags the degree-n hub heavy, so the semi-join
    round concentrates the hub's full edge on one machine — measured load
    exceeds the Theorem 6.2 model bound and the verifier rejects the run."""
    q = hub_triangle()
    p = 256
    stats = compute_stats(q, 2)  # deliberately mis-planned heavy parameter
    prog = compile_plan(q, stats, p, verify=False)
    res = SimulatorExecutor(p=p).run(prog, materialize=False)
    with pytest.raises(ProgramVerificationError) as ei:
        check_load(prog, res)
    assert rule_of(ei) == "load-bound"
    assert ei.value.op_round in ("step2-bx", "step3-route")
    # the same measurement also works from a plain {round: load} mapping
    with pytest.raises(ProgramVerificationError):
        check_load(prog, res.sim.merged_round_loads())


# ---------------------------------------------------------------------------
# service integration: counters + warm path
# ---------------------------------------------------------------------------


def test_service_verifies_cold_and_rebinds_warm():
    q = triangle()
    s = JoinSession(p=4, backend="simulator", verify=True)
    try:
        cold = s.submit(q, lam=16)
        warm = s.submit(q, lam=16)
        assert cold.verified and not cold.plan_cache_hit
        assert cold.verify_us > 0
        assert warm.plan_cache_hit and not warm.verified  # bindings-only re-check
        assert warm.verify_us < cold.verify_us
        assert s.stats.verified == 1  # one full verification, not two
        assert s.stats.verify_us >= cold.verify_us
        assert cold.total_us == pytest.approx(
            cold.stats_us + cold.compile_us + cold.verify_us + cold.execute_us
        )
    finally:
        s.close()


def test_service_verify_off_is_free():
    q = triangle()
    s = JoinSession(p=4, backend="simulator", verify=False)
    try:
        r = s.submit(q, lam=16)
        assert not r.verified and r.verify_us == 0.0
        assert s.stats.verified == 0 and s.stats.verify_us == 0.0
    finally:
        s.close()


def test_compile_plan_env_default(monkeypatch):
    q = triangle()
    stats = compute_stats(q, 16)
    prog = compile_plan(q, stats, 8)
    prog.stages[0].cfg.m_eta += 1  # corrupt, then recompile under each mode
    monkeypatch.setenv("REPRO_VERIFY", "0")
    compile_plan(q, stats, 8)  # off: no verification, no raise possible
    monkeypatch.setenv("REPRO_VERIFY", "1")
    compile_plan(q, stats, 8)  # on + clean program: still fine
    with pytest.raises(ProgramVerificationError):
        verify_program(prog)  # the corrupted copy is rejected


# ---------------------------------------------------------------------------
# mutation: general (arbitrary-arity) programs — join-tree / share-exponent
# ---------------------------------------------------------------------------


def general_compiled(kind="star3", p=8, lam=8):
    q = general_query(kind, n=60, dom_size=6, skew=0.5, seed=9)
    return compile_plan(q, compute_stats(q, lam), p, verify=False)


def test_good_general_programs_verify_clean():
    for kind in ("star3", "snowflake", "path4", "triangle"):
        prog = general_compiled(kind)
        rep = verify_program(prog)
        assert rep.checks > 0 and rep.geometry_probes == 0
        want = "hypercube" if kind == "triangle" else "yannakakis"
        assert prog.general.kind == want


def test_corrupted_tree_edge_caught():
    # reattach the first GYO-removed child under a non-parent leaf: star3's
    # dimension tables share no attribute, so the edge label can no longer be
    # the full scheme intersection and the running-intersection property dies
    prog = general_compiled("star3")
    gen = prog.general
    c, par, sh = gen.tree_edges[0]
    other = next(i for i, _ in enumerate(prog.query.relations)
                 if i not in (c, par, gen.tree_root))
    prog.general = replace(
        gen, tree_edges=((c, other, sh),) + gen.tree_edges[1:]
    )
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "join-tree"


def test_sweep_order_not_leaves_first_caught():
    # snowflake's GYO order must remove A1-A2 before A-A1; swapping the two
    # edges makes the up sweep filter a parent before its child was reduced
    prog = general_compiled("snowflake")
    gen = prog.general
    e = list(gen.tree_edges)
    e[0], e[1] = e[1], e[0]
    prog.general = replace(gen, tree_edges=tuple(e))
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "join-tree"


def test_join_order_child_before_parent_caught():
    prog = general_compiled("star3")
    gen = prog.general
    order = list(gen.join_order)
    order[0], order[1] = order[1], order[0]  # chain no longer starts at root
    prog.general = replace(gen, join_order=tuple(order))
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "join-tree"


def test_acyclic_demoted_to_cyclic_caught():
    # pretending star3 is cyclic (dropping the tree, taking the pure
    # HyperCube route) is wasteful and must not verify
    prog = general_compiled("star3")
    prog.general = replace(prog.general, kind="hypercube", tree_edges=())
    prog.ops = GENERAL_CYCLIC_OPS
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "join-tree"


def test_share_product_over_budget_caught():
    prog = general_compiled("triangle")
    gen = prog.general
    prog.general = replace(gen, shares=tuple((a, s * 4) for a, s in gen.shares))
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "share-exponent"
    assert "exceeds the machine budget" in str(ei.value)


def test_budget_legal_but_non_lp_shares_caught():
    # Π = 8 ≤ p, but (8,1,1) is not the edge-cover LP optimum (2,2,2):
    # budget-legal tampering must still fail the share-exponent rule
    prog = general_compiled("triangle")
    gen = prog.general
    attrs = [a for a, _ in gen.shares]
    bad = ((attrs[0], 8),) + tuple((a, 1) for a in attrs[1:])
    prog.general = replace(gen, shares=bad)
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "share-exponent"


def test_general_sweep_out_of_order_caught():
    prog = general_compiled("star3")
    prog.ops = (
        Scatter(),
        TreeSemiJoin(phase="down"),  # down before up: children filter an
        TreeSemiJoin(phase="up"),    # unreduced parent — not Yannakakis
        ShareRoute(),
        CellJoin(),
    )
    with pytest.raises(ProgramVerificationError) as ei:
        verify_program(prog)
    assert rule_of(ei) == "collective-stream"
