"""Cross-query coalescing: the async submission queue and run_many scheduler.

The acceptance bar of the coalescing layer (docs/design/09-service.md):

  * coalesced execution is a pure *scheduling* change — results (rows, order,
    per-H counts) are byte-identical to serial ``submit()``, both when
    identical submissions dedup onto one execution and when distinct-data
    queries stack into fused dispatches;
  * ``submit_async`` futures resolve to the same results with queue-inclusive
    latency filled in; a full bounded queue rejects with ``AdmissionError``
    (admission control) instead of queueing unboundedly;
  * plan LRU + learned caps stay correct under interleaved multi-query
    submission, including an eviction mid-stream (the satellite-3 scenario);
  * cache provenance is unambiguous: the learned-caps counters are metered
    separately from the plan LRU and the executable cache, per-result and
    session-wide.
"""

import numpy as np
import pytest

from repro.core.query import JoinQuery, Relation, random_query, reference_join
from repro.core.taxonomy import compute_stats
from repro.mpc import (
    AdmissionError,
    DataplaneExecutor,
    JoinSession,
    coalesce_signature,
    programs_coalescible,
)
from repro.mpc.program import compile_plan


def rows_key(rows):
    rows = getattr(rows, "data", rows)  # reference_join returns a Relation
    return sorted(map(tuple, np.asarray(rows).tolist()))


def skew_triangle():
    return random_query(
        np.random.default_rng(2), "clique", 3, tuples_per_rel=120, dom_size=24,
        skew=2.0,
    )


def perm_query(seed: int, n: int = 60) -> JoinQuery:
    """(A,B) ⋈ (B,C) over permutation graphs: no heavy values, so two seeds
    produce different data behind an identical plan cache key."""
    rng = np.random.default_rng(seed)
    ab = np.stack([np.arange(n), rng.permutation(n)], axis=1)
    bc = np.stack([np.arange(n), rng.permutation(n)], axis=1)
    return JoinQuery.make(
        [Relation.make(("A", "B"), ab), Relation.make(("B", "C"), bc)]
    )


def path_query(seed: int) -> JoinQuery:
    return random_query(
        np.random.default_rng(seed), "line", 3, tuples_per_rel=90, dom_size=18,
        skew=1.2,
    )


def serial_reference(queries, lam):
    """Isolated serial submits, one fresh session — the ground truth."""
    s = JoinSession(p=8, backend="dataplane")
    return [s.submit(q, lam=lam) for q in queries]


# ---------------------------------------------------------------------------
# Byte identity: coalesced == serial
# ---------------------------------------------------------------------------


def test_coalesced_mixed_shapes_byte_identical_to_serial():
    # different shapes land in different coalesce groups but share one drain
    # batch; every result must be byte-identical to a serial submit — cold
    # (first pass compiles) AND warm (stacked signatures cached)
    queries = [skew_triangle(), perm_query(3), path_query(5), perm_query(4)]
    serial = serial_reference(queries, lam=4)
    session = JoinSession(p=8, backend="dataplane")
    for _ in range(2):  # cold pass, then warm pass
        out = session.submit_coalesced(queries, lam=4)
        for r, s in zip(out, serial):
            assert r.count == s.count
            assert dict(r.per_h_counts) == dict(s.per_h_counts)
            assert np.array_equal(r.rows, s.rows)  # bytes AND order
    assert session.stats.coalesced_batches == 2
    assert session.stats.max_coalesced_batch == len(queries)


def test_stacked_distinct_data_byte_identical():
    # same plan key, different tables: dedup cannot apply, so these exercise
    # the stage-stacking path (one fused dispatch serves all four queries)
    queries = [perm_query(s) for s in (10, 11, 12, 13)]
    serial = serial_reference(queries, lam=4)
    session = JoinSession(p=8, backend="dataplane")
    out = session.submit_coalesced(queries, lam=4)
    assert session.stats.deduped == 0
    for r, s, q in zip(out, serial, queries):
        assert np.array_equal(r.rows, s.rows)
        assert rows_key(r.rows) == rows_key(reference_join(q))
        assert r.coalesced and r.batch_size == len(queries)


def test_identical_submissions_share_one_execution():
    q = perm_query(21)
    oracle = rows_key(reference_join(q))
    session = JoinSession(p=8, backend="dataplane")
    out = session.submit_coalesced([q, q, q, q], lam=4)
    assert session.stats.deduped == 3
    assert [r.deduplicated for r in out] == [False, True, True, True]
    for r in out:
        assert rows_key(r.rows) == oracle
        assert r.coalesced
    # dedup shares the representative's result object — same bytes for free
    assert out[1].result is out[0].result


# ---------------------------------------------------------------------------
# Async queue: futures, admission control, drainer lifecycle
# ---------------------------------------------------------------------------


def test_submit_async_futures_match_serial():
    queries = [perm_query(30), skew_triangle(), perm_query(31), perm_query(30)]
    serial = serial_reference(queries, lam=4)
    session = JoinSession(p=8, backend="dataplane")
    try:
        futs = [session.submit_async(q, lam=4) for q in queries]
        out = [f.result(timeout=120) for f in futs]
        for r, s in zip(out, serial):
            assert np.array_equal(r.rows, s.rows)
            assert r.e2e_us > 0.0 and r.e2e_us >= r.queue_us
        assert session.stats.async_submits == len(queries)
        assert len(session.stats.e2e_us) == len(queries)
    finally:
        session.close()
    # closed session refuses new async work
    with pytest.raises(RuntimeError):
        session.submit_async(queries[0], lam=4)


def test_admission_control_bounded_queue():
    session = JoinSession(
        p=8, backend="dataplane", max_queue=1, async_autostart=False
    )
    q = perm_query(40)
    fut = session.submit_async(q, lam=4, block=False)
    with pytest.raises(AdmissionError):
        session.submit_async(q, lam=4, block=False)
    assert session.stats.rejected == 1
    assert session.stats.async_submits == 1
    # close() on a drainer-less session drains inline: the admitted request
    # still resolves (backpressure rejects, it never drops admitted work)
    session.close()
    r = fut.result(timeout=0)
    assert rows_key(r.rows) == rows_key(reference_join(q))


def test_drainer_survives_a_failing_request():
    session = JoinSession(p=8, backend="dataplane", async_autostart=False)
    good = perm_query(41)
    # lam=0 blows up in plan preparation — a per-request failure that must
    # resolve its own future exceptionally without poisoning the batch
    f_bad = session.submit_async(perm_query(42), lam=0)
    f_good = session.submit_async(good, lam=4)
    session.close()  # inline drain: one batch with both requests
    with pytest.raises(BaseException):
        f_bad.result(timeout=0)
    r = f_good.result(timeout=0)
    assert rows_key(r.rows) == rows_key(reference_join(good))


# ---------------------------------------------------------------------------
# Interleaved multi-query submission: plan LRU + learned caps (satellite 3)
# ---------------------------------------------------------------------------


def test_interleaved_datasets_with_eviction_mid_stream():
    # two datasets alternate on ONE plan key while a third shape evicts that
    # plan mid-stream (plan_cache_size=1); every result — serial interleaved
    # and coalesced — must match its isolated serial submit
    a, b, tri = perm_query(50), perm_query(51), skew_triangle()
    ref = {id(q): r for q, r in zip(
        (a, b, tri), serial_reference([a, b, tri], lam=4)
    )}
    session = JoinSession(p=8, backend="dataplane", plan_cache_size=1)
    stream = [a, b, tri, a, b, tri, b, a]
    for q in stream:
        r = session.submit(q, lam=4)
        assert np.array_equal(r.rows, ref[id(q)].rows), "interleaved serial"
    assert session.stats.plan_evictions > 0
    # now the same alternation through one coalesced batch (the plan for a/b
    # was just evicted by tri — the batch recompiles and still demuxes right)
    out = session.submit_coalesced([a, b, a, tri, b], lam=4)
    for r, q in zip(out, [a, b, a, tri, b]):
        assert np.array_equal(r.rows, ref[id(q)].rows), "coalesced after evict"
    # learned caps are executor-lifetime: the eviction churn above must not
    # have cost retries
    assert session.stats.retries == 0


# ---------------------------------------------------------------------------
# Cache provenance: learned-caps counters split from the plan LRU
# ---------------------------------------------------------------------------


def test_caps_counters_are_distinct_from_plan_counters():
    session = JoinSession(p=8, backend="dataplane")
    q = skew_triangle()
    cold = session.submit(q, lam=4)
    warm = session.submit(q, lam=4)
    # cold run discovers capacities (misses), warm run reuses them (hits)
    assert cold.caps_misses > 0 and cold.caps_hits == 0
    assert warm.caps_hits > 0 and warm.caps_misses == 0
    # session-wide mirrors, accumulated separately from the plan LRU
    assert session.stats.caps_misses == cold.caps_misses
    assert session.stats.caps_hits == warm.caps_hits
    assert (session.stats.plan_hits, session.stats.plan_misses) == (1, 1)
    # plan-LRU churn does not touch the caps counters
    session.clear_plans()
    before = (session.stats.caps_hits, session.stats.caps_misses,
              session.stats.caps_evictions)
    session.submit(q, lam=4)  # plan miss, caps all hit
    assert session.stats.plan_misses == 2
    assert session.stats.caps_misses == before[1]
    assert session.stats.caps_hits > before[0]


# ---------------------------------------------------------------------------
# Coalescibility predicate + executor-level validation
# ---------------------------------------------------------------------------


def test_coalesce_signature_groups_same_shape_programs():
    a, b = perm_query(60), perm_query(61)
    tri = skew_triangle()
    pa = compile_plan(a, compute_stats(a, lam=4), 8)
    pb = compile_plan(b, compute_stats(b, lam=4), 8)
    pt = compile_plan(tri, compute_stats(tri, lam=4), 8)
    assert coalesce_signature(pa) == coalesce_signature(pb)
    assert programs_coalescible(pa, pb)
    assert not programs_coalescible(pa, pt)


def test_run_many_rejects_mismatched_op_sequences():
    # fused vs unfused plans of one query: same buckets, different op list —
    # the executor must refuse to stack them rather than misinterpret ops
    tri = skew_triangle()
    st = compute_stats(tri, lam=4)
    plain = compile_plan(tri, st, 8)
    fused = compile_plan(tri, st, 8, fuse_semijoin=True)
    assert plain.ops != fused.ops  # precondition of the rejection
    ex = DataplaneExecutor()
    with pytest.raises(ValueError, match="coalescible"):
        ex.run_many([plain, fused])


# ---------------------------------------------------------------------------
# SLO + latency percentiles
# ---------------------------------------------------------------------------


def test_slo_counters_and_percentiles():
    session = JoinSession(p=8, backend="dataplane", slo_target_us=1e12)
    q = perm_query(70)
    session.submit(q, lam=4)
    session.submit(q, lam=4)
    assert session.stats.slo_ok == 2 and session.stats.slo_violations == 0
    session.slo_target_us = 0.0  # nothing is that fast
    session.submit(q, lam=4)
    assert session.stats.slo_violations == 1
    p50 = session.stats.percentile(50, window="warm")
    p99 = session.stats.percentile(99, window="warm")
    assert 0.0 < p50 <= p99
    assert session.stats.percentile(50, window="e2e") == 0.0  # no async yet
    with pytest.raises(ValueError):
        session.stats.percentile(50, window="nope")
