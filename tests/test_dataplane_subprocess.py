"""Multi-device tests run in a subprocess so the 8-device XLA flag never leaks into
this process (smoke tests must see 1 device — dry-run contract)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = Path(__file__).parent / "subproc" / "dataplane_check.py"


@pytest.mark.slow
def test_dataplane_multi_device():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, str(SCRIPT)],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"
    assert "ALL DATAPLANE CHECKS PASSED" in res.stdout
