"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the kernel body + BlockSpec schedule on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional test extra; only the property test needs it
    HAVE_HYPOTHESIS = False

from repro.kernels.ops import fold64, hash_partition, merge_join_counts, ssd_chunk
from repro.kernels import ref as kref
from repro.models.mamba import ssd_reference


# ---------------------------------------------------------------------------
# merge_join
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m", [(256, 1024), (512, 2048), (300, 1500), (256, 999)])
@pytest.mark.parametrize("dom", [50, 10_000])
def test_merge_join_counts_matches_searchsorted(n, m, dom):
    rng = np.random.default_rng(n + m + dom)
    a = np.sort(rng.integers(0, dom, n).astype(np.int32))
    b = np.sort(rng.integers(0, dom, m).astype(np.int32))
    lo, up = merge_join_counts(jnp.asarray(a), jnp.asarray(b))
    lo_ref, up_ref = kref.merge_join_counts_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(lo_ref))
    np.testing.assert_array_equal(np.asarray(up), np.asarray(up_ref))


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 1_000),
        n=st.integers(1, 700),
        m=st.integers(1, 3000),
        dom=st.integers(1, 500),
    )
    def test_merge_join_property(seed, n, m, dom):
        rng = np.random.default_rng(seed)
        a = np.sort(rng.integers(0, dom, n).astype(np.int32))
        b = np.sort(rng.integers(0, dom, m).astype(np.int32))
        lo, up = merge_join_counts(jnp.asarray(a), jnp.asarray(b))
        lo, up = np.asarray(lo), np.asarray(up)
        # counts == true multiplicity
        want = np.array([np.sum(b == x) for x in a])
        np.testing.assert_array_equal(up - lo, want)
        # ranges actually index matches
        for i in range(0, n, max(1, n // 10)):
            assert np.all(b[lo[i] : up[i]] == a[i])

else:

    @pytest.mark.skip(reason="property test needs the optional hypothesis extra")
    def test_merge_join_property():
        pass


def test_merge_join_total_pairs_vs_join():
    """Σ counts == |A ⋈ B| on the shared key."""
    rng = np.random.default_rng(7)
    a = np.sort(rng.integers(0, 40, 512).astype(np.int32))
    b = np.sort(rng.integers(0, 40, 2048).astype(np.int32))
    lo, up = merge_join_counts(jnp.asarray(a), jnp.asarray(b))
    total = int(np.sum(np.asarray(up) - np.asarray(lo)))
    brute = sum(int(np.sum(b == x)) for x in a)
    assert total == brute


# ---------------------------------------------------------------------------
# hash_partition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1024, 4096, 1000])
@pytest.mark.parametrize("parts", [8, 64, 256])
def test_hash_partition_matches_ref(n, parts):
    rng = np.random.default_rng(n * parts)
    keys = rng.integers(-(2**62), 2**62, n).astype(np.int64)
    part, hist = hash_partition(jnp.asarray(keys), parts)
    part_ref, hist_ref = kref.hash_partition_ref(fold64(jnp.asarray(keys)), parts, tile=1)
    np.testing.assert_array_equal(np.asarray(part), np.asarray(part_ref).reshape(-1))
    np.testing.assert_array_equal(
        np.asarray(hist), np.bincount(np.asarray(part), minlength=parts)
    )
    assert int(np.asarray(hist).sum()) == n


def test_hash_partition_balanced():
    """2-universal-ish mix: no partition should be grossly overloaded on uniform keys."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**62, 1 << 14).astype(np.int64)
    _, hist = hash_partition(jnp.asarray(keys), 16)
    h = np.asarray(hist)
    assert h.max() < 2.0 * h.mean()


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (2, 64, 16, 32, 16),
    (3, 128, 32, 64, 32),
    (1, 64, 64, 128, 64),
])
def test_ssd_kernel_matches_recurrence(bh, s, p, n, chunk):
    rng = np.random.default_rng(bh * s + p)
    x = rng.normal(size=(bh, s, p)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, size=(bh, s)).astype(np.float32)
    a = -rng.uniform(0.5, 2.0, size=(bh,)).astype(np.float32)
    b = rng.normal(size=(bh, s, n)).astype(np.float32)
    c = rng.normal(size=(bh, s, n)).astype(np.float32)

    y_k, st_k = ssd_chunk(*map(jnp.asarray, (x, dt, a, b, c)), chunk=chunk)

    # oracle: naive per-token recurrence (ssd_reference vectorizes `a` per head, not
    # per batch — run one (batch·head) slice at a time with H=1, groups=1)
    for i in range(bh):
        y_i, st_i = ssd_reference(
            jnp.asarray(x[i : i + 1, :, None, :]),
            jnp.asarray(dt[i : i + 1, :, None]),
            jnp.asarray(a[i : i + 1]),
            jnp.asarray(b[i : i + 1, :, None, :]),
            jnp.asarray(c[i : i + 1, :, None, :]),
        )
        np.testing.assert_allclose(
            np.asarray(y_k[i]), np.asarray(y_i[0, :, 0, :]), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(
            np.asarray(st_k[i]), np.asarray(st_i[0, 0]), rtol=2e-4, atol=2e-4
        )


def test_ssd_kernel_matches_ops_oracle():
    """Pallas path ≡ the jnp chunked oracle in ops.py (same chunking)."""
    rng = np.random.default_rng(3)
    bh, s, p, n, chunk = 2, 128, 16, 32, 32
    args = (
        rng.normal(size=(bh, s, p)).astype(np.float32),
        rng.uniform(0.01, 0.2, size=(bh, s)).astype(np.float32),
        -rng.uniform(0.5, 2.0, size=(bh,)).astype(np.float32),
        rng.normal(size=(bh, s, n)).astype(np.float32),
        rng.normal(size=(bh, s, n)).astype(np.float32),
    )
    jargs = tuple(map(jnp.asarray, args))
    y1, s1 = ssd_chunk(*jargs, chunk=chunk, use_pallas=True)
    y2, s2 = ssd_chunk(*jargs, chunk=chunk, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)
