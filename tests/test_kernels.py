"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the kernel body + BlockSpec schedule on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional test extra; only the property test needs it
    HAVE_HYPOTHESIS = False

from repro.kernels.ops import (
    fold64,
    hash_partition,
    hash_partition_pack,
    merge_join_counts,
    merge_join_pairs,
    ssd_chunk,
)
from repro.kernels import ref as kref
from repro.models.mamba import ssd_reference


# ---------------------------------------------------------------------------
# merge_join
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m", [(256, 1024), (512, 2048), (300, 1500), (256, 999)])
@pytest.mark.parametrize("dom", [50, 10_000])
def test_merge_join_counts_matches_searchsorted(n, m, dom):
    rng = np.random.default_rng(n + m + dom)
    a = np.sort(rng.integers(0, dom, n).astype(np.int32))
    b = np.sort(rng.integers(0, dom, m).astype(np.int32))
    lo, up = merge_join_counts(jnp.asarray(a), jnp.asarray(b))
    lo_ref, up_ref = kref.merge_join_counts_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(lo_ref))
    np.testing.assert_array_equal(np.asarray(up), np.asarray(up_ref))


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 1_000),
        n=st.integers(1, 700),
        m=st.integers(1, 3000),
        dom=st.integers(1, 500),
    )
    def test_merge_join_property(seed, n, m, dom):
        rng = np.random.default_rng(seed)
        a = np.sort(rng.integers(0, dom, n).astype(np.int32))
        b = np.sort(rng.integers(0, dom, m).astype(np.int32))
        lo, up = merge_join_counts(jnp.asarray(a), jnp.asarray(b))
        lo, up = np.asarray(lo), np.asarray(up)
        # counts == true multiplicity
        want = np.array([np.sum(b == x) for x in a])
        np.testing.assert_array_equal(up - lo, want)
        # ranges actually index matches
        for i in range(0, n, max(1, n // 10)):
            assert np.all(b[lo[i] : up[i]] == a[i])

else:

    @pytest.mark.skip(reason="property test needs the optional hypothesis extra")
    def test_merge_join_property():
        pass


def _pairs_fixture(seed, n, m, dom, cap_out):
    """Sorted sides → (lower, starts, total, expected pair list) for the
    pair-emission kernel, built the exact way local_sorted_join builds them."""
    rng = np.random.default_rng(seed)
    a = np.sort(rng.integers(0, dom, n).astype(np.int32))
    b = np.sort(rng.integers(0, dom, m).astype(np.int32))
    lower = np.searchsorted(b, a, side="left").astype(np.int32)
    upper = np.searchsorted(b, a, side="right").astype(np.int32)
    counts = upper - lower
    starts = (np.cumsum(counts) - counts).astype(np.int32)
    total = int(counts.sum())
    exp_a = np.concatenate([np.full(c, i, np.int32) for i, c in enumerate(counts)]) \
        if total else np.zeros(0, np.int32)
    exp_b = np.concatenate(
        [lower[i] + np.arange(c, dtype=np.int32) for i, c in enumerate(counts)]
    ) if total else np.zeros(0, np.int32)
    return lower, starts, total, exp_a[:cap_out], exp_b[:cap_out]


@pytest.mark.parametrize("n,m,dom,cap_out", [
    (256, 1024, 50, 1 << 13),
    (300, 1500, 40, 1 << 12),
    (512, 2048, 10_000, 1 << 10),
    (1, 7, 3, 64),
])
def test_merge_join_pairs_matches_ref_and_expansion(n, m, dom, cap_out):
    lower, starts, total, exp_a, exp_b = _pairs_fixture(n + m + dom, n, m, dom, cap_out)
    out_k = merge_join_pairs(
        jnp.asarray(lower), jnp.asarray(starts), cap_out, use_pallas=True
    )
    out_r = merge_join_pairs(
        jnp.asarray(lower), jnp.asarray(starts), cap_out, use_pallas=False
    )
    # kernel ≡ jnp reference on the full padded range (pads alias the last key
    # in both paths), and both enumerate exactly the true pair list up front
    for k, r in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(k), np.asarray(r))
    v = min(total, cap_out)
    np.testing.assert_array_equal(np.asarray(out_k[0])[:v], exp_a[:v])
    np.testing.assert_array_equal(np.asarray(out_k[1])[:v], exp_b[:v])


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 1_000),
        n=st.integers(1, 700),
        m=st.integers(1, 2000),
        dom=st.integers(1, 300),
        cap_log=st.integers(4, 12),
    )
    def test_merge_join_pairs_property(seed, n, m, dom, cap_log):
        cap_out = 1 << cap_log
        lower, starts, total, exp_a, exp_b = _pairs_fixture(seed, n, m, dom, cap_out)
        a_idx, b_idx = merge_join_pairs(
            jnp.asarray(lower), jnp.asarray(starts), cap_out, use_pallas=True
        )
        a_ref, b_ref = merge_join_pairs(
            jnp.asarray(lower), jnp.asarray(starts), cap_out, use_pallas=False
        )
        np.testing.assert_array_equal(np.asarray(a_idx), np.asarray(a_ref))
        np.testing.assert_array_equal(np.asarray(b_idx), np.asarray(b_ref))
        v = min(total, cap_out)
        np.testing.assert_array_equal(np.asarray(a_idx)[:v], exp_a[:v])
        np.testing.assert_array_equal(np.asarray(b_idx)[:v], exp_b[:v])

else:

    @pytest.mark.skip(reason="property test needs the optional hypothesis extra")
    def test_merge_join_pairs_property():
        pass


def test_merge_join_total_pairs_vs_join():
    """Σ counts == |A ⋈ B| on the shared key."""
    rng = np.random.default_rng(7)
    a = np.sort(rng.integers(0, 40, 512).astype(np.int32))
    b = np.sort(rng.integers(0, 40, 2048).astype(np.int32))
    lo, up = merge_join_counts(jnp.asarray(a), jnp.asarray(b))
    total = int(np.sum(np.asarray(up) - np.asarray(lo)))
    brute = sum(int(np.sum(b == x)) for x in a)
    assert total == brute


# ---------------------------------------------------------------------------
# hash_partition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1024, 4096, 1000])
@pytest.mark.parametrize("parts", [8, 64, 256])
def test_hash_partition_matches_ref(n, parts):
    rng = np.random.default_rng(n * parts)
    keys = rng.integers(-(2**62), 2**62, n).astype(np.int64)
    part, hist = hash_partition(jnp.asarray(keys), parts)
    part_ref, hist_ref = kref.hash_partition_ref(fold64(jnp.asarray(keys)), parts, tile=1)
    np.testing.assert_array_equal(np.asarray(part), np.asarray(part_ref).reshape(-1))
    np.testing.assert_array_equal(
        np.asarray(hist), np.bincount(np.asarray(part), minlength=parts)
    )
    assert int(np.asarray(hist).sum()) == n


def _pack_check(keys, count, parts):
    """Semantic contract of the fused pack: rows before ``count`` carry their
    hash partition and a stable in-partition rank; rows at or past ``count``
    are ghosted to partition id ``parts``."""
    part, slot, send = hash_partition_pack(jnp.asarray(keys), count, parts)
    part, slot, send = np.asarray(part), np.asarray(slot), np.asarray(send)
    ref_part, _ = hash_partition(jnp.asarray(keys), parts)
    ref_part = np.asarray(ref_part)
    n = len(keys)
    assert np.all(part[count:] == parts)
    np.testing.assert_array_equal(part[:count], ref_part[:count])
    for pid in range(parts):
        ranks = slot[:count][part[:count] == pid]
        np.testing.assert_array_equal(np.sort(ranks), np.arange(len(ranks)))
        assert send[pid] == len(ranks)
    assert int(send.sum()) == int(count)
    return part, slot, send


@pytest.mark.parametrize("n,parts", [(1024, 8), (4096, 64), (1000, 16)])
@pytest.mark.parametrize("frac", [1.0, 0.7])
def test_hash_partition_pack_matches_ref(n, parts, frac):
    rng = np.random.default_rng(n * parts)
    keys = rng.integers(-(2**62), 2**62, n).astype(np.int64)
    count = int(n * frac)
    out_k = hash_partition_pack(jnp.asarray(keys), count, parts, use_pallas=True)
    out_r = hash_partition_pack(jnp.asarray(keys), count, parts, use_pallas=False)
    for k, r in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(k), np.asarray(r))
    _pack_check(keys, count, parts)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 1_000),
        n=st.integers(1, 2000),
        parts=st.sampled_from([2, 8, 32, 128]),
        frac=st.floats(0.0, 1.0),
    )
    def test_hash_partition_pack_property(seed, n, parts, frac):
        rng = np.random.default_rng(seed)
        keys = rng.integers(-(2**62), 2**62, n).astype(np.int64)
        count = int(n * frac)
        out_k = hash_partition_pack(jnp.asarray(keys), count, parts, use_pallas=True)
        out_r = hash_partition_pack(jnp.asarray(keys), count, parts, use_pallas=False)
        for k, r in zip(out_k, out_r):
            np.testing.assert_array_equal(np.asarray(k), np.asarray(r))
        _pack_check(keys, count, parts)

else:

    @pytest.mark.skip(reason="property test needs the optional hypothesis extra")
    def test_hash_partition_pack_property():
        pass


def test_hash_partition_balanced():
    """2-universal-ish mix: no partition should be grossly overloaded on uniform keys."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**62, 1 << 14).astype(np.int64)
    _, hist = hash_partition(jnp.asarray(keys), 16)
    h = np.asarray(hist)
    assert h.max() < 2.0 * h.mean()


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (2, 64, 16, 32, 16),
    (3, 128, 32, 64, 32),
    (1, 64, 64, 128, 64),
])
def test_ssd_kernel_matches_recurrence(bh, s, p, n, chunk):
    rng = np.random.default_rng(bh * s + p)
    x = rng.normal(size=(bh, s, p)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, size=(bh, s)).astype(np.float32)
    a = -rng.uniform(0.5, 2.0, size=(bh,)).astype(np.float32)
    b = rng.normal(size=(bh, s, n)).astype(np.float32)
    c = rng.normal(size=(bh, s, n)).astype(np.float32)

    y_k, st_k = ssd_chunk(*map(jnp.asarray, (x, dt, a, b, c)), chunk=chunk)

    # oracle: naive per-token recurrence (ssd_reference vectorizes `a` per head, not
    # per batch — run one (batch·head) slice at a time with H=1, groups=1)
    for i in range(bh):
        y_i, st_i = ssd_reference(
            jnp.asarray(x[i : i + 1, :, None, :]),
            jnp.asarray(dt[i : i + 1, :, None]),
            jnp.asarray(a[i : i + 1]),
            jnp.asarray(b[i : i + 1, :, None, :]),
            jnp.asarray(c[i : i + 1, :, None, :]),
        )
        np.testing.assert_allclose(
            np.asarray(y_k[i]), np.asarray(y_i[0, :, 0, :]), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(
            np.asarray(st_k[i]), np.asarray(st_i[0, 0]), rtol=2e-4, atol=2e-4
        )


def test_ssd_kernel_matches_ops_oracle():
    """Pallas path ≡ the jnp chunked oracle in ops.py (same chunking)."""
    rng = np.random.default_rng(3)
    bh, s, p, n, chunk = 2, 128, 16, 32, 32
    args = (
        rng.normal(size=(bh, s, p)).astype(np.float32),
        rng.uniform(0.01, 0.2, size=(bh, s)).astype(np.float32),
        -rng.uniform(0.5, 2.0, size=(bh,)).astype(np.float32),
        rng.normal(size=(bh, s, n)).astype(np.float32),
        rng.normal(size=(bh, s, n)).astype(np.float32),
    )
    jargs = tuple(map(jnp.asarray, args))
    y1, s1 = ssd_chunk(*jargs, chunk=chunk, use_pallas=True)
    y2, s2 = ssd_chunk(*jargs, chunk=chunk, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)
