"""Per-arch smoke tests: reduced same-family config, one forward + one train step on
CPU, asserting output shapes and finite values; plus prefill→decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_for_smoke
from repro.models.model import (
    decode_step,
    init_cache,
    init_params,
    loss_fn,
    model_forward,
    prefill,
)
from repro.train.data import synth_batch
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step

ARCH_NAMES = sorted(ARCHS)
SEQ = 32
BATCH = 2


def _batch_for(cfg, seq=SEQ, batch=BATCH, step=0):
    return {
        k: jnp.asarray(v)
        for k, v in synth_batch(cfg, step=step, global_batch=batch, seq=seq).items()
    }


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced_for_smoke(ARCHS[name])
            params = init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_finite(built, name):
    cfg, params = built(name)
    batch = _batch_for(cfg)
    logits, aux = jax.jit(lambda p, b: model_forward(cfg, p, b))(params, batch)
    s_total = SEQ if cfg.frontend != "prefix_embeds" else SEQ
    assert logits.shape == (BATCH, s_total, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step(built, name):
    cfg, params = built(name)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    step = jax.jit(make_train_step(cfg, tcfg))
    state = init_train_state(cfg, tcfg, params)
    batch = _batch_for(cfg)
    new_params, new_state, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    delta = sum(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert delta > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_then_decode(built, name):
    cfg, params = built(name)
    batch = _batch_for(cfg)
    logits, cache = jax.jit(lambda p, b: prefill(cfg, p, b))(params, batch)
    assert logits.shape == (BATCH, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits2, cache = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))(params, cache, tok)
    assert logits2.shape == (BATCH, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits2).all())
    assert int(cache["pos"]) == (SEQ if cfg.frontend != "prefix_embeds" else SEQ) + 1


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce forward logits (full-attention arch)."""
    cfg = reduced_for_smoke(ARCHS["internlm2-20b"])
    params = init_params(cfg, jax.random.PRNGKey(1))
    seq = 16
    batch = _batch_for(cfg, seq=seq, batch=1)
    full_logits, _ = model_forward(cfg, params, batch)

    pre = {"tokens": batch["tokens"][:, : seq - 4], "labels": batch["labels"][:, : seq - 4]}
    logits, cache = prefill(cfg, params, pre, cache_len=seq)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, seq - 5]), rtol=2e-2, atol=2e-2
    )
    for i in range(seq - 4, seq):
        tok = batch["tokens"][:, i]
        logits, cache = decode_step(cfg, params, cache, tok)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, i]), rtol=2e-2, atol=2e-2
        )


def test_decode_matches_forward_ssm():
    """Same for the SSM arch: recurrent decode ≡ chunked-parallel forward."""
    cfg = reduced_for_smoke(ARCHS["mamba2-780m"])
    params = init_params(cfg, jax.random.PRNGKey(2))
    seq = 16
    batch = _batch_for(cfg, seq=seq, batch=1)
    full_logits, _ = model_forward(cfg, params, batch)
    pre = {"tokens": batch["tokens"][:, : seq - 4], "labels": batch["labels"][:, : seq - 4]}
    logits, cache = prefill(cfg, params, pre)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, seq - 5]), rtol=2e-2, atol=2e-2
    )
    for i in range(seq - 4, seq):
        tok = batch["tokens"][:, i]
        logits, cache = decode_step(cfg, params, cache, tok)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, i]), rtol=2e-2, atol=2e-2
        )


def test_loss_decreases():
    """A few steps on the tiny dense arch: loss must drop on a repeated batch."""
    cfg = reduced_for_smoke(ARCHS["h2o-danube-1.8b"])
    params = init_params(cfg, jax.random.PRNGKey(3))
    tcfg = TrainConfig(adamw=AdamWConfig(lr=3e-3, warmup_steps=1, total_steps=50))
    step = jax.jit(make_train_step(cfg, tcfg))
    state = init_train_state(cfg, tcfg, params)
    batch = _batch_for(cfg, seq=32, batch=4)
    losses = []
    for _ in range(8):
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses
