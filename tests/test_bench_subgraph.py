"""Locks the warm-path accounting contract of ``benchmarks/bench_subgraph.py``.

Historically the bench bound the *cold* run's engine stats to the warm report
line, publishing 3–6 compile misses as the warm figure — contradicting the
ExecutableCache's zero-miss steady-state promise that the warm runs actually
keep.  ``measure_case`` now returns distinct ``cold_stats``/``warm_stats``
objects; this suite pins the zero-miss/zero-retry warm steady state and the
cold/warm separation so the regression can't silently return.
"""

import numpy as np

from benchmarks.bench_subgraph import measure_case
from repro.graph import triangle, zipf_graph


def _tiny_case():
    rng = np.random.default_rng(3)
    g = zipf_graph(rng, 60, 220, skew=1.2)
    return g, triangle(), 8


def test_warm_stats_come_from_a_warm_run():
    g, pat, lam = _tiny_case()
    m = measure_case(g, pat, lam, warm_repeats=2)
    cold, warm = m["cold_stats"], m["warm_stats"]
    # distinct result objects: the historical bug aliased warm to cold
    assert warm is not cold
    # cold run pays the trace+compile misses ...
    assert cold.jit_cache_misses > 0
    # ... and the warm steady state is zero-miss, zero-retry, all cache hits
    assert warm.jit_cache_misses == 0
    assert warm.retries == 0
    assert warm.jit_cache_hits > 0


def test_warm_breakdown_feeds_the_snapshot():
    # the JSON snapshot publishes the warm run's per-phase/per-round latency
    # maps (trend lines that localize a warm regression); they must be
    # present and non-trivial on the warm stats object the bench reads
    g, pat, lam = _tiny_case()
    m = measure_case(g, pat, lam, warm_repeats=1)
    warm = m["warm_stats"]
    assert {"host_prep", "launch", "sync"} <= set(warm.phase_us)
    assert warm.round_us and all(v >= 0.0 for v in warm.round_us.values())


def test_cold_and_warm_agree_on_results():
    g, pat, lam = _tiny_case()
    m = measure_case(g, pat, lam, warm_repeats=1)
    assert m["warm"].count == m["cold"].count
    assert m["cold_us"] > 0 and m["warm_us"] > 0
