"""Beyond-paper engine optimization: semi-join round fusion — correctness preserved,
one data round saved when light edges have non-border first attributes."""

import numpy as np

from repro.core.query import JoinQuery, Relation, random_query, reference_join
from repro.mpc.engine import mpc_join


def test_fused_semijoin_exact():
    rng = np.random.default_rng(0)
    for kind, k, skew in [("clique", 3, 2.0), ("cycle", 4, 1.0), ("line", 4, 0.0)]:
        q = random_query(rng, kind, k, tuples_per_rel=150, dom_size=20, skew=skew)
        oracle = reference_join(q)
        a = mpc_join(q, p=8, lam=8, materialize=True, fuse_semijoin=False)
        b = mpc_join(q, p=8, lam=8, materialize=True, fuse_semijoin=True)
        assert a.count == b.count == len(oracle)
        assert set(map(tuple, b.rows.tolist())) == oracle.rows_as_set()


def test_fused_semijoin_saves_load():
    """On a query whose residuals have few cross edges (uniform data ⇒ H=∅ dominates,
    no border attrs), fusion removes the step2-bx round entirely."""
    rng = np.random.default_rng(1)
    q = random_query(rng, "clique", 3, tuples_per_rel=800, dom_size=800, skew=0.0)
    a = mpc_join(q, p=8, materialize=False, fuse_semijoin=False)
    b = mpc_join(q, p=8, materialize=False, fuse_semijoin=True)
    assert a.count == b.count
    loads_a = a.sim.merged_round_loads()
    loads_b = b.sim.merged_round_loads()
    assert loads_a.get("step2-bx", 0) > 0
    assert loads_b.get("step2-bx", 0) == 0          # round gone
    assert b.load < a.load                           # net win
