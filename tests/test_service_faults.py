"""Chaos suite: the fault-injection layer and the hardened JoinSession.

The robustness acceptance bar (docs/design/10-robustness.md):

  * **Typed failures.**  Every failed request surfaces a
    ``JoinServiceError`` subclass *naming the query*, with the root cause —
    executor frames included — chained on ``__cause__`` (no lost
    tracebacks across the future / ``raise out`` boundary).
  * **No hung futures.**  Under any seeded FaultPlan (dispatch failures,
    persistent overflow, drainer crashes, deadlines) every admitted request
    resolves exactly once, including requests in flight when the drainer
    dies.
  * **Isolation.**  A poisoned query inside a coalesced batch fails alone:
    the fused dispatch falls back to per-member serial execution and the
    batchmates return rows byte-identical to a fault-free serial run
    (routing salts never depend on the batch shape — the PR 7 invariant).
  * **Recovery.**  Caches touched by a failed attempt are quarantined, so
    once the fault plan drains the session converges back to the
    retries=0 / jit_misses=0 warm steady state.

Determinism: FaultPlan decisions are pure functions of
(seed, site, event index, rule index), so every scenario here replays
identically — the chaos sweep is as reproducible as a unit test.
"""

import time
import traceback

import numpy as np
import pytest

from repro.core.query import JoinQuery, Relation, random_query, reference_join
from repro.core.taxonomy import compute_stats
from repro.mpc import (
    DataplaneExecutor,
    DeadlineExceededError,
    DegradedSessionError,
    FaultPlan,
    FaultRule,
    InjectedDispatchError,
    JoinServiceError,
    JoinSession,
    QueryFailedError,
    RetryExhaustedError,
    RunConfig,
)
from repro.mpc.faults import describe_query
from repro.mpc.program import compile_plan


def rows_key(rows):
    rows = getattr(rows, "data", rows)
    return sorted(map(tuple, np.asarray(rows).tolist()))


def perm_query(seed: int, n: int = 60) -> JoinQuery:
    """(A,B) ⋈ (B,C) permutation graphs: distinct data, one plan key."""
    rng = np.random.default_rng(seed)
    ab = np.stack([np.arange(n), rng.permutation(n)], axis=1)
    bc = np.stack([np.arange(n), rng.permutation(n)], axis=1)
    return JoinQuery.make(
        [Relation.make(("A", "B"), ab), Relation.make(("B", "C"), bc)]
    )


def skew_triangle():
    return random_query(
        np.random.default_rng(2), "clique", 3, tuples_per_rel=120, dom_size=24,
        skew=2.0,
    )


def serial_reference(queries, lam=4):
    s = JoinSession(p=8, backend="dataplane")
    return [s.submit(q, lam=lam) for q in queries]


def outcomes(futures, timeout=120.0):
    """Resolve every future (bounded wait — a hang IS the failure)."""
    outs = []
    for f in futures:
        try:
            outs.append(f.result(timeout=timeout))
        except BaseException as e:
            outs.append(e)
    return outs


# ---------------------------------------------------------------------------
# FaultPlan determinism and rule mechanics
# ---------------------------------------------------------------------------


def test_fault_plan_is_deterministic_and_rule_scoped():
    def run(seed):
        fp = FaultPlan([FaultRule(site="dispatch", rate=0.3)], seed=seed)
        fired = []
        for _ in range(200):
            try:
                fp.at_dispatch("output")
                fired.append(0)
            except InjectedDispatchError:
                fired.append(1)
        return fired

    a, b = run(7), run(7)
    assert a == b, "same seed ⇒ identical injection schedule"
    assert 20 < sum(a) < 110, "rate≈0.3 over 200 events"
    assert run(8) != a, "different seed ⇒ different schedule"

    # count caps total injections; after skips warmup events; rounds filter
    fp = FaultPlan(
        [FaultRule(site="dispatch", rate=1.0, count=2, after=3,
                   rounds=("step1",))],
        seed=0,
    )
    hits = 0
    for rnd in ["step1"] * 10 + ["output"] * 10:
        try:
            fp.at_dispatch(rnd)
        except InjectedDispatchError:
            hits += 1
    assert hits == 2, "after=3 skips 3 step1 events, count=2 then drains"
    assert fp.drained() and fp.injected["dispatch"] == 2
    assert all(rnd == "step1" for _, rnd, _, _ in fp.log)

    with pytest.raises(ValueError):
        FaultRule(site="nonsense")
    with pytest.raises(ValueError):
        FaultRule(site="dispatch", rate=1.5)


def test_overflow_rules_only_force_carried_channels():
    fp = FaultPlan.persistent_overflow(channels=("slot", "out"))
    assert fp.overflow("step1") == ("out", "slot")
    assert FaultPlan.none().overflow("step1") == ()
    assert FaultPlan.none().drained()


# ---------------------------------------------------------------------------
# Typed errors + traceback preservation (satellite: the `raise out` fix)
# ---------------------------------------------------------------------------


def test_dispatch_fault_surfaces_as_query_failed_with_executor_frames():
    q = perm_query(2)
    session = JoinSession(
        p=8, backend="dataplane",
        fault_plan=FaultPlan.dispatch_failures(1.0, count=1),
    )
    with pytest.raises(QueryFailedError) as ei:
        session.submit(q, lam=4)
    err = ei.value
    assert err.query is q and describe_query(q) in str(err)
    assert isinstance(err.__cause__, InjectedDispatchError)
    # the satellite fix: the formatted chain must still show where inside
    # the executor the failure happened, across the stored-exception re-raise
    chain = "".join(traceback.format_exception(type(err), err, err.__traceback__))
    assert "_run_buckets" in chain
    assert "InjectedDispatchError" in chain
    # plan quarantine: the failed attempt dropped its plan-LRU entry
    assert session.stats.failed == 1
    assert session.stats.quarantined_plans == 1
    # the drained plan injects nothing more — full recovery
    r = session.submit(q, lam=4)
    assert rows_key(r.rows) == rows_key(reference_join(q))
    assert r.retries == 0


def test_all_faults_resolve_as_typed_join_service_errors():
    # a completely broken request (lam=0 dies in preparation) still comes
    # back typed and named — not a bare exception
    session = JoinSession(p=8, backend="dataplane")
    q = perm_query(3)
    with pytest.raises(JoinServiceError) as ei:
        session.submit(q, lam=0)
    assert ei.value.query is q
    # JoinServiceError subclasses RuntimeError: pre-taxonomy callers keep working
    assert isinstance(ei.value, RuntimeError)


# ---------------------------------------------------------------------------
# max_retries exhaustion + learned-caps quarantine (satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("batch_stages", [True, False])
def test_retry_exhaustion_raises_typed_and_quarantines(batch_stages):
    q = perm_query(4)
    prog = compile_plan(q, compute_stats(q, lam=4), 8)
    ex = DataplaneExecutor(max_retries=2, batch_stages=batch_stages)
    with pytest.raises(RetryExhaustedError) as ei:
        ex.run(prog.rebind(q), config=RunConfig(
            fault_plan=FaultPlan.persistent_overflow(channels=("slot",))
        ))
    err = ei.value
    assert err.op_round is not None and err.attempts == 3
    assert any("slot" in entry[2] for entry in err.attempt_log)
    # quarantine: no fault-inflated capacities survive the failed attempt —
    # the next clean run rebuilds exact caps and converges straight back
    res = ex.run(prog.rebind(q))
    assert res.retries == 0
    assert rows_key(res.rows) == rows_key(reference_join(q))


def test_retry_exhaustion_through_run_many_and_service():
    queries = [perm_query(s) for s in (5, 6)]
    progs = [compile_plan(q, compute_stats(q, lam=4), 8).rebind(q) for q in queries]
    ex = DataplaneExecutor(max_retries=1)
    with pytest.raises(RetryExhaustedError):
        ex.run_many(progs, config=RunConfig(
            fault_plan=FaultPlan.persistent_overflow(channels=("slot",))
        ))
    # service wraps it per query, cause preserved
    session = JoinSession(
        p=8, backend="dataplane",
        executor=DataplaneExecutor(max_retries=1),
    )
    session.fault_plan = FaultPlan.persistent_overflow(channels=("slot",))
    with pytest.raises(QueryFailedError) as ei:
        session.submit(queries[0], lam=4)
    assert isinstance(ei.value.cause, RetryExhaustedError)
    assert ei.value.attempt_log, "retry entries travel on the wrapper"
    # drop the plan's fault source and verify steady-state recovery
    session.fault_plan = None
    r1 = session.submit(queries[0], lam=4)
    r2 = session.submit(queries[0], lam=4)
    assert r1.retries == 0 and r2.retries == 0
    assert r2.jit_cache_misses == 0
    assert rows_key(r2.rows) == rows_key(reference_join(queries[0]))


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------


def test_expired_deadline_fails_before_any_dispatch():
    session = JoinSession(p=8, backend="dataplane")
    q = perm_query(7)
    with pytest.raises(DeadlineExceededError) as ei:
        session.submit(q, lam=4, deadline_s=-0.001)
    assert ei.value.query is q
    assert session.stats.deadline_exceeded == 1
    assert session.stats.failed == 1
    # no budget ⇒ normal service
    r = session.submit(q, lam=4)
    assert rows_key(r.rows) == rows_key(reference_join(q))


def test_deadline_trips_between_dispatches_mid_run():
    # injected dispatch latency (the straggler site) guarantees the budget
    # expires mid-run even when the process-wide executable cache is already
    # warm from earlier suites — the overrun must not depend on compile time
    session = JoinSession(
        p=8, backend="dataplane",
        fault_plan=FaultPlan(
            [FaultRule(site="latency", rate=1.0, delay_s=0.05)], seed=5
        ),
    )
    q = skew_triangle()
    with pytest.raises(DeadlineExceededError) as ei:
        session.submit(q, lam=4, deadline_s=0.02)
    err = ei.value
    assert err.query is q
    assert isinstance(err.__cause__, DeadlineExceededError)
    assert err.op_round is not None, "raised between dispatches, op round known"
    # the same query without a deadline completes fine afterwards
    r = session.submit(q, lam=4)
    assert rows_key(r.rows) == rows_key(reference_join(q))


def test_async_deadline_counts_queue_time():
    session = JoinSession(p=8, backend="dataplane", async_autostart=False)
    q = perm_query(8)
    fut = session.submit_async(q, lam=4, deadline_s=0.02)
    time.sleep(0.1)         # budget burns away while queued, drainer asleep
    session.close()         # inline drain resolves the (now expired) request
    with pytest.raises(DeadlineExceededError):
        fut.result(timeout=0)


# ---------------------------------------------------------------------------
# Coalesced-group failure isolation (tentpole item 3)
# ---------------------------------------------------------------------------


def test_poisoned_query_fails_alone_batchmates_byte_identical():
    queries = [perm_query(s) for s in (10, 11, 12, 13)]
    serial = serial_reference(queries)
    # injection 1 kills the fused 4-query dispatch; injection 2 kills the
    # first member's serial fallback run; the rule then drains, so members
    # 2..4 complete — deterministic single-victim schedule
    session = JoinSession(
        p=8, backend="dataplane",
        fault_plan=FaultPlan([FaultRule(site="dispatch", rate=1.0, count=2)]),
        async_autostart=False,
    )
    futs = [session.submit_async(q, lam=4) for q in queries]
    session.close()     # one inline drain batch → one coalesced group
    outs = outcomes(futs, timeout=0)
    assert isinstance(outs[0], QueryFailedError)
    assert outs[0].query is queries[0]
    for out, ref in zip(outs[1:], serial[1:]):
        assert np.array_equal(out.rows, ref.rows), "survivor byte-identity"
        assert out.coalesced is False, "fallback runs are serial passes"
    assert session.stats.degraded_fallbacks == 1
    assert session.stats.failed == 1


# ---------------------------------------------------------------------------
# Drainer supervision: crash, degraded state, restart (tentpole item 4)
# ---------------------------------------------------------------------------


def _wait_degraded(session, timeout=30.0):
    t0 = time.monotonic()
    while not session.degraded:
        if time.monotonic() - t0 > timeout:
            raise AssertionError("session never degraded")
        time.sleep(0.02)


def test_drainer_crash_resolves_every_future_and_degrades(tmp_path):
    queries = [perm_query(s) for s in (20, 21, 22)]
    session = JoinSession(
        p=8, backend="dataplane",
        fault_plan=FaultPlan([FaultRule(site="drainer", rate=1.0, count=1)]),
        async_autostart=False,
        heartbeat_path=tmp_path / "hb",
    )
    futs = [session.submit_async(q, lam=4) for q in queries]
    session.start()     # first drain batch crashes between dequeue and demux
    _wait_degraded(session)
    outs = outcomes(futs, timeout=30)
    assert all(isinstance(o, DegradedSessionError) for o in outs), \
        "zero hung futures: in-flight batch AND queued leftovers resolve"
    assert session.stats.drainer_crashes == 1
    assert session.stats.failed == len(queries)
    assert (tmp_path / "hb").exists(), "heartbeat beaten before the crash"
    # degraded session fails fast on both entry points
    with pytest.raises(DegradedSessionError):
        session.submit_async(queries[0], lam=4)
    with pytest.raises(DegradedSessionError):
        session.start()
    # supervised restart: plan drained, the session serves again
    session.restart()
    assert not session.degraded
    r = session.submit_async(queries[0], lam=4).result(timeout=120)
    assert rows_key(r.rows) == rows_key(reference_join(queries[0]))
    session.close()


def test_close_sweeps_queue_of_degraded_session():
    # the shutdown-race satellite: requests admitted around a drainer death
    # must still resolve exactly once, through close()
    session = JoinSession(
        p=8, backend="dataplane",
        fault_plan=FaultPlan([FaultRule(site="drainer", rate=1.0, count=1)]),
        async_autostart=False,
    )
    f1 = session.submit_async(perm_query(23), lam=4)
    session.start()
    _wait_degraded(session)
    # bypass the degraded fast-fail to model the race where a request is
    # admitted just as the drainer dies: it must not hang forever
    from repro.mpc.service import _Request
    from concurrent.futures import Future
    straggler = _Request(query=perm_query(24), lam=4, future=Future(),
                         t_enqueue=time.perf_counter())
    session._queue.put(straggler)
    session.close()
    outs = outcomes([f1, straggler.future], timeout=5)
    assert all(isinstance(o, DegradedSessionError) for o in outs)


def test_resolve_is_exactly_once():
    from repro.mpc.service import JoinSession as S, _Request
    from concurrent.futures import Future
    req = _Request(query=None, future=Future())
    assert S._resolve(req, RuntimeError("first"))
    assert not S._resolve(req, RuntimeError("second")), "done futures stay won"
    assert not S._resolve(_Request(query=None), RuntimeError("x")), \
        "inline requests have no future to resolve"


# ---------------------------------------------------------------------------
# Seeded chaos sweep (acceptance criterion: 5% dispatch failures)
# ---------------------------------------------------------------------------


def test_chaos_sweep_mixed_workload_recovers_to_steady_state():
    mixed = [perm_query(30), perm_query(31), skew_triangle(), perm_query(32)]
    serial = serial_reference(mixed)
    ref = {id(q): r for q, r in zip(mixed, serial)}

    fault_plan = FaultPlan(
        [FaultRule(site="dispatch", rate=0.05, count=4)], seed=1234
    )
    session = JoinSession(p=8, backend="dataplane", fault_plan=fault_plan)
    try:
        waves, failed = 0, 0
        while not fault_plan.drained() and waves < 12:
            waves += 1
            futs = [(q, session.submit_async(q, lam=4)) for q in mixed]
            for q, f in futs:
                try:
                    r = f.result(timeout=180)   # bounded: a hang is a failure
                except BaseException as e:
                    failed += 1
                    assert isinstance(e, JoinServiceError), \
                        f"untyped failure {type(e).__name__}"
                    assert getattr(e, "query", None) is q or \
                        describe_query(q) in str(e), "failure must name its query"
                else:
                    assert np.array_equal(r.rows, ref[id(q)].rows), \
                        "survivor byte-identity under injected faults"
        assert fault_plan.drained(), "the seeded schedule must actually inject"
        assert fault_plan.injected["dispatch"] == 4

        # counters reconcile with the injection schedule: every query failure
        # consumed at least one injected fault, and fused-group failures that
        # fell back serially are separately visible
        assert session.stats.failed == failed
        assert failed <= fault_plan.total_injected
        assert session.stats.degraded_fallbacks <= fault_plan.injected["dispatch"]
        assert session.stats.deadline_exceeded == 0

        # recovery: with the plan drained, one settling wave re-derives any
        # quarantined caches, then the steady state must be clean
        session.submit_coalesced(mixed, lam=4)
        jit0, ret0 = session.stats.jit_misses, session.stats.retries
        out = session.submit_coalesced(mixed, lam=4)
        for r, q in zip(out, mixed):
            assert np.array_equal(r.rows, ref[id(q)].rows)
        assert session.stats.jit_misses == jit0, "warm steady state: no recompiles"
        assert session.stats.retries == ret0, "warm steady state: no retries"
    finally:
        session.close()


def test_latency_faults_are_invisible_to_results():
    # stragglers (injected dispatch latency) slow things down but change
    # nothing: results stay byte-identical, nothing fails
    q = perm_query(33)
    serial = serial_reference([q])[0]
    session = JoinSession(
        p=8, backend="dataplane",
        fault_plan=FaultPlan(
            [FaultRule(site="latency", rate=0.5, delay_s=0.005)], seed=5
        ),
    )
    r = session.submit(q, lam=4)
    assert np.array_equal(r.rows, serial.rows)
    assert session.stats.failed == 0
    assert session.fault_plan.injected["latency"] > 0
