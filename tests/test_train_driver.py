"""Launcher-level end-to-end: the train driver's auto-resume restart path and the
serve driver's prefill+decode loop (tiny configs, single device)."""

import jax
import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_driver_and_auto_resume(tmp_path):
    ckpt = str(tmp_path / "run")
    args = [
        "--arch", "mamba2-780m", "--reduced",
        "--steps", "6", "--global-batch", "2", "--seq", "32",
        "--ckpt-dir", ckpt, "--ckpt-every", "2", "--log-every", "10",
    ]
    out1 = train_mod.main(args)
    assert len(out1["history"]) == 6
    assert np.isfinite(out1["history"]).all()

    # simulate a restart with a larger step budget: --resume must pick up the latest
    # checkpoint (step 5) and run only the remaining steps
    args2 = [a if a != "6" else "8" for a in args]
    out2 = train_mod.main(args2 + ["--resume"])
    assert len(out2["history"]) == 2      # steps 6 and 7 only
    assert np.isfinite(out2["history"]).all()


def test_serve_driver(tmp_path):
    out = serve_mod.main(
        ["--arch", "mamba2-780m", "--reduced", "--batch", "2",
         "--prompt-len", "16", "--gen", "4"]
    )
    gen = out["gen"]
    assert gen.shape == (2, 4)
    assert np.isfinite(out["t_decode"])
