"""Lemma 3.1 (cartesian grid), Lemma 3.3 (HyperCube), statistics protocol loads."""

import math

import numpy as np
import pytest

from repro.core.hypergraph import Hypergraph
from repro.core.planner import grid_dims
from repro.core.query import JoinQuery, Relation, random_query, reference_join
from repro.mpc.cartesian import CartesianGrid, cartesian_product_mpc
from repro.mpc.hypercube import skewfree_hypercube_join, uniform_lp_shares


def test_grid_dims_basic():
    dims, t_prime, load = grid_dims([100, 100, 100], 64)
    assert t_prime == 3
    assert all(1 <= d for d in dims)
    assert math.prod(dims) <= 64


def test_grid_dims_small_tail():
    # tiny trailing list should be broadcast (t' < t)
    dims, t_prime, load = grid_dims([10_000, 10_000, 2], 16)
    assert t_prime == 2


def test_grid_dims_budget_invariant_adversarial():
    """Regression for the rounding guard: Π dims ≤ p_grid must hold AFTER the
    guard for adversarial size vectors (the old decrement-the-max + clamp
    could drive a dimension to 0 and then reinstate Π dims > p_grid)."""
    cases = [
        ([7, 7, 7], 1),
        ([5, 4], 2),
        ([3, 3, 3, 3, 3], 2),
        ([10**9, 10**9], 4),
        ([2, 1, 1, 1], 1),
        ([1], 1),
        ([6, 6, 6], 5),
        ([10**15, 10**15], 10**6),
        ([13, 11, 7, 5, 3], 3),
    ]
    for sizes, p_grid in cases:
        dims, t_prime, load = grid_dims(sizes, p_grid)
        assert all(d >= 1 for d in dims), (sizes, p_grid, dims)
        assert math.prod(dims) <= p_grid, (sizes, p_grid, dims)
        assert len(dims) == t_prime


def test_grid_dims_budget_invariant_fuzz():
    rng = np.random.default_rng(0)
    for _ in range(3000):
        t = int(rng.integers(1, 6))
        hi = int(rng.choice([8, 100, 10**4, 10**9]))
        sizes = sorted(
            (int(x) for x in rng.integers(1, hi, size=t)), reverse=True
        )
        p_grid = int(rng.integers(1, 200))
        dims, t_prime, load = grid_dims(sizes, p_grid)
        assert all(d >= 1 for d in dims)
        assert math.prod(dims) <= p_grid, (sizes, p_grid, dims)


def test_grid_dims_rejects_degenerate_inputs():
    """Empty lists and non-positive sizes raise even under ``python -O``
    (ValueError, not a bare assert): an empty CP list means the caller must
    have skipped the stage (geo.skip)."""
    with pytest.raises(ValueError):
        grid_dims([], 4)
    with pytest.raises(ValueError):
        grid_dims([0], 4)
    with pytest.raises(ValueError):
        grid_dims([5, 0], 4)
    with pytest.raises(ValueError):
        grid_dims([3], 0)


def test_cartesian_product_exact():
    rels = [
        Relation.make(("A",), np.arange(37).reshape(-1, 1)),
        Relation.make(("B",), (np.arange(23) + 100).reshape(-1, 1)),
        Relation.make(("C",), (np.arange(11) + 500).reshape(-1, 1)),
    ]
    sim, count, rows = cartesian_product_mpc(rels, p=16, materialize=True)
    assert count == 37 * 23 * 11
    assert rows.shape[0] == count              # exactly-once assembly
    assert len(set(map(tuple, rows.tolist()))) == count


def test_cartesian_load_within_bound():
    """Measured load ≤ c × the paper's bound (3.2)."""
    sizes = [512, 256, 64]
    rels = [
        Relation.make((f"X{i}",), (np.arange(s) + 1000 * i).reshape(-1, 1))
        for i, s in enumerate(sizes)
    ]
    p = 64
    sim, count, _ = cartesian_product_mpc(rels, p=p, materialize=False)
    assert count == math.prod(sizes)
    grid = CartesianGrid(sizes, p)
    assert sim.max_round_load <= 8 * max(grid.theoretical_load(), 1.0)


def test_hypercube_uniform_join():
    rng = np.random.default_rng(0)
    q = random_query(rng, "clique", 3, tuples_per_rel=200, dom_size=50)
    g = q.hypergraph
    shares = uniform_lp_shares(g, 27)
    sim, count, result = skewfree_hypercube_join(q, shares, p=27)
    oracle = reference_join(q)
    assert count == len(oracle)
    assert result.rows_as_set() == oracle.rows_as_set()


def test_hypercube_shares_triangle():
    g = Hypergraph.from_edges([("A", "B"), ("B", "C"), ("A", "C")])
    shares = uniform_lp_shares(g, 64)
    # classic: p^{1/3} per attribute
    assert sorted(shares.values()) == [4, 4, 4]


def test_hypercube_skew_free_load():
    """On skew-free data the one-round HyperCube meets Õ(m / p^{1/ρ}) (ρ = 3/2 for the
    triangle → p^{2/3}); on hub-skewed data of the same size its load-per-bound ratio
    degrades — the paper's motivation for the multi-round algorithm."""
    rng = np.random.default_rng(1)
    p = 27
    q = random_query(rng, "clique", 3, tuples_per_rel=2000, dom_size=2000, skew=0.0)
    g = q.hypergraph
    shares = uniform_lp_shares(g, p)
    sim, _, _ = skewfree_hypercube_join(q, shares, p=p, materialize=False)
    bound = q.m / p ** (2.0 / 3.0)
    ratio_uniform = sim.max_round_load / bound
    assert ratio_uniform <= 12

    # hub skew: value 0 is heavy on attribute X0 in both incident relations; every
    # tuple is distinct so set-dedup cannot shrink the instance.
    n = 2000
    ab = np.stack([np.zeros(n, np.int64), np.arange(n)], axis=1)
    ac = np.stack([np.zeros(n, np.int64), np.arange(n)], axis=1)
    bc = np.stack([rng.integers(0, n, n), rng.integers(0, n, n)], axis=1)
    q_skew = JoinQuery.make(
        [
            Relation.make(("X0", "X1"), ab),
            Relation.make(("X1", "X2"), bc),
            Relation.make(("X0", "X2"), ac),
        ]
    )
    sim2, _, _ = skewfree_hypercube_join(q_skew, shares, p=p, materialize=False)
    bound2 = q_skew.m / p ** (2.0 / 3.0)
    ratio_skew = sim2.max_round_load / bound2
    # load concentrates on the cells matching h(0): strictly worse per-bound ratio
    assert ratio_skew > 1.5 * ratio_uniform
