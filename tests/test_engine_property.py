"""Property-based validation (hypothesis): the MPC engine is exact and exactly-once on
random queries/data; the isolated cartesian product theorem holds empirically; the
heavy/light taxonomy (4.2) is a *disjoint* partition of the join result."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional hypothesis extra")
from hypothesis import given, settings, strategies as st

from repro.core.icp import all_icp_checks
from repro.core.query import JoinQuery, Relation, pattern_edges, reference_join
from repro.core.taxonomy import compute_stats, configurations, plan_for_h
from repro.core.semijoin import join_reduced, semijoin_reduce
from repro.mpc.engine import mpc_join

KINDS = ["line", "cycle", "clique", "star"]


def _build_query(rng: np.random.Generator, kind: str, n_attrs: int, n_tuples: int, dom: int, skew: float):
    edges = pattern_edges(kind, n_attrs)
    rels = []
    for e in edges:
        cols = []
        for _ in range(2):
            if skew > 0:
                ranks = np.arange(1, dom + 1, dtype=np.float64) ** (-skew)
                ranks /= ranks.sum()
                cols.append(rng.choice(dom, size=n_tuples, p=ranks))
            else:
                cols.append(rng.integers(0, dom, size=n_tuples))
        rels.append(Relation.make(e, np.stack(cols, axis=1)))
    return JoinQuery.make(rels)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    kind=st.sampled_from(KINDS),
    n_attrs=st.integers(3, 4),
    n_tuples=st.integers(20, 120),
    dom=st.integers(3, 25),
    skew=st.sampled_from([0.0, 1.0, 2.5]),
    p=st.sampled_from([4, 8]),
    lam=st.sampled_from([2, 4, 8]),
)
def test_engine_matches_oracle(seed, kind, n_attrs, n_tuples, dom, skew, p, lam):
    rng = np.random.default_rng(seed)
    q = _build_query(rng, kind, n_attrs, n_tuples, dom, skew)
    oracle = reference_join(q)
    res = mpc_join(q, p=p, lam=lam, materialize=True, seed=seed % 7)
    assert res.count == len(oracle)
    assert res.rows.shape[0] == res.count          # exactly-once, no dedup needed
    assert set(map(tuple, res.rows.tolist())) == oracle.rows_as_set()


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    kind=st.sampled_from(KINDS),
    n_attrs=st.integers(3, 4),
    dom=st.integers(3, 12),
    lam=st.sampled_from([2, 4]),
)
def test_taxonomy_is_disjoint_partition(seed, kind, n_attrs, dom, lam):
    """(4.2): Join(Q) = ⊎_H ⊎_η Join(Q'(η)) × {η} — disjoint because each result tuple
    determines its own H (the set of attributes where it takes heavy values)."""
    rng = np.random.default_rng(seed)
    q = _build_query(rng, kind, n_attrs, 60, dom, skew=2.0)
    stats = compute_stats(q, lam)
    oracle = reference_join(q)
    attrs = q.attset

    total = 0
    import itertools

    for r in range(len(attrs) + 1):
        for h in itertools.combinations(attrs, r):
            plan = plan_for_h(q, h)
            for eta in configurations(stats, plan.h_set):
                if len(h) == len(attrs):
                    ok = all(
                        stats.pair.get(
                            (rel.edge, eta.value(rel.scheme[0]), eta.value(rel.scheme[1])), 0
                        )
                        > 0
                        for rel in q.relations
                    )
                    total += 1 if ok else 0
                    continue
                reduced = semijoin_reduce(q, stats, plan, eta)
                if reduced is None:
                    continue
                total += join_reduced(reduced, plan).shape[0]
    assert total == len(oracle)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    kind=st.sampled_from(["star", "cycle", "clique"]),
    n_attrs=st.integers(3, 4),
    lam=st.sampled_from([2, 3, 4]),
)
def test_isolated_cartesian_product_theorem(seed, kind, n_attrs, lam):
    """Theorem 5.4 (and the weaker Lemma 5.5): Σ_η |Join(Q''_J(η))| ≤ bound, for every
    H and every non-empty J ⊆ I."""
    rng = np.random.default_rng(seed)
    q = _build_query(rng, kind, n_attrs, 50, dom=6, skew=2.0)
    stats = compute_stats(q, lam)
    for chk in all_icp_checks(q, stats):
        assert chk.lhs <= chk.rhs_thm54 + 1e-9, (chk.h_set, chk.j_set, chk.lhs, chk.rhs_thm54)
        assert chk.lhs <= chk.rhs_lem55 + 1e-9
