"""Docs integrity: intra-repo markdown links must resolve (tools/check_docs.py).

The CI docs job runs the same checker plus headless example smoke runs; this
tier-1 wrapper makes a moved/renamed doc page fail locally too.
"""

import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def test_markdown_links_resolve():
    proc = subprocess.run(
        [sys.executable, str(REPO_ROOT / "tools" / "check_docs.py"), str(REPO_ROOT)],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_design_index_covers_every_design_page():
    """Every page under docs/design/ must be reachable from the DESIGN.md
    index (a new section added without indexing it is invisible)."""
    index = (REPO_ROOT / "docs" / "DESIGN.md").read_text()
    for page in sorted((REPO_ROOT / "docs" / "design").glob("*.md")):
        assert f"design/{page.name}" in index, f"{page.name} missing from DESIGN.md"
