"""Subgraph enumeration subsystem: DSL, orientation, compile, and end-to-end
counts against the brute-force oracle on both executors.

The acceptance bar of the pattern → JoinQuery reduction: for seeded ER and
Zipf graphs at several sizes, the engine pipeline (compile, join, injectivity
filter, automorphic dedup) must return the exact occurrence set of the
independent backtracking oracle — each occurrence exactly once — on the
simulator and on the dataplane, batched and unbatched."""

import numpy as np
import pytest

from repro.graph import (
    Graph,
    Pattern,
    automorphisms,
    brute_force_occurrences,
    canonical_rows,
    clique,
    compile_pattern,
    cycle,
    enumerate_subgraphs,
    erdos_renyi,
    from_edge_list,
    path,
    plan_orientation,
    star,
    triangle,
    vertex_order_rank,
    zipf_graph,
)
from repro.mpc.executors import SimulatorExecutor


# ---------------------------------------------------------------------------
# Pattern DSL + automorphisms
# ---------------------------------------------------------------------------


def test_builtin_patterns():
    assert triangle().edges == ((0, 1), (0, 2), (1, 2))
    assert cycle(4).edges == ((0, 1), (0, 3), (1, 2), (2, 3))
    assert len(clique(5).edges) == 10
    assert star(3).edges == ((0, 1), (0, 2), (0, 3))
    assert path(4).edges == ((0, 1), (1, 2), (2, 3))
    # arbitrary edge lists compact vertex ids ("paw" = triangle + pendant)
    paw = from_edge_list([(5, 7), (7, 9), (5, 9), (5, 2)], name="paw")
    assert paw.n_vertices == 4 and len(paw.edges) == 4


def test_pattern_validation():
    with pytest.raises(ValueError):
        Pattern.make("loop", 2, [(0, 0)])
    with pytest.raises(ValueError):
        Pattern.make("dup", 2, [(0, 1), (1, 0)])
    with pytest.raises(ValueError):
        Pattern.make("island", 3, [(0, 1)])        # vertex 2 untouched
    with pytest.raises(ValueError):
        Pattern.make("big", 9, [(i, i + 1) for i in range(8)])


def test_automorphism_counts():
    assert len(automorphisms(triangle())) == 6
    assert len(automorphisms(cycle(4))) == 8       # dihedral
    assert len(automorphisms(clique(4))) == 24
    assert len(automorphisms(path(4))) == 2        # reflection
    assert len(automorphisms(star(3))) == 6        # S_3 on the leaves


# ---------------------------------------------------------------------------
# Orientation plans (soundness is covered end-to-end by the count tests)
# ---------------------------------------------------------------------------


def test_orientation_clique_total_and_complete():
    for k in (3, 4, 5):
        plan = plan_orientation(clique(k))
        assert plan.constraints == clique(k).edges
        assert plan.complete
        assert not plan.needs_injectivity       # total order separates all


def test_orientation_cycle4_partial():
    plan = plan_orientation(cycle(4))
    # the local-minimum orientation is sound but cannot be complete, and
    # opposite cycle vertices can collapse ⇒ injectivity filter required
    assert plan.constraints, "cycle must orient at least one edge"
    assert not plan.complete
    assert plan.needs_injectivity


def test_orientation_path4_middle_edge_complete():
    plan = plan_orientation(path(4))
    # orienting the middle edge kills the reflection: exactly one embedding
    # survives per occurrence (completeness), but ends may still collapse
    assert plan.constraints == ((1, 2),)
    assert plan.complete
    assert plan.needs_injectivity


def test_orientation_star_unorientable():
    plan = plan_orientation(star(3))
    # every hub-leaf constraint is unsound (the hub can be the global max or
    # min); the leaf symmetry survives to the dedup stage
    assert plan.constraints == ()
    assert not plan.complete


def test_canonical_rows_lexmin():
    autos = automorphisms(triangle())
    rows = np.array([[3, 1, 2], [1, 2, 3], [9, 9, 9]], dtype=np.int64)
    out = canonical_rows(rows, autos)
    assert out.tolist() == [[1, 2, 3], [1, 2, 3], [9, 9, 9]]


# ---------------------------------------------------------------------------
# Graphs + compile (shared physical tables)
# ---------------------------------------------------------------------------


def test_graph_normalization():
    g = Graph.from_edges([[1, 0], [0, 1], [2, 2], [3, 1]])
    assert g.edges.tolist() == [[0, 1], [1, 3]]    # dedup, self-loop dropped
    assert g.degrees().tolist() == [1, 2, 0, 1]


def test_vertex_order_rank_is_total():
    rng = np.random.default_rng(0)
    g = zipf_graph(rng, 50, 120, skew=1.0)
    for mode in ("id", "degree"):
        rank = vertex_order_rank(g, mode)
        assert sorted(rank.tolist()) == list(range(g.n_vertices))


def test_compile_shares_one_physical_table():
    rng = np.random.default_rng(1)
    g = erdos_renyi(rng, 40, 100)
    c = compile_pattern(g, clique(4))
    # fully oriented: all 6 copies bind the SAME oriented table object
    assert len({id(r.data) for r in c.query.relations}) == 1
    assert len({r.table for r in c.query.relations}) == 1
    assert all(len(r) == g.n_edges for r in c.query.relations)
    assert c.query.m == 6 * g.n_edges              # m counts every copy

    # a partially oriented pattern uses at most two tables
    c2 = compile_pattern(g, cycle(4))
    assert len({id(r.data) for r in c2.query.relations}) <= 2


def test_shared_input_scatter_places_once():
    rng = np.random.default_rng(2)
    g = erdos_renyi(rng, 40, 100)
    c = compile_pattern(g, triangle())
    ex = SimulatorExecutor(p=8)
    ex.place_inputs(c.query)
    e0, e1, e2 = [r.edge for r in c.query.relations]
    for mid in range(8):
        parts = [ex.sim.stores[mid].get(("in", e)) for e in (e0, e1, e2)]
        present = [ps for ps in parts if ps]
        assert len(present) in (0, 3)              # same placement everywhere
        for ps in present[1:]:                      # aliased blocks, no copies
            assert all(a is b for a, b in zip(present[0], ps))


# ---------------------------------------------------------------------------
# Counts vs the brute-force oracle (the satellite acceptance)
# ---------------------------------------------------------------------------

SIZES = [(40, 120), (70, 260), (110, 480)]          # ≥3 sizes per family
PATTERNS = [triangle, lambda: cycle(4), lambda: clique(4)]


def _graph(kind: str, n: int, m: int, seed: int) -> Graph:
    rng = np.random.default_rng(seed)
    if kind == "er":
        return erdos_renyi(rng, n, m)
    return zipf_graph(rng, n, m, skew=1.2)


@pytest.mark.parametrize("kind", ["er", "zipf"])
@pytest.mark.parametrize("size", range(len(SIZES)))
@pytest.mark.parametrize("mk", range(len(PATTERNS)))
def test_simulator_counts_match_brute_force(kind, size, mk):
    n, m = SIZES[size]
    g = _graph(kind, n, m, seed=100 + size)
    pat = PATTERNS[mk]()
    brute = brute_force_occurrences(g, pat)
    res = enumerate_subgraphs(g, pat, p=8, backend="simulator", lam=8)
    assert np.array_equal(res.occurrences, brute), (
        kind, n, m, pat.name, res.count, len(brute)
    )
    # dedup verified: canonical rows are unique (exactly-once enumeration)
    assert len(np.unique(res.occurrences, axis=0)) == res.count


@pytest.mark.parametrize("kind", ["er", "zipf"])
@pytest.mark.parametrize("mk", range(len(PATTERNS)))
@pytest.mark.parametrize("batch", [True, False])
def test_dataplane_counts_match_brute_force(kind, mk, batch):
    from repro.mpc.executors import DataplaneExecutor

    n, m = SIZES[1]
    g = _graph(kind, n, m, seed=101)
    pat = PATTERNS[mk]()
    brute = brute_force_occurrences(g, pat)
    res = enumerate_subgraphs(
        g, pat, p=8, backend="dataplane", lam=8,
        executor=DataplaneExecutor(batch_stages=batch),
    )
    assert np.array_equal(res.occurrences, brute), (
        kind, pat.name, batch, res.count, len(brute)
    )


def test_simulator_and_dataplane_agree_on_load_bearing_case():
    """One heavier skewed case where the taxonomy fans out (heavy hubs).
    Orientation halves each hub's per-column count, so the skew/λ must be
    strong enough that hubs stay heavy in the oriented table."""
    g = zipf_graph(np.random.default_rng(11), 150, 700, skew=2.0)
    pat = triangle()
    brute = brute_force_occurrences(g, pat)
    sim = enumerate_subgraphs(g, pat, p=8, backend="simulator", lam=24)
    dp = enumerate_subgraphs(g, pat, p=8, backend="dataplane", lam=24)
    assert np.array_equal(sim.occurrences, brute)
    assert np.array_equal(dp.occurrences, brute)
    # the hub must actually be heavy so the run exercises cross/CP stages
    from repro.core.taxonomy import compute_stats

    stats = compute_stats(sim.compiled.query, 24)
    assert stats.n_heavy() > 0, "skewed graph must produce heavy values"


def test_empty_and_tiny_graphs():
    empty = Graph.from_edges(np.zeros((0, 2), np.int64), n_vertices=5)
    res = enumerate_subgraphs(empty, triangle(), p=4, backend="simulator")
    assert res.count == 0 and res.occurrences.shape == (0, 3)
    single = Graph.from_edges([[0, 1]])
    res = enumerate_subgraphs(single, triangle(), p=4, backend="simulator")
    assert res.count == 0
    tri = Graph.from_edges([[0, 1], [1, 2], [0, 2]])
    res = enumerate_subgraphs(tri, triangle(), p=4, backend="simulator")
    assert res.count == 1 and res.occurrences.tolist() == [[0, 1, 2]]


def test_id_and_degree_orientation_agree():
    g = zipf_graph(np.random.default_rng(13), 60, 240, skew=1.0)
    a = enumerate_subgraphs(g, cycle(4), p=8, backend="simulator",
                            orientation="id", lam=8)
    b = enumerate_subgraphs(g, cycle(4), p=8, backend="simulator",
                            orientation="degree", lam=8)
    assert np.array_equal(a.occurrences, b.occurrences)


@pytest.mark.slow
def test_acceptance_zipf_12k_triangle_and_clique4_both_executors():
    """The acceptance case: a ≥10k-edge Zipf graph; triangle + 4-clique
    occurrence sets must be brute-force-identical on both executors."""
    from repro.mpc.executors import DataplaneExecutor

    g = zipf_graph(np.random.default_rng(42), 5000, 12000, skew=0.9)
    assert g.n_edges >= 10_000
    for pat, lam in [(triangle(), 8), (clique(4), 2)]:
        brute = brute_force_occurrences(g, pat)
        sim = enumerate_subgraphs(g, pat, p=8, backend="simulator", lam=lam)
        dp = enumerate_subgraphs(
            g, pat, p=8, backend="dataplane", lam=lam,
            executor=DataplaneExecutor(),
        )
        assert np.array_equal(sim.occurrences, brute), pat.name
        assert np.array_equal(dp.occurrences, brute), pat.name
        assert len(np.unique(brute, axis=0)) == len(brute)
