"""Theorem 6.2 engine vs the oracle: exactness, exactly-once, load sanity."""

import numpy as np
import pytest

from repro.core.query import JoinQuery, Relation, random_query, reference_join
from repro.core.taxonomy import compute_stats
from repro.mpc.engine import mpc_join
from repro.mpc.statistics import distributed_stats
from repro.mpc.simulator import MPCSimulator, scatter_input


def _check(query, p, seed=0, lam=None):
    oracle = reference_join(query)
    res = mpc_join(query, p=p, seed=seed, lam=lam, materialize=True)
    assert res.count == len(oracle), (res.count, len(oracle), res.per_h_counts)
    got = set(map(tuple, res.rows.tolist()))
    want = oracle.rows_as_set()
    assert got == want
    # exactly-once: materialized rows (pre-dedup) match the count
    assert res.rows.shape[0] == res.count
    return res


def test_two_relation_join_uniform():
    rng = np.random.default_rng(0)
    q = random_query(rng, "line", 3, tuples_per_rel=200, dom_size=40)
    _check(q, p=8)


def test_triangle_uniform():
    rng = np.random.default_rng(1)
    q = random_query(rng, "clique", 3, tuples_per_rel=150, dom_size=25)
    _check(q, p=8)


def test_triangle_skewed():
    """Zipf-skewed columns produce real heavy values — exercises every step."""
    rng = np.random.default_rng(2)
    q = random_query(rng, "clique", 3, tuples_per_rel=300, dom_size=30, skew=2.0)
    res = _check(q, p=8, lam=16)  # λ=16 → threshold m/λ ≈ 57: the Zipf head is heavy
    # heavy taxonomy must actually trigger: some H != empty contributes
    assert any(len(h) > 0 and c > 0 for h, c in res.per_h_counts.items())


def test_cycle4_skewed():
    rng = np.random.default_rng(3)
    q = random_query(rng, "cycle", 4, tuples_per_rel=200, dom_size=20, skew=1.0)
    _check(q, p=16, lam=3)


def test_star_skewed():
    """Star joins: hub attribute heavy — isolated attributes appear after removing it
    (the isolated-CP machinery is exercised)."""
    rng = np.random.default_rng(4)
    q = random_query(rng, "star", 4, tuples_per_rel=150, dom_size=12, skew=1.5)
    _check(q, p=8, lam=3)


def test_line5():
    rng = np.random.default_rng(5)
    q = random_query(rng, "line", 5, tuples_per_rel=120, dom_size=15, skew=0.8)
    _check(q, p=8, lam=3)


def test_single_heavy_value_cross_product():
    """Adversarial: one super-heavy hub value; join is near a cartesian product of the
    leaf lists — classic case where one-round algorithms blow up."""
    n = 120
    hub = np.zeros(n, dtype=np.int64)  # every tuple shares hub value 0
    a = np.arange(n, dtype=np.int64)
    b = np.arange(n, dtype=np.int64) + 1000
    q = JoinQuery.make(
        [
            Relation.make(("H", "A"), np.stack([hub, a], axis=1)),
            Relation.make(("H", "B"), np.stack([hub, b], axis=1)),
        ]
    )
    res = _check(q, p=8, lam=4)
    assert res.count == n * n


def test_empty_result():
    q = JoinQuery.make(
        [
            Relation.make(("A", "B"), np.array([[1, 2], [3, 4]])),
            Relation.make(("B", "C"), np.array([[9, 9]])),
        ]
    )
    res = mpc_join(q, p=4, materialize=True)
    assert res.count == 0
    assert res.rows.shape[0] == 0


def test_distributed_stats_match_oracle():
    """The 3-round histogram protocol computes exactly the centralized statistics."""
    rng = np.random.default_rng(7)
    q = random_query(rng, "clique", 3, tuples_per_rel=250, dom_size=20, skew=1.3)
    lam = 5
    sim = MPCSimulator(8, seed=0)
    for rel in q.relations:
        scatter_input(sim, ("in", rel.edge), rel.data, seed=17)
    got = distributed_stats(sim, q, lam)
    want = compute_stats(q, lam)
    assert got.m == want.m
    assert set(got.heavy) == set(want.heavy)
    for a in want.heavy:
        assert np.array_equal(got.heavy[a], want.heavy[a])
    assert got.cond == want.cond
    assert got.pair == want.pair
    assert got.light_cnt == want.light_cnt


def test_load_reported():
    rng = np.random.default_rng(8)
    q = random_query(rng, "clique", 3, tuples_per_rel=400, dom_size=25, skew=1.0)
    res = mpc_join(q, p=8, materialize=False)
    assert res.load > 0
    names = [n for n, _ in res.sim.load_report()]
    assert "step1" in names and "step3-route" in names
    # count-only mode must agree with the oracle too
    assert res.count == len(reference_join(q))
