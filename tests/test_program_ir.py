"""Round-program IR: compilation structure, executor equivalence, hash exactness.

The golden numbers in `GOLDEN` were recorded by running the pre-refactor
monolithic engine (commit e4d9f4e) on these exact seeded inputs; the
SimulatorExecutor interpreting the compiled program must reproduce them
byte-for-byte — join count, per-H counts, and parallel total load, fused and
unfused."""

import numpy as np
import pytest

from repro.core.query import (
    JoinQuery,
    Relation,
    hub_triangle_query,
    random_query,
    reference_join,
)
from repro.core.taxonomy import compute_stats
from repro.mpc.engine import mpc_join
from repro.mpc.program import compile_plan, fuse_semijoin_pass
from repro.mpc.executors import SimulatorExecutor
from repro.mpc.simulator import HashFamily, _PRIME


# ---------------------------------------------------------------------------
# Compilation structure
# ---------------------------------------------------------------------------

BASE_SEQUENCE = [
    "Scatter",
    "RouteResidual",
    "HashPartition",
    "SemiJoin[x]",
    "SemiJoin[y]",
    "BroadcastSizes",
    "GridRoute",
    "LocalJoin",
]

FUSED_SEQUENCE = [
    "Scatter",
    "RouteResidual",
    "HashPartition",
    "SemiJoin[fused-route]",
    "SemiJoin[fused-filter]",
    "BroadcastSizes",
    "GridRoute",
    "LocalJoin",
]


def _hub_triangle():
    """Triangle with one planted heavy hub value on X0 only."""
    return hub_triangle_query(n=150, hub_n=60, dom_size=30)


def _hub_star():
    """Star X0–X1, X0–X2, X0–X3 with a heavy hub: removing the hub leaves the
    leaves isolated (the Lemma 3.1 CP machinery)."""
    rng = np.random.default_rng(2)
    n, hub = 120, 777
    rels = []
    for leaf in ("X1", "X2", "X3"):
        planted = np.stack([np.full(50, hub), np.arange(50) + 100], 1)
        noise = rng.integers(0, 25, size=(n, 2))
        rels.append(Relation.make(("X0", leaf), np.concatenate([planted, noise])))
    return JoinQuery.make(rels)


def test_triangle_program_structure():
    q = _hub_triangle()
    stats = compute_stats(q, lam=12)
    assert "X0" in stats.heavy and len(stats.heavy) == 1
    program = compile_plan(q, stats, p=8)
    assert program.op_sequence() == BASE_SEQUENCE

    by_h = {}
    for st in program.stages:
        by_h.setdefault(st.hkey, []).append(st)
    # only X0 has heavy values ⇒ exactly H=∅ and H={X0} produce stages
    assert set(by_h) == {(), ("X0",)}
    (empty_stage,) = by_h[()]
    assert empty_stage.plan.light_edges == tuple(
        sorted([r.edge for r in q.relations], key=sorted)
    )
    (hub_stage,) = by_h[("X0",)]
    assert hub_stage.ekey == (999,)
    assert hub_stage.plan.border == ("X1", "X2")
    assert len(hub_stage.plan.cross_edges) == 2
    assert len(hub_stage.plan.light_edges) == 1
    assert hub_stage.plan.isolated == ()
    assert hub_stage.cfg.step1_group.size >= 1


def test_star_program_structure():
    q = _hub_star()
    stats = compute_stats(q, lam=10)
    assert "X0" in stats.heavy
    program = compile_plan(q, stats, p=8)
    assert program.op_sequence() == BASE_SEQUENCE

    hub_stages = [st for st in program.stages if st.hkey == ("X0",)]
    assert hub_stages, "heavy hub must produce an H={X0} stage"
    for st in hub_stages:
        # all leaves become isolated: no light edges survive under the hub
        assert st.plan.isolated == ("X1", "X2", "X3")
        assert st.plan.light_edges == ()
        assert len(st.plan.cross_edges) == 3
    # the planner view groups stages back per H
    qp = program.query_plan()
    assert set(qp.h_plans) == {st.hkey for st in program.stages}


def test_fuse_semijoin_is_a_program_rewrite():
    q = _hub_triangle()
    stats = compute_stats(q, lam=12)
    plain = compile_plan(q, stats, p=8)
    fused = fuse_semijoin_pass(plain)
    assert plain.op_sequence() == BASE_SEQUENCE
    assert fused.op_sequence() == FUSED_SEQUENCE
    assert fused.fused and not plain.fused
    # stages are shared, not recomputed
    assert fused.stages is plain.stages
    assert compile_plan(q, stats, p=8, fuse_semijoin=True).op_sequence() == FUSED_SEQUENCE


def test_emit_only_configurations_compile_to_emits():
    """H = attset(Q): η itself is the result tuple, compiled to host-side emits."""
    n = 80
    hub = np.zeros(n, dtype=np.int64)
    q = JoinQuery.make(
        [Relation.make(("H", "A"), np.stack([hub, np.arange(n)], 1)),
         Relation.make(("H", "B"), np.stack([hub, np.arange(n) + 1000], 1))]
    )
    # make one (h, a, b) combination fully heavy
    stats = compute_stats(q, lam=2 * n)   # threshold 1: everything is heavy
    program = compile_plan(q, stats, p=4)
    k = len(q.attset)
    assert all(len(h) < k for h in (st.hkey for st in program.stages))
    assert sum(program.emit_counts.values()) == len(program.emit)
    assert len(program.emit) == len(reference_join(q))   # all pairs heavy-heavy


# ---------------------------------------------------------------------------
# Executor equivalence vs the pre-refactor engine (golden values)
# ---------------------------------------------------------------------------

GOLDEN = {
    # name: (kind, n_attrs, rng_seed, tuples, dom, skew, p, lam,
    #        count, load_plain, load_fused)
    "triangle": ("clique", 3, 2, 300, 30, 2.0, 8, 16, 116, 3013, 2929),
    "star": ("star", 4, 4, 150, 12, 1.5, 8, 3, 1934, 1649, 1371),
    "cycle": ("cycle", 4, 3, 200, 20, 1.0, 16, 3, 2469, 2824, 2328),
}

GOLDEN_TRIANGLE_PER_H = {
    (): 19,
    ("X0",): 11,
    ("X0", "X1"): 7,
    ("X0", "X1", "X2"): 2,
    ("X0", "X2"): 19,
    ("X1",): 16,
    ("X1", "X2"): 17,
    ("X2",): 25,
}


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_simulator_executor_matches_pre_refactor_engine(name):
    kind, n_attrs, seed, n, dom, skew, p, lam, count, load_plain, load_fused = GOLDEN[name]
    q = random_query(
        np.random.default_rng(seed), kind, n_attrs,
        tuples_per_rel=n, dom_size=dom, skew=skew,
    )
    res = mpc_join(q, p=p, lam=lam, materialize=True)
    assert res.count == count == len(reference_join(q))
    assert res.sim.parallel_total_load == load_plain
    fused = mpc_join(q, p=p, lam=lam, materialize=True, fuse_semijoin=True)
    assert fused.count == count
    assert fused.sim.parallel_total_load == load_fused
    assert fused.per_h_counts == res.per_h_counts
    if name == "triangle":
        assert res.per_h_counts == GOLDEN_TRIANGLE_PER_H


def test_one_program_runs_on_a_fresh_simulator():
    """The program is a reusable artifact: compile once, execute on a bare
    simulator (no statistics rounds metered) — results identical, load ledger
    contains exactly the program's rounds."""
    q = _hub_triangle()
    stats = compute_stats(q, lam=12)
    program = compile_plan(q, stats, p=8)
    res = SimulatorExecutor(p=8).run(program)
    assert res.count == len(reference_join(q))
    names = [n for n, _ in res.sim.load_report()]
    assert names == [op.round for op in program.ops if op.round not in ("scatter", "output")]
    # same program again, different executor seed: same result, different routes
    res2 = SimulatorExecutor(p=8, seed=5).run(program)
    assert res2.count == res.count
    assert sorted(map(tuple, res2.rows.tolist())) == sorted(map(tuple, res.rows.tolist()))


# ---------------------------------------------------------------------------
# Vectorized HashFamily vs the scalar big-int reference
# ---------------------------------------------------------------------------


def test_hash_family_matches_bigint_loop():
    hf = HashFamily(seed=7)
    rng = np.random.default_rng(0)
    vals = np.concatenate(
        [
            rng.integers(-(2**62), 2**62, size=2000),
            np.array(
                [0, -1, 1, _PRIME, _PRIME - 1, _PRIME + 1, 2**63 - 1, -(2**63)],
                dtype=np.int64,
            ),
        ]
    )
    for key in [("a",), (("X0",), (999,), "sj", "X1"), 42]:
        a, b = hf._coeffs(key)
        for mod in [1, 2, 7, 97, 1 << 20]:
            ref = np.array(
                [((a * int(x) + b) % _PRIME) % mod for x in vals.tolist()],
                dtype=np.int64,
            )
            got = hf.hash(key, vals, mod)
            assert np.array_equal(ref, got), (key, mod)


def test_hash_family_deterministic_across_instances():
    """Shared randomness (paper footnote 2): two machines with the same seed
    evaluate identical functions."""
    v = np.arange(1000, dtype=np.int64) * 7919
    assert np.array_equal(
        HashFamily(seed=3).hash("k", v, 64), HashFamily(seed=3).hash("k", v, 64)
    )
    assert not np.array_equal(
        HashFamily(seed=3).hash("k", v, 64), HashFamily(seed=4).hash("k", v, 64)
    )
