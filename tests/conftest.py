"""Suite-wide defaults.

Turns compile-time static verification (repro.mpc.verify, gated by the
``REPRO_VERIFY`` env var — see ``repro.mpc.program._verify_default``) on for
every test: each ``compile_plan`` call in the suite verifies its output, so
the whole tier-1 battery doubles as the verifier's zero-false-positive gate.
An explicit REPRO_VERIFY in the environment still wins (set ``REPRO_VERIFY=0``
to time the suite without verification)."""

import os

os.environ.setdefault("REPRO_VERIFY", "1")
