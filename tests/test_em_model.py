"""Sec. 7 remark: the MPC→EM reduction instantiated on metered runs — concrete I/O
counts track the Õ(m^ρ/(B·M^{ρ-1})) closed form as M varies."""

import numpy as np

from repro.core.em_model import em_cost_from_run, simulated_p
from repro.core.query import random_query
from repro.mpc.engine import mpc_join


def test_em_cost_tracks_closed_form():
    rng = np.random.default_rng(0)
    q = random_query(rng, "clique", 3, tuples_per_rel=1000, dom_size=1000, skew=0.0)
    block = 64
    ratios = []
    for mem in (1500, 3000, 6000):
        p = simulated_p(q.m, mem)
        res = mpc_join(q, p=p, materialize=False)
        cost = em_cost_from_run(q, res, memory_words=mem, block_words=block)
        assert cost.io_blocks > 0
        ratios.append(cost.ratio)
    # the concrete count stays within a bounded polylog factor of the closed form,
    # and doesn't diverge as M shrinks (the reduction's point)
    assert max(ratios) / min(ratios) < 8.0, ratios
    assert all(r < 200 for r in ratios), ratios


def test_simulated_p_scaling():
    assert simulated_p(10_000, 1_000) >= 40      # 4× safety
    assert simulated_p(10_000, 10_000) >= 4 or simulated_p(10_000, 10_000) >= 2
