"""Sec. 2 machinery: fractional covers/packings, Lemma 2.1, ψ vs ρ."""

from fractions import Fraction

import pytest

from repro.core.hypergraph import (
    Hypergraph,
    fractional_edge_cover,
    fractional_edge_packing,
    quasi_packing_number,
    zero_one_packing,
)
from repro.core.query import pattern_edges


def _graph(kind, n):
    return Hypergraph.from_edges(pattern_edges(kind, n))


def test_triangle_rho_tau():
    g = _graph("clique", 3)
    rho_v, w = fractional_edge_cover(g)
    tau_v, _ = fractional_edge_packing(g)
    assert rho_v == Fraction(3, 2)
    assert tau_v == Fraction(3, 2)
    # all-half cover
    assert all(x == Fraction(1, 2) for x in w.values())


@pytest.mark.parametrize(
    "kind,n,expect_rho",
    [
        ("clique", 4, Fraction(2)),
        ("clique", 5, Fraction(5, 2)),
        ("cycle", 4, Fraction(2)),
        ("cycle", 5, Fraction(5, 2)),
        ("cycle", 6, Fraction(3)),
        ("line", 4, Fraction(2)),     # path X0-X1-X2-X3: edges {01},{12},{23} -> 2
        ("star", 5, Fraction(4)),     # hub + 4 leaves: every leaf edge weight 1
    ],
)
def test_rho_known_values(kind, n, expect_rho):
    rho_v, w = fractional_edge_cover(_graph(kind, n))
    assert rho_v == expect_rho
    # verify cover validity
    g = _graph(kind, n)
    for v in g.vertices:
        assert sum(w[e] for e in g.edges if v in e) >= 1


def test_lemma_2_1_identity():
    """ρ + τ = |V| and ρ ≥ τ for binary graphs."""
    for kind, n in [("clique", 3), ("clique", 4), ("cycle", 5), ("line", 5), ("star", 4)]:
        g = _graph(kind, n)
        rho_v, _ = fractional_edge_cover(g)
        tau_v, _ = fractional_edge_packing(g)
        assert rho_v + tau_v == len(g.vertices)
        assert rho_v >= tau_v


def test_zero_one_packing_properties():
    """Lemma 2.1 bullet 2: vertex weights all 0/1, ρ - τ = |Z|."""
    for kind, n in [("clique", 3), ("cycle", 5), ("line", 4), ("star", 5), ("cycle", 6)]:
        g = _graph(kind, n)
        tau_v, w, z = zero_one_packing(g)
        rho_v, _ = fractional_edge_cover(g)
        weights = {v: sum(w[e] for e in g.edges if v in e) for v in g.vertices}
        assert all(x in (0, 1) for x in weights.values())
        assert rho_v - tau_v == len(z)
        assert sum(w.values()) == tau_v


def test_quasi_packing_clique_cycle():
    """[13]: clique ψ = |V|-1; cycle ψ = ceil(2(|V|-1)/3)."""
    g = _graph("clique", 4)
    assert quasi_packing_number(g) == Fraction(3)
    g = _graph("cycle", 5)
    assert quasi_packing_number(g) == Fraction(3)  # ceil(8/3) = 3
    g = _graph("cycle", 6)
    assert quasi_packing_number(g) == Fraction(4)  # ceil(10/3) = 4


def test_paper_figure1_example():
    """The Fig. 1a query (12 attributes; the 11 edges named in the text): the paper's
    W1/W2 certify ρ = 6.5, τ = 5.5 — both remain optimal on this reconstruction."""
    edges = [
        ("A", "B"), ("A", "C"), ("B", "C"),            # the triangle
        ("A", "D"), ("A", "E"),                        # cross edges named in Sec. 4/5.2
        ("D", "G"), ("D", "K"), ("E", "H"), ("E", "L"), ("F", "G"),
        ("I", "J"),
    ]
    g = Hypergraph.from_edges(edges)
    rho_v, _ = fractional_edge_cover(g)
    tau_v, _ = fractional_edge_packing(g)
    assert rho_v + tau_v == 12
    assert rho_v == Fraction(13, 2)
    assert tau_v == Fraction(11, 2)
    _, _, z = zero_one_packing(g)
    assert len(z) == 1  # paper: Z = {L} (any single exposed vertex is acceptable)
